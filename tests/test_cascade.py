"""Conformance suite for auto-expanding cascades (DESIGN.md §8).

Every ``supports_expand`` backend runs the same insert-past-capacity ->
query -> FPR -> delete -> compact scenario through
``amq.make(..., auto_expand=True)`` — no backend gets a bespoke path.
Also pins the consumer integrations: streaming dedup without a-priori
sizing, and the prefix cache's stale-key accounting on append-only
backends.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import amq
from repro.core import keys_from_numpy

CAPACITY = 256          # initial level size
N_PAST = 2048           # streamed keys: 8x the initial capacity
N_NEG = 1 << 13
CHUNK = 512

EXPANDABLE = [n for n in amq.names()
              if amq.get(n).capabilities.supports_expand]
NON_EXPANDABLE = [n for n in amq.names()
                  if not amq.get(n).capabilities.supports_expand]


def _keys(seed, n, lo=0, hi=2**32):
    rng = np.random.default_rng(seed)
    raw = np.unique(rng.integers(lo, hi, size=3 * n, dtype=np.uint64))[:n]
    assert raw.shape[0] == n
    return jnp.asarray(keys_from_numpy(raw))


def _np(x):
    return np.asarray(x)


def _stream(handle, keys, **opts):
    oks = []
    for start in range(0, keys.shape[0], CHUNK):
        oks.append(_np(handle.insert(keys[start:start + CHUNK], **opts).ok))
    return np.concatenate(oks)


@pytest.fixture(params=EXPANDABLE)
def backend(request):
    return request.param


def test_non_expandable_set_is_explicit():
    # TCF's uint32 stash packing caps its fingerprint width — the flag
    # exists so cascades refuse it instead of silently blowing the budget.
    assert NON_EXPANDABLE == ["tcf"]


def test_auto_expand_gating_and_arg_errors():
    for name in NON_EXPANDABLE:
        with pytest.raises(NotImplementedError, match="supports_expand"):
            amq.make(name, capacity=64, auto_expand=True)
    with pytest.raises(TypeError, match="capacity"):
        amq.make("cuckoo", auto_expand=True)
    with pytest.raises(TypeError, match="config"):
        amq.make("cuckoo", auto_expand=True,
                 config=amq.get("cuckoo").make_config(64))


def test_insert_past_capacity_no_false_negatives(backend):
    h = amq.make(backend, capacity=CAPACITY, auto_expand=True)
    pos = _keys(0, N_PAST)
    ok = _stream(h, pos)
    assert ok.all(), f"{backend}: cascade refused keys at 8x capacity"
    assert len(h.levels) > 1, f"{backend}: never grew past level 0"
    assert h.count() == int(ok.sum())
    # Geometric level sizing, and no level driven past its watermark.
    report = h.report()
    for prev, cur in zip(report.levels, report.levels[1:]):
        assert cur.num_slots >= prev.num_slots
    for level in report.levels:
        slack = 2.0 / level.num_slots
        assert level.load_factor <= h.watermark + slack, \
            f"{backend}: level {level.level} past watermark: {level}"
    hits = _np(h.query(pos).hits)
    assert hits.all(), f"{backend}: false negative after expansion"


def test_bulk_insert_streams_through_cascade(backend):
    caps = amq.get(backend).capabilities
    h = amq.make(backend, capacity=CAPACITY, auto_expand=True)
    pos = _keys(1, N_PAST)
    if not caps.supports_bulk:
        with pytest.raises(NotImplementedError):
            h.insert(pos[:CHUNK], bulk=True)
        return
    ok = _stream(h, pos, bulk=True)
    assert ok.all()
    assert _np(h.query(pos).hits).all()


def test_fpr_within_split_budget(backend):
    h = amq.make(backend, capacity=CAPACITY, auto_expand=True)
    pos = _keys(2, N_PAST)
    assert _stream(h, pos).all()
    report = h.report()
    # Analytic: every level met its share, and the aggregate respects the
    # declared budget (the sum-of-levels claim the split exists for).
    for level in report.levels:
        assert level.expected_fpr <= level.fpr_share * (1 + 1e-9), \
            f"{backend}: level {level.level} exceeds its FPR share"
    assert report.expected_fpr <= report.fpr_budget * (1 + 1e-9)
    # Empirical: measured FPR within the tolerance band of the budget.
    neg = _keys(3, N_NEG, lo=2**32, hi=2**64)
    fpr = float(_np(h.query(neg).hits).mean())
    _, hi = amq.fpr_tolerance(report.fpr_budget, N_NEG)
    if amq.get(backend).capabilities.exact:
        assert fpr == 0.0
    else:
        assert fpr <= hi, (f"{backend}: measured fpr {fpr} vs budget "
                           f"{report.fpr_budget}")


def test_delete_routes_to_owning_level_and_compact(backend):
    caps = amq.get(backend).capabilities
    h = amq.make(backend, capacity=CAPACITY, auto_expand=True)
    pos = _keys(4, N_PAST)
    ok = _stream(h, pos)
    if not caps.supports_delete:
        with pytest.raises(NotImplementedError):
            h.delete(pos)
        return
    assert ok.all()
    levels_before = len(h.levels)
    dok = _np(h.delete(pos).ok)
    assert dok.mean() > 0.99, f"{backend}: cross-level delete failed"
    residue = N_PAST - int(dok.sum())
    assert h.count() == residue
    if residue == 0:
        assert not _np(h.query(pos).hits).any(), \
            f"{backend}: deleted keys still visible after full wipe"
        # Fully drained: compaction resets to one fresh base level.
        report = h.compact()
        assert report.num_levels == 1
        assert report.count == 0
        assert len(h.levels) < levels_before
        # ... and the reset cascade still works.
        assert _np(h.insert(pos[:CHUNK]).ok).all()
        assert _np(h.query(pos[:CHUNK]).hits).all()


def test_partial_drain_compacts_only_empty_levels():
    h = amq.make("cuckoo", capacity=CAPACITY, auto_expand=True)
    pos = _keys(5, N_PAST)
    assert _stream(h, pos).all()
    n_levels = len(h.levels)
    per_level = [lvl.count() for lvl in h.levels]
    # Drain exactly the keys the cascade put in level 0.
    lvl0_hits = _np(h.levels[0].query(pos).hits)
    h.delete(pos, valid=jnp.asarray(lvl0_hits))
    report = h.compact()
    assert report.num_levels in (n_levels - 1, n_levels)  # aliasing slack
    assert h.count() == sum(per_level) - int(lvl0_hits.sum())


def test_cascade_of_shards_pins_mesh_across_levels():
    """Sharded levels must share one mesh/topology (DESIGN.md §8)."""
    h = amq.make("sharded-cuckoo", capacity=CAPACITY, auto_expand=True)
    pos = _keys(8, 1024)
    assert _stream(h, pos).all()
    assert len(h.levels) > 1
    assert len({id(lvl.config.mesh) for lvl in h.levels}) == 1
    assert len({(lvl.config.inner.num_shards, lvl.config.inner.axis_name,
                 lvl.config.inner.capacity_factor)
                for lvl in h.levels}) == 1
    # Levels still grow geometrically through the grow_config hook.
    slots = [lvl.config.num_slots for lvl in h.levels]
    assert slots == sorted(slots) and slots[-1] > slots[0]
    assert _np(h.query(pos).hits).all()


def test_cascade_valid_mask():
    h = amq.make("cuckoo", capacity=CAPACITY, auto_expand=True)
    pos = _keys(6, N_PAST)
    valid = np.arange(N_PAST) % 2 == 0
    report = h.insert(pos, valid=jnp.asarray(valid))
    ok = _np(report.ok)
    assert not ok[~valid].any(), "masked key entered the cascade"
    assert h.count() == int(ok.sum()) <= valid.sum()


def test_streaming_deduper_needs_no_apriori_sizing(backend):
    from repro.data import make_deduper

    dedup = make_deduper(64, backend=backend)
    tokens = jnp.arange(64 * 32, dtype=jnp.int32).reshape(64, 32)
    seen_batches = []
    for step in range(4):  # 256 distinct sequences through a 64-key window
        batch = {"tokens": tokens + 10_000 * step}
        out, stats = dedup.dedup(batch)
        seen_batches.append(batch)
        assert stats["duplicates"] == 0, f"{backend}: fresh batch masked"
        assert stats["insert_failures"] == 0, \
            f"{backend}: streaming deduper hit a capacity wall"
        assert int(_np(out["mask"]).sum()) == 64
    out, stats = dedup.dedup(seen_batches[0])  # replay the oldest batch
    assert stats["duplicates"] == 64
    assert int(_np(out["mask"]).sum()) == 0
    assert dedup.stats["duplicates"] == 64


def test_prefix_cache_stale_accounting_regression():
    """Append-only guard filters count stale keys — also under auto-expand.

    Regression pin: the cache must (a) use a cascade by default so the
    guard never saturates, (b) keep true-deletion semantics on
    delete-capable backends (stale == 0), and (c) keep counting rot on
    append-only ones (stale == evictions), exactly as with static handles.
    """
    from repro.amq.cascade import CascadeHandle
    from repro.serve.prefix_cache import PrefixCache

    for backend, expect_stale in (("cuckoo", 0), ("bloom", 2)):
        pc = PrefixCache(2, backend=backend)
        assert isinstance(pc.filter, CascadeHandle)
        for i in range(4):
            pc.insert([i, i + 1, i + 2], entry=f"e{i}")
        assert pc.stats["evictions"] == 2
        assert pc.stats["stale"] == expect_stale
        assert pc.lookup([3, 4, 5]) == "e3"
        assert pc.lookup([0, 1, 2]) is None
    # Opting out returns the classic fixed-size handle.
    from repro.amq.handle import FilterHandle

    pc = PrefixCache(2, backend="cuckoo", auto_expand=False)
    assert isinstance(pc.filter, FilterHandle)
    # TCF cannot expand: the cache silently falls back to a static guard.
    pc = PrefixCache(2, backend="tcf")
    assert isinstance(pc.filter, FilterHandle)
