"""End-to-end integration: train driver, serve driver, dedup-in-training."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, env=env, timeout=timeout, cwd=ROOT)


@pytest.mark.slow
def test_train_driver_with_dedup(tmp_path):
    p = _run(["-m", "repro.launch.train", "--arch", "gemma2_2b", "--reduced",
              "--steps", "12", "--batch", "4", "--seq", "64",
              "--ckpt-dir", str(tmp_path), "--ckpt-every", "6", "--dedup"])
    assert p.returncode == 0, p.stdout + p.stderr
    assert "step 10" in p.stdout
    assert os.path.isdir(os.path.join(str(tmp_path), "step_00000012"))
    # resume continues from the checkpoint
    p2 = _run(["-m", "repro.launch.train", "--arch", "gemma2_2b", "--reduced",
               "--steps", "14", "--batch", "4", "--seq", "64",
               "--ckpt-dir", str(tmp_path), "--resume"])
    assert p2.returncode == 0, p2.stdout + p2.stderr
    assert "resumed from step 12" in p2.stdout


@pytest.mark.slow
def test_serve_driver():
    p = _run(["-m", "repro.launch.serve", "--arch", "mamba2_130m",
              "--reduced", "--batch", "2", "--prompt-len", "16",
              "--steps", "4", "--requests", "4"])
    assert p.returncode == 0, p.stdout + p.stderr
    assert "prefix-cache stats" in p.stdout


@pytest.mark.slow
def test_examples_run():
    for ex in ("quickstart.py", "kmer_index.py"):
        p = _run([os.path.join("examples", ex)])
        assert p.returncode == 0, f"{ex}: {p.stdout + p.stderr}"


def test_benchmark_harness_importable():
    import benchmarks.run as br

    assert set(br.SUITES) == {"fig3", "fig4", "fig5_6", "fig7", "fig8",
                              "s463", "expansion", "mixed", "lifecycle",
                              "serving_slo", "roofline", "tiering"}
