"""Property test: serving-engine schedules match a sequential oracle.

Random interleavings of ``submit`` (including n=0), ``flush``, explicit
``poll`` with a virtual clock advanced past ``max_delay`` (deadline-
triggered dispatches), and ``hot_swap`` — every acknowledged submission
must bit-match a *direct sequential replay*: the same global op stream
executed submission-by-submission on a bare handle. However the engine
chops the stream into ladder-shaped micro-batches, pads it, or migrates
state mid-stream, the per-client scatter is invariant (DESIGN.md §11).
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised in the bare container
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from _tuning import examples

import jax.numpy as jnp
import numpy as np

from repro import amq
from repro.amq.protocol import OpBatch
from repro.core import keys_from_numpy

CAPACITY = 4096
UNIVERSE = 8          # tiny key universe -> dense same-key interleavings
ACTIONS = ("submit", "submit", "submit", "empty", "flush", "tick", "swap")


class _Clock:
    """Virtual service clock: deadlines fire only when ``tick`` advances."""

    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def _universe(seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return keys_from_numpy(
        rng.integers(1, 2**63, size=UNIVERSE, dtype=np.uint64))


def _replay(submissions, backend="cuckoo", **mk):
    """Sequential oracle: one padded apply_ops per submission, in order."""
    handle = amq.make(backend, capacity=CAPACITY, **mk)
    out = []
    for keys, ops in submissions:
        m = keys.shape[0]
        batch = OpBatch.make(jnp.asarray(keys), jnp.asarray(ops)).pad_to(8)
        rep = handle.apply_ops(batch)
        out.append((np.asarray(rep.ok)[:m], np.asarray(rep.routed)[:m]))
    return out


@settings(max_examples=examples(40), deadline=None)
@given(data=st.data())
def test_schedules_bit_match_sequential_replay(data):
    seed = data.draw(st.integers(0, 2**31 - 1))
    uni = _universe(seed)
    clock = _Clock()
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=16, max_delay=0.05, clock=clock)
    submissions, tickets = [], []
    for _ in range(data.draw(st.integers(4, 14))):
        action = data.draw(st.sampled_from(ACTIONS))
        if action == "submit":
            m = data.draw(st.integers(1, 6))
            picks = [data.draw(st.integers(0, UNIVERSE - 1))
                     for _ in range(m)]
            ops = np.asarray([data.draw(st.integers(0, 2))
                              for _ in range(m)], np.int32)
            keys = uni[np.asarray(picks)]
            submissions.append((keys, ops))
            tickets.append(svc.submit(keys, ops))
        elif action == "empty":
            t = svc.submit(np.zeros((0,), np.uint64),
                           np.zeros((0,), np.int32))
            assert t.dispatched and t.result().shape == (0,)
        elif action == "flush":
            svc.flush()
        elif action == "tick":
            clock.now += 0.1            # every pending op is now past due
            svc.poll()
        elif action == "swap":
            svc.hot_swap(amq.make("cuckoo", config=svc.handle.config))
    svc.drain()
    for i, ((keys, ops), ticket, (ok, routed)) in enumerate(
            zip(submissions, tickets, _replay(submissions))):
        np.testing.assert_array_equal(
            ticket.result(), ok,
            err_msg=f"submission {i} diverged from sequential replay")
        np.testing.assert_array_equal(ticket.routed(), routed)
    assert svc.pending_ops == 0
    snap = svc.stats()
    assert snap["ready"]["count"] == sum(k.shape[0]
                                         for k, _ in submissions)


@settings(max_examples=examples(15), deadline=None)
@given(data=st.data())
def test_reshard_mid_schedule_bit_matches(data):
    """K→K′ reshard under queued load: same oracle, zero acked-op loss."""
    seed = data.draw(st.integers(0, 2**31 - 1))
    uni = _universe(seed)
    svc = amq.FilterService(
        amq.make("sharded-cuckoo", capacity=CAPACITY, num_shards=1,
                 partitions_per_shard=2),
        batch_size=16)
    submissions, tickets = [], []

    def _submit():
        m = data.draw(st.integers(1, 6))
        picks = [data.draw(st.integers(0, UNIVERSE - 1)) for _ in range(m)]
        ops = np.asarray([data.draw(st.integers(0, 2))
                          for _ in range(m)], np.int32)
        keys = uni[np.asarray(picks)]
        submissions.append((keys, ops))
        tickets.append(svc.submit(keys, ops))

    for _ in range(data.draw(st.integers(2, 5))):
        _submit()
    swap = svc.hot_swap(svc.handle.resharded(num_shards=1))
    assert swap["migrated"]
    for _ in range(data.draw(st.integers(2, 5))):
        _submit()
    svc.drain()
    oracle = _replay(submissions, backend="sharded-cuckoo", num_shards=1,
                     partitions_per_shard=2)
    for i, ((keys, ops), ticket, (ok, routed)) in enumerate(
            zip(submissions, tickets, oracle)):
        np.testing.assert_array_equal(
            ticket.result() & ticket.routed(), ok & routed,
            err_msg=f"submission {i} diverged across the reshard")
