"""Filter-state lifecycle invariants (DESIGN.md §10).

* snapshot → restore round-trips are **bit-exact** for every
  ``supports_snapshot`` backend (arrays identical, query answers identical);
* restores onto mismatched configs/backends/kinds fail loudly with
  :class:`~repro.amq.protocol.SnapshotMismatchError`;
* sharded resharding K→K′ (and mesh moves) preserve query results exactly
  against pre-migration answers;
* :meth:`~repro.amq.FilterService.hot_swap` loses no acknowledged
  operation; and
* snapshot files round-trip through ``save_snapshot``/``load_snapshot``
  (including cascade files, via deterministic level-sizing replay) and the
  ``filterctl`` CLI.
"""

import jax
import numpy as np
import pytest

from repro import amq
from repro.amq.protocol import (
    SnapshotMismatchError,
    load_snapshot,
    save_snapshot,
)

CAPACITY = 2048


@pytest.fixture(params=list(amq.names()))
def backend(request):
    return request.param


def _raw(n, seed=0, lo=1, hi=2**64):
    rng = np.random.default_rng(seed)
    return np.unique(rng.integers(lo, hi, size=2 * n + 16,
                                  dtype=np.uint64))[:n]


def _assert_same_arrays(a: dict, b: dict):
    assert sorted(a) == sorted(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"array {k!r} differs")


# ---------------------------------------------------------------------------
# Snapshot / restore: bit-exact on every backend.
# ---------------------------------------------------------------------------

def test_snapshot_restore_bit_exact(backend):
    handle = amq.make(backend, capacity=CAPACITY)
    assert handle.capabilities.supports_snapshot
    keys = _raw(1200)
    handle.insert(keys)
    if handle.capabilities.supports_delete:
        handle.delete(keys[:100])
    snap = handle.snapshot()
    assert snap.kind == "filter" and snap.backend == backend
    assert snap.meta["count"] == handle.count()

    twin = amq.make(backend, config=handle.config, snapshot=snap)
    _assert_same_arrays(snap.arrays, twin.snapshot().arrays)
    assert twin.count() == handle.count()
    probe = np.concatenate([keys, _raw(4096, seed=9, lo=2**32)])
    np.testing.assert_array_equal(np.asarray(twin.query(probe).hits),
                                  np.asarray(handle.query(probe).hits))


def test_snapshot_restore_in_place(backend):
    """restore() replaces a live handle's state (rollback use case)."""
    handle = amq.make(backend, capacity=CAPACITY)
    keys = _raw(500)
    handle.insert(keys[:250])
    snap = handle.snapshot()
    handle.insert(keys[250:])
    assert handle.count() == 500
    handle.restore(snap)
    assert handle.count() == 250


def test_restore_mismatch_fails_loudly(backend):
    handle = amq.make(backend, capacity=CAPACITY)
    handle.insert(_raw(100))
    snap = handle.snapshot()
    with pytest.raises(SnapshotMismatchError, match="fingerprint"):
        amq.make(backend, capacity=4 * CAPACITY, snapshot=snap)
    other = "bloom" if backend != "bloom" else "cuckoo"
    with pytest.raises(SnapshotMismatchError, match="backend"):
        amq.make(other, capacity=CAPACITY, snapshot=snap)


def test_snapshot_file_roundtrip(backend, tmp_path):
    handle = amq.make(backend, capacity=CAPACITY)
    keys = _raw(800)
    handle.insert(keys)
    path = tmp_path / "snap.npz"
    save_snapshot(path, handle.snapshot())
    loaded = load_snapshot(path)
    assert loaded.configs == ()  # files carry arrays + JSON, never code
    twin = amq.make(backend, capacity=CAPACITY, snapshot=loaded)
    assert twin.count() == handle.count()
    np.testing.assert_array_equal(np.asarray(twin.query(keys).hits),
                                  np.asarray(handle.query(keys).hits))


def test_snapshot_future_version_refused(tmp_path):
    handle = amq.make("cuckoo", capacity=CAPACITY)
    snap = handle.snapshot()._replace(version=99)
    path = tmp_path / "future.npz"
    save_snapshot(path, snap)
    with pytest.raises(SnapshotMismatchError, match="v99"):
        load_snapshot(path)


# ---------------------------------------------------------------------------
# Cascade snapshots: all live levels.
# ---------------------------------------------------------------------------

def _grown_cascade(n_keys=6000, capacity=1024):
    cascade = amq.make("cuckoo", capacity=capacity, auto_expand=True)
    keys = _raw(n_keys, seed=3)
    assert np.asarray(cascade.insert(keys).ok).all()
    assert len(cascade.levels) >= 2, "test needs a multi-level cascade"
    return cascade, keys


def test_cascade_snapshot_covers_all_levels():
    cascade, keys = _grown_cascade()
    snap = cascade.snapshot()
    assert snap.kind == "cascade"
    assert len(snap.meta["levels"]) == len(cascade.levels)
    assert snap.meta["count"] == cascade.count()

    twin = amq.make("cuckoo", capacity=1024, auto_expand=True, snapshot=snap)
    assert len(twin.levels) == len(cascade.levels)
    assert twin.count() == cascade.count()
    _assert_same_arrays(snap.arrays, twin.snapshot().arrays)
    probe = np.concatenate([keys, _raw(4096, seed=17, lo=2**32)])
    np.testing.assert_array_equal(np.asarray(twin.query(probe).hits),
                                  np.asarray(cascade.query(probe).hits))
    # the restored cascade keeps growing correctly
    more = _raw(3000, seed=23, lo=2**33)
    assert np.asarray(twin.insert(more).ok).all()
    assert np.asarray(twin.query(more).hits).all()


def test_cascade_snapshot_file_roundtrip(tmp_path):
    cascade, keys = _grown_cascade()
    path = tmp_path / "cascade.npz"
    save_snapshot(path, cascade.snapshot())
    twin = amq.make("cuckoo", capacity=1024, auto_expand=True,
                    snapshot=load_snapshot(path))
    assert twin.count() == cascade.count()
    np.testing.assert_array_equal(np.asarray(twin.query(keys).hits),
                                  np.asarray(cascade.query(keys).hits))


def test_cascade_snapshot_survives_compaction():
    cascade, keys = _grown_cascade()
    # drain the oldest level and reclaim it, then round-trip
    cascade.delete(keys)
    cascade.compact()
    cascade.insert(_raw(500, seed=31, lo=2**33))
    snap = cascade.snapshot()
    twin = amq.make("cuckoo", capacity=1024, auto_expand=True, snapshot=snap)
    assert twin.count() == cascade.count()
    assert [lvl.config for lvl in twin.levels] == \
        [lvl.config for lvl in cascade.levels]


def test_cascade_restore_mismatched_knobs_fails():
    cascade, _ = _grown_cascade()
    snap = cascade.snapshot()
    with pytest.raises(SnapshotMismatchError, match="base_capacity"):
        amq.make("cuckoo", capacity=512, auto_expand=True, snapshot=snap)
    handle = amq.make("cuckoo", capacity=1024)
    with pytest.raises(SnapshotMismatchError, match="cascade"):
        handle.restore(snap)
    with pytest.raises(SnapshotMismatchError, match="filter"):
        amq.make("cuckoo", capacity=1024, auto_expand=True,
                 snapshot=handle.snapshot())


# ---------------------------------------------------------------------------
# Exact resharding (fixed partitions).
# ---------------------------------------------------------------------------

def test_reshard_membership_differential():
    """K→K′ reshard: every query answers exactly as before migration."""
    handle = amq.make("sharded-cuckoo", capacity=4096,
                      partitions_per_shard=4)
    keys = _raw(2000, seed=5)
    report = handle.insert(keys)
    stored = np.asarray(report.ok) & np.asarray(report.routed)
    probe = np.concatenate([keys, _raw(4096, seed=7, lo=2**32)])
    pre_hits = np.asarray(handle.query(probe).hits)
    pre_routed = np.asarray(handle.query(probe).routed)

    moved = handle.resharded(num_shards=1)
    assert moved is not handle
    # bit-exact state relocation, zero membership change
    np.testing.assert_array_equal(np.asarray(moved.state.table),
                                  np.asarray(handle.state.table))
    post = moved.query(probe)
    np.testing.assert_array_equal(np.asarray(post.hits) & np.asarray(
        post.routed), pre_hits & pre_routed)
    # and the moved filter still serves mutations
    dr = moved.delete(keys[:50])
    assert (np.asarray(dr.ok) & np.asarray(dr.routed))[stored[:50]].all()


def test_reshard_requires_divisible_partitions():
    handle = amq.make("sharded-cuckoo", capacity=4096,
                      partitions_per_shard=4)
    with pytest.raises(ValueError, match="partitions"):
        handle.config.resharded(num_shards=3)


def test_reshard_unsupported_backend_raises():
    with pytest.raises(NotImplementedError, match="resharding"):
        amq.make("cuckoo", capacity=CAPACITY).resharded(num_shards=2)


def test_sharded_snapshot_restores_across_meshes():
    """Mesh migration = snapshot → restore under a resharded config."""
    handle = amq.make("sharded-cuckoo", capacity=4096,
                      partitions_per_shard=2)
    keys = _raw(1500, seed=13)
    handle.insert(keys)
    snap = handle.snapshot()
    new_mesh = jax.make_mesh((1,), ("data",))
    new_cfg = handle.config.resharded(mesh=new_mesh)
    twin = amq.make("sharded-cuckoo", config=new_cfg, snapshot=snap)
    np.testing.assert_array_equal(np.asarray(twin.query(keys).hits),
                                  np.asarray(handle.query(keys).hits))


# ---------------------------------------------------------------------------
# Zero-downtime hot swap.
# ---------------------------------------------------------------------------

def test_hot_swap_loses_no_acknowledged_op():
    handle = amq.make("cuckoo", capacity=CAPACITY)
    svc = amq.FilterService(handle, batch_size=64)
    keys = _raw(500, seed=19)
    t_full = svc.insert(keys[:448])       # dispatches 7 full batches
    t_tail = svc.insert(keys[448:])       # stays pending
    assert not t_tail.dispatched

    swap = svc.hot_swap(amq.make("cuckoo", config=handle.config))
    assert swap["migrated"] and swap["drained_ops"] > 0
    assert swap["pause_s"] >= 0.0
    assert svc.handle is not handle
    # every acknowledged op: tickets readable, membership carried over
    assert t_full.result().all() and t_tail.result().all()
    assert svc.query(keys).result().all()
    # old handle still intact (tickets drew from its dispatches)
    assert handle.count() == 500


def test_hot_swap_migrate_false_swaps_prepopulated():
    handle = amq.make("cuckoo", capacity=CAPACITY)
    svc = amq.FilterService(handle, batch_size=32)
    keys = _raw(100, seed=29)
    svc.insert(keys[:50]).result()
    prebuilt = amq.make("cuckoo", config=handle.config)
    prebuilt.insert(keys)                  # rebuilt from source of truth
    swap = svc.hot_swap(prebuilt, migrate=False)
    assert not swap["migrated"]
    assert svc.query(keys).result().all()


def test_hot_swap_mismatch_keeps_old_backend():
    handle = amq.make("cuckoo", capacity=CAPACITY)
    svc = amq.FilterService(handle, batch_size=32)
    keys = _raw(64, seed=37)
    svc.insert(keys).result()
    with pytest.raises(SnapshotMismatchError):
        svc.hot_swap(amq.make("cuckoo", capacity=8 * CAPACITY))
    assert svc.handle is handle            # swap never happened
    assert svc.query(keys).result().all()


def test_hot_swap_reshard_under_service():
    """The headline flow: grow/shrink the mesh without dropping traffic."""
    handle = amq.make("sharded-cuckoo", capacity=4096,
                      partitions_per_shard=4)
    svc = amq.FilterService(handle, batch_size=64)
    keys = _raw(800, seed=41)
    svc.insert(keys).result()
    swap = svc.hot_swap(handle.resharded(num_shards=1))
    assert swap["migrated"]
    assert svc.query(keys).result().all()


def test_prefix_cache_filter_tracks_hot_swap():
    from repro.serve.prefix_cache import PrefixCache

    cache = PrefixCache(capacity_entries=8, backend="cuckoo",
                        filter_capacity=CAPACITY, auto_expand=False)
    for i in range(8):
        cache.insert([1, 2, i], entry=i)
    old = cache.filter
    swap = cache.hot_swap_filter(amq.make("cuckoo", config=old.config))
    assert cache.filter is not old         # property follows the service
    assert swap["migrated"]
    assert cache.lookup([1, 2, 3]) == 3    # guarded lookups still hit


# ---------------------------------------------------------------------------
# filterctl CLI.
# ---------------------------------------------------------------------------

def test_filterctl_cli_roundtrip(tmp_path, capsys):
    import importlib.util
    import pathlib
    spec = importlib.util.spec_from_file_location(
        "filterctl", pathlib.Path(__file__).resolve().parents[1]
        / "tools" / "filterctl.py")
    filterctl = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(filterctl)

    path = str(tmp_path / "f.npz")
    assert filterctl.main(["save", path, "--backend", "cuckoo",
                           "--capacity", "4096",
                           "--insert-random", "1000"]) == 0
    assert filterctl.main(["inspect", path]) == 0
    out = capsys.readouterr().out
    assert "backend:     cuckoo" in out and "fingerprint" in out
    assert filterctl.main(["load", path, "--backend", "cuckoo",
                           "--capacity", "4096",
                           "--verify-random", "1000"]) == 0
    with pytest.raises(SnapshotMismatchError):
        filterctl.main(["load", path, "--backend", "cuckoo",
                        "--capacity", "64"])
