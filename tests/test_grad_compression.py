"""int8 gradient compression with error feedback."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.grad_compression import (
    CompressedGrads,
    compress,
    decompress,
    zero_residual,
)


def _grads(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(64, 33)) * 1e-2, jnp.bfloat16),
        "b": jnp.asarray(rng.normal(size=(7,)) * 1e-3, jnp.bfloat16),
    }


def test_roundtrip_error_bounded():
    g = _grads()
    c, res = compress(g, zero_residual(g))
    back = decompress(c, g)
    for k in g:
        x = np.asarray(g[k], np.float32)
        y = np.asarray(back[k], np.float32)
        assert np.max(np.abs(x - y)) <= np.max(np.abs(x)) / 127 + 1e-6


def test_payload_is_int8():
    g = _grads()
    c, _ = compress(g, zero_residual(g))
    for q in jax.tree.leaves(c.q):
        assert q.dtype == jnp.int8


def test_error_feedback_is_unbiased_over_steps():
    """Sum of decompressed updates converges to the sum of true gradients."""
    g = _grads()
    res = zero_residual(g)
    true_sum = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
    sent_sum = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
    for step in range(20):
        gs = _grads(step)
        c, res = compress(gs, res)
        back = decompress(c, gs)
        true_sum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                true_sum, gs)
        sent_sum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                sent_sum, back)
    # with error feedback, the residual bounds the cumulative discrepancy
    for k in g:
        diff = np.abs(np.asarray(true_sum[k] - sent_sum[k]))
        r = np.abs(np.asarray(res[k])) + 1e-5
        assert (diff <= r + 1e-4).all(), (k, diff.max(), r.max())


def test_compress_under_jit():
    g = _grads()
    fn = jax.jit(lambda g, r: compress(g, r))
    c, res = fn(g, zero_residual(g))
    assert isinstance(c, CompressedGrads)
