"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + finiteness (no NaNs), plus a prefill/decode
consistency check for the serving path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model

B, S = 2, 64


def make_batch(cfg, rng):
    if cfg.frontend == "frames":
        return {
            "frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32),
        }
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.key(0))

    loss_fn = jax.jit(lambda p, b: model.loss(p, b))
    batch = make_batch(cfg, rng)
    loss = loss_fn(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # one SGD step must also be finite (exercises the backward pass)
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)))(params, batch)
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, jnp.zeros(()))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert_xlarge"])
def test_prefill_decode_consistency(arch):
    """Decode with caches must reproduce the full-sequence forward."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.key(1))
    prompt_len, gen = 32, 4
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (B, prompt_len + gen)), jnp.int32)

    # ground truth: full forward logits at each position
    x = model.forward(params, {"tokens": tokens}, remat=False)
    full_logits = model._logits(params, x)

    # serving path: prefill prompt, then decode the next `gen` tokens
    logits, caches = jax.jit(model.prefill)(
        params, {"tokens": tokens[:, :prompt_len]})
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, prompt_len - 1]),
        rtol=2e-2, atol=2e-2)

    # pad caches to full length for the decode steps
    big = model.init_caches(B, prompt_len + gen)

    def fill(dst, src):
        return jax.lax.dynamic_update_slice(
            dst.astype(src.dtype), src, (0,) * src.ndim)

    caches = jax.tree.map(fill, big, caches)

    decode = jax.jit(model.decode_step)
    for t in range(gen):
        pos = jnp.asarray(prompt_len + t, jnp.int32)
        # feeding the true token at `pos`; logits must predict full_logits[pos]
        logits, caches = decode(params, tokens[:, prompt_len + t], caches, pos)
        # atol scaled to logit magnitude: chunked-scan prefill vs sequential
        # decode accumulate fp32 in different orders (SSD / RG-LRU scans).
        ref = np.asarray(full_logits[:, prompt_len + t])
        atol = max(5e-2, 2e-2 * float(np.abs(ref).max()))
        np.testing.assert_allclose(
            np.asarray(logits), ref, rtol=5e-2, atol=atol,
            err_msg=f"{arch}: decode step {t} diverges from full forward")


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    assert n > 0
    if cfg.moe:
        assert cfg.param_count(active_only=True) < n


def test_deepseek_param_count_in_range():
    cfg = get_config("deepseek_v3_671b")
    n = cfg.param_count()
    # 256 experts x 61-3 layers x 3 x 7168 x 2048 alone is ~654B
    assert 6e11 < n < 8e11, n


def test_segments_cover_all_layers():
    for arch in ARCHS:
        cfg = get_config(arch)
        segs = cfg.segments()
        total = sum(len(period) * reps for period, reps in segs)
        assert total == cfg.num_layers, (arch, segs)
