"""Hardened API boundaries: key normalization + argument validation.

Regression suite for the former raw-``uint64[n]`` crash (a bare
``ValueError: indices and arr must have the same number of dimensions``
thrown from deep inside the jitted eviction loop — ``layout.py:184`` via
``cuckoo_filter.py``) and conformance for the key-format contract: every
registry backend × op accepts raw ``uint64[n]`` keys, ``n=0``, and ``n=1``
batches, and rejects genuinely malformed shapes/dtypes with a
``ValueError`` that names the offending argument.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import amq
from repro.amq import OP_DELETE, OP_INSERT, OP_QUERY, OpBatch
from repro.core import CuckooConfig, CuckooFilter, keys_from_numpy
from repro.core.hashing import normalize_keys

CAPACITY = 2048


@pytest.fixture(params=list(amq.names()))
def backend(request):
    return request.param


def _raw(n, seed=0):
    rng = np.random.default_rng(seed)
    return np.unique(rng.integers(1, 2**64, size=2 * n + 16,
                                  dtype=np.uint64))[:n]


# ---------------------------------------------------------------------------
# normalize_keys: the one key-format contract.
# ---------------------------------------------------------------------------

def test_normalize_accepts_all_documented_forms():
    raw = _raw(16)
    packed = keys_from_numpy(raw)
    for form in (raw, raw.tolist(), packed, jnp.asarray(packed),
                 packed.astype(np.int32)):
        got = np.asarray(normalize_keys(form))
        assert got.dtype == np.uint32 and got.shape == (16, 2)
        np.testing.assert_array_equal(got, packed)


def test_normalize_widens_narrow_scalars():
    small = np.arange(5, dtype=np.uint32)
    np.testing.assert_array_equal(
        np.asarray(normalize_keys(small)),
        keys_from_numpy(small.astype(np.uint64)))


@pytest.mark.parametrize("bad, fragment", [
    (np.zeros((4, 3), np.uint32), "keys"),
    (np.zeros((2, 2, 2), np.uint32), "keys"),
    (np.zeros((4,), np.float32), "keys"),
    (np.asarray(["a", "b"], object), "keys"),
    ((np.zeros((4, 2), np.uint64) + (1 << 40)), "lane"),
])
def test_normalize_rejects_malformed(bad, fragment):
    with pytest.raises(ValueError, match=fragment):
        normalize_keys(bad)


# ---------------------------------------------------------------------------
# The pinned regression: raw uint64 keys through every backend x op.
# ---------------------------------------------------------------------------

def test_raw_uint64_insert_regression_layout_crash():
    """Pinned: this exact call used to die inside jit with
    ``ValueError: indices and arr must have the same number of dimensions;
    2 vs 1`` at src/repro/core/layout.py:184 (gather_bucket_words) via
    src/repro/core/cuckoo_filter.py (prepare_keys), for every fp_bits."""
    for fp_bits in (8, 16, 32):
        handle = amq.make("cuckoo", capacity=CAPACITY, fp_bits=fp_bits)
        raw = _raw(64)
        assert np.asarray(handle.insert(raw).ok).all()
        assert np.asarray(handle.query(raw).hits).all()


@pytest.mark.parametrize("n", [0, 1, 37])
def test_raw_uint64_all_backends_all_ops(backend, n):
    handle = amq.make(backend, capacity=CAPACITY)
    caps = handle.capabilities
    raw = _raw(n, seed=n)

    report = handle.insert(raw)
    ok = np.asarray(report.ok) & np.asarray(report.routed)
    assert ok.shape == (n,)
    assert ok.all(), f"{backend}: raw-key insert failed"
    hits = np.asarray(handle.query(raw).hits)
    assert hits[ok].all(), f"{backend}: false negative on raw keys"
    if caps.supports_bulk:
        handle.insert(raw, bulk=True)
    if caps.supports_delete:
        dr = handle.delete(raw)
        assert (np.asarray(dr.ok) & np.asarray(dr.routed)).shape == (n,)
    batch = OpBatch.make(raw, np.full((n,), OP_INSERT, np.int32))
    m = handle.apply_ops(batch)
    assert np.asarray(m.ok).shape == (n,)


def test_raw_uint64_equals_packed(backend):
    """Raw and pre-packed key batches must produce identical answers."""
    raw = _raw(200)
    packed = jnp.asarray(keys_from_numpy(raw))
    h1 = amq.make(backend, capacity=CAPACITY)
    h2 = amq.make(backend, capacity=CAPACITY)
    np.testing.assert_array_equal(np.asarray(h1.insert(raw).ok),
                                  np.asarray(h2.insert(packed).ok))
    np.testing.assert_array_equal(np.asarray(h1.query(raw).hits),
                                  np.asarray(h2.query(packed).hits))


def test_malformed_keys_rejected_at_handle(backend):
    handle = amq.make(backend, capacity=CAPACITY)
    with pytest.raises(ValueError, match="keys"):
        handle.insert(np.zeros((4, 3), np.uint32))
    with pytest.raises(ValueError, match="keys"):
        handle.query(np.zeros((4,), np.float64))


def test_raw_uint64_cascade_and_core_wrappers():
    cascade = amq.make("cuckoo", capacity=256, auto_expand=True)
    raw = _raw(400)
    assert np.asarray(cascade.insert(raw).ok).all()
    assert np.asarray(cascade.query(raw).hits).all()
    assert np.asarray(cascade.delete(raw[:10]).ok).all()

    filt = CuckooFilter(CuckooConfig.for_capacity(CAPACITY))
    ok, _ = filt.insert(raw)
    assert np.asarray(ok).all()
    assert np.asarray(filt.query(raw)).all()
    assert np.asarray(filt.delete(raw[:10])).all()


def test_core_functional_op_raises_clear_error():
    """The jitted core rejects un-normalized keys with a pointer to the
    contract instead of the old opaque dimension error."""
    from repro.core import insert

    cfg = CuckooConfig.for_capacity(CAPACITY)
    with pytest.raises(ValueError, match="normalize_keys|lo, hi"):
        insert(cfg, cfg.init(), jnp.zeros((8,), jnp.uint32))


# ---------------------------------------------------------------------------
# OpBatch.make validation.
# ---------------------------------------------------------------------------

def test_opbatch_accepts_raw_uint64():
    raw = _raw(8)
    batch = OpBatch.make(raw, np.full((8,), OP_QUERY, np.int32))
    np.testing.assert_array_equal(np.asarray(batch.keys),
                                  keys_from_numpy(raw))


def test_opbatch_rejects_bad_op_codes():
    raw = _raw(4)
    with pytest.raises(ValueError, match="ops.*unknown op code 7"):
        OpBatch.make(raw, np.array([0, 1, 2, 7], np.int32))
    with pytest.raises(ValueError, match="ops.*-1"):
        OpBatch.make(raw, np.array([0, -1, 2, 1], np.int32))


def test_opbatch_rejects_bad_ops_dtype_and_shape():
    raw = _raw(4)
    with pytest.raises(ValueError, match="ops.*dtype"):
        OpBatch.make(raw, np.zeros((4,), np.float32))
    with pytest.raises(ValueError, match="ops.*shape"):
        OpBatch.make(raw, np.zeros((3,), np.int32))


def test_opbatch_rejects_bad_valid_shape():
    raw = _raw(4)
    with pytest.raises(ValueError, match="valid.*shape"):
        OpBatch.make(raw, np.zeros((4,), np.int32),
                     valid=np.ones((3,), bool))


# ---------------------------------------------------------------------------
# FilterService submission boundary.
# ---------------------------------------------------------------------------

def test_service_accepts_raw_uint64_and_scatters():
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=16)
    raw = _raw(40)
    t_ins = svc.insert(raw)
    t_q = svc.query(raw)
    assert t_ins.result().all() and t_q.result().all()


def test_service_rejects_malformed_submissions():
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=16)
    raw = _raw(4)
    with pytest.raises(ValueError, match="keys"):
        svc.submit(np.zeros((4, 3), np.uint32), np.zeros((4,), np.int32))
    with pytest.raises(ValueError, match="ops.*dtype"):
        svc.submit(raw, np.zeros((4,), np.float64))
    with pytest.raises(ValueError, match=r"ops.*expected \(3,\)"):
        svc.submit(raw[:3], np.zeros((4,), np.int32))
    with pytest.raises(ValueError, match="ops.*dtype"):
        svc.submit(raw, np.array([True, False, True, True]))  # mask != ops
    with pytest.raises(ValueError, match="ops.*shape"):
        svc.submit(raw[:3], np.zeros((3, 1), np.int32))  # no silent flatten
    with pytest.raises(ValueError, match="ops.*unknown op code 9"):
        svc.submit(raw, np.array([9, 0, 0, 0], np.int32))
    assert svc.pending_ops == 0  # nothing half-enqueued


def test_service_delete_capability_gate_names_backend():
    svc = amq.FilterService(amq.make("bloom", capacity=CAPACITY),
                            batch_size=16)
    with pytest.raises(NotImplementedError, match="bloom"):
        svc.delete(_raw(4))
