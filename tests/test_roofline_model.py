"""The bytes model (kernels/roofline.py) vs real lowered HLO programs.

Two layers of defence against the roofline suite silently reporting
nonsense:

* **Model algebra** — the per-op minimal-bytes figures must obey the
  structural facts they encode (two bucket reads per cuckoo probe, one
  block per bloom probe, bulk amortization, residency regimes, mix
  blending). These are exact, fast, and catch layout-change drift.
* **HLO cross-check** — ``launch.filter_roofline.cross_check`` lowers the
  actual core programs and parses their materialized bytes with
  ``launch.hlo_cost``. The model is a *lower bound*, so ``ratio =
  hlo_bytes / model_bytes >= 1`` must hold for every op; for query (a
  simple two-gather program) the compiled program is also pinned to stay
  within an order of magnitude of the model — if either edge moves, the
  denominators of every achieved-bandwidth number have gone stale.
"""

from __future__ import annotations

import pytest

from repro.core.cuckoo_filter import CuckooConfig
from repro.filters.bcht import BCHTConfig
from repro.filters.blocked_bloom import BloomConfig
from repro.kernels import roofline as RM
from repro.launch import filter_roofline as FR


CFG = CuckooConfig(num_buckets=1 << 8, fp_bits=16)


# ---------------------------------------------------------------------------
# Model algebra.
# ---------------------------------------------------------------------------

def test_cuckoo_query_reads_both_buckets():
    t = RM.cuckoo_op_traffic(CFG, "query")
    bucket_bytes = CFG.layout.words_per_bucket * 4
    assert t.table_read == 2 * bucket_bytes
    assert t.table_write == 0.0
    assert t.stream_read == RM.KEY_BYTES
    assert t.stream_write == RM.RESULT_BYTES


def test_cuckoo_mutations_add_one_word_write():
    for op in ("insert", "delete"):
        t = RM.cuckoo_op_traffic(CFG, op)
        assert t.table_write == 4.0
        assert t.table_read == RM.cuckoo_op_traffic(CFG, "query").table_read


def test_bulk_insert_amortizes_primary_bucket():
    # A batch spanning every bucket many times amortizes the primary
    # bucket load; a tiny batch cannot beat the per-key insert model.
    big = RM.cuckoo_op_traffic(CFG, "bulk_insert",
                               batch=64 * CFG.num_buckets)
    ins = RM.cuckoo_op_traffic(CFG, "insert")
    assert big.per_key < ins.per_key
    small = RM.cuckoo_op_traffic(CFG, "bulk_insert", batch=1)
    assert small.per_key >= ins.per_key


def test_orient_bulk_amortizes_whole_table():
    # Graph-orientation bulk build: commit streams the whole table once,
    # amortized over the batch — a big batch beats both the round-loop
    # insert model and the bucket-major bulk model, a tiny one cannot.
    big = RM.cuckoo_op_traffic(CFG, "orient_bulk_insert",
                               batch=64 * CFG.num_slots)
    bulk = RM.cuckoo_op_traffic(CFG, "bulk_insert",
                                batch=64 * CFG.num_slots)
    ins = RM.cuckoo_op_traffic(CFG, "insert")
    assert big.per_key < bulk.per_key < ins.per_key
    small = RM.cuckoo_op_traffic(CFG, "orient_bulk_insert", batch=1)
    assert small.per_key >= ins.per_key
    # The table is both read and written (unpack + repack commit).
    assert big.table_read == big.table_write > 0.0


def test_orient_bulk_is_cuckoo_only():
    bloom = BloomConfig(num_blocks=1 << 8, words_per_block=16, k=8)
    with pytest.raises(ValueError, match="unknown bloom op"):
        RM.bloom_op_traffic(bloom, "orient_bulk_insert")
    bcht = BCHTConfig(num_buckets=1 << 8, bucket_size=16)
    with pytest.raises(ValueError, match="unknown bcht op"):
        RM.bcht_op_traffic(bcht, "orient_bulk_insert")


def test_apply_ops_blends_mix():
    q_only = RM.cuckoo_op_traffic(CFG, "apply_ops", op_mix=(1.0, 0.0, 0.0))
    assert q_only.table_write == 0.0
    heavy = RM.cuckoo_op_traffic(CFG, "apply_ops", op_mix=(0.0, 1.0, 0.0))
    assert heavy.table_write == 4.0
    mixed = RM.cuckoo_op_traffic(CFG, "apply_ops", op_mix=(0.5, 0.5, 0.0))
    assert 0.0 < mixed.table_write < 4.0


def test_fp_bits_scale_probe_bytes():
    # Wider fingerprints = more words per bucket = more probe traffic.
    per_key = [RM.cuckoo_op_traffic(
        CuckooConfig(num_buckets=1 << 8, fp_bits=fb), "query").per_key
        for fb in (8, 16, 32)]
    assert per_key[0] < per_key[1] < per_key[2]


def test_bloom_reads_one_block():
    cfg = BloomConfig(num_blocks=1 << 8, words_per_block=16, k=8)
    t = RM.bloom_op_traffic(cfg, "query")
    assert t.table_read == 16 * 4
    assert RM.bloom_op_traffic(cfg, "insert").table_write == 8 * 4


def test_bloom_rejects_delete_fraction():
    cfg = BloomConfig(num_blocks=1 << 8, words_per_block=16, k=8)
    with pytest.raises(ValueError, match="append-only"):
        RM.bloom_op_traffic(cfg, "apply_ops", op_mix=(0.8, 0.1, 0.1))


def test_bcht_costs_full_slots():
    cfg = BCHTConfig(num_buckets=1 << 8, bucket_size=16)
    t = RM.bcht_op_traffic(cfg, "query")
    assert t.table_read == 2 * 16 * 9
    assert RM.bcht_op_traffic(cfg, "insert").table_write == 9.0


def test_dispatch_routes_by_config_type():
    assert RM.op_traffic(CFG, "query").table_read > 0
    bloom = BloomConfig(num_blocks=1 << 8, words_per_block=16, k=8)
    assert RM.op_traffic(bloom, "query").table_read == 64
    bcht = BCHTConfig(num_buckets=1 << 8, bucket_size=16)
    assert RM.op_traffic(bcht, "query").table_read == 288
    with pytest.raises(TypeError, match="no bytes model"):
        RM.op_traffic(object(), "query")


def test_unknown_op_raises():
    with pytest.raises(ValueError, match="unknown cuckoo op"):
        RM.cuckoo_op_traffic(CFG, "frobnicate")


def test_min_batch_bytes_linear_in_n():
    b1 = RM.min_batch_bytes(CFG, "query", 1024)
    b2 = RM.min_batch_bytes(CFG, "query", 2048)
    assert b2 == 2 * b1


def test_table_resident_regime():
    n = 1024
    resident = RM.min_batch_bytes(CFG, "query", n, table_resident=True)
    streaming = RM.min_batch_bytes(CFG, "query", n)
    stream_only = n * (RM.KEY_BYTES + RM.RESULT_BYTES)
    # Pinned: streams + exactly one table load (query writes nothing).
    assert resident == stream_only + CFG.table_bytes
    # Mutating ops spill the table back: one load + one store.
    res_ins = RM.min_batch_bytes(CFG, "insert", n, table_resident=True)
    assert res_ins == stream_only + 2 * CFG.table_bytes
    # Both regimes are lower-bounded by the key/result streams.
    assert streaming > stream_only


def test_model_floor_is_the_stream():
    for op in RM.OPS:
        t = RM.op_traffic(CFG, op, batch=4096)
        assert t.per_key >= RM.KEY_BYTES + RM.RESULT_BYTES


# ---------------------------------------------------------------------------
# HLO cross-check: the model vs actually-lowered programs.
# ---------------------------------------------------------------------------

XCFG = CuckooConfig(num_buckets=1 << 8, fp_bits=16)


@pytest.mark.parametrize("op", ["query", "insert", "apply_ops",
                                "orient_bulk_insert"])
def test_model_is_lower_bound_of_lowered_hlo(op):
    r = FR.cross_check(XCFG, op, n=512)
    assert r["model_bytes"] > 0
    assert r["hlo_bytes"] > 0
    # A *minimal* model can never exceed what the compiled program moves.
    assert r["ratio"] >= 1.0, r


def test_query_hlo_stays_near_model():
    # The lowered query is two gathers + compares; XLA materializes
    # operand-sized buffers so the ratio is > 1, but it must stay within
    # an order of magnitude (measured ~4-5x) — a blowout here means the
    # model (or the core query) changed shape without the other.
    r = FR.cross_check(XCFG, "query", n=512)
    assert 1.0 <= r["ratio"] < 50.0, r


def test_cross_check_rejects_unknown_op():
    with pytest.raises(ValueError, match="unknown op"):
        FR.cross_check(XCFG, "nope", n=64)


def test_lowered_cost_parses_flops_and_bytes():
    import functools

    import jax.numpy as jnp

    from repro.core import cuckoo_filter as CF

    state = XCFG.init()
    keys = jnp.zeros((256, 2), jnp.uint32)
    cost = FR.lowered_cost(functools.partial(CF.query, XCFG), state, keys)
    assert cost["bytes"] > 0 and cost["n_computations"] >= 1
