"""SWAR primitives and packed layout vs naive unpack oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade to fixed-seed example tests
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from _tuning import examples

from repro.core import layout as L

u32s = st.integers(min_value=0, max_value=(1 << 32) - 1)


@pytest.mark.parametrize("fp_bits", [8, 16, 32])
@settings(max_examples=examples(200), deadline=None)
@given(word=u32s)
def test_swar_zero_mask_matches_naive(word, fp_bits):
    mask = L.swar_zero_mask(jnp.uint32(word), fp_bits)
    flags = np.asarray(L.swar_mask_to_bools(mask, fp_bits))
    tags = np.asarray(L.unpack_words(jnp.asarray([word], jnp.uint32), fp_bits))
    np.testing.assert_array_equal(flags, tags == 0)


@pytest.mark.parametrize("fp_bits", [8, 16, 32])
@settings(max_examples=examples(200), deadline=None)
@given(word=u32s, tag=u32s)
def test_swar_match_mask_matches_naive(word, tag, fp_bits):
    tag &= (1 << fp_bits) - 1
    mask = L.swar_match_mask(jnp.uint32(word), jnp.uint32(tag), fp_bits)
    flags = np.asarray(L.swar_mask_to_bools(mask, fp_bits))
    tags = np.asarray(L.unpack_words(jnp.asarray([word], jnp.uint32), fp_bits))
    np.testing.assert_array_equal(flags, tags == tag)


@pytest.mark.parametrize("fp_bits", [8, 16, 32])
def test_pack_unpack_roundtrip(fp_bits):
    rng = np.random.default_rng(0)
    tags = rng.integers(0, 1 << fp_bits, size=(5, 32), dtype=np.uint32)
    packed = L.pack_tags(jnp.asarray(tags), fp_bits)
    assert packed.shape == (5, 32 // (32 // fp_bits))
    back = np.asarray(L.unpack_words(packed, fp_bits))
    np.testing.assert_array_equal(back, tags)


@pytest.mark.parametrize("fp_bits", [8, 16, 32])
@settings(max_examples=examples(100), deadline=None)
@given(word=u32s, tag=u32s, slot=st.integers(min_value=0, max_value=3))
def test_extract_replace(word, tag, slot, fp_bits):
    tpw = 32 // fp_bits
    slot = slot % tpw
    tag &= (1 << fp_bits) - 1
    w = jnp.uint32(word)
    s = jnp.int32(slot)
    new = L.replace_tag(w, s, jnp.uint32(tag), fp_bits)
    assert int(L.extract_tag(new, s, fp_bits)) == tag
    # other lanes untouched
    for other in range(tpw):
        if other != slot:
            assert int(L.extract_tag(new, jnp.int32(other), fp_bits)) == int(
                L.extract_tag(w, jnp.int32(other), fp_bits))


def test_first_true_circular():
    flags = jnp.asarray([[False, True, False, True],
                         [False, False, False, False],
                         [True, False, False, False]])
    start = jnp.asarray([2, 0, 3], jnp.int32)
    found, slot = L.first_true_circular(flags, start)
    np.testing.assert_array_equal(np.asarray(found), [True, False, True])
    assert int(slot[0]) == 3          # scan 2,3 -> 3
    assert int(slot[2]) == 0          # scan 3,0 -> 0


def test_broadcast_tag():
    assert int(L.broadcast_tag(jnp.uint32(0xAB), 8)) == 0xABABABAB
    assert int(L.broadcast_tag(jnp.uint32(0x1234), 16)) == 0x12341234
    assert int(L.broadcast_tag(jnp.uint32(0xDEADBEEF), 32)) == 0xDEADBEEF


def test_gather_bucket_words():
    lay = L.BucketLayout(num_buckets=4, bucket_size=4, fp_bits=16)
    table = jnp.arange(lay.num_words, dtype=jnp.uint32)
    words = L.gather_bucket_words(table, jnp.asarray([2, 0], jnp.uint32), lay)
    np.testing.assert_array_equal(np.asarray(words),
                                  [[4, 5], [0, 1]])
