"""Property-based differentials: fused Pallas kernels vs oracles (§4).

Every fused kernel is replayed against *two* independent oracles on
hypothesis-drawn key streams: the sequential reference in
``kernels/ref.py`` (exact equality — table words and per-key outcomes)
and the core ``cuckoo_filter`` jit path where the semantics overlap
(query hits, landed inserts must be queryable). The sweep dimensions are
the ones that change the packed layout under the kernels — bucket size ×
``fp_bits`` × occupancy — plus a ≥95%-load BFS-eviction stress cell: the
filter is driven to the paper's high-load regime through the
eviction-capable core insert, and the fused query kernel must report
**zero false negatives** over everything the filter accepted.

Example counts route through ``tests/_tuning.examples`` (CI caps them via
``REPRO_MAX_EXAMPLES``); the hypothesis import degrades to the in-repo
shim in the bare container.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised in the bare container
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from _tuning import examples

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CuckooConfig, keys_from_numpy
from repro.core import cuckoo_filter as CF
from repro.kernels import autotune
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.kernels.cuckoo_insert import cuckoo_insert_pallas
from repro.kernels.cuckoo_mixed import cuckoo_mixed_pallas
from repro.kernels.cuckoo_query import (
    cuckoo_query_fused_pallas,
    cuckoo_query_pallas,
)

NUM_BUCKETS = 64
BLOCK = 64

# bucket_size x fp_bits x target occupancy — every packed-word shape the
# SWAR paths can take (1..32 words/bucket), from near-empty to contended.
CELLS = [
    (4, 8, 0.30),
    (4, 32, 0.70),
    (8, 16, 0.50),
    (16, 8, 0.70),
    (16, 16, 0.30),
    (32, 16, 0.85),
]


def _cfg(bucket_size: int, fp_bits: int, **kw) -> CuckooConfig:
    return CuckooConfig(num_buckets=NUM_BUCKETS, fp_bits=fp_bits,
                        bucket_size=bucket_size, **kw)


def _rand_keys(rng, n: int) -> jnp.ndarray:
    return jnp.asarray(keys_from_numpy(
        rng.integers(1, 2**64, size=n, dtype=np.uint64)))


# Configs are frozen dataclasses (hashable), shapes are fixed per cell, so
# every oracle/kernel compiles exactly once per cell and the hypothesis
# examples replay through the cached executable — the suite would be
# minutes-per-test in op-by-op eager dispatch otherwise.

@functools.lru_cache(maxsize=None)
def _jit(fn, cfg):
    return jax.jit(functools.partial(fn, cfg))


@functools.lru_cache(maxsize=None)
def _jit_blk(fn, cfg):
    return jax.jit(functools.partial(fn, cfg, block_keys=BLOCK))


def _filled(cfg: CuckooConfig, rng, occupancy: float):
    """(state, accepted_keys): core-inserted stream at ~``occupancy``."""
    n = max(BLOCK, int(cfg.num_buckets * cfg.bucket_size * occupancy))
    keys = _rand_keys(rng, n)
    state, ok, _ = _jit(CF.insert, cfg)(cfg.init(), keys)
    return state, keys[np.asarray(ok)]


def _eq(got, want, **ctx):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                  err_msg=repr(ctx))


# ---------------------------------------------------------------------------
# Fused query: vs the unpack kernel, the ref oracle, and the core path.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bs,fb,occ", CELLS)
@settings(max_examples=examples(10), deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_fused_query_differential(bs, fb, occ, seed):
    cfg = _cfg(bs, fb)
    rng = np.random.default_rng(seed)
    state, _ = _filled(cfg, rng, occ)
    # Probe a mix of resident-ish and definitely-fresh keys.
    probe = _rand_keys(rng, 4 * BLOCK)
    fused = _jit_blk(cuckoo_query_fused_pallas, cfg)(
        state.table, probe[:, 0], probe[:, 1])
    _eq(fused, _jit_blk(cuckoo_query_pallas, cfg)(
            state.table, probe[:, 0], probe[:, 1]),
        cell=(bs, fb, occ), seed=seed, vs="prepr kernel")
    _eq(fused, _jit(R.cuckoo_query_ref, cfg)(
            state.table, probe[:, 0], probe[:, 1]),
        cell=(bs, fb, occ), seed=seed, vs="ref oracle")
    _eq(fused.astype(bool), _jit(CF.query, cfg)(state, probe),
        cell=(bs, fb, occ), seed=seed, vs="core jit path")


@pytest.mark.parametrize("bs,fb,occ", CELLS[:3])
@settings(max_examples=examples(6), deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_ops_wrapper_query_matches_core(bs, fb, occ, seed):
    """The public wrapper (autotune-resolved blocks, padding) == core."""
    cfg = _cfg(bs, fb)
    rng = np.random.default_rng(seed)
    state, _ = _filled(cfg, rng, occ)
    # A deliberately non-multiple length exercises the padding path.
    probe = _rand_keys(rng, 3 * BLOCK + 17)
    want = _jit(CF.query, cfg)(state, probe)
    for fused in (True, False):
        _eq(K.cuckoo_query(cfg, state, probe, fused=fused), want,
            cell=(bs, fb, occ), seed=seed, fused=fused)


# ---------------------------------------------------------------------------
# Direct insert: kernel vs sequential ref, then queryable through fusion.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bs,fb,occ", CELLS)
@settings(max_examples=examples(8), deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_insert_differential(bs, fb, occ, seed):
    cfg = _cfg(bs, fb)
    rng = np.random.default_rng(seed)
    n = max(BLOCK, (int(cfg.num_buckets * cfg.bucket_size * occ)
                    // BLOCK) * BLOCK)
    keys = _rand_keys(rng, n)
    table = cfg.layout.empty_table()
    t_got, ok_got = _jit_blk(cuckoo_insert_pallas, cfg)(
        table, keys[:, 0], keys[:, 1])
    t_want, ok_want = _jit(R.cuckoo_insert_ref, cfg)(
        table, keys[:, 0], keys[:, 1])
    _eq(t_got, t_want, cell=(bs, fb, occ), seed=seed, what="table")
    _eq(ok_got, ok_want, cell=(bs, fb, occ), seed=seed, what="ok")
    # Everything the kernel accepted must be a fused-query hit.
    hit = _jit_blk(cuckoo_query_fused_pallas, cfg)(
        t_got, keys[:, 0], keys[:, 1])
    landed = np.asarray(ok_got).astype(bool)
    assert np.asarray(hit).astype(bool)[landed].all(), (bs, fb, occ, seed)


# ---------------------------------------------------------------------------
# Mixed op stream: fused kernel vs the sequential ref oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bs,fb,occ", CELLS)
@settings(max_examples=examples(8), deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_mixed_stream_differential(bs, fb, occ, seed):
    cfg = _cfg(bs, fb)
    rng = np.random.default_rng(seed)
    state, _ = _filled(cfg, rng, occ)
    n = 2 * BLOCK
    # Draw from a small universe so deletes/queries collide with inserts
    # inside one stream (the order-sensitive cases).
    uni = _rand_keys(rng, 24)
    picks = rng.integers(0, uni.shape[0], size=n)
    keys = uni[picks]
    ops = jnp.asarray(rng.integers(0, 3, size=n, dtype=np.int32))
    valid = jnp.asarray((rng.random(n) < 0.9).astype(np.uint32))
    t_got, ok_got = _jit_blk(cuckoo_mixed_pallas, cfg)(
        state.table, keys[:, 0], keys[:, 1], ops, valid)
    t_want, ok_want = _jit(R.cuckoo_mixed_ref, cfg)(
        state.table, keys[:, 0], keys[:, 1], ops, valid)
    _eq(t_got, t_want, cell=(bs, fb, occ), seed=seed, what="table")
    _eq(ok_got, ok_want, cell=(bs, fb, occ), seed=seed, what="ok")


# ---------------------------------------------------------------------------
# ≥95%-occupancy BFS-eviction stress: zero false negatives through fusion.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fb", [8, 16])
@settings(max_examples=examples(5), deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_bfs_high_load_zero_false_negatives(fb, seed):
    """Fill to >=95% via BFS eviction; every resident key must hit.

    The eviction cascade relocates fingerprints far from their insert-time
    slots — exactly the table state where a query kernel bug (wrong
    alternate bucket, SWAR lane mixup at packed widths) shows up as a
    false negative, which a cuckoo filter must never produce.
    """
    cfg = _cfg(16, fb, eviction="bfs", max_evictions=256)
    rng = np.random.default_rng(seed)
    slots = cfg.num_buckets * cfg.bucket_size
    # 0.97 of capacity: bucket-size-16 BFS absorbs this failure-free, and
    # failure-free is what makes zero-FN a theorem — every failed insert
    # drops exactly the victim fingerprint it was carrying (Alg. 1), so
    # the general sound bound is misses <= fails.
    keys = _rand_keys(rng, int(slots * 0.97))
    state, ok, _ = _jit(CF.insert, cfg)(cfg.init(), keys)
    accepted = np.asarray(ok)
    fails = int((~accepted).sum())
    load = accepted.sum() / slots
    assert load >= 0.95, f"stress cell under-filled: load={load:.3f}"

    pad = (-keys.shape[0]) % BLOCK
    probe = jnp.pad(keys, ((0, pad), (0, 0)))
    hit = np.asarray(_jit_blk(cuckoo_query_fused_pallas, cfg)(
        state.table, probe[:, 0], probe[:, 1]))[: keys.shape[0]].astype(bool)
    misses = accepted & ~hit
    assert misses.sum() <= fails, (
        f"{misses.sum()} false negatives vs {fails} failed inserts "
        f"at load {load:.3f} (seed {seed})")
    assert fails == 0 and not misses.any(), (
        f"fill not failure-free (fails={fails}) at load {load:.3f}")
    # The core path agrees lane-for-lane on the same stressed table.
    _eq(hit, _jit(CF.query, cfg)(state, keys), fb=fb, seed=seed)


# ---------------------------------------------------------------------------
# Autotune plumbing: resolved blocks never change results.
# ---------------------------------------------------------------------------

def test_block_resolution_is_semantics_free():
    cfg = _cfg(8, 16)
    rng = np.random.default_rng(7)
    state, _ = _filled(cfg, rng, 0.5)
    probe = _rand_keys(rng, 1000)   # not a multiple of any candidate
    want = np.asarray(_jit(CF.query, cfg)(state, probe))
    try:
        for bk in (64, 256, 1024):
            autotune.record(cfg, "query", bk)
            got = np.asarray(K.cuckoo_query(cfg, state, probe))
            np.testing.assert_array_equal(got, want, err_msg=f"bk={bk}")
    finally:
        autotune.clear()
