"""FilterService: micro-batching, multi-client scatter, consumer migration.

The acceptance scenario of DESIGN.md §9: many logical clients submit
interleaved op streams; the service coalesces them into fixed-size padded
OpBatches, executes each as one fused pass, and every client gets exactly
its own results back — verified against a direct replay of the same global
stream on a fresh handle.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro import amq
from repro.amq.protocol import OP_DELETE, OP_INSERT, OP_QUERY
from repro.core import keys_from_numpy

CAPACITY = 4096


def _kk(raw) -> np.ndarray:
    return keys_from_numpy(np.asarray(raw, np.uint64))


def _client_streams(seed: int, n_clients: int = 4, per_client: int = 5):
    """Interleaved per-client op streams over a shared small key universe."""
    rng = np.random.default_rng(seed)
    uni = rng.integers(1, 2**63, size=12, dtype=np.uint64)
    streams = []
    for c in range(n_clients):
        for _ in range(per_client):
            m = int(rng.integers(1, 7))
            keys = uni[rng.integers(0, uni.size, size=m)]
            ops = rng.integers(0, 3, size=m).astype(np.int32)
            streams.append((c, _kk(keys), ops))
    return streams


def _replay_direct(streams, backend="cuckoo"):
    """The same global op stream on a bare handle, submission by
    submission — the scatter ground truth."""
    handle = amq.make(backend, capacity=CAPACITY)
    out = []
    for _, keys, ops in streams:
        batch = amq.OpBatch.make(jnp.asarray(keys),
                                 jnp.asarray(ops)).pad_to(8)
        out.append(np.asarray(handle.apply_ops(batch).ok)[:keys.shape[0]])
    return out


@pytest.mark.parametrize("batch_size", [8, 32, 256])
def test_multi_client_interleaved_scatter(batch_size):
    """Per-client results match the direct replay at every batch size.

    batch_size 8 forces submissions to straddle micro-batch boundaries;
    256 forces everything into one padded batch — the scatter must be
    invariant to how the stream is chopped.
    """
    streams = _client_streams(seed=0)
    expected = _replay_direct(streams)
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=batch_size)
    tickets = [svc.submit(keys, ops) for _, keys, ops in streams]
    for (client, keys, ops), ticket, want in zip(streams, tickets, expected):
        got = ticket.result()
        np.testing.assert_array_equal(
            got, want,
            err_msg=f"client {client} scatter mismatch @bs={batch_size}")
        assert ticket.routed().all()


def test_fixed_shape_batches_and_padding_stats():
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=16)
    svc.insert(_kk(np.arange(1, 25)))       # 24 ops -> one full batch + 8
    assert svc.stats["dispatches"] == 1     # full batch dispatched eagerly
    assert svc.pending_ops == 8
    svc.flush()
    assert svc.pending_ops == 0
    assert svc.stats["dispatches"] == 2
    assert svc.stats["padded"] == 8         # the tail batch was padded
    assert 0.0 < svc.stats_fill <= 1.0


def test_result_forces_flush():
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=64)
    t_ins = svc.insert(_kk([42, 43]))
    t_q = svc.query(_kk([42, 43, 44]))
    assert svc.stats["dispatches"] == 0     # everything still pending
    hits = t_q.result()                     # forces the flush, in order
    assert svc.stats["dispatches"] == 1
    np.testing.assert_array_equal(hits, [True, True, False])
    assert t_ins.result().all()


def test_submission_order_is_batch_order():
    """Insert->query->delete->query of one key across separate clients."""
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=32)
    key = _kk([7])
    t1 = svc.insert(key)
    t2 = svc.query(key)
    t3 = svc.delete(key)
    t4 = svc.query(key)
    assert t1.result().all() and t2.result().all() and t3.result().all()
    assert not t4.result().any()


def test_submit_validation_and_capability_gate():
    svc = amq.FilterService(amq.make("bloom", capacity=CAPACITY),
                            batch_size=8)
    with pytest.raises(NotImplementedError):
        svc.delete(_kk([1]))
    with pytest.raises(ValueError, match="op code"):
        svc.submit(_kk([1]), np.asarray([7], np.int32))
    with pytest.raises(ValueError, match="keys"):
        # [n, 3] is genuinely malformed; 1-D integer batches are *raw keys*
        # under the key-format contract (DESIGN.md §10) and now accepted.
        svc.submit(np.zeros((3, 3), np.uint32), np.zeros((3,), np.int32))
    ok = svc.insert(_kk([1, 2])).result()   # bloom still serves ins/query
    assert ok.all()


def test_service_on_cascade_grows():
    svc = amq.FilterService(
        amq.make("cuckoo", capacity=128, auto_expand=True), batch_size=64)
    raw = np.unique(np.random.default_rng(3).integers(
        1, 2**63, size=2048, dtype=np.uint64))[:512]
    t = svc.submit(_kk(raw), np.full((512,), OP_INSERT, np.int32))
    assert t.result().all()                 # grew instead of refusing
    assert len(svc.handle.levels) > 1
    assert svc.query(_kk(raw)).result().all()


def test_prefix_cache_rides_the_service():
    """The serving consumer coalesces filter ops through one service."""
    from repro.serve.prefix_cache import PrefixCache

    pc = PrefixCache(2, backend="cuckoo")
    for i in range(4):
        pc.insert([i, i + 1, i + 2], entry=f"e{i}")
    # admissions/evictions were enqueued; no lookup has forced them yet
    assert pc.service.stats["ops"] > 0
    assert pc.lookup([3, 4, 5]) == "e3"     # flushes, then answers
    assert pc.lookup([0, 1, 2]) is None     # evicted + deleted from filter
    assert pc.stats["evictions"] == 2 and pc.stats["stale"] == 0
    assert pc.service.pending_ops == 0


def test_shared_service_across_prefix_caches():
    """Several caches coalesce into one filter service (one guard filter)."""
    from repro.serve.prefix_cache import PrefixCache

    svc = amq.FilterService(amq.make("cuckoo", capacity=1024), batch_size=32)
    a = PrefixCache(4, service=svc)
    b = PrefixCache(4, service=svc)
    a.insert([1, 2, 3], entry="a")
    b.insert([4, 5, 6], entry="b")
    assert a.lookup([1, 2, 3]) == "a"
    assert b.lookup([4, 5, 6]) == "b"
    assert a.filter is b.filter is svc.handle


def test_streaming_dedup_on_service():
    from repro.data import make_deduper

    d = make_deduper(1024, service_batch=64)
    tokens = jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (16, 1))
    tokens = tokens.at[8:].add(1)           # 2 distinct sequences, 8 copies
    out, stats = d.dedup({"tokens": tokens})
    assert stats["duplicates"] == 14
    assert int(out["mask"].sum()) == 2
    out2, stats2 = d.dedup({"tokens": tokens})
    assert stats2["duplicates"] == 16       # all seen now
    assert d.stats["duplicates"] == 30
