"""FilterService: micro-batching, multi-client scatter, consumer migration.

The acceptance scenario of DESIGN.md §9: many logical clients submit
interleaved op streams; the service coalesces them into fixed-size padded
OpBatches, executes each as one fused pass, and every client gets exactly
its own results back — verified against a direct replay of the same global
stream on a fresh handle.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro import amq
from repro.amq.protocol import OP_DELETE, OP_INSERT, OP_QUERY
from repro.core import keys_from_numpy

CAPACITY = 4096


def _kk(raw) -> np.ndarray:
    return keys_from_numpy(np.asarray(raw, np.uint64))


def _client_streams(seed: int, n_clients: int = 4, per_client: int = 5):
    """Interleaved per-client op streams over a shared small key universe."""
    rng = np.random.default_rng(seed)
    uni = rng.integers(1, 2**63, size=12, dtype=np.uint64)
    streams = []
    for c in range(n_clients):
        for _ in range(per_client):
            m = int(rng.integers(1, 7))
            keys = uni[rng.integers(0, uni.size, size=m)]
            ops = rng.integers(0, 3, size=m).astype(np.int32)
            streams.append((c, _kk(keys), ops))
    return streams


def _replay_direct(streams, backend="cuckoo"):
    """The same global op stream on a bare handle, submission by
    submission — the scatter ground truth."""
    handle = amq.make(backend, capacity=CAPACITY)
    out = []
    for _, keys, ops in streams:
        batch = amq.OpBatch.make(jnp.asarray(keys),
                                 jnp.asarray(ops)).pad_to(8)
        out.append(np.asarray(handle.apply_ops(batch).ok)[:keys.shape[0]])
    return out


@pytest.mark.parametrize("batch_size", [8, 32, 256])
def test_multi_client_interleaved_scatter(batch_size):
    """Per-client results match the direct replay at every batch size.

    batch_size 8 forces submissions to straddle micro-batch boundaries;
    256 forces everything into one padded batch — the scatter must be
    invariant to how the stream is chopped.
    """
    streams = _client_streams(seed=0)
    expected = _replay_direct(streams)
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=batch_size)
    tickets = [svc.submit(keys, ops) for _, keys, ops in streams]
    for (client, keys, ops), ticket, want in zip(streams, tickets, expected):
        got = ticket.result()
        np.testing.assert_array_equal(
            got, want,
            err_msg=f"client {client} scatter mismatch @bs={batch_size}")
        assert ticket.routed().all()


def test_fixed_shape_batches_and_padding_stats():
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=16)
    assert svc.shape_ladder == (8, 16)
    svc.insert(_kk(np.arange(1, 25)))       # 24 ops -> one full batch + 8
    assert svc.stats["dispatches"] == 1     # full batch dispatched eagerly
    assert svc.pending_ops == 8
    svc.flush()
    assert svc.pending_ops == 0
    assert svc.stats["dispatches"] == 2
    assert svc.stats["padded"] == 0         # 8-op tail fits rung 8 exactly
    assert svc.metrics.dispatch_sizes == {16: 1, 8: 1}
    svc.insert(_kk(np.arange(1, 4)))        # 3-op tail -> rung 8, 5 padded
    svc.flush()
    assert svc.stats["padded"] == 5
    assert 0.0 < svc.stats_fill <= 1.0


def test_result_forces_flush():
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=64)
    t_ins = svc.insert(_kk([42, 43]))
    t_q = svc.query(_kk([42, 43, 44]))
    assert svc.stats["dispatches"] == 0     # everything still pending
    hits = t_q.result()                     # forces the flush, in order
    assert svc.stats["dispatches"] == 1
    np.testing.assert_array_equal(hits, [True, True, False])
    assert t_ins.result().all()


def test_submission_order_is_batch_order():
    """Insert->query->delete->query of one key across separate clients."""
    svc = amq.FilterService(amq.make("cuckoo", capacity=CAPACITY),
                            batch_size=32)
    key = _kk([7])
    t1 = svc.insert(key)
    t2 = svc.query(key)
    t3 = svc.delete(key)
    t4 = svc.query(key)
    assert t1.result().all() and t2.result().all() and t3.result().all()
    assert not t4.result().any()


def test_submit_validation_and_capability_gate():
    svc = amq.FilterService(amq.make("bloom", capacity=CAPACITY),
                            batch_size=8)
    with pytest.raises(NotImplementedError):
        svc.delete(_kk([1]))
    with pytest.raises(ValueError, match="op code"):
        svc.submit(_kk([1]), np.asarray([7], np.int32))
    with pytest.raises(ValueError, match="keys"):
        # [n, 3] is genuinely malformed; 1-D integer batches are *raw keys*
        # under the key-format contract (DESIGN.md §10) and now accepted.
        svc.submit(np.zeros((3, 3), np.uint32), np.zeros((3,), np.int32))
    ok = svc.insert(_kk([1, 2])).result()   # bloom still serves ins/query
    assert ok.all()


def test_service_on_cascade_grows():
    svc = amq.FilterService(
        amq.make("cuckoo", capacity=128, auto_expand=True), batch_size=64)
    raw = np.unique(np.random.default_rng(3).integers(
        1, 2**63, size=2048, dtype=np.uint64))[:512]
    t = svc.submit(_kk(raw), np.full((512,), OP_INSERT, np.int32))
    assert t.result().all()                 # grew instead of refusing
    assert len(svc.handle.levels) > 1
    assert svc.query(_kk(raw)).result().all()


def test_prefix_cache_rides_the_service():
    """The serving consumer coalesces filter ops through one service."""
    from repro.serve.prefix_cache import PrefixCache

    pc = PrefixCache(2, backend="cuckoo")
    for i in range(4):
        pc.insert([i, i + 1, i + 2], entry=f"e{i}")
    # admissions/evictions were enqueued; no lookup has forced them yet
    assert pc.service.stats["ops"] > 0
    assert pc.lookup([3, 4, 5]) == "e3"     # flushes, then answers
    assert pc.lookup([0, 1, 2]) is None     # evicted + deleted from filter
    assert pc.stats["evictions"] == 2 and pc.stats["stale"] == 0
    assert pc.service.pending_ops == 0


def test_shared_service_across_prefix_caches():
    """Several caches coalesce into one filter service (one guard filter)."""
    from repro.serve.prefix_cache import PrefixCache

    svc = amq.FilterService(amq.make("cuckoo", capacity=1024), batch_size=32)
    a = PrefixCache(4, service=svc)
    b = PrefixCache(4, service=svc)
    a.insert([1, 2, 3], entry="a")
    b.insert([4, 5, 6], entry="b")
    assert a.lookup([1, 2, 3]) == "a"
    assert b.lookup([4, 5, 6]) == "b"
    assert a.filter is b.filter is svc.handle


def test_streaming_dedup_on_service():
    from repro.data import make_deduper

    d = make_deduper(1024, service_batch=64)
    tokens = jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (16, 1))
    tokens = tokens.at[8:].add(1)           # 2 distinct sequences, 8 copies
    out, stats = d.dedup({"tokens": tokens})
    assert stats["duplicates"] == 14
    assert int(out["mask"].sum()) == 2
    out2, stats2 = d.dedup({"tokens": tokens})
    assert stats2["duplicates"] == 16       # all seen now
    assert d.stats["duplicates"] == 30


# ---------------------------------------------------------------------------
# §11 serving engine: deadlines, shape ladder, admission control, metrics.
# ---------------------------------------------------------------------------

class FakeClock:
    """Deterministic injectable service clock (seconds)."""

    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += dt


def _svc(batch_size=64, backend="cuckoo", **kw):
    return amq.FilterService(amq.make(backend, capacity=CAPACITY),
                             batch_size=batch_size, **kw)


def test_empty_submission_is_immediately_ready():
    """n=0 never enqueues, never forces a padded dispatch, never flushes."""
    svc = _svc(batch_size=16)
    pending_before = svc.insert(_kk([1, 2]))    # real ops stay pending
    t = svc.query(np.zeros((0,), np.uint64))
    assert t.dispatched
    assert t.result().shape == (0,) and t.routed().shape == (0,)
    assert t.t_ready is not None
    assert svc.pending_ops == 2                 # untouched: no forced flush
    assert svc.stats["dispatches"] == 0
    assert pending_before.result().shape == (2,)


@pytest.mark.parametrize("kw,match", [
    ({"batch_size": 0}, "batch_size"),
    ({"batch_size": -8}, "batch_size"),
    ({"max_delay": -1.0}, "max_delay"),
    ({"max_delay": "soon"}, "max_delay"),
    ({"max_pending": 0}, "max_pending"),
    ({"max_pending": -5}, "max_pending"),
    ({"admission": "panic"}, "admission"),
    ({"client_share": 0.0}, "client_share"),
    ({"client_share": 1.5}, "client_share"),
    ({"max_in_flight": 0}, "max_in_flight"),
])
def test_constructor_validation_names_the_argument(kw, match):
    with pytest.raises(ValueError, match=match):
        amq.FilterService(amq.make("cuckoo", capacity=256), **kw)


def test_deadline_dispatch_bounded_by_max_delay():
    """Once the oldest op has waited max_delay, the next poll dispatches."""
    clock = FakeClock()
    svc = _svc(batch_size=64, max_delay=0.5, clock=clock)
    svc.insert(_kk([1, 2, 3]))
    assert svc.poll() == 0 and svc.stats["dispatches"] == 0
    clock.advance(0.49)
    assert svc.poll() == 0                  # not due yet
    clock.advance(0.02)
    assert svc.poll() == 1                  # due: dispatched at a ladder rung
    assert svc.stats["dispatches"] == 1
    assert svc.metrics.dispatch_kinds == {"deadline": 1}
    assert svc.metrics.dispatch_sizes == {8: 1}
    # queue-wait latency was recorded and is bounded by max_delay + poll gap
    assert svc.metrics.queue_wait.total == 3
    assert svc.metrics.queue_wait.percentile(1.0) <= 1.0


def test_deadline_fires_on_next_submit_too():
    clock = FakeClock()
    svc = _svc(batch_size=64, max_delay=0.1, clock=clock)
    svc.insert(_kk([1]))
    clock.advance(0.2)
    svc.insert(_kk([2]))                    # submit itself polls the deadline
    assert svc.stats["dispatches"] == 1
    assert svc.pending_ops == 0             # both ops rode the dispatch


def test_admission_block_bounds_queue_via_backpressure():
    svc = _svc(batch_size=64, max_pending=8, admission="block")
    for i in range(6):
        svc.insert(_kk(np.arange(1, 4) + 10 * i))   # 3 ops each
    assert svc.pending_ops <= 8             # bound held by early dispatches
    assert svc.metrics.dispatch_kinds.get("backpressure", 0) > 0
    assert svc.metrics.shed_ops == 0        # block never drops


def test_admission_shed_keeps_bound_and_marks_tickets():
    svc = _svc(batch_size=64, max_pending=4, admission="shed")
    kept = svc.insert(_kk([1, 2, 3]))
    shed = svc.insert(_kk([4, 5, 6]))       # 3 + 3 > 4 -> refused whole
    assert not kept.shed and shed.shed and shed.dispatched
    assert not shed.result().any() and not shed.routed().any()
    assert svc.pending_ops == 3             # bound held, nothing dispatched
    assert svc.stats["dispatches"] == 0
    assert svc.metrics.shed_ops == 3 and svc.metrics.shed_submissions == 1
    assert kept.result().all()              # accepted ops still correct


def test_admission_error_raises_queue_full():
    svc = _svc(batch_size=64, max_pending=4, admission="error")
    svc.insert(_kk([1, 2, 3]))
    with pytest.raises(amq.QueueFullError, match="max_pending=4"):
        svc.insert(_kk([4, 5]))
    assert svc.pending_ops == 3
    svc.flush()                             # accepted traffic unaffected


def test_client_share_fairness():
    svc = _svc(batch_size=64, max_pending=10, admission="shed",
               client_share=0.5)            # any one client: <= 5 slots
    a1 = svc.insert(_kk([1, 2, 3]), client="a")
    a2 = svc.insert(_kk([4, 5, 6]), client="a")   # a would hold 6 > 5
    b1 = svc.insert(_kk([7, 8, 9]), client="b")   # b is under its share
    assert not a1.shed and a2.shed and not b1.shed
    assert svc.metrics.clients["a"] == {"accepted": 3, "shed": 3}
    assert svc.metrics.clients["b"] == {"accepted": 3, "shed": 0}


def test_stats_callable_snapshot_and_ready_histogram():
    svc = _svc(batch_size=16, max_in_flight=1)
    svc.insert(_kk(np.arange(1, 20)))       # 16 dispatch + 3 pending
    svc.drain()
    snap = svc.stats()
    assert snap["dispatches"] == svc.stats["dispatches"] == 2
    assert snap["pending_ops"] == 0
    assert snap["ready"]["count"] == 19     # every op's latency recorded
    assert snap["queue_wait"]["count"] == 19
    assert snap["ready"]["p99_s"] >= snap["ready"]["p50_s"] >= 0.0
    assert snap["backend"] == "cuckoo"
    assert snap["shape_ladder"] == [8, 16]
    assert 0.0 <= snap["padding_waste"] < 1.0


def test_ticket_timestamps_progress():
    clock = FakeClock()
    svc = _svc(batch_size=8, clock=clock)
    t = svc.insert(_kk([1, 2]))
    assert t.t_enqueue == 0.0 and t.t_dispatch is None and t.t_ready is None
    clock.advance(1.0)
    svc.flush()
    assert t.t_dispatch == 1.0 and t.t_ready is None
    clock.advance(1.0)
    t.result()
    assert t.t_ready is not None and t.t_ready >= t.t_dispatch >= t.t_enqueue


def test_sharded_service_ladder_respects_batch_align():
    svc = _svc(batch_size=64, backend="sharded-cuckoo")
    assert all(r % svc.handle.config.batch_align == 0
               for r in svc.shape_ladder)
    keys = _kk(np.arange(1, 6))             # 5 ops -> forced ladder dispatch
    assert svc.insert(keys).result().all()
    assert svc.query(keys).result().all()


def test_hot_swap_records_metrics_and_validates_align():
    svc = _svc(batch_size=64)
    svc.insert(_kk(np.arange(1, 40)))
    swap = svc.hot_swap(amq.make("cuckoo", config=svc.handle.config))
    assert svc.metrics.swaps and svc.metrics.swaps[0]["drained_ops"] == \
        swap["drained_ops"]
    assert svc.query(_kk(np.arange(1, 40))).result().all()


def test_hot_swap_refuses_incompatible_batch_align():
    svc = _svc(batch_size=64)

    class _Misaligned:
        name = "misaligned"
        batch_align = 7

    with pytest.raises(ValueError, match="batch_align"):
        svc.hot_swap(_Misaligned(), migrate=False)
    assert svc.handle.name == "cuckoo"      # swap refused before the drain
