"""Launch-layer tests: cost model, input specs, shardings, small-mesh dryrun."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch import hlo_cost as HC
from repro.launch.hlo_analysis import analytic_model_flops
from repro.launch.input_specs import SHAPES, SKIPS, input_specs, live_cells


def test_hlo_cost_scan_multiplier_exact():
    def f(x, w):
        def step(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(step, x, w)
        return y.sum()

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)).compile()
    r = HC.analyse_text(c.as_text(), 1)
    expect = 12 * (2 * 64**3)
    assert abs(r["flops"] - expect) / expect < 0.05


def test_hlo_cost_nested_scan():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return ci @ wi, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, w)
        return y.sum()

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)).compile()
    r = HC.analyse_text(c.as_text(), 1)
    expect = 5 * 3 * 2 * 32**3
    assert abs(r["flops"] - expect) / expect < 0.1


def test_live_cells_count():
    cells = list(live_cells())
    assert len(cells) == 4 * len(ARCHS) - len(SKIPS) == 35
    for skip in SKIPS:
        assert skip not in cells


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_no_allocation(arch):
    cfg = get_config(arch)
    for shape in SHAPES:
        if (arch, shape) in SKIPS:
            continue
        spec = input_specs(cfg, shape)
        leaves = jax.tree.leaves(
            {k: v for k, v in spec.items() if k.endswith("_spec")})
        assert leaves, (arch, shape)
        for leaf in leaves:
            assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, shape, leaf)


def test_analytic_model_flops_attention_grows_with_seq():
    cfg = get_config("gemma2_2b")
    po = 6 * cfg.param_count()
    r4k = analytic_model_flops(cfg, "train", 256, 4096) / (po * 256 * 4096)
    r32k = analytic_model_flops(cfg, "train", 32, 32768) / (po * 32 * 32768)
    assert r4k > 1.0  # attention adds on top of 6ND
    assert r32k > r4k  # and its share grows with context (global layers)


def test_param_shardings_divisibility_guards():
    """Every generated sharding must divide its dim (hubert's 504-vocab head
    and mamba's 3352-wide in_proj exercise the fallbacks)."""
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.launch.shardings import make_param_shardings
    from repro.models import build_model

    from repro.core import compat

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         **compat.auto_axis_types_kw(2))
    for arch in ("hubert_xlarge", "mamba2_130m", "mixtral_8x22b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        shape = jax.eval_shape(model.init, jax.random.key(0))
        sh = make_param_shardings(mesh, shape)
        assert jax.tree.structure(sh, is_leaf=lambda x: hasattr(x, "spec")) \
            .num_leaves > 0


@pytest.mark.slow
def test_dryrun_one_cell_subprocess():
    """Full 512-device lower+compile for one small cell in a subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_test")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2_130m", "--shape", "long_500k",
         "--multi-pod", "--out-dir", out],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "[ok]" in proc.stdout
