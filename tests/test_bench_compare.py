"""tools/bench_compare.py: diff mode, trend mode, and the CI exit codes.

The bench-smoke gate hangs off this tool's exit status, so the contract is
pinned end-to-end: 0 = clean, 1 = regressions (or removals with
``--fail-on-missing``), 2 = empty/missing inputs — and ``--warn-only``
flattens everything to 0. Trend mode (a baseline directory holding a run
history) must ratchet on ``--agg min``, tolerate outliers on ``median``,
and collapse to plain diff mode for a flat single-run directory.
"""

from __future__ import annotations

import importlib.util
import json
import pathlib

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "bench_compare", _ROOT / "tools" / "bench_compare.py")
bc = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bc)


def write_suite(dirpath: pathlib.Path, suite: str, rows: dict) -> None:
    dirpath.mkdir(parents=True, exist_ok=True)
    payload = {"suite": suite,
               "rows": [{"name": k, "us_per_call": v, "derived": ""}
                        for k, v in rows.items()]}
    (dirpath / f"BENCH_{suite}.json").write_text(json.dumps(payload))


# ---------------------------------------------------------------------------
# load_history / aggregate
# ---------------------------------------------------------------------------

def test_flat_dir_is_single_run(tmp_path):
    write_suite(tmp_path, "a", {"r": 10.0})
    runs = bc.load_history(tmp_path)
    assert len(runs) == 1
    assert runs[0] == {"a": {"r": 10.0}}


def test_history_orders_top_then_sorted_subdirs(tmp_path):
    write_suite(tmp_path, "a", {"r": 30.0})
    write_suite(tmp_path / "run-2026-02", "a", {"r": 20.0})
    write_suite(tmp_path / "run-2026-01", "a", {"r": 10.0})
    runs = bc.load_history(tmp_path)
    assert [r["a"]["r"] for r in runs] == [30.0, 10.0, 20.0]


def test_history_skips_empty_subdirs(tmp_path):
    write_suite(tmp_path / "run-1", "a", {"r": 5.0})
    (tmp_path / "empty").mkdir()
    assert len(bc.load_history(tmp_path)) == 1


def test_aggregate_min_is_best_ever():
    runs = [{"a": {"r": 30.0}}, {"a": {"r": 10.0}}, {"a": {"r": 20.0}}]
    assert bc.aggregate(runs, "min") == {"a": {"r": 10.0}}


def test_aggregate_median_tolerates_outlier():
    runs = [{"a": {"r": 30.0}}, {"a": {"r": 10.0}}, {"a": {"r": 20.0}}]
    assert bc.aggregate(runs, "median") == {"a": {"r": 20.0}}


def test_aggregate_last_is_newest_run_only():
    runs = [{"a": {"r": 30.0}, "b": {"x": 1.0}}, {"a": {"r": 20.0}}]
    # b disappeared from the newest run: "last" must not resurrect it.
    assert bc.aggregate(runs, "last") == {"a": {"r": 20.0}}


def test_aggregate_row_added_mid_history():
    runs = [{"a": {"old": 10.0}}, {"a": {"old": 12.0, "new": 7.0}}]
    agg = bc.aggregate(runs, "min")
    assert agg["a"] == {"old": 10.0, "new": 7.0}


def test_aggregate_rejects_unknown_agg():
    with pytest.raises(ValueError):
        bc.aggregate([{"a": {"r": 1.0}}], "mean")


# ---------------------------------------------------------------------------
# compare(): threshold edges, added/removed
# ---------------------------------------------------------------------------

def test_threshold_edge_exact_is_not_regression():
    base = {"a": {"r": 100.0}}
    new = {"a": {"r": 125.0}}   # exactly +25%: > is strict, so no flag
    _, regressions, _ = bc.compare(base, new, 0.25)
    assert regressions == []


def test_threshold_edge_just_past_is_regression():
    base = {"a": {"r": 100.0}}
    new = {"a": {"r": 125.1}}
    _, regressions, _ = bc.compare(base, new, 0.25)
    assert [(s, n) for s, n, _ in regressions] == [("a", "r")]


def test_added_rows_never_count():
    base = {"a": {"r": 100.0}}
    new = {"a": {"r": 100.0, "shiny": 1e9}, "b": {"x": 1e9}}
    _, regressions, removed = bc.compare(base, new, 0.25)
    assert regressions == [] and removed == []


def test_removed_rows_reported():
    base = {"a": {"r": 100.0, "gone": 5.0}, "z": {"x": 1.0}}
    new = {"a": {"r": 100.0}}
    _, regressions, removed = bc.compare(base, new, 0.25)
    assert regressions == []
    assert ("a", "gone") in removed and ("z", None) in removed


# ---------------------------------------------------------------------------
# main(): exit codes the CI gate hangs off
# ---------------------------------------------------------------------------

def _main(base, cand, *extra):
    return bc.main([str(base), str(cand), *extra])


def test_exit_0_clean(tmp_path):
    write_suite(tmp_path / "base", "a", {"r": 100.0})
    write_suite(tmp_path / "cand", "a", {"r": 101.0})
    assert _main(tmp_path / "base", tmp_path / "cand") == 0


def test_exit_1_on_regression(tmp_path):
    write_suite(tmp_path / "base", "a", {"r": 100.0})
    write_suite(tmp_path / "cand", "a", {"r": 300.0})
    assert _main(tmp_path / "base", tmp_path / "cand") == 1


def test_exit_2_on_missing_baseline(tmp_path):
    (tmp_path / "base").mkdir()
    write_suite(tmp_path / "cand", "a", {"r": 1.0})
    assert _main(tmp_path / "base", tmp_path / "cand") == 2


def test_exit_2_on_missing_candidate(tmp_path):
    write_suite(tmp_path / "base", "a", {"r": 1.0})
    (tmp_path / "cand").mkdir()
    assert _main(tmp_path / "base", tmp_path / "cand") == 2


def test_warn_only_flattens_everything_to_0(tmp_path):
    write_suite(tmp_path / "base", "a", {"r": 100.0})
    write_suite(tmp_path / "cand", "a", {"r": 900.0})
    assert _main(tmp_path / "base", tmp_path / "cand", "--warn-only") == 0
    (tmp_path / "empty").mkdir()
    assert _main(tmp_path / "empty", tmp_path / "cand", "--warn-only") == 0


def test_fail_on_missing_gates_removals(tmp_path):
    write_suite(tmp_path / "base", "a", {"r": 100.0, "gone": 1.0})
    write_suite(tmp_path / "cand", "a", {"r": 100.0})
    assert _main(tmp_path / "base", tmp_path / "cand") == 0
    assert _main(tmp_path / "base", tmp_path / "cand",
                 "--fail-on-missing") == 1


def test_suites_filter_unknown_name_exit_2(tmp_path):
    write_suite(tmp_path / "base", "a", {"r": 1.0})
    write_suite(tmp_path / "cand", "a", {"r": 1.0})
    assert _main(tmp_path / "base", tmp_path / "cand",
                 "--suites", "nope") == 2


# ---------------------------------------------------------------------------
# Trend mode through main(): the ratchet the CI gate runs
# ---------------------------------------------------------------------------

def _history(tmp_path):
    base = tmp_path / "base"
    write_suite(base, "a", {"r": 100.0})                 # oldest (flat)
    write_suite(base / "run-02", "a", {"r": 60.0})       # best ever
    write_suite(base / "run-03", "a", {"r": 90.0})       # newest
    return base


def test_trend_min_ratchets_on_best_run(tmp_path):
    base = _history(tmp_path)
    # 100us would pass vs the newest run (90us) but fails vs best-ever
    # 60us at threshold 0.5 (60 * 1.5 = 90 < 100): the ratchet.
    write_suite(tmp_path / "cand", "a", {"r": 100.0})
    assert _main(base, tmp_path / "cand", "--threshold", "0.5") == 1
    assert _main(base, tmp_path / "cand", "--threshold", "0.5",
                 "--agg", "last") == 0


def test_trend_median_tolerates_one_fast_outlier(tmp_path):
    base = _history(tmp_path)                            # median = 90us
    write_suite(tmp_path / "cand", "a", {"r": 100.0})
    assert _main(base, tmp_path / "cand", "--threshold", "0.5",
                 "--agg", "median") == 0


def test_trend_flat_dir_equals_diff_mode(tmp_path):
    # No subdirectories: every agg sees the same single run.
    write_suite(tmp_path / "base", "a", {"r": 100.0})
    write_suite(tmp_path / "cand", "a", {"r": 120.0})
    for agg in ("min", "median", "last"):
        assert _main(tmp_path / "base", tmp_path / "cand",
                     "--agg", agg) == 0


def test_trend_row_only_in_old_run_is_removed_coverage(tmp_path):
    base = tmp_path / "base"
    write_suite(base / "run-01", "a", {"r": 10.0, "legacy": 5.0})
    write_suite(base / "run-02", "a", {"r": 10.0})
    write_suite(tmp_path / "cand", "a", {"r": 10.0})
    # min-agg keeps the union, so legacy counts as lost coverage.
    assert _main(base, tmp_path / "cand", "--fail-on-missing") == 1
    # last-agg sees only run-02, where legacy was already gone.
    assert _main(base, tmp_path / "cand", "--fail-on-missing",
                 "--agg", "last") == 0
