"""Optimizer, checkpointing, fault-tolerant runner, data dedup, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CuckooConfig
from repro.data import DataConfig, DedupConfig, dedup_batch, make_batch, sequence_keys
from repro.models import build_model
from repro.train import (
    AdamWConfig,
    TrainingRunner,
    adamw_init,
    adamw_update,
    checkpoint,
    init_train_state,
    make_train_step,
    schedule,
)
from repro.train.optimizer import QTensor, _dequantize, _quantize


def small_setup(quantize=False, microbatches=1):
    cfg = get_config("mamba2_130m").reduced()
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50,
                          quantize_state=quantize)
    params, opt_state = init_train_state(model, opt_cfg, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt_cfg,
                                   microbatches=microbatches))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, batch=4, seq_len=32)
    return cfg, model, opt_cfg, params, opt_state, step, data_cfg


def test_loss_decreases_over_steps():
    _, _, _, params, opt_state, step, data_cfg = small_setup()
    losses = []
    for i in range(8):
        batch = make_batch(data_cfg, 0)  # same batch: loss must fall fast
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_quantized_optimizer_tracks_fp32():
    _, _, _, params, opt0, step_q, data_cfg = small_setup(quantize=True)
    _, _, _, _, opt1, step_f, _ = small_setup(quantize=False)
    p_q, p_f = params, params
    for i in range(5):
        batch = make_batch(data_cfg, i)
        p_q, opt0, mq = step_q(p_q, opt0, batch)
        p_f, opt1, mf = step_f(p_f, opt1, batch)
    # int8 state must not derail training: losses within 5%
    assert abs(float(mq["loss"]) - float(mf["loss"])) \
        < 0.05 * float(mf["loss"]) + 0.05


def test_quantize_roundtrip_error_small():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
    back = _dequantize(_quantize(x))
    # blockwise symmetric int8: |err| <= blockmax / 127 / 2 (+ rounding slop)
    bound = float(jnp.max(jnp.abs(x))) / 127 * 0.55
    assert float(jnp.max(jnp.abs(back - x))) < bound


def test_microbatch_accumulation_matches_full_batch():
    _, _, _, params, opt_state, step1, data_cfg = small_setup(microbatches=1)
    *_, opt_state2, step2, _ = small_setup(microbatches=2)
    batch = make_batch(data_cfg, 0)
    p1, o1, m1 = step1(params, opt_state, batch)
    p2, o2, m2 = step2(params, opt_state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)


def test_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(1e-4)


def test_checkpoint_roundtrip(tmp_path):
    _, _, _, params, opt_state, step, data_cfg = small_setup()
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 3, {"params": params, "opt": opt_state},
                    aux={"cursor": 3})
    got, step_no, aux = checkpoint.restore(
        d, {"params": params, "opt": opt_state})
    assert step_no == 3 and aux["cursor"] == 3
    for a, b in zip(jax.tree.leaves(got["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_runner_resumes_after_injected_failure(tmp_path):
    cfg, model, opt_cfg, params, opt_state, step, data_cfg = small_setup()
    d = str(tmp_path / "ckpt")
    runner = TrainingRunner(
        train_step=step, data_fn=lambda s: make_batch(data_cfg, s),
        ckpt_dir=d, ckpt_every=4, fail_at_step=9, keep=2)
    with pytest.raises(RuntimeError, match="injected failure"):
        runner.run(params, opt_state, num_steps=16, log_every=100)
    # restart: resume from step 8 and finish
    runner2 = TrainingRunner(
        train_step=step, data_fn=lambda s: make_batch(data_cfg, s),
        ckpt_dir=d, ckpt_every=4, keep=2)
    p2, o2, start = runner2.resume(params, opt_state)
    assert start == 8
    p2, o2, mon = runner2.run(p2, o2, num_steps=16, start_step=start,
                              log_every=100)
    assert checkpoint.latest_step(d) == 16


def test_dedup_masks_duplicates():
    data_cfg = DataConfig(vocab_size=1024, batch=16, seq_len=32,
                          duplicate_fraction=0.5)
    dcfg = DedupConfig(CuckooConfig.for_capacity(4096, hash_kind="fmix32"))
    state = dcfg.filter.init()
    batch = make_batch(data_cfg, 0)
    state, out, stats = jax.jit(
        lambda s, b: dedup_batch(dcfg, s, b))(state, batch)
    dup1 = int(stats["duplicates"])
    assert dup1 >= 1  # the injected duplicate pool collides in-batch
    # feeding the same batch again: everything is now a duplicate
    state, out2, stats2 = dedup_batch(dcfg, state, batch)
    assert int(stats2["duplicates"]) == data_cfg.batch
    assert not bool(out2["mask"].any())


def test_sequence_keys_order_sensitive():
    a = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    b = jnp.asarray([[4, 3, 2, 1]], jnp.int32)
    ka, kb = sequence_keys(a), sequence_keys(b)
    assert not bool(jnp.all(ka == kb))


def test_serve_engine_prefix_cache():
    cfg = get_config("qwen1_5_4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    from repro.serve import ServeEngine

    eng = ServeEngine(model, params, batch=2, max_len=48)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    out1, stats1 = eng.generate(prompts, steps=4)
    assert out1.shape == (2, 5)
    assert stats1["filtered"] >= 1  # first lookup was a definite negative
    out2, stats2 = eng.generate(prompts, steps=4)
    assert stats2["hits"] == 1      # second pass reuses the cached prefill
    np.testing.assert_array_equal(out1, out2)


def test_kmer_pipeline_roundtrip():
    from repro.data.kmer import canonicalize, kmer_keys, synthetic_genome

    bases = synthetic_genome(2048, seed=1)
    keys = kmer_keys(bases, k=31, canonical=False)
    assert keys.shape == (2048 - 30, 2)
    # python oracle for a few positions
    for i in (0, 100, 1000):
        want = 0
        for j in range(31):
            want = (want << 2) | int(bases[i + j])
        got = (int(keys[i, 1]) << 32) | int(keys[i, 0])
        assert got == want
    # canonicalization is an involution fixed point
    ck = canonicalize(keys, 31)
    ck2 = canonicalize(ck, 31)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(ck2))
