"""Bulk-build insertion fast path (DESIGN.md §6): equivalence with the
round-loop path, order restoration, duplicate semantics, sharded bulk=True."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CuckooConfig,
    CuckooFilter,
    insert,
    insert_bulk,
    keys_from_numpy,
    query,
)
from repro.core import delete as cf_delete
from repro.core import layout as L


def make_keys(rng, n):
    raw = rng.integers(0, 2**64, size=4 * n, dtype=np.uint64)
    return jnp.asarray(keys_from_numpy(np.unique(raw)[:n]))


CONFIGS = [
    CuckooConfig(num_buckets=256, fp_bits=16, bucket_size=16,
                 policy="xor", eviction="bfs", hash_kind="fmix32"),
    CuckooConfig(num_buckets=300, fp_bits=16, bucket_size=16,
                 policy="offset", eviction="bfs", hash_kind="fmix32"),
    CuckooConfig(num_buckets=512, fp_bits=8, bucket_size=8,
                 policy="xor", eviction="dfs", hash_kind="fmix32"),
]


@pytest.mark.parametrize("cfg", CONFIGS,
                         ids=lambda c: f"{c.policy}-f{c.fp_bits}b{c.bucket_size}")
def test_equivalent_to_insert_on_same_batch(cfg):
    """Same ok count as the round loop, identical query results, fewer rounds."""
    rng = np.random.default_rng(7)
    n = int(cfg.num_slots * 0.85)
    keys = make_keys(rng, n)

    s_loop, ok_loop, st_loop = insert(cfg, cfg.init(), keys)
    s_bulk, ok_bulk, st_bulk = insert_bulk(cfg, cfg.init(), keys)

    assert int(ok_loop.sum()) == int(ok_bulk.sum())
    assert int(s_bulk.count) == int(ok_bulk.sum())
    # identical query results on the batch (both fully succeed at this load)
    np.testing.assert_array_equal(
        np.asarray(query(cfg, s_loop, keys)),
        np.asarray(query(cfg, s_bulk, keys)))
    assert np.asarray(query(cfg, s_bulk, keys))[np.asarray(ok_bulk)].all()
    # the single up-front sort beats per-round claim sorting
    assert int(st_bulk.rounds) < int(st_loop.rounds)


def test_order_restoration_with_valid_mask():
    """ok must come back in original batch order despite the internal sorts:
    with all-valid keys succeeding at low load, ok == the valid pattern."""
    cfg = CONFIGS[0]
    rng = np.random.default_rng(11)
    keys = make_keys(rng, 512)
    valid = jnp.asarray(rng.random(512) < 0.6)
    state, ok, _ = insert_bulk(cfg, cfg.init(), keys, valid=valid)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(valid))
    assert int(state.count) == int(valid.sum())
    present = np.asarray(query(cfg, state, keys))
    assert present[np.asarray(valid)].all()


def test_bulk_insert_delete_roundtrip():
    cfg = CONFIGS[1]
    rng = np.random.default_rng(3)
    keys = make_keys(rng, int(cfg.num_slots * 0.8))
    state, ok, _ = insert_bulk(cfg, cfg.init(), keys)
    assert np.asarray(ok).all()
    state, del_ok = cf_delete(cfg, state, keys)
    assert np.asarray(del_ok).all()
    assert int(state.count) == 0
    assert not np.asarray(state.table).any()


def test_bulk_jit_and_wrapper():
    cfg = CONFIGS[0]
    jbulk = jax.jit(functools.partial(insert_bulk, cfg))
    keys = make_keys(np.random.default_rng(5), 256)
    state, ok, _ = jbulk(cfg.init(), keys)
    assert np.asarray(ok).all()
    f = CuckooFilter(cfg)
    ok2, _ = f.insert_bulk(keys)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(ok2))
    np.testing.assert_array_equal(np.asarray(state.table),
                                  np.asarray(f.state.table))


@pytest.mark.parametrize("fn", [insert, insert_bulk],
                         ids=["insert", "insert_bulk"])
def test_dedup_within_batch_roundtrip(fn):
    """Regression: duplicated batches under dedup are idempotent sets —
    insert -> delete -> query round-trips leave the filter empty."""
    cfg = CONFIGS[0]
    base = make_keys(np.random.default_rng(13), 32)
    dup = jnp.concatenate([base, base, base[:16]])       # 80 keys, 32 unique

    # multiset default: every copy inserted
    s_multi, ok_multi, _ = fn(cfg, cfg.init(), dup)
    assert np.asarray(ok_multi).all()
    assert int(s_multi.count) == 80

    # dedup: one copy per value; duplicates report the first copy's ok
    s_set, ok_set, _ = fn(cfg, cfg.init(), dup, dedup_within_batch=True)
    assert np.asarray(ok_set).all()
    assert int(s_set.count) == 32
    assert np.asarray(query(cfg, s_set, dup)).all()
    # one delete round per value empties the filter (no stranded copies)
    s_after, del_ok = cf_delete(cfg, s_set, base)
    assert np.asarray(del_ok).all()
    assert int(s_after.count) == 0
    assert not np.asarray(query(cfg, s_after, base)).any()


def test_dedup_respects_valid_mask():
    """A padding (invalid) copy must never become the representative."""
    cfg = CONFIGS[0]
    base = make_keys(np.random.default_rng(17), 8)
    dup = jnp.concatenate([base, base])
    valid = jnp.concatenate([jnp.zeros((8,), bool), jnp.ones((8,), bool)])
    state, ok, _ = insert_bulk(cfg, cfg.init(), dup, valid=valid,
                               dedup_within_batch=True)
    assert int(state.count) == 8
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(valid))


def test_bulk_residue_spills_to_eviction_loop():
    """At very high load phase 1+2 can't place everything; the residue must
    still land via the eviction loop.

    Pinned to ``insert_engine="legacy"``: the two-phase primary/alternate
    placement provably leaves a residue at this load, whereas the
    graph-orientation engine (the ``auto`` bulk route) may converge with no
    residue at all — its rounds stay at 2 by design.
    """
    cfg = CuckooConfig(num_buckets=64, fp_bits=16, bucket_size=16,
                       policy="xor", eviction="bfs", hash_kind="fmix32",
                       insert_engine="legacy")
    rng = np.random.default_rng(19)
    n = int(cfg.num_slots * 0.95)
    keys = make_keys(rng, n)
    state, ok, stats = insert_bulk(cfg, cfg.init(), keys)
    assert float(np.asarray(ok).mean()) > 0.98
    assert int(stats.rounds) > 2          # residue loop actually ran
    present = np.asarray(query(cfg, state, keys))
    assert present[np.asarray(ok)].all()


def test_sharded_bulk_single_device_mesh():
    """bulk=True through the all-to-all on a 1-device mesh matches plain."""
    from repro.core.sharded_filter import (
        ShardedCuckooConfig,
        ShardedCuckooFilter,
    )

    mesh = jax.make_mesh((1,), ("data",))
    cfg = ShardedCuckooConfig.for_capacity(
        2048, num_shards=1, fp_bits=16, bucket_size=16, hash_kind="fmix32")
    filt = ShardedCuckooFilter(cfg, mesh, local_batch=1024)
    rng = np.random.default_rng(23)
    keys = make_keys(rng, 1024)
    ok, routed = filt.insert(keys, bulk=True)
    assert np.asarray(routed).all()
    assert np.asarray(ok).all()
    q, _ = filt.query(keys)
    assert np.asarray(q).all()
    assert filt.total_count == 1024


# ---------------------------------------------------------------------------
# Segmented-scan helpers (core/layout.py).
# ---------------------------------------------------------------------------

def test_segment_ranks():
    ids = jnp.asarray([2, 2, 2, 5, 7, 7, 9], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(L.segment_ranks(ids)), [0, 1, 2, 0, 0, 1, 0])


def test_nth_free_slot():
    btags = jnp.asarray([[0, 3, 0, 0],     # free slots at 0, 2, 3
                         [1, 2, 3, 4],     # full
                         [0, 0, 0, 0]], jnp.uint32)
    rank = jnp.asarray([1, 0, 3], jnp.int32)
    placed, slot = L.nth_free_slot(btags, rank)
    np.testing.assert_array_equal(np.asarray(placed), [True, False, True])
    assert int(slot[0]) == 2              # rank 1 -> second free slot
    assert int(slot[2]) == 3
    placed2, _ = L.nth_free_slot(btags, jnp.asarray([3, 0, 0], jnp.int32))
    np.testing.assert_array_equal(np.asarray(placed2), [False, False, True])
