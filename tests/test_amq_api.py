"""Parametrized conformance suite for the unified AMQ protocol.

Every registered backend runs the same insert -> query -> delete -> FPR
scenario through ``amq.make``, cross-checked against the key universe the
pure-Python oracle (``cpu-cuckoo``) tracks, with capability-gated skips —
no backend gets a bespoke code path.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import amq
from repro.core import CuckooConfig, keys_from_numpy

CAPACITY = 2048
N_KEYS = 1200          # ~0.6 load: every backend should take all of these
N_NEG = 1 << 14


def _keys(seed, n, lo=0, hi=2**32):
    rng = np.random.default_rng(seed)
    raw = np.unique(rng.integers(lo, hi, size=3 * n, dtype=np.uint64))[:n]
    assert raw.shape[0] == n
    return raw, jnp.asarray(keys_from_numpy(raw))


def _np(x):
    return np.asarray(x)


@pytest.fixture(params=list(amq.names()))
def backend(request):
    return request.param


def test_registry_names_complete():
    got = set(amq.names())
    assert {"cuckoo", "bloom", "tcf", "gqf", "bcht",
            "sharded-cuckoo", "cpu-cuckoo"} <= got


def test_make_rejects_unknown_backend():
    with pytest.raises(KeyError, match="registered"):
        amq.make("no-such-filter", capacity=16)


def test_conformance_scenario(backend):
    """insert -> query(+) -> FPR(-) -> delete -> query(-) on every backend."""
    handle = amq.make(backend, capacity=CAPACITY)
    caps = handle.capabilities
    _, pos = _keys(0, N_KEYS)
    _, neg = _keys(1, N_NEG, lo=2**32, hi=2**64)

    # Config protocol surface.
    assert handle.config.num_slots > 0
    assert handle.config.table_bytes > 0
    assert 0.0 <= handle.expected_fpr(0.95) < 1.0

    # The sequential reference runs the same scenario as ground truth for
    # what a correct AMQ must achieve on these keys at this load.
    oracle = amq.make("cpu-cuckoo", capacity=CAPACITY, hash_kind="fmix32")
    oracle_ok = _np(oracle.insert(pos).ok)

    # Insert: well under capacity, everything must land and be routed.
    report = handle.insert(pos)
    ok = _np(report.ok)
    assert _np(report.routed).all()
    assert ok.mean() > 0.99, f"{backend}: insert ok ratio {ok.mean()}"
    assert ok.mean() >= oracle_ok.mean() - 0.01, \
        f"{backend}: admits fewer keys than the sequential reference"
    assert abs(handle.load_factor - ok.sum() / handle.config.num_slots) < 1e-6
    assert handle.count() == int(ok.sum())

    # No false negatives on any stored key.
    hits = _np(handle.query(pos).hits)
    assert hits[ok].all(), f"{backend}: false negative on stored key"

    # Bounded false positives vs the analytic model (exact => zero).
    fpr = float(_np(handle.query(neg).hits).mean())
    expected = handle.expected_fpr(handle.load_factor)
    _, hi = amq.fpr_tolerance(expected, N_NEG)
    if caps.exact:
        assert fpr == 0.0
    else:
        assert fpr <= hi, f"{backend}: fpr {fpr} vs expected {expected}"

    # Delete (capability-gated): removing every stored key empties the
    # structure up to the documented false-delete residue.
    if not caps.supports_delete:
        with pytest.raises(NotImplementedError):
            handle.delete(pos)
        return
    dreport = handle.delete(pos, valid=jnp.asarray(ok))
    dok = _np(dreport.ok)
    assert dok[ok].mean() > 0.99, f"{backend}: delete failed"
    residue = int(ok.sum()) - int(dok[ok].sum())
    assert handle.count() == residue
    # A full wipe leaves an empty structure: nothing can alias, so no key
    # may remain visible (TCF's documented false-delete residue excepted).
    if residue == 0:
        assert not _np(handle.query(pos).hits)[ok].any(), \
            f"{backend}: deleted keys still visible after full wipe"


def test_conformance_bulk_matches_insert(backend):
    """bulk=True stores the same membership set as the incremental path."""
    caps = amq.get(backend).capabilities
    if not caps.supports_bulk:
        handle = amq.make(backend, capacity=CAPACITY)
        _, pos = _keys(2, 64)
        with pytest.raises(NotImplementedError):
            handle.insert(pos, bulk=True)
        return
    _, pos = _keys(2, N_KEYS)
    a = amq.make(backend, capacity=CAPACITY)
    b = amq.make(backend, capacity=CAPACITY)
    ra = a.insert(pos)
    rb = b.insert(pos, bulk=True)
    assert _np(ra.ok).all() and _np(rb.ok).all()
    assert a.count() == b.count()
    assert _np(b.query(pos).hits).all()


def test_conformance_valid_mask(backend):
    """Masked (padding) keys must never enter any backend."""
    handle = amq.make(backend, capacity=CAPACITY)
    _, pos = _keys(3, 256)
    valid = jnp.arange(256) % 2 == 0
    report = handle.insert(pos, valid=valid)
    ok = _np(report.ok)
    assert not ok[~_np(valid)].any(), f"{backend}: masked key inserted"
    assert handle.count() == int(ok.sum()) <= 128
    hits = _np(handle.query(pos).hits)
    # Valid keys stored; masked keys absent (up to FPR aliasing on the
    # non-exact backends, which is why we also check the count above).
    assert hits[_np(valid) & ok].all()


def test_conformance_dedup_within_batch_capability(backend):
    """dedup_within_batch either dedups or raises NotImplementedError."""
    handle = amq.make(backend, capacity=CAPACITY)
    raw, one = _keys(4, 1)
    dup = jnp.tile(one, (8, 1))
    try:
        report = handle.insert(dup, dedup_within_batch=True)
    except NotImplementedError:
        return
    assert _np(report.ok).all()  # duplicates report the first copy's ok
    if handle.capabilities.counting:
        assert handle.count() == 1


def test_cuckoo_differential_vs_oracle():
    """Same config, same keys: the JAX backend and the Python oracle agree
    on the full membership universe (identical hash/tag/bucket derivation).
    """
    from repro.filters import PyCuckooConfig

    cfg = CuckooConfig(num_buckets=128, fp_bits=16, bucket_size=8,
                       policy="xor", eviction="dfs", hash_kind="fmix32")
    jf = amq.make("cuckoo", config=cfg)
    pf = amq.make("cpu-cuckoo", config=PyCuckooConfig(
        num_buckets=128, fp_bits=16, bucket_size=8, hash_kind="fmix32"))
    raw, keys = _keys(5, 512)
    ok_j = _np(jf.insert(keys).ok)
    ok_p = _np(pf.insert(keys).ok)
    if ok_j.all() and ok_p.all():
        probe_raw, probe = _keys(6, 2048)
        np.testing.assert_array_equal(_np(jf.query(probe).hits),
                                      _np(pf.query(probe).hits))


def test_sharded_routed_mask_and_agreement():
    """The sharded backend reports routed overflow instead of dropping keys,
    and agrees with an unsharded filter of the same per-shard config."""
    h = amq.make("sharded-cuckoo", capacity=4096, num_shards=1,
                 capacity_factor=2.0)
    _, pos = _keys(7, 1024)
    report = h.insert(pos)
    assert _np(report.routed).all()  # capacity_factor covers a 1-shard batch
    assert _np(report.ok).all()
    plain = amq.make("cuckoo", config=h.config.inner.shard)
    plain.insert(pos)
    _, probe = _keys(8, 4096)
    np.testing.assert_array_equal(_np(h.query(probe).hits),
                                  _np(plain.query(probe).hits))


def test_dedup_runs_on_every_backend(backend):
    """The dedup consumer is backend-generic: capability gates, no names."""
    from repro.data import dedup_batch, forget_keys, make_dedup, sequence_keys

    cfg, state = make_dedup(CAPACITY, backend=backend)
    tokens = jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (16, 1))
    tokens = tokens.at[8:].add(1)  # two distinct sequences, 8 copies each
    batch = {"tokens": tokens}
    state, out, stats = dedup_batch(cfg, state, batch)
    assert int(stats["duplicates"]) == 14
    assert int(out["mask"].sum()) == 2
    state, _, stats2 = dedup_batch(cfg, state, batch)
    assert int(stats2["duplicates"]) == 16  # all seen now
    keys = sequence_keys(tokens)
    if amq.get(backend).capabilities.supports_delete:
        forget_keys(cfg, state, keys)
    else:
        with pytest.raises(NotImplementedError):
            forget_keys(cfg, state, keys)


def test_prefix_cache_any_backend():
    """The serving consumer degrades by capability: stale counting on
    append-only backends, true deletion otherwise."""
    from repro.serve.prefix_cache import PrefixCache

    for backend, expect_stale in (("cuckoo", 0), ("bloom", 2)):
        pc = PrefixCache(2, backend=backend)
        for i in range(4):
            pc.insert([i, i + 1, i + 2], entry=f"e{i}")
        assert pc.stats["evictions"] == 2
        assert pc.stats["stale"] == expect_stale
        assert pc.lookup([3, 4, 5]) == "e3"
        assert pc.lookup([0, 1, 2]) is None


def test_protocol_reexports():
    from repro.core import Capabilities as C1, InsertReport as I1
    from repro.filters import Capabilities as C2, QueryResult as Q2
    from repro.amq import Capabilities as C3

    assert C1 is C2 is C3
    assert I1 is amq.InsertReport
    assert Q2 is amq.QueryResult
    # the registry is reachable from repro.filters too (the docstring's
    # promise made true)
    from repro import filters

    assert filters.make is amq.make
    assert set(filters.names()) == set(amq.names())
