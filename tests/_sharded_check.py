"""Subprocess worker: sharded-filter equivalence on an 8-device CPU mesh.

Run directly (tests/test_sharded_filter.py drives it):
    XLA flags are set before jax import — 8 host devices.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import CuckooConfig, CuckooFilter, keys_from_numpy  # noqa: E402
from repro.core.sharded_filter import (  # noqa: E402
    ShardedCuckooConfig,
    ShardedCuckooFilter,
    shard_of,
)


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((8,), ("data",))

    cfg = ShardedCuckooConfig.for_capacity(
        8 * 2048, num_shards=8, load_factor=0.9,
        fp_bits=16, bucket_size=16, hash_kind="fmix32", policy="xor")
    local_batch = 1024
    filt = ShardedCuckooFilter(cfg, mesh, local_batch)

    rng = np.random.default_rng(0)
    raw = np.unique(rng.integers(0, 2**64, size=20000, dtype=np.uint64))
    keys = jnp.asarray(keys_from_numpy(raw[: 8 * local_batch]))

    ok, routed = filt.insert(keys)
    ok, routed = np.asarray(ok), np.asarray(routed)
    assert routed.mean() > 0.95, f"too much overflow: {1 - routed.mean()}"
    assert ok[routed].mean() > 0.99, "insert failures at modest load"

    # retry unrouted keys (fixed-capacity overflow) — must eventually land
    retries = 0
    pending = keys[~routed]
    while pending.shape[0] and retries < 5:
        pad = (-pending.shape[0]) % (8 * local_batch)
        # pad by repeating (duplicates allowed; they just add copies)
        batch = jnp.concatenate(
            [pending, jnp.zeros((pad, 2), jnp.uint32)])[: 8 * local_batch]
        ok2, routed2 = filt.insert(batch)
        pending = batch[~np.asarray(routed2)]
        retries += 1
    assert pending.shape[0] == 0, "overflow keys never routed"

    # query everything — no false negatives across the mesh
    q, qrouted = filt.query(keys)
    q, qrouted = np.asarray(q), np.asarray(qrouted)
    assert qrouted[ok & routed].all()
    assert q[ok & routed].all(), "sharded false negative"

    # equivalence vs manually-routed single-device shards
    dest = np.asarray(shard_of(cfg, keys))
    single = [CuckooFilter(cfg.shard) for _ in range(8)]
    for s in range(8):
        sk = keys[dest == s]
        if sk.shape[0]:
            single[s].insert(sk)
    got = np.zeros(len(keys), bool)
    for s in range(8):
        m = dest == s
        if m.any():
            got[m] = np.asarray(single[s].query(keys[m]))
    # both views must agree on membership for keys inserted exactly once
    inserted_once = ok & routed
    assert (q[inserted_once] == got[inserted_once]).all() or \
        got[inserted_once].all()

    # exact K->K' resharding (DESIGN.md §10): 8 partitions over 8 devices
    # relocate onto 4-, 2-, and 1-device meshes with zero membership change.
    refill = jnp.asarray(keys_from_numpy(raw[: 8 * local_batch]))
    ok3, routed3 = filt.insert(refill)
    pre_q, pre_r = map(np.asarray, filt.query(refill))
    pre_table = np.asarray(filt.state.table)
    for k in (4, 2, 1):
        moved = filt.resharded(jax.make_mesh((k,), ("data",),
                                             devices=jax.devices()[:k]))
        assert moved.config.num_shards == k
        assert moved.config.partitions == 8
        np.testing.assert_array_equal(np.asarray(moved.state.table),
                                      pre_table)
        post_q, post_r = map(np.asarray, moved.query(refill))
        np.testing.assert_array_equal(post_q & post_r, pre_q & pre_r)
    print("RESHARD_OK 8->4->2->1 exact")

    # deletion across the mesh
    dok, drouted = filt.delete(keys)
    dok, drouted = np.asarray(dok), np.asarray(drouted)
    assert dok[inserted_once & drouted].mean() > 0.99
    print("SHARDED_OK total_count", filt.total_count)


if __name__ == "__main__":
    main()
