"""Per-kernel validation: shape/dtype sweeps vs the ref.py oracles.

All kernels are integer-exact, so comparisons are strict equality
(assert_allclose with rtol=0 == assert_array_equal for ints).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CuckooConfig, CuckooFilter, keys_from_numpy
from repro.core import bits64 as b64
from repro.filters.blocked_bloom import BloomConfig
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.kernels.bloom import bloom_insert_pallas, bloom_query_pallas
from repro.kernels.cuckoo_insert import (
    cuckoo_insert_bulk_pallas,
    cuckoo_insert_pallas,
)
from repro.kernels.cuckoo_query import cuckoo_query_pallas
from repro.kernels.hash64 import hash64_pallas
from repro.kernels.kmer_pack import kmer_pack_pallas


def rand_keys(rng, n):
    return jnp.asarray(keys_from_numpy(
        rng.integers(0, 2**64, size=n, dtype=np.uint64)))


CUCKOO_SWEEP = [
    # (num_buckets, fp_bits, bucket_size, policy, hash_kind, n, block)
    (64, 16, 16, "xor", "fmix32", 512, 128),
    (128, 8, 8, "xor", "fmix32", 1024, 256),
    (32, 32, 4, "xor", "xxhash64", 256, 64),
    (100, 16, 16, "offset", "fmix32", 512, 512),
    (256, 16, 32, "xor", "xxhash64", 1024, 512),
]


@pytest.mark.parametrize("nb,f,b,pol,hk,n,blk", CUCKOO_SWEEP)
def test_cuckoo_query_kernel_sweep(nb, f, b, pol, hk, n, blk):
    rng = np.random.default_rng(nb + f)
    cfg = CuckooConfig(num_buckets=nb, fp_bits=f, bucket_size=b,
                       policy=pol, hash_kind=hk)
    filt = CuckooFilter(cfg)
    keys = rand_keys(rng, n)
    ok, _ = filt.insert(keys[: n // 2])
    got = cuckoo_query_pallas(cfg, filt.state.table, keys[:, 0], keys[:, 1],
                              block_keys=blk)
    want = R.cuckoo_query_ref(cfg, filt.state.table, keys[:, 0], keys[:, 1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)
    # inserted keys must be hits — guaranteed only for failure-free batches
    # (failed inserts drop their carried victim fingerprint, paper Alg. 1)
    if np.asarray(ok).all():
        assert np.asarray(got)[: n // 2].all()


@pytest.mark.parametrize("nb,f,b,pol,hk,n,blk", CUCKOO_SWEEP)
def test_cuckoo_insert_kernel_sweep(nb, f, b, pol, hk, n, blk):
    rng = np.random.default_rng(nb * 7 + f)
    cfg = CuckooConfig(num_buckets=nb, fp_bits=f, bucket_size=b,
                       policy=pol, hash_kind=hk)
    table = cfg.layout.empty_table()
    keys = rand_keys(rng, n)
    t_got, ok_got = cuckoo_insert_pallas(cfg, table, keys[:, 0], keys[:, 1],
                                         block_keys=blk)
    t_want, ok_want = R.cuckoo_insert_ref(cfg, table, keys[:, 0], keys[:, 1])
    np.testing.assert_allclose(np.asarray(t_got), np.asarray(t_want), rtol=0)
    np.testing.assert_allclose(np.asarray(ok_got), np.asarray(ok_want), rtol=0)


@pytest.mark.parametrize("nb,f,b,pol,hk,n,blk", CUCKOO_SWEEP)
def test_cuckoo_insert_bulk_kernel_sweep(nb, f, b, pol, hk, n, blk):
    """Bucket-major kernel == sequential ref on the bucket-sorted stream."""
    from repro.core import prepare_keys

    rng = np.random.default_rng(nb * 13 + f)
    cfg = CuckooConfig(num_buckets=nb, fp_bits=f, bucket_size=b,
                       policy=pol, hash_kind=hk)
    table = cfg.layout.empty_table()
    keys = rand_keys(rng, n)
    _, i1, _ = prepare_keys(cfg, keys)
    ks = keys[jnp.argsort(i1.astype(jnp.int32), stable=True)]
    t_got, ok_got = cuckoo_insert_bulk_pallas(cfg, table, ks[:, 0], ks[:, 1],
                                              block_keys=blk)
    t_want, ok_want = R.cuckoo_insert_ref(cfg, table, ks[:, 0], ks[:, 1])
    np.testing.assert_array_equal(np.asarray(t_got), np.asarray(t_want))
    np.testing.assert_array_equal(np.asarray(ok_got), np.asarray(ok_want))


def test_cuckoo_insert_bulk_ops_wrapper():
    """ops.cuckoo_insert_bulk sorts, pads, and restores batch order."""
    cfg = CuckooConfig(num_buckets=128, fp_bits=16, bucket_size=16,
                       hash_kind="fmix32")
    rng = np.random.default_rng(2)
    keys = rand_keys(rng, 1000)  # not a block multiple
    state, ok = K.cuckoo_insert_bulk(cfg, cfg.init(), keys)
    assert ok.shape == (1000,)
    assert int(state.count) == int(np.asarray(ok).sum())
    got = K.cuckoo_query(cfg, state, keys)
    assert np.asarray(got)[np.asarray(ok)].all()


def test_cuckoo_insert_kernel_respects_valid_mask():
    cfg = CuckooConfig(num_buckets=64, fp_bits=16, bucket_size=16,
                       hash_kind="fmix32")
    table = cfg.layout.empty_table()
    rng = np.random.default_rng(0)
    keys = rand_keys(rng, 128)
    valid = jnp.asarray(([1] * 64) + ([0] * 64), jnp.uint32)
    t, ok = cuckoo_insert_pallas(cfg, table, keys[:, 0], keys[:, 1], valid,
                                 block_keys=64)
    assert np.asarray(ok)[:64].all() and not np.asarray(ok)[64:].any()
    # table must contain exactly the 64 valid keys' fingerprints
    t2, _ = R.cuckoo_insert_ref(cfg, table, keys[:64, 0], keys[:64, 1])
    np.testing.assert_array_equal(np.asarray(t), np.asarray(t2))


def test_cuckoo_ops_wrapper_pads_and_hybrid():
    """ops.cuckoo_insert_direct + core eviction fallback round-trip."""
    from repro.core.cuckoo_filter import insert as core_insert

    cfg = CuckooConfig(num_buckets=128, fp_bits=16, bucket_size=16,
                       hash_kind="fmix32")
    state = cfg.init()
    rng = np.random.default_rng(1)
    keys = rand_keys(rng, 1000)  # not a block multiple
    state, ok = K.cuckoo_insert_direct(cfg, state, keys)
    assert ok.shape == (1000,)
    # finish stragglers through the eviction-capable path
    rest = keys[~np.asarray(ok)]
    if rest.shape[0]:
        state, ok2, _ = core_insert(cfg, state, rest)
        assert np.asarray(ok2).all()
    got = K.cuckoo_query(cfg, state, keys)
    assert np.asarray(got).all()
    assert int(state.count) == 1000


BLOOM_SWEEP = [
    (64, 16, 8, 512, 128),
    (256, 8, 4, 1024, 256),
    (31, 16, 12, 256, 64),
]


@pytest.mark.parametrize("blocks,wpb,k,n,blk", BLOOM_SWEEP)
def test_bloom_kernels_sweep(blocks, wpb, k, n, blk):
    rng = np.random.default_rng(blocks)
    cfg = BloomConfig(num_blocks=blocks, words_per_block=wpb, k=k)
    table = cfg.init().table
    keys = rand_keys(rng, n)
    t_got = bloom_insert_pallas(cfg, table, keys[:, 0], keys[:, 1],
                                block_keys=blk)
    t_want = R.bloom_insert_ref(cfg, table, keys[:, 0], keys[:, 1])
    np.testing.assert_array_equal(np.asarray(t_got), np.asarray(t_want))
    q_got = bloom_query_pallas(cfg, t_got, keys[:, 0], keys[:, 1],
                               block_keys=blk)
    q_want = R.bloom_query_ref(cfg, t_want, keys[:, 0], keys[:, 1])
    np.testing.assert_array_equal(np.asarray(q_got), np.asarray(q_want))
    assert np.asarray(q_got).all()  # no false negatives


@pytest.mark.parametrize("n,blk,seed", [(2048, 2048, 0), (4096, 1024, 7)])
def test_hash64_kernel(n, blk, seed):
    rng = np.random.default_rng(n)
    keys = rand_keys(rng, n)
    hi_g, lo_g = hash64_pallas(keys[:, 0], keys[:, 1], seed=seed,
                               block_keys=blk)
    hi_w, lo_w = R.hash64_ref(keys[:, 0], keys[:, 1], seed=seed)
    np.testing.assert_array_equal(np.asarray(hi_g), np.asarray(hi_w))
    np.testing.assert_array_equal(np.asarray(lo_g), np.asarray(lo_w))


@pytest.mark.parametrize("n,k,blk", [(1024, 31, 256), (2048, 15, 512),
                                     (512, 7, 512)])
def test_kmer_pack_kernel(n, k, blk):
    rng = np.random.default_rng(k)
    bases = jnp.asarray(rng.integers(0, 4, size=n), jnp.uint32)
    hi_g, lo_g = kmer_pack_pallas(bases, k=k, block=blk)
    hi_w, lo_w = R.kmer_pack_ref(bases, k=k)
    m = n - k + 1
    np.testing.assert_array_equal(np.asarray(hi_g)[:m], np.asarray(hi_w)[:m])
    np.testing.assert_array_equal(np.asarray(lo_g)[:m], np.asarray(lo_w)[:m])
    # spot-check against python packing
    arr = np.asarray(bases)
    for i in [0, 5, m - 1]:
        want = 0
        for j in range(k):
            want = (want << 2) | int(arr[i + j])
        got = (int(hi_g[i]) << 32) | int(lo_g[i])
        assert got == want


def test_kmer_ops_wrapper_shapes():
    rng = np.random.default_rng(3)
    bases = jnp.asarray(rng.integers(0, 4, size=1000), jnp.uint32)
    keys = K.kmer_pack(bases, k=31, block=256)
    assert keys.shape == (1000 - 31 + 1, 2)
    assert keys.dtype == jnp.uint32
