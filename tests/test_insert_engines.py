"""High-load insertion engines vs the sequential oracle (DESIGN.md §14).

The graph-orientation bulk build and the batched BFS frontier search are
*routing* alternatives to the legacy eviction round loop — they may place
keys differently, but they must be semantics-free: every accepted key
queryable (zero false negatives), multiset duplicate semantics preserved,
delete round-trips exact, and zero failed inserts everywhere the legacy
oracle places everything, including the paper's ≥95%-load regime.

Differentials run on hypothesis-drawn key streams over the layout
dimensions that change the packed words under the engines — bucket size ×
``fp_bits`` × occupancy — with the legacy round loop (the pre-engine
committed path, kept reachable via ``insert_engine="legacy"`` exactly for
this) and ``kernels/ref.py``'s sequential direct-insert as oracles.
Example counts route through ``tests/_tuning.examples`` (CI caps them via
``REPRO_MAX_EXAMPLES``).
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised in the bare container
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from _tuning import examples

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CuckooConfig, CuckooFilter, keys_from_numpy
from repro.core import cuckoo_filter as CF
from repro.kernels import ref as R

NUM_BUCKETS = 64

# bucket_size x fp_bits x target occupancy. The 0.95+ cells are the
# tentpole's contract: zero failed inserts and zero false negatives at
# the paper's high-load regime, for every engine.
CELLS = [
    (4, 8, 0.50),
    (4, 16, 0.95),
    (8, 16, 0.75),
    (8, 8, 0.95),
    (16, 16, 0.95),
    (16, 8, 0.97),
]

ENGINES = ("legacy", "frontier", "orientation")


def _cfg(bucket_size, fp_bits, engine="auto", policy="xor", eviction="bfs"):
    return CuckooConfig(
        num_buckets=NUM_BUCKETS, fp_bits=fp_bits, bucket_size=bucket_size,
        policy=policy, eviction=eviction, hash_kind="fmix32",
        max_evictions=256, insert_engine=engine)


def _keys(seed: int, n: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    raw = rng.integers(0, 2**64, size=4 * n, dtype=np.uint64)
    return jnp.asarray(keys_from_numpy(np.unique(raw)[:n]))


# Module-level jitted entry points: static config means jax caches one
# compilation per (config, shape) across all hypothesis examples — a
# fresh jax.jit per call would recompile the while-loop-heavy engines
# on every example and blow the tier-1 time budget.
_JIT_INSERT = jax.jit(CF.insert, static_argnums=0,
                      static_argnames=("dedup_within_batch",))
_JIT_BULK = jax.jit(CF.insert_bulk, static_argnums=0,
                    static_argnames=("dedup_within_batch",))


def _run(cfg, keys, bulk):
    entry = _JIT_BULK if bulk else _JIT_INSERT
    state, ok, stats = entry(cfg, cfg.init(), keys)
    return state, np.asarray(ok), stats


# ---------------------------------------------------------------------------
# Differential: orientation + frontier vs the sequential oracles.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", CELLS,
                         ids=lambda c: f"b{c[0]}f{c[1]}o{int(c[2] * 100)}")
@settings(max_examples=examples(10), deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_engines_match_oracle_across_cells(cell, seed):
    """Orientation (bulk) and frontier (incremental) vs the legacy loop.

    Wherever the oracle places the whole batch, the new engines must too
    (zero failed inserts, ``stats.failed == 0``), every accepted key must
    be queryable (zero false negatives), and the committed count must
    equal the accepted count.
    """
    b, fb, occ = cell
    n = int(NUM_BUCKETS * b * occ)
    keys = _keys(seed, n)

    _, ok_oracle, _ = _run(_cfg(b, fb, "legacy"), keys, bulk=False)

    for engine, bulk in (("frontier", False), ("orientation", True)):
        cfg = _cfg(b, fb, engine)
        state, ok, stats = _run(cfg, keys, bulk)
        assert int(state.count) == int(ok.sum())
        assert int(np.asarray(stats.failed)) == int((~ok).sum())
        # zero false negatives over everything the engine accepted
        hit = np.asarray(CF.query(cfg, state, keys))
        assert hit[ok].all(), f"{engine}: accepted key not queryable"
        if ok_oracle.all():
            assert ok.all(), (
                f"{engine} failed {int((~ok).sum())}/{n} keys the legacy "
                f"oracle placed (cell {cell})")


@settings(max_examples=examples(10), deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_direct_placements_agree_with_ref_oracle(seed):
    """At direct-insert loads the engines and kernels/ref.py agree exactly:
    everything the sequential no-eviction oracle places, every engine
    places too, and the resulting filters answer queries identically."""
    cfg = _cfg(8, 16)
    n = NUM_BUCKETS * 8 // 4                     # 25% load: no evictions
    keys = _keys(seed, n)
    _, ok_ref = R.cuckoo_insert_ref(
        cfg, cfg.init().table, keys[:, 0], keys[:, 1])
    assert np.asarray(ok_ref).all()
    probes = _keys(seed + 1, n)
    answers = []
    for engine, bulk in (("legacy", False), ("frontier", False),
                         ("orientation", True)):
        state, ok, _ = _run(_cfg(8, 16, engine), keys, bulk)
        assert ok.all()
        answers.append(np.asarray(CF.query(cfg, state, probes)))
    for got in answers[1:]:
        np.testing.assert_array_equal(got, answers[0])


# ---------------------------------------------------------------------------
# Routing is semantics-free.
# ---------------------------------------------------------------------------

@settings(max_examples=examples(10), deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dedup=st.booleans())
def test_routing_is_semantics_free(seed, dedup):
    """Same batch (duplicates + a valid mask) through every engine: the
    per-key ok vector, the committed count, and the delete round-trip are
    identical — the engine is an implementation detail, not a semantic."""
    base = _keys(seed, 96)
    dup = jnp.concatenate([base, base[:32]])     # 128 keys, 96 unique
    rng = np.random.default_rng(seed)
    valid = jnp.asarray(rng.random(128) < 0.8)

    results = {}
    for engine in ENGINES:
        for bulk in (False, True):
            cfg = _cfg(16, 16, engine)
            entry = _JIT_BULK if bulk else _JIT_INSERT
            state, ok, stats = entry(cfg, cfg.init(), dup, valid=valid,
                                     dedup_within_batch=dedup)
            ok = np.asarray(ok)
            results[(engine, bulk)] = ok
            if dedup:
                # one stored copy per value: delete via the unique keys,
                # marking each value that had any accepted copy
                stored = ok[:96].copy()
                stored[:32] |= ok[96:]
                del_keys, del_valid = base, jnp.asarray(stored)
                assert int(state.count) == int(stored.sum())
            else:
                # multiset: every accepted copy is its own deletion
                del_keys, del_valid = dup, jnp.asarray(ok)
                assert int(state.count) == int(ok.sum())
            del_state, del_ok = CF.delete(cfg, state, del_keys,
                                          valid=del_valid)
            # invalid lanes report False by convention; every *requested*
            # deletion must land
            assert np.asarray(del_ok)[np.asarray(del_valid)].all()
            assert int(del_state.count) == 0
            assert not np.asarray(del_state.table).any()
    ref = results[("legacy", False)]
    for key, got in results.items():
        np.testing.assert_array_equal(got, ref, err_msg=str(key))


def test_resolve_engine_routing():
    """auto → orientation for bulk; frontier iff eviction="bfs" else
    legacy; explicit names force; unknown names raise."""
    assert CF.resolve_engine(_cfg(8, 16, "auto"), bulk=True) == "orientation"
    assert CF.resolve_engine(_cfg(8, 16, "auto"), bulk=False) == "frontier"
    dfs = _cfg(8, 16, "auto", eviction="dfs")
    assert CF.resolve_engine(dfs, bulk=False) == "legacy"
    for engine in ENGINES:
        assert CF.resolve_engine(_cfg(8, 16, engine), bulk=True) == engine
        assert CF.resolve_engine(_cfg(8, 16, engine), bulk=False) == engine
    with pytest.raises(ValueError, match="unknown insert_engine"):
        CF.resolve_engine(_cfg(8, 16, "dfs"), bulk=False)


# ---------------------------------------------------------------------------
# The loud failure report (the silent max_rounds fix).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,bulk", [("legacy", False),
                                         ("frontier", False),
                                         ("orientation", True)],
                         ids=["legacy", "frontier", "orientation"])
def test_overload_reports_failed_count_and_load(engine, bulk):
    """Driving any engine past capacity must surface a nonzero
    ``stats.failed`` and the end-of-batch load factor — not silently
    report unplaced keys as per-key False and nothing else."""
    cfg = _cfg(4, 16, engine)
    n = 2 * cfg.num_slots                        # 2x capacity: must fail
    keys = _keys(5, n)
    state, ok, stats = _run(cfg, keys, bulk)
    assert not ok.all()
    assert int(np.asarray(stats.failed)) == int((~ok).sum()) > 0
    load = float(np.asarray(stats.load))
    assert load == pytest.approx(int(state.count) / cfg.num_slots)
    assert load > 0.9


def test_wrapper_warns_on_unplaced_keys():
    """The OO wrapper turns a nonzero failure report into a RuntimeWarning
    naming the count and load factor (it cannot raise under jit)."""
    cfg = _cfg(4, 16)
    filt = CuckooFilter(cfg)
    keys = _keys(7, 2 * cfg.num_slots)
    with pytest.warns(RuntimeWarning, match=r"unplaced at load factor"):
        filt.insert(keys)


def test_no_warning_when_everything_lands():
    cfg = _cfg(16, 16)
    filt = CuckooFilter(cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        ok, stats = filt.insert(_keys(9, cfg.num_slots // 2))
    assert np.asarray(ok).all()
    assert int(np.asarray(stats.failed)) == 0
