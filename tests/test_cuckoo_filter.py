"""Behavioural invariants of the batch Cuckoo filter (paper Algs. 1-3)."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade to fixed-seed example tests
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from _tuning import examples

from repro.core import (
    CuckooConfig,
    CuckooFilter,
    keys_from_numpy,
    prepare_keys,
)


def make_keys(rng, n):
    raw = rng.integers(0, 2**64, size=n, dtype=np.uint64)
    return jnp.asarray(keys_from_numpy(np.unique(raw)[:n]))


def signatures(cfg, keys):
    """(fp, frozenset{i1,i2}) per key — identifies indistinguishable keys."""
    tag, i1, i2 = prepare_keys(cfg, keys)
    tag, i1, i2 = np.asarray(tag), np.asarray(i1), np.asarray(i2)
    return [(int(t), frozenset((int(a), int(b)))) for t, a, b in zip(tag, i1, i2)]


CONFIGS = [
    CuckooConfig(num_buckets=256, fp_bits=16, bucket_size=16,
                 policy="xor", eviction="bfs", hash_kind="fmix32"),
    CuckooConfig(num_buckets=256, fp_bits=16, bucket_size=16,
                 policy="xor", eviction="dfs", hash_kind="fmix32"),
    CuckooConfig(num_buckets=300, fp_bits=16, bucket_size=16,
                 policy="offset", eviction="bfs", hash_kind="fmix32"),
    CuckooConfig(num_buckets=300, fp_bits=16, bucket_size=16,
                 policy="offset", eviction="dfs", hash_kind="fmix32"),
    CuckooConfig(num_buckets=512, fp_bits=8, bucket_size=8,
                 policy="xor", eviction="bfs", hash_kind="fmix32"),
    CuckooConfig(num_buckets=128, fp_bits=32, bucket_size=4,
                 policy="xor", eviction="dfs", hash_kind="xxhash64"),
]


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: f"{c.policy}-{c.eviction}-f{c.fp_bits}b{c.bucket_size}")
def test_no_false_negatives_at_high_load(cfg):
    rng = np.random.default_rng(42)
    f = CuckooFilter(cfg)
    n = int(cfg.num_slots * 0.9)
    keys = make_keys(rng, n)
    ok, _ = f.insert(keys)
    ok = np.asarray(ok)
    assert ok.mean() > 0.98, f"too many failures: {1 - ok.mean():.3f}"
    present = np.asarray(f.query(keys))
    assert present[ok].all(), "false negative for successfully inserted key"
    assert int(f.state.count) == int(ok.sum())


@pytest.mark.parametrize("cfg", CONFIGS[:4], ids=lambda c: f"{c.policy}-{c.eviction}")
def test_delete_restores_empty(cfg):
    rng = np.random.default_rng(1)
    f = CuckooFilter(cfg)
    keys = make_keys(rng, int(cfg.num_slots * 0.8))
    ok, _ = f.insert(keys)
    ok = np.asarray(ok)
    del_ok = np.asarray(f.delete(keys[ok]))
    assert del_ok.all()
    assert int(f.state.count) == 0
    assert not np.asarray(f.state.table).any(), "table not empty after delete-all"


def test_failed_delete_reports_false():
    cfg = CONFIGS[0]
    f = CuckooFilter(cfg)
    rng = np.random.default_rng(2)
    keys = make_keys(rng, 64)
    f.insert(keys[:32])
    # Deleting never-inserted keys must fail (up to fp collisions, rare here).
    ok = np.asarray(f.delete(keys[32:]))
    assert ok.mean() < 0.2
    assert int(f.state.count) >= 32 - int(ok.sum())


def test_duplicate_inserts_accumulate_copies():
    cfg = CONFIGS[0]
    f = CuckooFilter(cfg)
    key = make_keys(np.random.default_rng(3), 1)
    dup = jnp.tile(key, (5, 1))
    ok, _ = f.insert(dup)
    assert np.asarray(ok).all()
    assert int(f.state.count) == 5
    # five deletes succeed, the sixth fails
    ok = np.asarray(f.delete(jnp.tile(key, (6, 1))))
    assert ok.sum() == 5
    assert int(f.state.count) == 0


def test_overload_reports_failures():
    cfg = CuckooConfig(num_buckets=8, fp_bits=16, bucket_size=4,
                       policy="xor", eviction="dfs", hash_kind="fmix32",
                       max_evictions=16)
    f = CuckooFilter(cfg)
    rng = np.random.default_rng(4)
    keys = make_keys(rng, cfg.num_slots * 2)  # 2x capacity
    ok, _ = f.insert(keys)
    ok = np.asarray(ok)
    assert not ok.all(), "must fail beyond capacity"
    assert int(f.state.count) == int(ok.sum())
    assert int(f.state.count) <= cfg.num_slots
    # NOTE: after a failed insert the carried victim fingerprint is dropped
    # (paper Alg. 1 "caller will have to rebuild"), so earlier successful
    # keys may have lost their copy — the strict no-false-negative guarantee
    # only holds for failure-free batches (covered by the high-load test).
    # At 2x overload with a saturated table we only smoke-check that a
    # meaningful fraction survived.
    present = np.asarray(f.query(keys))
    assert present[ok].mean() > 0.25


def test_bfs_bounds_eviction_chains_vs_dfs():
    """Paper Fig. 5: BFS suppresses tail eviction-chain lengths."""
    rng = np.random.default_rng(5)
    tails = {}
    for evic in ("bfs", "dfs"):
        cfg = CuckooConfig(num_buckets=1024, fp_bits=16, bucket_size=16,
                           policy="xor", eviction=evic, hash_kind="fmix32",
                           max_evictions=256)
        f = CuckooFilter(cfg)
        n = int(cfg.num_slots * 0.96)
        keys = make_keys(rng, n)
        # pre-fill 3/4 then measure the contended final quarter (paper §5.4.1)
        ok1, _ = f.insert(keys[: 3 * n // 4])
        ok2, stats = f.insert(keys[3 * n // 4:])
        ev = np.asarray(stats.evictions)
        tails[evic] = np.percentile(ev, 99)
        assert np.asarray(ok1).mean() > 0.95
        assert np.asarray(ok2).mean() > 0.9
    assert tails["bfs"] <= tails["dfs"], (
        f"BFS p99 evictions {tails['bfs']} should not exceed DFS {tails['dfs']}")


def test_fpr_tracks_equation4():
    """Paper Eq. (4) within loose statistical bounds."""
    cfg = CuckooConfig(num_buckets=1 << 12, fp_bits=8, bucket_size=4,
                       policy="xor", eviction="bfs", hash_kind="fmix32")
    f = CuckooFilter(cfg)
    rng = np.random.default_rng(6)
    n = int(cfg.num_slots * 0.95)
    keys = rng.integers(0, 2**32, size=n, dtype=np.uint64)
    ok, _ = f.insert(jnp.asarray(keys_from_numpy(keys)))
    load = int(f.state.count) / cfg.num_slots
    neg = rng.integers(2**32, 2**64, size=1 << 16, dtype=np.uint64)
    fpr = float(np.asarray(f.query(jnp.asarray(keys_from_numpy(neg)))).mean())
    expected = cfg.expected_fpr(load)
    assert 0.3 * expected < fpr < 3.0 * expected, (fpr, expected)


@settings(max_examples=examples(20), deadline=None)
@given(seed=st.integers(0, 2**31 - 1), data=st.data())
def test_property_random_op_sequences(seed, data):
    """Model-based: filter agrees with a multiset model on collision-free keys."""
    cfg = CuckooConfig(num_buckets=64, fp_bits=16, bucket_size=8,
                       policy="xor", eviction="bfs", hash_kind="fmix32")
    rng = np.random.default_rng(seed)
    universe = make_keys(rng, 128)
    sigs = signatures(cfg, universe)
    # keys with unique signatures -> filter behaves exactly like a multiset
    uniq = [i for i, s in enumerate(sigs) if sigs.count(s) == 1]
    f = CuckooFilter(cfg)
    live = set()
    for _ in range(data.draw(st.integers(1, 6))):
        op = data.draw(st.sampled_from(["insert", "delete", "query"]))
        idx = data.draw(st.lists(st.sampled_from(uniq), min_size=1,
                                 max_size=16, unique=True))
        batch = universe[np.asarray(idx)]
        if op == "insert":
            ok, _ = f.insert(batch)
            for i, o in zip(idx, np.asarray(ok)):
                if o and i not in live:
                    live.add(i)
        elif op == "delete":
            ok = f.delete(batch)
            for i, o in zip(idx, np.asarray(ok)):
                assert bool(o) == (i in live)
                live.discard(i)
        else:
            got = np.asarray(f.query(batch))
            for i, g in zip(idx, got):
                if i in live:
                    assert g, "false negative in op sequence"


def test_for_capacity_sizing():
    cfg = CuckooConfig.for_capacity(10_000, load_factor=0.95, policy="xor")
    assert cfg.num_buckets & (cfg.num_buckets - 1) == 0
    assert cfg.num_slots * 0.95 >= 10_000
    cfg2 = CuckooConfig.for_capacity(10_000, load_factor=0.95, policy="offset")
    assert cfg2.num_slots < cfg.num_slots  # no power-of-two over-provisioning
    assert cfg2.num_slots * 0.95 >= 10_000


def test_expected_fpr_monotonic():
    cfg8 = CuckooConfig(num_buckets=64, fp_bits=8, bucket_size=16)
    cfg16 = CuckooConfig(num_buckets=64, fp_bits=16, bucket_size=16)
    assert cfg8.expected_fpr(0.95) > cfg16.expected_fpr(0.95)
    cfg_b4 = CuckooConfig(num_buckets=64, fp_bits=16, bucket_size=4)
    assert cfg_b4.expected_fpr(0.95) < cfg16.expected_fpr(0.95)
