"""Differential tests for mixed-op execution (DESIGN.md §9).

Every registry backend replays random interleaved QUERY/INSERT/DELETE
streams through ``apply_ops`` — the native fused path where the backend has
one, and the segmented fallback explicitly for every backend — and must
match a *per-op sequential oracle*: the same backend executing the same
ops one at a time through its per-op entry points. Same-key interleavings
are provoked by drawing keys from a tiny universe.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised in the bare container
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from _tuning import examples

import jax.numpy as jnp
import numpy as np
import pytest

from repro import amq
from repro.amq.adapters import segmented_apply_ops
from repro.amq.protocol import (
    OP_DELETE,
    OP_INSERT,
    OP_QUERY,
    MixedReport,
    OpBatch,
)
from repro.core import keys_from_numpy

CAPACITY = 2048
N_OPS = 48
UNIVERSE = 8          # tiny key universe -> dense same-key interleavings


def _np(x):
    return np.asarray(x)


def _keys_for(seed: int, picks) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    uni = rng.integers(1, 2**63, size=UNIVERSE, dtype=np.uint64)
    return jnp.asarray(keys_from_numpy(uni[np.asarray(picks) % UNIVERSE]))


_HANDLES = {}


def _make(backend: str):
    """One cached handle per backend, state reset per use (keeps every
    per-op jit compiled exactly once across the whole module)."""
    if backend not in _HANDLES:
        kw = {"num_shards": 1} if backend == "sharded-cuckoo" else {}
        _HANDLES[backend] = amq.make(backend, capacity=CAPACITY, **kw)
    handle = _HANDLES[backend]
    handle.state = handle.adapter.init(handle.config)
    return handle


def _sequential_oracle(backend: str, batch: OpBatch) -> np.ndarray:
    """Replay the batch one op at a time through per-op entry points."""
    handle = _make(backend)
    ops = _np(batch.ops)
    v = _np(batch.valid)
    ok = np.zeros((batch.size,), bool)
    for i in range(batch.size):
        if not v[i]:
            continue
        k1 = batch.keys[i:i + 1]
        if ops[i] == OP_QUERY:
            ok[i] = bool(_np(handle.query(k1).hits)[0])
        elif ops[i] == OP_INSERT:
            ok[i] = bool(_np(handle.insert(k1).ok)[0])
        else:
            ok[i] = bool(_np(handle.delete(k1).ok)[0])
    return ok, handle.count()


def _ops_strategy(with_deletes: bool):
    codes = [OP_QUERY, OP_INSERT] + ([OP_DELETE] if with_deletes else [])
    return st.lists(st.sampled_from(codes), min_size=N_OPS, max_size=N_OPS)


@pytest.fixture(params=list(amq.names()))
def backend(request):
    return request.param


@pytest.fixture(params=["native", "segmented"])
def path(request):
    return request.param


def _apply(backend: str, path: str, batch: OpBatch) -> MixedReport:
    handle = _make(backend)
    if path == "segmented":
        report = segmented_apply_ops(handle, batch)
    else:
        report = handle.apply_ops(batch)   # native where supported
    return report, handle.count()


@settings(max_examples=examples(8), deadline=None)
@given(data=st.data())
def test_mixed_matches_sequential_oracle(backend, path, data):
    """apply_ops == one-op-at-a-time replay, per backend, both paths."""
    caps = amq.get(backend).capabilities
    ops = np.asarray(data.draw(_ops_strategy(caps.supports_delete)),
                     np.int32)
    picks = data.draw(st.lists(st.integers(0, UNIVERSE - 1),
                               min_size=N_OPS, max_size=N_OPS))
    seed = data.draw(st.integers(0, 2**16))
    keys = _keys_for(seed, picks)
    batch = OpBatch.make(keys, ops)

    ok_seq, count_seq = _sequential_oracle(backend, batch)
    report, count = _apply(backend, path, batch)
    np.testing.assert_array_equal(
        _np(report.ok), ok_seq,
        err_msg=f"{backend}/{path}: mixed != sequential oracle")
    assert _np(report.routed).all()
    assert count == count_seq, f"{backend}/{path}: count drift"


def test_mixed_valid_mask(backend):
    """Padding slots never touch the structure and never report ok."""
    rng = np.random.default_rng(0)
    ops = rng.integers(0, 2, size=N_OPS).astype(np.int32)  # query/insert
    keys = _keys_for(1, rng.integers(0, UNIVERSE, size=N_OPS))
    valid = jnp.arange(N_OPS) % 2 == 0
    handle = _make(backend)
    report = handle.apply_ops(OpBatch(keys, jnp.asarray(ops), valid))
    assert not _np(report.ok)[~_np(valid)].any()
    assert handle.count() == int(
        (_np(report.ok) & (ops == OP_INSERT) & _np(valid)).sum())


def test_mixed_delete_capability_gated(backend):
    """Batches with deletes raise on append-only backends, on every path."""
    caps = amq.get(backend).capabilities
    if caps.supports_delete:
        pytest.skip("delete-capable backend")
    keys = _keys_for(2, range(N_OPS))
    ops = jnp.full((N_OPS,), OP_DELETE, jnp.int32)
    with pytest.raises(NotImplementedError):
        _make(backend).apply_ops(OpBatch.make(keys, ops))


def test_mixed_report_subviews():
    """Per-op sub-reports carry op-masked routed views."""
    handle = _make("cuckoo")
    keys = _keys_for(3, range(12))
    ops = jnp.asarray([OP_INSERT] * 4 + [OP_QUERY] * 4 + [OP_DELETE] * 4,
                      jnp.int32)
    batch = OpBatch.make(keys, ops)
    report = handle.apply_ops(batch)
    ir = report.insert_report(batch)
    qr = report.query_result(batch)
    dr = report.delete_report(batch)
    np.testing.assert_array_equal(_np(ir.routed),
                                  _np(batch.ops) == OP_INSERT)
    np.testing.assert_array_equal(_np(qr.routed),
                                  _np(batch.ops) == OP_QUERY)
    np.testing.assert_array_equal(_np(dr.routed),
                                  _np(batch.ops) == OP_DELETE)
    # The three views tile the batch: ok decomposes exactly.
    recombined = (_np(ir.ok) | _np(qr.hits) | _np(dr.ok))
    np.testing.assert_array_equal(recombined, _np(report.ok))


def test_segmented_all_padding_batch_is_noop():
    """A fully padded batch (forced flush) reports all-False, no crash."""
    keys = _keys_for(7, range(8))
    batch = OpBatch(keys, jnp.full((8,), OP_DELETE, jnp.int32),
                    jnp.zeros((8,), bool))
    for backend in ("bloom", "cuckoo"):   # fallback + native paths
        handle = _make(backend)
        report = handle.apply_ops(batch)
        assert not _np(report.ok).any()
        assert handle.count() == 0


def test_opbatch_pad_to():
    keys = _keys_for(4, range(5))
    batch = OpBatch.make(keys, [OP_INSERT] * 5).pad_to(8)
    assert batch.size == 8
    assert not _np(batch.valid)[5:].any()
    report = _make("cuckoo").apply_ops(batch)
    assert _np(report.ok)[:5].all() and not _np(report.ok)[5:].any()
    with pytest.raises(ValueError, match="pad"):
        batch.pad_to(4)


def test_cascade_mixed_grows_past_capacity():
    """Cascade apply_ops keeps absorbing inserts past level capacity."""
    h = amq.make("cuckoo", capacity=256, auto_expand=True)
    rng = np.random.default_rng(5)
    raw = np.unique(rng.integers(1, 2**63, size=4096, dtype=np.uint64))[:1024]
    keys = jnp.asarray(keys_from_numpy(raw))
    ops = jnp.full((1024,), OP_INSERT, jnp.int32)
    report = h.apply_ops(OpBatch.make(keys, ops))
    assert _np(report.ok).all()       # growth, never refusal
    assert len(h.levels) > 1
    hits = h.apply_ops(OpBatch.make(keys, jnp.full((1024,), OP_QUERY,
                                                   jnp.int32)))
    assert _np(hits.ok).all()         # no false negatives across levels


def test_kernel_mixed_matches_core():
    """The Pallas mixed kernel (interpret mode) matches the fused core op."""
    from repro.core import CuckooConfig
    from repro.kernels.ops import cuckoo_apply_ops

    cfg = CuckooConfig.for_capacity(512, hash_kind="fmix32")
    rng = np.random.default_rng(6)
    uni = rng.integers(1, 2**63, size=UNIVERSE, dtype=np.uint64)
    raw = uni[rng.integers(0, UNIVERSE, size=96)]
    keys = jnp.asarray(keys_from_numpy(raw))
    ops = jnp.asarray(rng.integers(0, 3, size=96), jnp.int32)

    handle = amq.make("cpu-cuckoo", capacity=512, hash_kind="fmix32")
    oracle = handle.apply_ops(OpBatch.make(keys, ops))
    state, ok = cuckoo_apply_ops(cfg, cfg.init(), keys, ops, 32)
    np.testing.assert_array_equal(_np(ok), _np(oracle.ok))
    assert int(state.count) == handle.count()
