"""Placement-policy invariants: involution / choice-bit recovery."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade to fixed-seed example tests
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from _tuning import examples

from repro.core.policies import OffsetPolicy, XorPolicy, make_policy

u32s = st.integers(min_value=0, max_value=(1 << 32) - 1)


@settings(max_examples=examples(200), deadline=None)
@given(h=u32s, idx=u32s)
def test_xor_involution(h, idx):
    pol = XorPolicy(num_buckets=1 << 12, fp_bits=16)
    tag = pol.make_tag(jnp.uint32(h))
    i1 = pol.primary_bucket(jnp.uint32(idx))
    i2 = pol.alt_bucket(i1, tag)
    back = pol.alt_bucket(i2, tag)
    assert int(back) == int(i1)
    assert int(tag) != 0  # EMPTY is reserved


def test_xor_requires_power_of_two():
    with pytest.raises(ValueError):
        XorPolicy(num_buckets=300, fp_bits=16)


@settings(max_examples=examples(200), deadline=None)
@given(h=u32s, idx=u32s, m=st.sampled_from([3, 100, 257, 4096, 99991]))
def test_offset_roundtrip(h, idx, m):
    pol = OffsetPolicy(num_buckets=m, fp_bits=16)
    tag = pol.make_tag(jnp.uint32(h))
    i1, i2 = pol.initial_buckets(jnp.uint32(idx), tag)
    assert int(i1) < m and int(i2) < m
    if m > 1:
        assert int(i1) != int(i2)  # offset is never 0
    # entry placed at primary: choice bit 0; its alt must be i2
    stored1 = pol.place_tag(tag, jnp.asarray(False))
    assert int(pol.alt_bucket(i1, stored1)) == int(i2)
    # entry placed at alternate: choice bit 1; its alt must be i1
    stored2 = pol.place_tag(tag, jnp.asarray(True))
    assert int(pol.alt_bucket(i2, stored2)) == int(i1)
    # relocation flips the choice bit and returns to the other bucket
    assert int(pol.on_relocate(stored1)) == int(stored2)
    back = pol.alt_bucket(jnp.uint32(int(i2)), pol.on_relocate(stored1))
    assert int(back) == int(i1)


def test_offset_effective_bits():
    pol = OffsetPolicy(num_buckets=100, fp_bits=16)
    assert pol.effective_fp_bits == 15
    xor = XorPolicy(num_buckets=128, fp_bits=16)
    assert xor.effective_fp_bits == 16


def test_query_match_tags_offset():
    pol = OffsetPolicy(num_buckets=100, fp_bits=16)
    tag = pol.make_tag(jnp.uint32(0x1234))
    t1, t2 = pol.query_match_tags(tag)
    assert int(t1) & pol.choice_bit == 0
    assert int(t2) & pol.choice_bit == pol.choice_bit
    assert (int(t1) & pol.fp_value_mask) == (int(t2) & pol.fp_value_mask)


def test_make_policy_dispatch():
    assert make_policy("xor", 64, 8).kind == "xor"
    assert make_policy("offset", 65, 8).kind == "offset"
    with pytest.raises(ValueError):
        make_policy("nope", 64, 8)
