"""Sharded filter: routing math unit tests + 8-device subprocess check."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CuckooConfig, keys_from_numpy
from repro.core.sharded_filter import (
    ShardedCuckooConfig,
    ShardedCuckooFilter,
    _route,
    _unroute,
    shard_of,
)


def test_shard_of_is_uniform_ish():
    cfg = ShardedCuckooConfig(CuckooConfig(num_buckets=64), num_shards=16)
    rng = np.random.default_rng(0)
    keys = jnp.asarray(keys_from_numpy(
        rng.integers(0, 2**64, size=1 << 14, dtype=np.uint64)))
    dest = np.asarray(shard_of(cfg, keys))
    counts = np.bincount(dest, minlength=16)
    assert counts.min() > 0.7 * counts.mean()
    assert counts.max() < 1.3 * counts.mean()


def test_route_unroute_roundtrip():
    cfg = ShardedCuckooConfig(CuckooConfig(num_buckets=64), num_shards=4)
    rng = np.random.default_rng(1)
    keys = jnp.asarray(keys_from_numpy(
        rng.integers(0, 2**64, size=256, dtype=np.uint64)))
    cap = cfg.bin_capacity(256)
    bins, bin_valid, order, dest_s, idxg, routed, _slot = _route(cfg, keys,
                                                                 cap)
    assert bins.shape == (4, cap, 2)
    # every routed key appears in its destination bin
    dest = np.asarray(shard_of(cfg, keys))
    nb = np.asarray(bins)
    for s in range(4):
        sent = nb[s][np.asarray(bin_valid)[s]]
        want = np.asarray(keys)[dest == s]
        assert sent.shape[0] == min(want.shape[0], cap)
    # unroute returns each key its own channel value
    back = jnp.arange(4 * cap, dtype=jnp.int32).reshape(4, cap)
    got = np.asarray(_unroute(order, dest_s, idxg, routed, back))
    slot_of_key = dest * cap  # base; exact slot checked via set membership
    for i in range(256):
        if np.asarray(routed)[np.asarray(order).tolist().index(i)]:
            assert got[i] // cap == dest[i]


def test_single_shard_matches_plain_filter():
    """num_shards=1 on a 1-device mesh == the plain filter."""
    mesh = jax.make_mesh((1,), ("data",))
    cfg = ShardedCuckooConfig.for_capacity(
        2048, num_shards=1, fp_bits=16, bucket_size=16, hash_kind="fmix32")
    filt = ShardedCuckooFilter(cfg, mesh, local_batch=1024)
    rng = np.random.default_rng(2)
    keys = jnp.asarray(keys_from_numpy(
        np.unique(rng.integers(0, 2**64, size=4096, dtype=np.uint64))[:1024]))
    ok, routed = filt.insert(keys)
    assert np.asarray(routed).all()  # cap >= batch for 1 shard
    assert np.asarray(ok).all()
    q, _ = filt.query(keys)
    assert np.asarray(q).all()
    from repro.core import CuckooFilter
    plain = CuckooFilter(cfg.shard)
    plain.insert(keys)
    np.testing.assert_array_equal(
        np.asarray(filt.state.table[0]), np.asarray(plain.state.table))


@pytest.mark.slow
def test_sharded_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "_sharded_check.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED_OK" in proc.stdout
