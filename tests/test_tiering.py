"""Property tests: the tiered GPU-hot / host-cold handle vs a flat oracle.

Random schedules of insert / query / delete / demote / promote /
maintain / compact / snapshot-roundtrip run against a
:class:`~repro.amq.tiering.TieredHandle` while a flat host-side oracle (a
plain key multiset — the reference a single right-sized filter would
answer from) tracks the true membership. At *every* step:

* zero false negatives — every live key answers positive, wherever its
  level currently resides (device or host RAM);
* the empirical FPR on a disjoint probe set stays within the cascade's
  declared budget band (``fpr_tolerance``);
* the device footprint respects ``device_budget_bytes`` (DESIGN.md §12).

Plus deterministic units for the wiring: registry validation, budget
enforcement, tier surgery guards, service stats, snapshot files.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised in the bare container
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from _tuning import examples

import numpy as np
import pytest

from repro import amq
from repro.core import keys_from_numpy

CAPACITY = 256
BUDGET = 8 * 1024                 # a few small levels' worth of device RAM
UNIVERSE = 2048                   # insertable keys
N_NEG = 2048                      # disjoint probe set for the FPR band
ACTIONS = ("insert", "insert", "insert", "delete", "demote", "promote",
           "maintain", "compact", "snapshot")


def _keyspace(seed: int):
    """(universe, absent) uint32[n, 2] keys — globally distinct uint64s."""
    rng = np.random.default_rng(seed)
    raw = np.unique(rng.integers(1, 2**63, size=2 * (UNIVERSE + N_NEG),
                                 dtype=np.uint64))[:UNIVERSE + N_NEG]
    assert raw.size == UNIVERSE + N_NEG
    return (keys_from_numpy(raw[:UNIVERSE]),
            keys_from_numpy(raw[UNIVERSE:]))


def _mk(snapshot=None):
    return amq.make("cuckoo", capacity=CAPACITY, tiered=True,
                    device_budget_bytes=BUDGET, snapshot=snapshot)


def _check_invariants(h, universe, live, absent) -> None:
    """Zero FN over live keys, FPR band over absent keys, budget held."""
    hits = np.asarray(h.query(universe).hits)
    fn = live & ~hits
    assert not fn.any(), (
        f"false negatives on live keys at {np.flatnonzero(fn)[:8]} "
        f"(tiers: {h.tier_stats()})")
    fp = float(np.asarray(h.query(absent).hits).mean())
    _, hi = amq.fpr_tolerance(h.fpr_budget, N_NEG)
    assert fp <= hi, f"FPR {fp} above budget band {hi}"
    assert h.device_bytes <= h.device_budget_bytes, (
        f"device footprint {h.device_bytes} exceeds budget "
        f"{h.device_budget_bytes}")


@settings(max_examples=examples(40), deadline=None)
@given(st.data())
def test_tiered_schedules_match_flat_oracle(data):
    """Random tier-shuffling schedules keep flat-filter semantics."""
    universe, absent = _keyspace(data.draw(st.integers(0, 2**16)))
    h = _mk()
    live = np.zeros((UNIVERSE,), bool)   # the flat oracle: the true set
    for step in range(data.draw(st.integers(2, 10))):
        action = data.draw(st.sampled_from(ACTIONS))
        if action == "insert":
            want = data.draw(st.integers(1, 400))
            idx = np.flatnonzero(~live)[:want]
            if idx.size:
                rep = h.insert(universe[idx])
                landed = np.asarray(rep.ok) & np.asarray(rep.routed)
                live[idx[landed]] = True
                assert landed.all(), "tiered insert refused keys"
        elif action == "delete":
            want = data.draw(st.integers(1, 200))
            idx = np.flatnonzero(live)[:want]
            if idx.size:
                dr = h.delete(universe[idx])
                gone = np.asarray(dr.ok) & np.asarray(dr.routed)
                assert gone.all(), "delete missed a live key"
                live[idx] = False
        elif action == "demote":
            before = len(h.hot.levels)
            cold = h.demote()
            assert (cold is None) == (before <= 1)
        elif action == "promote":
            if h.promote(force=bool(data.draw(st.integers(0, 1)))):
                assert h.cold == [] or (
                    h.cold[-1].alloc_id < h.hot.level_alloc_ids[0])
        elif action == "maintain":
            for _ in range(8):
                if h.maintain()["action"] == "none":
                    break
        elif action == "compact":
            h.compact()
            assert all(c.count > 0 for c in h.cold)
        elif action == "snapshot":
            h = _mk(snapshot=h.snapshot())
        if action == "promote":
            # force=True may legitimately overshoot the budget; rebalance
            # before asserting it, as a background maintainer would.
            while h.maintain()["action"] == "demote":
                pass
        _check_invariants(h, universe, live, absent)
    assert h.count() == int(live.sum()), (
        f"count drift: {h.count()} vs {int(live.sum())}")


def test_beyond_budget_capacity_with_zero_false_negatives():
    """The tiered handle holds a keyset far past the device budget."""
    rng = np.random.default_rng(7)
    raw = np.unique(rng.integers(1, 2**63, size=40_000, dtype=np.uint64))
    keys, absent = (keys_from_numpy(raw[:32_000]),
                    keys_from_numpy(raw[32_000:32_000 + N_NEG]))
    h = _mk()
    rep = h.insert(keys)
    assert bool((np.asarray(rep.ok) & np.asarray(rep.routed)).all())
    assert h.device_bytes <= h.device_budget_bytes
    assert h.table_bytes > 4 * h.device_budget_bytes   # genuinely tiered
    assert len(h.cold) >= 1
    assert bool(np.asarray(h.query(keys).hits).all())
    _, hi = amq.fpr_tolerance(h.fpr_budget, N_NEG)
    assert float(np.asarray(h.query(absent).hits).mean()) <= hi


def test_mixed_ops_route_across_tiers():
    """apply_ops: hot misses fall through to cold; deletes stay exact."""
    universe, _ = _keyspace(11)
    h = _mk()
    h.insert(universe)
    assert len(h.cold) >= 1
    # Cold-resident keys: the oldest inserted ones.
    probe = universe[:16]
    ops = np.array([amq.OP_QUERY, amq.OP_DELETE, amq.OP_QUERY] * 16,
                   np.int32)
    batch = amq.OpBatch.make(np.repeat(probe, 3, axis=0), ops)
    rep = h.apply_ops(batch)
    ok = np.asarray(rep.ok).reshape(16, 3)
    assert ok[:, 0].all(), "pre-delete query missed a cold key"
    assert ok[:, 1].all(), "cold-routed delete failed"
    assert not ok[:, 2].any(), "post-delete query still hits"
    stats = h.tier_stats()
    assert stats["cold_probe_keys"] > 0


def test_snapshot_file_roundtrip(tmp_path):
    """Tiered snapshots survive the .npz file path with tiers intact."""
    universe, _ = _keyspace(3)
    h = _mk()
    h.insert(universe)
    path = tmp_path / "tiered.npz"
    amq.save_snapshot(path, h.snapshot())
    snap = amq.load_snapshot(path)
    assert snap.kind == "tiered"
    h2 = _mk(snapshot=snap)
    assert h2.count() == h.count()
    assert len(h2.cold) == len(h.cold)
    assert bool(np.asarray(h2.query(universe).hits).all())
    # Budget can also come from the snapshot itself.
    h3 = amq.make("cuckoo", capacity=CAPACITY, tiered=True, snapshot=snap)
    assert h3.device_budget_bytes == BUDGET


def test_snapshot_knob_mismatch_fails_loudly():
    universe, _ = _keyspace(5)
    h = _mk()
    h.insert(universe[:512])
    snap = h.snapshot()
    other = amq.make("cuckoo", capacity=CAPACITY, tiered=True,
                     device_budget_bytes=2 * BUDGET)
    with pytest.raises(amq.SnapshotMismatchError):
        other.restore(snap)
    flat = amq.make("cuckoo", capacity=CAPACITY, auto_expand=True)
    with pytest.raises(amq.SnapshotMismatchError):
        flat.restore(snap)


def test_budget_validation():
    with pytest.raises(ValueError):
        amq.make("cuckoo", capacity=CAPACITY, tiered=True,
                 device_budget_bytes=0)
    with pytest.raises(ValueError):
        # Base level alone cannot fit a 16-byte budget.
        amq.make("cuckoo", capacity=1 << 16, tiered=True,
                 device_budget_bytes=16)
    with pytest.raises(TypeError):
        amq.make("cuckoo", capacity=CAPACITY, tiered=True)
    with pytest.raises(TypeError):
        amq.make("cuckoo", capacity=CAPACITY, tiered=True,
                 auto_expand=True, device_budget_bytes=BUDGET)


def test_tier_surgery_guards():
    h = _mk()
    with pytest.raises(ValueError):      # the active level never detaches
        h.hot.detach_oldest()
    assert h.demote() is None
    assert not h.promote()
    universe, _ = _keyspace(9)
    h.insert(universe)
    lvl, share, aid = h.hot.detach_oldest() if len(h.hot.levels) > 1 else (
        None, None, None)
    if lvl is not None:
        with pytest.raises(ValueError):  # out-of-order re-attachment
            h.hot.attach_oldest(lvl, share, aid + 10_000)
        h.hot.attach_oldest(lvl, share, aid)


def test_bloom_tiers_without_delete():
    """Append-only backends tier too; deletes stay capability-gated."""
    universe, absent = _keyspace(13)
    h = amq.make("bloom", capacity=CAPACITY, tiered=True,
                 device_budget_bytes=BUDGET)
    h.insert(universe)
    assert h.device_bytes <= h.device_budget_bytes
    assert bool(np.asarray(h.query(universe).hits).all())
    with pytest.raises(NotImplementedError):
        h.delete(universe[:4])


def test_service_surfaces_tier_stats():
    h = _mk()
    svc = amq.FilterService(h, batch_size=64)
    universe, _ = _keyspace(17)
    t = svc.insert(universe[:1500])
    svc.flush()
    assert bool(np.asarray(t.result()).all())
    stats = svc.stats()
    assert stats["tiers"]["device_budget_bytes"] == BUDGET
    assert stats["tiers"]["demotions"] >= 0
    q = svc.query(universe[:1500])
    svc.flush()
    assert bool(np.asarray(q.result()).all())


def test_capability_flag_matches_hooks():
    """Every supports_tiering backend has the host probes it advertises."""
    for name in amq.names():
        ad = amq.get(name)
        if ad.capabilities.supports_tiering:
            assert callable(ad.host_query)
            if ad.capabilities.supports_delete:
                assert callable(ad.host_delete)
