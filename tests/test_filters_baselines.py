"""Baseline filters: correctness + differential checks vs the Python oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CuckooConfig, CuckooFilter, keys_from_numpy
from repro.filters import (
    BCHTConfig,
    BloomConfig,
    BlockedBloomFilter,
    BucketedCuckooHashTable,
    GQFConfig,
    PyCuckooFilter,
    QuotientFilter,
    TCFConfig,
    TwoChoiceFilter,
)


def raw_keys(rng, n):
    return np.unique(rng.integers(0, 2**64, size=2 * n, dtype=np.uint64))[:n]


# --------------------------------------------------------------------------
# Blocked Bloom
# --------------------------------------------------------------------------

def test_bloom_no_false_negatives():
    rng = np.random.default_rng(0)
    cfg = BloomConfig.for_capacity(4096, bits_per_key=16)
    f = BlockedBloomFilter(cfg)
    raw = raw_keys(rng, 4096)
    keys = jnp.asarray(keys_from_numpy(raw))
    ok = f.insert(keys)
    assert np.asarray(ok).all()
    assert np.asarray(f.query(keys)).all()


def test_bloom_fpr_reasonable():
    rng = np.random.default_rng(1)
    cfg = BloomConfig.for_capacity(1 << 14, bits_per_key=16)
    f = BlockedBloomFilter(cfg)
    f.insert(jnp.asarray(keys_from_numpy(
        rng.integers(0, 2**32, size=1 << 14, dtype=np.uint64))))
    neg = rng.integers(2**32, 2**64, size=1 << 15, dtype=np.uint64)
    fpr = float(np.asarray(f.query(jnp.asarray(keys_from_numpy(neg)))).mean())
    # paper Fig. 4: BBF FPR is the worst of the pack, 0.5%..6%
    assert fpr < 0.06, fpr


def test_bloom_duplicate_insert_batch():
    cfg = BloomConfig.for_capacity(256)
    f = BlockedBloomFilter(cfg)
    key = jnp.asarray(keys_from_numpy(np.asarray([42], np.uint64)))
    f.insert(jnp.tile(key, (8, 1)))
    assert bool(f.query(key)[0])


# --------------------------------------------------------------------------
# Two-Choice filter
# --------------------------------------------------------------------------

def test_tcf_roundtrip():
    rng = np.random.default_rng(2)
    cfg = TCFConfig.for_capacity(4096, load_factor=0.85)
    f = TwoChoiceFilter(cfg)
    raw = raw_keys(rng, int(cfg.num_slots * 0.85))
    keys = jnp.asarray(keys_from_numpy(raw))
    ok = np.asarray(f.insert(keys))
    assert ok.mean() > 0.98
    assert np.asarray(f.query(keys))[ok].all()
    del_ok = np.asarray(f.delete(keys[ok]))
    # Unlike the cuckoo filter (where tag collisions imply the *same* bucket
    # pair), TCF keys sharing a tag + one block can false-delete each other's
    # copy and orphan their own (paper §2.1 accepts this "with a small
    # probability"). Allow a tiny residue; count must equal the residue.
    assert (~del_ok).sum() <= 3
    assert int(f.state.count) == int((~del_ok).sum())


def test_tcf_stash_overflow_path():
    # Tiny table so both blocks fill and the stash is exercised.
    cfg = TCFConfig(num_blocks=2, fp_bits=16, block_size=4, stash_size=16)
    f = TwoChoiceFilter(cfg)
    rng = np.random.default_rng(3)
    keys = jnp.asarray(keys_from_numpy(raw_keys(rng, 16)))
    ok = np.asarray(f.insert(keys))
    assert ok.sum() >= 8  # 8 block slots + stash room
    assert np.asarray(f.query(keys))[ok].all()
    assert np.asarray(f.delete(keys[ok])).all()
    assert int(f.state.count) == 0
    assert not np.asarray(f.state.stash).any()


# --------------------------------------------------------------------------
# Quotient filter (Robin Hood analogue)
# --------------------------------------------------------------------------

def test_gqf_roundtrip():
    rng = np.random.default_rng(4)
    cfg = GQFConfig.for_capacity(2048, load_factor=0.9)
    f = QuotientFilter(cfg)
    raw = raw_keys(rng, int(cfg.num_slots * 0.9))
    keys = jnp.asarray(keys_from_numpy(raw))
    ok = np.asarray(f.insert(keys))
    assert ok.mean() > 0.97, ok.mean()
    assert np.asarray(f.query(keys))[ok].all(), "GQF false negative"
    del_ok = np.asarray(f.delete(keys[ok]))
    assert del_ok.all()
    assert int(f.state.count) == 0
    assert not np.asarray(f.state.table).any()


def test_gqf_low_fpr():
    """Paper Fig. 4: the quotient filter has the lowest FPR of the pack."""
    rng = np.random.default_rng(5)
    cfg = GQFConfig.for_capacity(4096, load_factor=0.9, remainder_bits=16)
    f = QuotientFilter(cfg)
    f.insert(jnp.asarray(keys_from_numpy(
        rng.integers(0, 2**32, size=int(cfg.num_slots * 0.9), dtype=np.uint64))))
    neg = rng.integers(2**32, 2**64, size=1 << 15, dtype=np.uint64)
    fpr = float(np.asarray(f.query(jnp.asarray(keys_from_numpy(neg)))).mean())
    assert fpr < 0.005, fpr


# --------------------------------------------------------------------------
# BCHT (exact)
# --------------------------------------------------------------------------

def test_bcht_exact_membership():
    rng = np.random.default_rng(6)
    cfg = BCHTConfig.for_capacity(2048, load_factor=0.85)
    t = BucketedCuckooHashTable(cfg)
    raw = raw_keys(rng, int(cfg.num_slots * 0.85))
    keys = jnp.asarray(keys_from_numpy(raw))
    ok = np.asarray(t.insert(keys))
    assert ok.mean() > 0.98
    assert np.asarray(t.query(keys))[ok].all()
    # exact: zero false positives, always
    neg = rng.integers(0, 2**64, size=1 << 14, dtype=np.uint64)
    neg = np.setdiff1d(neg, raw)
    got = np.asarray(t.query(jnp.asarray(keys_from_numpy(neg))))
    assert not got.any(), "BCHT must be exact"
    del_ok = np.asarray(t.delete(keys[ok]))
    assert del_ok.all()
    assert int(t.state.count) == 0


# --------------------------------------------------------------------------
# Differential: JAX filter vs pure-Python reference (same derivation)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("hash_kind", ["xxhash64", "fmix32"])
def test_jax_matches_python_reference_queries(hash_kind):
    rng = np.random.default_rng(7)
    cfg = CuckooConfig(num_buckets=128, fp_bits=16, bucket_size=8,
                       policy="xor", eviction="dfs", hash_kind=hash_kind)
    jf = CuckooFilter(cfg)
    pf = PyCuckooFilter(cfg.num_buckets, cfg.fp_bits, cfg.bucket_size,
                        hash_kind=hash_kind)
    raw = raw_keys(rng, 512)
    keys = jnp.asarray(keys_from_numpy(raw))
    ok_j, _ = jf.insert(keys)
    ok_p = pf.insert_batch(raw)
    # same load
    assert abs(int(jf.state.count) - pf.count) <= int((~np.asarray(ok_j)).sum()) \
        + int((~ok_p).sum())
    # every key the python filter stored must be visible to it AND the jax
    # filter must agree on all successfully-stored keys (identical derivation)
    probe = raw_keys(np.random.default_rng(8), 2048)
    got_j = np.asarray(jf.query(jnp.asarray(keys_from_numpy(probe))))
    got_p = pf.query_batch(probe)
    # membership universes are identical up to insert-failure differences;
    # for fully-successful runs demand exact agreement
    if np.asarray(ok_j).all() and ok_p.all():
        np.testing.assert_array_equal(got_j, got_p)


def test_python_reference_tag_derivation_matches_jax():
    from repro.core import prepare_keys
    rng = np.random.default_rng(9)
    raw = raw_keys(rng, 64)
    cfg = CuckooConfig(num_buckets=256, fp_bits=16, bucket_size=8,
                       policy="xor", hash_kind="xxhash64")
    pf = PyCuckooFilter(256, 16, 8, hash_kind="xxhash64")
    tag, i1, i2 = prepare_keys(cfg, jnp.asarray(keys_from_numpy(raw)))
    for k, t, a, b in zip(raw, np.asarray(tag), np.asarray(i1), np.asarray(i2)):
        pt, pa, pb = pf._prepare(int(k))
        assert (pt, pa, pb) == (int(t), int(a), int(b))
