"""Tier-1 runtime budget knobs (shared by the property-test modules).

``examples(n)`` is the one place hypothesis example counts are set: each
test passes its *full* count (what a thorough accelerator/nightly run
should use) and the environment may cap it — CI exports
``REPRO_MAX_EXAMPLES=25`` on its CPU runners (see .github/workflows/ci.yml)
so the suite stays inside the tier-1 time budget without deleting a single
assertion. Unset, counts pass through untouched.
"""

from __future__ import annotations

import os


def examples(n: int) -> int:
    cap = os.environ.get("REPRO_MAX_EXAMPLES")
    if cap:
        return max(1, min(n, int(cap)))
    return n
