"""Fixed-seed fallback for ``hypothesis`` when it isn't installed.

The property-test modules degrade to deterministic example tests: ``given``
re-runs the test body for a bounded number of examples drawn from a
seeded PRNG (seeded by the test's qualified name, so failures reproduce).
Install the real dependency (``pip install -e .[test]`` — see
pyproject.toml) to get actual shrinking/coverage-guided search.

Only the surface the test suite uses is implemented: ``given`` (kwargs
form), ``settings(max_examples, deadline)``, and the ``integers`` /
``booleans`` / ``sampled_from`` / ``lists`` / ``data`` strategies.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

# Keep the degraded suite fast: the shim caps requested example counts.
_MAX_EXAMPLES_CAP = 25
_DEFAULT_EXAMPLES = 10


class Strategy:
    def __init__(self, draw_fn, label=""):
        self._draw = draw_fn
        self._label = label

    def example(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"shim.{self._label}"


class _DataStrategy(Strategy):
    def __init__(self):
        super().__init__(lambda rng: None, "data()")


class DataObject:
    """Shim for the object injected by ``st.data()``."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: Strategy):
        return strategy.example(self._rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value=0, max_value=2**63 - 1):
        return Strategy(lambda rng: rng.randint(min_value, max_value),
                        f"integers({min_value}, {max_value})")

    @staticmethod
    def booleans():
        return Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return Strategy(lambda rng: elements[rng.randrange(len(elements))],
                        "sampled_from")

    @staticmethod
    def lists(elements: Strategy, min_size=0, max_size=10, unique=False):
        def draw(rng):
            size = rng.randint(min_size, max_size)
            out, seen = [], set()
            attempts = 0
            while len(out) < size and attempts < 100 * (size + 1):
                v = elements.example(rng)
                attempts += 1
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out

        return Strategy(draw, "lists")

    @staticmethod
    def data():
        return _DataStrategy()


def given(*strategy_args, **strategy_kwargs):
    def decorate(fn):
        sig = inspect.signature(fn)
        if strategy_args:
            # hypothesis matches positional strategies to the *rightmost*
            # parameters (leaving self/fixtures on the left untouched).
            names = list(sig.parameters)[-len(strategy_args):]
            strategy_kwargs.update(zip(names, strategy_args))
        passthrough = [p for name, p in sig.parameters.items()
                       if name not in strategy_kwargs]

        @functools.wraps(fn)
        def wrapper(*call_args, **call_kwargs):
            n = min(getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES),
                    _MAX_EXAMPLES_CAP)
            base_seed = zlib.crc32(fn.__qualname__.encode())
            for example in range(n):
                rng = random.Random(base_seed + example)
                drawn = {}
                for name, strat in strategy_kwargs.items():
                    if isinstance(strat, _DataStrategy):
                        drawn[name] = DataObject(rng)
                    else:
                        drawn[name] = strat.example(rng)
                fn(*call_args, **call_kwargs, **drawn)

        # pytest must only see the non-strategy parameters (parametrize
        # marks / fixtures); the strategies are filled in per example.
        wrapper.__signature__ = sig.replace(parameters=passthrough)
        wrapper.is_hypothesis_shim = True
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._shim_max_examples = max_examples
        return fn

    return decorate
