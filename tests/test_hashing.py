"""Bit-exactness of the u64 emulation and xxHash64 vs pure-Python oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # degrade to fixed-seed example tests
    from _hypothesis_compat import given, settings
    from _hypothesis_compat import strategies as st

from _tuning import examples

from repro.core import bits64 as b64
from repro.core.hashing import (
    fmix32,
    fmix32_py,
    hash_key,
    keys_from_numpy,
    xxhash64_py,
    xxhash64_u64,
)

u64s = st.integers(min_value=0, max_value=(1 << 64) - 1)
u32s = st.integers(min_value=0, max_value=(1 << 32) - 1)
MASK = (1 << 64) - 1


def as_u64(x: int):
    return b64.from_py(x)


@settings(max_examples=examples(200), deadline=None)
@given(u64s, u64s)
def test_add(a, b):
    assert b64.to_py(b64.add(as_u64(a), as_u64(b))) == (a + b) & MASK


@settings(max_examples=examples(200), deadline=None)
@given(u64s, u64s)
def test_mul(a, b):
    assert b64.to_py(b64.mul(as_u64(a), as_u64(b))) == (a * b) & MASK


@settings(max_examples=examples(100), deadline=None)
@given(u64s, st.integers(min_value=0, max_value=63))
def test_shifts_and_rot(a, r):
    assert b64.to_py(b64.shl(as_u64(a), r)) == (a << r) & MASK
    assert b64.to_py(b64.shr(as_u64(a), r)) == (a >> r) & MASK
    want = ((a << r) | (a >> (64 - r))) & MASK if r else a
    assert b64.to_py(b64.rotl(as_u64(a), r)) == want


@settings(max_examples=examples(200), deadline=None)
@given(u32s)
def test_fmix32(x):
    got = int(np.asarray(fmix32(jnp.uint32(x))))
    assert got == fmix32_py(x)


@settings(max_examples=examples(100), deadline=None)
@given(u64s, st.sampled_from([0, 1, 0xDEADBEEF]))
def test_xxhash64_exact(key, seed):
    got = b64.to_py(xxhash64_u64(as_u64(key), seed=seed))
    assert got == xxhash64_py(key, seed)


def test_xxhash64_batch():
    rng = np.random.default_rng(7)
    raw = rng.integers(0, 2**64, size=256, dtype=np.uint64)
    hi, lo = hash_key(jnp.asarray(keys_from_numpy(raw)), "xxhash64")
    got = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) | np.asarray(lo)
    want = np.array([xxhash64_py(int(k)) for k in raw], np.uint64)
    np.testing.assert_array_equal(got, want)


def test_keys_from_numpy_roundtrip():
    rng = np.random.default_rng(3)
    raw = rng.integers(0, 2**64, size=100, dtype=np.uint64)
    k = keys_from_numpy(raw)
    back = k[..., 0].astype(np.uint64) | (k[..., 1].astype(np.uint64) << np.uint64(32))
    np.testing.assert_array_equal(back, raw)


def test_keys_to_numpy_is_the_shared_inverse():
    """keys_to_numpy is the one hoisted host-side inverse of
    keys_from_numpy, shared by the oracle module and the AMQ adapters —
    the packing convention cannot drift between consumers."""
    from repro.core.hashing import keys_to_numpy
    from repro.filters import cpu_reference

    rng = np.random.default_rng(4)
    raw = rng.integers(0, 2**64, size=100, dtype=np.uint64)
    np.testing.assert_array_equal(keys_to_numpy(keys_from_numpy(raw)), raw)
    # jnp inputs (device arrays) normalize identically
    np.testing.assert_array_equal(
        keys_to_numpy(jnp.asarray(keys_from_numpy(raw))), raw)
    # one shared callable, re-exported — not a copy that could drift; the
    # old numpy keys_to_u64 name is gone (it clashed with the jax helper
    # of the same name in core.hashing, which returns a U64 lane pair).
    assert cpu_reference.keys_to_numpy is keys_to_numpy
    assert not hasattr(cpu_reference, "keys_to_u64")


@pytest.mark.parametrize("kind", ["xxhash64", "fmix32"])
def test_hash_distribution_rough(kind):
    """Both hash kinds should look uniform at coarse granularity."""
    rng = np.random.default_rng(11)
    raw = rng.integers(0, 2**64, size=1 << 14, dtype=np.uint64)
    hi, lo = hash_key(jnp.asarray(keys_from_numpy(raw)), kind)
    for part in (np.asarray(hi), np.asarray(lo)):
        counts = np.bincount(part % 64, minlength=64)
        # chi-square-ish sanity: no bucket more than 2x the mean
        assert counts.max() < 2 * counts.mean()
        assert counts.min() > 0.5 * counts.mean()


def test_fmix32_pair_sensitivity():
    """Flipping any single input bit should flip ~half the output bits."""
    from repro.core.hashing import fmix32_pair

    base = (jnp.uint32(0x12345678), jnp.uint32(0x9ABCDEF0))
    h0, l0 = fmix32_pair(base)
    flips = []
    for word in range(2):
        for bit in range(0, 32, 5):
            k = [base[0], base[1]]
            k[word] = k[word] ^ jnp.uint32(1 << bit)
            h1, l1 = fmix32_pair((k[0], k[1]))
            x = (int(h0) ^ int(h1), int(l0) ^ int(l1))
            flips.append(bin(x[0]).count("1") + bin(x[1]).count("1"))
    flips = np.array(flips)
    assert flips.mean() > 20 and flips.mean() < 44
