"""Pallas fused flash-attention kernel vs the jnp online-softmax oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import flash_attention


def _to_kernel_layout(q, k, v):
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    g = H // KVH
    Sk, Dv = k.shape[1], v.shape[-1]
    qk = q.reshape(B, Sq, KVH, g, D).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KVH, g, Sq, D)
    kk = k.transpose(0, 2, 1, 3).reshape(B * KVH, Sk, D)
    vk = v.transpose(0, 2, 1, 3).reshape(B * KVH, Sk, Dv)
    return qk, kk, vk


def _from_kernel_layout(out, B, KVH, g, Sq, Dv):
    return out.reshape(B, KVH, g, Sq, Dv).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sq, KVH * g, Dv)


SWEEP = [
    # (B, KVH, g, Sq, Sk, D, Dv, causal, window, blk_q, blk_k, dtype)
    (2, 2, 3, 192, 256, 64, 32, True, None, 64, 64, jnp.float32),
    (2, 2, 3, 192, 256, 64, 32, True, 64, 64, 64, jnp.float32),
    (1, 4, 1, 256, 256, 128, 128, False, None, 128, 128, jnp.float32),
    (1, 1, 8, 100, 130, 32, 32, True, None, 64, 64, jnp.float32),  # ragged
    (2, 2, 2, 128, 128, 64, 64, True, None, 128, 64, jnp.bfloat16),
]


@pytest.mark.parametrize(
    "B,KVH,g,Sq,Sk,D,Dv,causal,window,bq,bk,dtype", SWEEP)
def test_flash_kernel_matches_oracle(B, KVH, g, Sq, Sk, D, Dv, causal,
                                     window, bq, bk, dtype):
    rng = np.random.default_rng(Sq + Sk)
    q = jnp.asarray(rng.normal(size=(B, Sq, KVH * g, D)), dtype) * 0.3
    k = jnp.asarray(rng.normal(size=(B, Sk, KVH, D)), dtype) * 0.3
    v = jnp.asarray(rng.normal(size=(B, Sk, KVH, Dv)), dtype) * 0.3
    want = flash_attention(q, k, v, causal=causal, window=window,
                           chunk_q=64, chunk_k=64)
    qk, kk, vk = _to_kernel_layout(q, k, v)
    got = flash_attention_pallas(qk, kk, vk, causal=causal, window=window,
                                 blk_q=bq, blk_k=bk)
    got = _from_kernel_layout(got, B, KVH, g, Sq, Dv)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
