"""Make ``repro`` importable without an externally-set PYTHONPATH.

Tier-1 runs use ``PYTHONPATH=src python -m pytest``; this keeps plain
``pytest`` (CI, editors) working from the repo root too.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(_SRC) not in (os.path.abspath(p) for p in sys.path):
    sys.path.insert(0, os.path.abspath(_SRC))
