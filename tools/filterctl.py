"""filterctl — save / load / inspect AMQ filter snapshots (DESIGN.md §10).

Operator front door to the filter-state lifecycle: build and populate a
filter, persist its versioned snapshot, inspect a snapshot file without
touching a device, and restore one onto a freshly built config (the
fingerprint check proves the config matches — a wrong ``--capacity`` or
sizing kwarg fails loudly instead of restoring a corrupt table).

    PYTHONPATH=src python tools/filterctl.py save out.npz \\
        --backend cuckoo --capacity 100000 --insert-random 80000
    PYTHONPATH=src python tools/filterctl.py inspect out.npz
    PYTHONPATH=src python tools/filterctl.py load out.npz \\
        --backend cuckoo --capacity 100000 --verify-random 80000

Sizing kwargs ride along as repeated ``--kw name=value`` flags (values are
parsed as int/float where possible), e.g. ``--kw fp_bits=8``.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro import amq  # noqa: E402
from repro.amq.protocol import load_snapshot, save_snapshot  # noqa: E402


def _parse_kw(pairs) -> dict:
    out = {}
    for pair in pairs or ():
        if "=" not in pair:
            raise SystemExit(f"--kw expects name=value, got {pair!r}")
        k, v = pair.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        out[k] = v
    return out


def _rand_keys(n: int, seed: int) -> np.ndarray:
    """First ``n`` distinct keys of the seeded stream — prefix-stable.

    Deduplicated in *generation order* (not sorted), so for one seed the
    first ``m <= n`` keys of a larger draw equal a smaller draw exactly:
    ``load --verify-random M`` (M <= save's ``--insert-random N``) queries
    keys that were actually inserted.
    """
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, 2**64, size=2 * n + 16, dtype=np.uint64)
    _, idx = np.unique(arr, return_index=True)
    return arr[np.sort(idx)][:n]


def _load_keys(args) -> np.ndarray:
    if args.keys is not None:
        return np.load(args.keys).astype(np.uint64).reshape(-1)
    if args.insert_random:
        return _rand_keys(args.insert_random, args.seed)
    return np.zeros((0,), np.uint64)


def _make(args):
    return amq.make(args.backend or "cuckoo", capacity=args.capacity,
                    **_parse_kw(args.kw))


def cmd_save(args) -> int:
    """Build + populate a filter, then persist its snapshot."""
    handle = _make(args)
    keys = _load_keys(args)
    if keys.size:
        report = handle.insert(keys)
        ok = np.asarray(report.ok) & np.asarray(report.routed)
        print(f"inserted {int(ok.sum())}/{keys.size} keys "
              f"(load {handle.load_factor:.3f})")
    snap = handle.snapshot()
    save_snapshot(args.path, snap)
    print(f"wrote {args.path}: backend={snap.backend} "
          f"count={snap.meta['count']} bytes={snap.nbytes}")
    return 0


def cmd_inspect(args) -> int:
    """Print a snapshot file's header and array inventory (host-only)."""
    snap = load_snapshot(args.path)
    print(f"backend:     {snap.backend}")
    print(f"kind:        {snap.kind}")
    print(f"format:      v{snap.version}")
    print(f"fingerprint: {snap.fingerprint or '(per-level, see meta)'}")
    for k, v in sorted(snap.meta.items()):
        print(f"meta.{k}: {v}")
    for name in sorted(snap.arrays):
        a = snap.arrays[name]
        print(f"array {name}: {a.dtype}{list(a.shape)} ({a.nbytes} B)")
    return 0


def cmd_load(args) -> int:
    """Restore a snapshot onto a freshly built config and sanity-check it."""
    snap = load_snapshot(args.path)
    handle = amq.make(args.backend or snap.backend, capacity=args.capacity,
                      snapshot=snap, **_parse_kw(args.kw))
    print(f"restored {handle.name}: count={handle.count()} "
          f"load={handle.load_factor:.3f}")
    if args.verify_random:
        keys = _rand_keys(args.verify_random, args.seed)
        hits = np.asarray(handle.query(keys).hits)
        print(f"verify: {int(hits.sum())}/{keys.size} stored keys answered "
              "positive" + ("" if hits.all() else "  <-- FALSE NEGATIVES"))
        if not hits.all():
            return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="filterctl", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, capacity_required):
        p.add_argument("path", help="snapshot file (.npz)")
        # None so `load` can fall back to the snapshot's recorded backend
        # (save defaults to cuckoo in _make).
        p.add_argument("--backend", default=None)
        p.add_argument("--capacity", type=int,
                       required=capacity_required)
        p.add_argument("--kw", action="append", metavar="NAME=VALUE",
                       help="backend sizing kwarg (repeatable)")
        p.add_argument("--seed", type=int, default=0)

    p = sub.add_parser("save", help="build + populate + snapshot to file")
    common(p, True)
    p.add_argument("--insert-random", type=int, default=0, metavar="N",
                   help="populate with N random uint64 keys before saving")
    p.add_argument("--keys", default=None,
                   help=".npy file of uint64 keys to insert before saving")
    p.set_defaults(fn=cmd_save)

    p = sub.add_parser("inspect", help="print snapshot header (no device)")
    p.add_argument("path")
    p.set_defaults(fn=cmd_inspect)

    p = sub.add_parser("load", help="restore onto a freshly built config")
    common(p, True)
    p.add_argument("--verify-random", type=int, default=0, metavar="N",
                   help="re-query the first N keys of the save-time seeded "
                        "stream (N <= save's --insert-random) and fail on "
                        "any false negative")
    p.set_defaults(fn=cmd_load)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
