"""filterctl — save / load / inspect AMQ filter snapshots (DESIGN.md §10).

Operator front door to the filter-state lifecycle: build and populate a
filter, persist its versioned snapshot, inspect a snapshot file without
touching a device, and restore one onto a freshly built config (the
fingerprint check proves the config matches — a wrong ``--capacity`` or
sizing kwarg fails loudly instead of restoring a corrupt table).

    PYTHONPATH=src python tools/filterctl.py save out.npz \\
        --backend cuckoo --capacity 100000 --insert-random 80000
    PYTHONPATH=src python tools/filterctl.py inspect out.npz
    PYTHONPATH=src python tools/filterctl.py load out.npz \\
        --backend cuckoo --capacity 100000 --verify-random 80000
    PYTHONPATH=src python tools/filterctl.py stats \\
        bench-json/BENCH_serving_slo.json --cell hot_swap

``--device-budget-bytes N`` on ``save``/``load`` builds a tiered GPU-hot /
host-cold handle (DESIGN.md §12); ``tiers`` prints a tiered snapshot's
per-level residency table without touching a device:

    PYTHONPATH=src python tools/filterctl.py save tiered.npz \\
        --backend cuckoo --capacity 4096 --device-budget-bytes 65536 \\
        --insert-random 60000
    PYTHONPATH=src python tools/filterctl.py tiers tiered.npz

Sizing kwargs ride along as repeated ``--kw name=value`` flags (values are
parsed as int/float where possible), e.g. ``--kw fp_bits=8``.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro import amq  # noqa: E402
from repro.amq.protocol import load_snapshot, save_snapshot  # noqa: E402


def _parse_kw(pairs) -> dict:
    out = {}
    for pair in pairs or ():
        if "=" not in pair:
            raise SystemExit(f"--kw expects name=value, got {pair!r}")
        k, v = pair.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        out[k] = v
    return out


def _rand_keys(n: int, seed: int) -> np.ndarray:
    """First ``n`` distinct keys of the seeded stream — prefix-stable.

    Deduplicated in *generation order* (not sorted), so for one seed the
    first ``m <= n`` keys of a larger draw equal a smaller draw exactly:
    ``load --verify-random M`` (M <= save's ``--insert-random N``) queries
    keys that were actually inserted.
    """
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, 2**64, size=2 * n + 16, dtype=np.uint64)
    _, idx = np.unique(arr, return_index=True)
    return arr[np.sort(idx)][:n]


def _load_keys(args) -> np.ndarray:
    if args.keys is not None:
        return np.load(args.keys).astype(np.uint64).reshape(-1)
    if args.insert_random:
        return _rand_keys(args.insert_random, args.seed)
    return np.zeros((0,), np.uint64)


def _make(args, snapshot=None):
    kw = _parse_kw(args.kw)
    budget = getattr(args, "device_budget_bytes", None)
    if budget is not None:
        kw.update(tiered=True, device_budget_bytes=budget)
    if snapshot is not None:
        kw["snapshot"] = snapshot
        if snapshot.kind == "tiered" and budget is None:
            kw["tiered"] = True   # budget comes from the snapshot meta
    return amq.make(args.backend or "cuckoo", capacity=args.capacity,
                    **kw)


def _tier_table(meta: dict) -> None:
    """Render a tiered snapshot's per-level table (tier, occupancy, bytes)."""
    rows = list(meta.get("cold_levels", ())) + list(meta.get("hot_levels", ()))
    print(f"{'tier':<6} {'alloc':>5} {'count':>10} {'slots':>10} "
          f"{'load':>6} {'bytes':>10} {'fpr_share':>10}")
    for lm in rows:
        load = lm["count"] / lm["num_slots"] if lm["num_slots"] else 0.0
        print(f"{lm['residency']:<6} {lm['alloc_index']:>5} "
              f"{lm['count']:>10} {lm['num_slots']:>10} {load:>6.3f} "
              f"{lm['table_bytes']:>10} {lm['share']:>10.2e}")
    device = sum(lm["table_bytes"] for lm in meta.get("hot_levels", ()))
    host = sum(lm["table_bytes"] for lm in meta.get("cold_levels", ()))
    print(f"device: {device} B of {meta.get('device_budget_bytes', '?')} B "
          f"budget; host: {host} B; total keys: {meta.get('count', '?')}")


def cmd_save(args) -> int:
    """Build + populate a filter, then persist its snapshot."""
    handle = _make(args)
    keys = _load_keys(args)
    if keys.size:
        report = handle.insert(keys)
        ok = np.asarray(report.ok) & np.asarray(report.routed)
        print(f"inserted {int(ok.sum())}/{keys.size} keys "
              f"(load {handle.load_factor:.3f})")
    snap = handle.snapshot()
    save_snapshot(args.path, snap)
    print(f"wrote {args.path}: backend={snap.backend} "
          f"count={snap.meta['count']} bytes={snap.nbytes}")
    return 0


def cmd_inspect(args) -> int:
    """Print a snapshot file's header and array inventory (host-only)."""
    snap = load_snapshot(args.path)
    print(f"backend:     {snap.backend}")
    print(f"kind:        {snap.kind}")
    print(f"format:      v{snap.version}")
    print(f"fingerprint: {snap.fingerprint or '(per-level, see meta)'}")
    for k, v in sorted(snap.meta.items()):
        if k in ("hot_levels", "cold_levels"):
            continue   # rendered as the tier table below
        print(f"meta.{k}: {v}")
    if snap.kind == "tiered":
        _tier_table(snap.meta)
    for name in sorted(snap.arrays):
        a = snap.arrays[name]
        print(f"array {name}: {a.dtype}{list(a.shape)} ({a.nbytes} B)")
    return 0


def cmd_tiers(args) -> int:
    """Print a tiered snapshot's per-level residency table (host-only)."""
    snap = load_snapshot(args.path)
    if snap.kind != "tiered":
        print(f"{args.path}: kind={snap.kind!r} — not a tiered snapshot "
              "(take one from amq.make(..., tiered=True).snapshot())",
              file=sys.stderr)
        return 2
    print(f"backend: {snap.backend} (format v{snap.version})")
    _tier_table(snap.meta)
    return 0


def cmd_load(args) -> int:
    """Restore a snapshot onto a freshly built config and sanity-check it."""
    snap = load_snapshot(args.path)
    if args.backend is None:
        args.backend = snap.backend
    handle = _make(args, snapshot=snap)
    print(f"restored {handle.name}: count={handle.count()} "
          f"load={handle.load_factor:.3f}")
    if args.verify_random:
        keys = _rand_keys(args.verify_random, args.seed)
        hits = np.asarray(handle.query(keys).hits)
        print(f"verify: {int(hits.sum())}/{keys.size} stored keys answered "
              "positive" + ("" if hits.all() else "  <-- FALSE NEGATIVES"))
        if not hits.all():
            return 1
    return 0


def cmd_stats(args) -> int:
    """Pretty-print serving-SLO metrics from a BENCH_*.json artifact.

    Reads the ``data.cells`` payload the serving_slo suite emits (each
    cell is a :meth:`repro.amq.FilterService.stats` snapshot plus harness
    context) and renders the operator view: latency percentiles, sustained
    throughput, dispatch mix, queue bound, padding waste.
    """
    import json

    payload = json.loads(pathlib.Path(args.path).read_text())
    cells = payload.get("data", {}).get("cells", [])
    if args.cell:
        cells = [c for c in cells if args.cell in c.get("label", "")]
    if not cells:
        print(f"no serving cells in {args.path}"
              + (f" matching {args.cell!r}" if args.cell else ""))
        return 1
    for cell in cells:
        print(f"cell {cell['label']}")
        print(f"  enqueue-to-ready: p50={cell['p50_us']:.0f}us "
              f"p99={cell['p99_us']:.0f}us")
        print(f"  sustained:        {cell['sustained_ops_per_s']:.0f} ops/s "
              f"({cell['acked_ops']} acked over {cell['sim_s']:.2f}s)")
        kinds = ", ".join(f"{k}={v}" for k, v in
                          sorted(cell.get("dispatch_kinds", {}).items()))
        print(f"  dispatches:       {kinds or '(none)'}")
        print(f"  queue depth max:  {cell['queue_depth_max']}"
              + (f" (bound {cell['max_pending']})"
                 if "max_pending" in cell else ""))
        print(f"  padding waste:    {cell['padding_waste']:.1%}")
        if cell.get("shed_ops") or cell.get("rejected_submissions"):
            print(f"  refused:          shed_ops={cell['shed_ops']} "
                  f"rejected={cell['rejected_submissions']}")
        if "swap" in cell:
            s = cell["swap"]
            print(f"  hot swap:         {s['old_backend']} -> "
                  f"{s['new_backend']} pause={s['pause_s'] * 1e3:.1f}ms "
                  f"drained={s['drained_ops']} "
                  f"acked_verified={cell.get('acked_inserts_verified', 0)}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="filterctl", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, capacity_required):
        p.add_argument("path", help="snapshot file (.npz)")
        # None so `load` can fall back to the snapshot's recorded backend
        # (save defaults to cuckoo in _make).
        p.add_argument("--backend", default=None)
        p.add_argument("--capacity", type=int,
                       required=capacity_required)
        p.add_argument("--kw", action="append", metavar="NAME=VALUE",
                       help="backend sizing kwarg (repeatable)")
        p.add_argument("--seed", type=int, default=0)
        p.add_argument("--device-budget-bytes", type=int, default=None,
                       help="build a tiered GPU-hot / host-cold handle "
                            "under this device budget (DESIGN.md §12)")

    p = sub.add_parser("save", help="build + populate + snapshot to file")
    common(p, True)
    p.add_argument("--insert-random", type=int, default=0, metavar="N",
                   help="populate with N random uint64 keys before saving")
    p.add_argument("--keys", default=None,
                   help=".npy file of uint64 keys to insert before saving")
    p.set_defaults(fn=cmd_save)

    p = sub.add_parser("inspect", help="print snapshot header (no device)")
    p.add_argument("path")
    p.set_defaults(fn=cmd_inspect)

    p = sub.add_parser("tiers", help="per-tier level table of a tiered "
                                     "snapshot (no device)")
    p.add_argument("path", help="tiered snapshot file (.npz)")
    p.set_defaults(fn=cmd_tiers)

    p = sub.add_parser("load", help="restore onto a freshly built config")
    common(p, True)
    p.add_argument("--verify-random", type=int, default=0, metavar="N",
                   help="re-query the first N keys of the save-time seeded "
                        "stream (N <= save's --insert-random) and fail on "
                        "any false negative")
    p.set_defaults(fn=cmd_load)

    p = sub.add_parser("stats", help="pretty-print serving-SLO metrics "
                                     "from a BENCH_*.json artifact")
    p.add_argument("path", help="BENCH_serving_slo.json (benchmarks.run "
                                "--json-dir output)")
    p.add_argument("--cell", default=None,
                   help="only cells whose label contains this substring")
    p.set_defaults(fn=cmd_stats)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
