"""Generate docs/backends.md from the live AMQ registry.

The backend reference is *derived*, never hand-written: every row comes
from the registered adapters (capability flags, growth ladders) and their
probed configs (analytic-FPR formula docstrings, sizing-kwarg signatures),
so the docs cannot drift from the code. CI's ``docs-sync`` job re-runs
this script with ``--check`` and fails the build on any diff.

    PYTHONPATH=src python tools/gen_backend_docs.py          # rewrite
    PYTHONPATH=src python tools/gen_backend_docs.py --check  # verify only
"""

from __future__ import annotations

import argparse
import dataclasses
import inspect
import pathlib
import re
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro import amq  # noqa: E402
from repro.amq.protocol import Capabilities  # noqa: E402

OUT = pathlib.Path(__file__).resolve().parents[1] / "docs" / "backends.md"

_PROBE_CAPACITY = 4096

HEADER = """\
# AMQ backend reference

> **Generated** by `tools/gen_backend_docs.py` from the live registry —
> do not edit by hand. CI's `docs-sync` job regenerates this file and
> fails on any diff, so it always matches the code.

Every backend is reached through one front door:

```python
from repro import amq
handle = amq.make(name, capacity=..., **sizing_kwargs)
cascade = amq.make(name, capacity=..., auto_expand=True)   # needs `expand`
```

Consumers branch on the capability flags below — never on backend names
(DESIGN.md §7); `auto_expand` wraps a backend as a growing cascade of
levels (DESIGN.md §8). Every handle also executes mixed operation batches
(`handle.apply_ops(OpBatch)`, DESIGN.md §9): backends with the `mixed`
capability run them as one fused program, the rest fall back to maximal
same-op runs. Backends with the `snapshot` capability round-trip their
state through versioned host-side snapshots (`handle.snapshot()` /
`handle.restore()` / `amq.make(..., snapshot=...)`, DESIGN.md §10) —
the substrate for persistence, exact resharding, and the serving layer's
zero-downtime `FilterService.hot_swap`. Backends with the `tiering`
capability additionally split their cascade across a GPU-hot /
host-cold residency boundary for beyond-HBM capacity
(`amq.make(..., tiered=True, device_budget_bytes=...)`, DESIGN.md §12).
"""


def _flag(value: bool) -> str:
    return "yes" if value else "—"


def _first_doc_sentence(obj) -> str:
    doc = " ".join((inspect.getdoc(obj) or "").split())
    if not doc:
        return "(undocumented)"
    # Sentence boundary = period before a capitalized word ("Eq. (4)" and
    # formula periods don't qualify), so formulas survive intact.
    head = re.split(r"(?<=\.)\s+(?=[A-Z])", doc)[0]
    return head if head.endswith(".") else head + "."


def _sizing_signature(adapter, config) -> str:
    """Sizing kwargs of ``make(name, capacity, ...)``, from live signatures.

    Prefers the adapter's ``make_config`` when it names parameters beyond
    ``capacity``; otherwise falls back to the probed config class's
    ``for_capacity`` constructor (the lambda-adapter case).
    """
    for fn in (adapter.make_config, getattr(type(config), "for_capacity",
                                            None)):
        if fn is None:
            continue
        params = [p for p in inspect.signature(fn).parameters.values()
                  if p.name != "capacity"]
        named = [p for p in params
                 if p.kind not in (inspect.Parameter.VAR_KEYWORD,
                                   inspect.Parameter.VAR_POSITIONAL)]
        if not named:
            continue
        parts = []
        for p in named:
            if p.default is inspect.Parameter.empty:
                parts.append(p.name)
            else:
                parts.append(f"{p.name}={p.default!r}")
        if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
            parts.append("...")
        return ", ".join(parts)
    return "(capacity only)"


def _growth_ladder(adapter) -> str:
    if not adapter.growth_sizings:
        return "—"
    steps = []
    for overlay in adapter.growth_sizings:
        if not overlay:
            steps.append("(exact — no tightening needed)")
        else:
            steps.append(" ".join(f"{k}={v}" for k, v in overlay.items()))
    return " → ".join(steps)


def render() -> str:
    cap_fields = [f.name for f in dataclasses.fields(Capabilities)]
    lines = [HEADER]

    lines.append("## Capability matrix\n")
    short = {"supports_delete": "delete", "supports_bulk": "bulk",
             "supports_sharding": "sharding", "counting": "counting",
             "exact": "exact", "serial_insert": "serial insert",
             "supports_expand": "expand", "supports_mixed": "mixed",
             "supports_snapshot": "snapshot",
             "supports_tiering": "tiering"}
    lines.append("| backend | " + " | ".join(short[f] for f in cap_fields)
                 + " |")
    lines.append("|---" * (len(cap_fields) + 1) + "|")
    for name in amq.names():
        caps = amq.get(name).capabilities
        cells = " | ".join(_flag(getattr(caps, f)) for f in cap_fields)
        lines.append(f"| `{name}` | {cells} |")
    lines.append("")
    lines.append("Flag semantics are documented on "
                 "`repro.amq.protocol.Capabilities`; handles raise "
                 "`NotImplementedError` on capability violations instead "
                 "of degrading silently.\n")

    lines.append("## Per-backend sizing and FPR\n")
    for name in amq.names():
        adapter = amq.get(name)
        config = adapter.make_config(_PROBE_CAPACITY)
        lines.append(f"### `{name}`\n")
        lines.append(f"- **config**: `{type(config).__module__}."
                     f"{type(config).__qualname__}`")
        lines.append(f"- **expected FPR**: "
                     f"{_first_doc_sentence(type(config).expected_fpr)}")
        lines.append(f"- **sizing kwargs**: "
                     f"`{_sizing_signature(adapter, config)}`")
        lines.append(f"- **cascade growth ladder**: "
                     f"{_growth_ladder(adapter)}")
        lines.append("")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="verify docs/backends.md is current; do not write")
    args = ap.parse_args()
    text = render()
    if args.check:
        current = OUT.read_text() if OUT.exists() else ""
        if current != text:
            sys.stderr.write(
                f"{OUT} is stale — regenerate with "
                "`PYTHONPATH=src python tools/gen_backend_docs.py`\n")
            return 2
        print(f"{OUT} is in sync with the registry")
        return 0
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(text)
    print(f"wrote {OUT}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
