"""bench_compare — diff two directories of BENCH_*.json artifacts.

Guards the perf trajectory the bench-smoke artifacts seed: point it at a
baseline directory (e.g. the committed ``benchmarks/baseline/``) and a
fresh ``--json-dir`` output, and it reports the per-row delta for every
suite present in both, flagging rows whose ``us_per_call`` regressed past
``--threshold`` (relative, default 25%).

    PYTHONPATH=src python tools/bench_compare.py benchmarks/baseline \\
        bench-json --threshold 0.5 --warn-only

Suites or rows present on only one side are reported as ``added``
(candidate-only — a new benchmark) or ``removed`` (baseline-only — lost
coverage) and are never counted as perf regressions; ``--fail-on-missing``
turns *removed* entries into failures so CI catches a suite silently
dropping out of the smoke run. ``--suites a,b`` restricts the comparison
(and the missing check) to named suites — the gating invocation compares
the stable suites strictly while the full set stays warn-only.

**Trend mode**: a baseline directory may hold a *history* — flat
``BENCH_*.json`` files (the oldest run) plus any number of run
subdirectories (``benchmarks/baseline/run-YYYYMMDD/...``), ordered by
sorted subdirectory name. The candidate is then compared against the
``--agg`` aggregate of every run a row appears in:

* ``min`` (default) — the best time ever recorded: a monotone ratchet.
  A candidate must stay within ``--threshold`` of the best-known run, so
  perf can only be lost once before CI complains.
* ``median`` — the typical run: tolerant of one lucky outlier run.
* ``last`` — the newest run only: plain drift detection.

A baseline directory with no subdirectories is a one-run history, so diff
mode is unchanged. Exit status is 1 when regressions (or, with
``--fail-on-missing``, removals) were found, unless ``--warn-only``
(CI's log-everything mode: CPU-runner wall clocks are too noisy to gate
merges on across the board); 2 on empty/missing inputs.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys

AGGS = ("min", "median", "last")


def load_dir(path: pathlib.Path) -> dict:
    """{suite: {row_name: us_per_call}} for every BENCH_*.json in ``path``."""
    suites = {}
    for f in sorted(path.glob("BENCH_*.json")):
        payload = json.loads(f.read_text())
        suites[payload.get("suite", f.stem)] = {
            row["name"]: row["us_per_call"] for row in payload.get("rows", [])
        }
    return suites


def load_history(path: pathlib.Path) -> list:
    """Ordered run history under ``path`` (oldest first).

    The top-level flat ``BENCH_*.json`` files (when present) are the
    first run; each immediate subdirectory containing ``BENCH_*.json``
    is a later run, in sorted name order (date-stamped names sort
    chronologically). Returns ``[{suite: {row: us}}, ...]``.
    """
    runs = []
    top = load_dir(path)
    if top:
        runs.append(top)
    if path.is_dir():
        for sub in sorted(p for p in path.iterdir() if p.is_dir()):
            d = load_dir(sub)
            if d:
                runs.append(d)
    return runs


def aggregate(runs: list, agg: str) -> dict:
    """Collapse a run history into one {suite: {row: us}} per ``agg``.

    Each row aggregates over the runs it appears in — a row added halfway
    through the history ratchets on its own runs only.
    """
    if agg not in AGGS:
        raise ValueError(f"agg must be one of {AGGS}, got {agg!r}")
    if agg == "last":
        runs = runs[-1:]
    series = {}
    for run in runs:
        for suite, rows in run.items():
            for name, us in rows.items():
                series.setdefault(suite, {}).setdefault(name, []).append(us)
    fold = min if agg == "min" else statistics.median
    return {suite: {name: float(fold(vals)) for name, vals in rows.items()}
            for suite, rows in series.items()}


def compare(base: dict, new: dict, threshold: float) -> tuple:
    """Returns (report_lines, regressions, removed) across the suite union.

    ``regressions`` are shared rows past ``threshold``; ``removed`` are
    baseline suites/rows absent from the candidate (lost coverage —
    ``--fail-on-missing``'s subject). Candidate-only entries are reported
    as added and never counted.
    """
    lines, regressions, removed = [], [], []
    for suite in sorted(set(base) | set(new)):
        if suite not in new:
            lines.append(f"~ {suite}: removed (baseline-only)")
            removed.append((suite, None))
            continue
        if suite not in base:
            lines.append(f"~ {suite}: added (candidate-only)")
            continue
        b_rows, n_rows = base[suite], new[suite]
        for name in sorted(set(b_rows) | set(n_rows)):
            if name not in n_rows:
                lines.append(f"~ {suite}/{name}: removed (baseline-only)")
                removed.append((suite, name))
                continue
            if name not in b_rows:
                lines.append(f"~ {suite}/{name}: added (candidate-only)")
                continue
            b_us, n_us = b_rows[name], n_rows[name]
            if b_us <= 0.0:
                delta = 0.0 if n_us <= 0.0 else float("inf")
            else:
                delta = (n_us - b_us) / b_us
            mark = " "
            if delta > threshold:
                mark = "!"
                regressions.append((suite, name, delta))
            elif delta < -threshold:
                mark = "+"          # improvement past the threshold
            lines.append(f"{mark} {suite}/{name}: {b_us:.1f} -> {n_us:.1f} "
                         f"us_per_call ({delta:+.1%})")
    return lines, regressions, removed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="bench_compare", description=__doc__)
    ap.add_argument("baseline", type=pathlib.Path,
                    help="directory of baseline BENCH_*.json files")
    ap.add_argument("candidate", type=pathlib.Path,
                    help="directory of freshly produced BENCH_*.json files")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative us_per_call increase that counts as a "
                         "regression (default 0.25 = 25%%)")
    ap.add_argument("--suites", default=None,
                    help="comma-separated suite names to compare; others "
                         "are ignored on both sides")
    ap.add_argument("--agg", default="min", choices=AGGS,
                    help="how to collapse a multi-run baseline history: "
                         "min = best-ever (ratchet, default), median = "
                         "typical run, last = newest run only")
    ap.add_argument("--fail-on-missing", action="store_true",
                    help="baseline suites/rows absent from the candidate "
                         "fail the comparison (CI coverage guard)")
    ap.add_argument("--warn-only", action="store_true",
                    help="always exit 0 (CI smoke on noisy CPU runners)")
    args = ap.parse_args(argv)

    base_runs, new = load_history(args.baseline), load_dir(args.candidate)
    if not base_runs or not new:
        empty = args.baseline if not base_runs else args.candidate
        print(f"bench_compare: no BENCH_*.json under {empty}",
              file=sys.stderr)
        return 0 if args.warn_only else 2
    base = aggregate(base_runs, args.agg)
    if len(base_runs) > 1:
        print(f"# baseline history: {len(base_runs)} runs, agg={args.agg}")
    if args.suites is not None:
        keep = {s.strip() for s in args.suites.split(",") if s.strip()}
        unknown = keep - (set(base) | set(new))
        if unknown:
            print(f"bench_compare: --suites names not found on either "
                  f"side: {sorted(unknown)}", file=sys.stderr)
            return 0 if args.warn_only else 2
        # A suite filtered to one side only is *lost coverage*, not an
        # empty input: fall through so compare() reports it as removed.
        base = {s: r for s, r in base.items() if s in keep}
        new = {s: r for s, r in new.items() if s in keep}
    lines, regressions, removed = compare(base, new, args.threshold)
    print("\n".join(lines))
    failed = False
    if regressions:
        worst = max(regressions, key=lambda r: r[2])
        print(f"\n{len(regressions)} row(s) regressed past "
              f"{args.threshold:.0%} (worst: {worst[0]}/{worst[1]} "
              f"{worst[2]:+.1%})")
        failed = True
    if removed and args.fail_on_missing:
        names = ", ".join(s if n is None else f"{s}/{n}"
                          for s, n in removed[:8])
        print(f"\n{len(removed)} baseline entr(ies) missing from the "
              f"candidate: {names}")
        failed = True
    if failed:
        return 0 if args.warn_only else 1
    print(f"\nno regressions past {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
