"""bench_compare — diff two directories of BENCH_*.json artifacts.

Guards the perf trajectory the bench-smoke artifacts seed: point it at a
baseline directory (e.g. the committed ``benchmarks/baseline/``) and a
fresh ``--json-dir`` output, and it reports the per-row delta for every
suite present in both, flagging rows whose ``us_per_call`` regressed past
``--threshold`` (relative, default 25%).

    PYTHONPATH=src python tools/bench_compare.py benchmarks/baseline \\
        bench-json --threshold 0.5 --warn-only

Exit status is 1 when regressions were found, unless ``--warn-only``
(CI's mode: CPU-runner wall clocks are too noisy to gate merges on, but
the deltas belong in the log of every run). Rows present on only one
side are listed, never counted as regressions.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def load_dir(path: pathlib.Path) -> dict:
    """{suite: {row_name: us_per_call}} for every BENCH_*.json in ``path``."""
    suites = {}
    for f in sorted(path.glob("BENCH_*.json")):
        payload = json.loads(f.read_text())
        suites[payload.get("suite", f.stem)] = {
            row["name"]: row["us_per_call"] for row in payload.get("rows", [])
        }
    return suites


def compare(base: dict, new: dict, threshold: float) -> tuple:
    """Returns (report_lines, regressions) across the shared suites/rows."""
    lines, regressions = [], []
    for suite in sorted(set(base) | set(new)):
        if suite not in base or suite not in new:
            side = "baseline" if suite in base else "candidate"
            lines.append(f"~ {suite}: only in {side}")
            continue
        b_rows, n_rows = base[suite], new[suite]
        for name in sorted(set(b_rows) | set(n_rows)):
            if name not in b_rows or name not in n_rows:
                side = "baseline" if name in b_rows else "candidate"
                lines.append(f"~ {suite}/{name}: only in {side}")
                continue
            b_us, n_us = b_rows[name], n_rows[name]
            if b_us <= 0.0:
                delta = 0.0 if n_us <= 0.0 else float("inf")
            else:
                delta = (n_us - b_us) / b_us
            mark = " "
            if delta > threshold:
                mark = "!"
                regressions.append((suite, name, delta))
            elif delta < -threshold:
                mark = "+"          # improvement past the threshold
            lines.append(f"{mark} {suite}/{name}: {b_us:.1f} -> {n_us:.1f} "
                         f"us_per_call ({delta:+.1%})")
    return lines, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="bench_compare", description=__doc__)
    ap.add_argument("baseline", type=pathlib.Path,
                    help="directory of baseline BENCH_*.json files")
    ap.add_argument("candidate", type=pathlib.Path,
                    help="directory of freshly produced BENCH_*.json files")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative us_per_call increase that counts as a "
                         "regression (default 0.25 = 25%%)")
    ap.add_argument("--warn-only", action="store_true",
                    help="always exit 0 (CI smoke on noisy CPU runners)")
    args = ap.parse_args(argv)

    base, new = load_dir(args.baseline), load_dir(args.candidate)
    if not base or not new:
        empty = args.baseline if not base else args.candidate
        print(f"bench_compare: no BENCH_*.json under {empty}",
              file=sys.stderr)
        return 0 if args.warn_only else 2
    lines, regressions = compare(base, new, args.threshold)
    print("\n".join(lines))
    if regressions:
        worst = max(regressions, key=lambda r: r[2])
        print(f"\n{len(regressions)} row(s) regressed past "
              f"{args.threshold:.0%} (worst: {worst[0]}/{worst[1]} "
              f"{worst[2]:+.1%})")
        return 0 if args.warn_only else 1
    print(f"\nno regressions past {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
