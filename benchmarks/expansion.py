"""Auto-expanding cascade vs right-sized static filters (DESIGN.md §8).

Streams keys into `amq.make(..., auto_expand=True)` from 1x to 16x the
initial capacity and, at each power-of-two occupancy milestone, compares
against a *right-sized* static filter built with hindsight:

* cumulative insert throughput (cascade pays growth + retry rounds),
* query throughput (cascade fans over all levels in one fused pass),
* measured FPR vs the cascade's declared budget (the split-budget claim),
* zero false negatives over everything inserted so far.

Acceptance (ISSUE 3): sustained inserts to >=8x with no false negatives,
measured FPR within budget, and cascade query throughput within 3x of the
static filter at the 8x milestone.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro import amq

from .common import bench, emit, rand_keys, throughput_m_per_s

MILESTONES = (1, 2, 4, 8, 16)


def _build_static(backend: str, n_keys: int, keys):
    """A static filter sized (with hindsight) to exactly the streamed load."""
    handle = amq.make(backend, capacity=int(np.ceil(n_keys / 0.85)))
    handle.insert(keys, bulk=True)
    return handle


def run(fast: bool = False, backend: str = "cuckoo") -> None:
    initial = 1 << 12 if fast else 1 << 15
    batch = initial // 4
    n_neg = 1 << 14
    keys = rand_keys(MILESTONES[-1] * initial, seed=3)
    neg = rand_keys(n_neg, seed=9, lo=2**63, hi=2**64)
    probe = keys[:batch]

    cascade = amq.make(backend, capacity=initial, auto_expand=True)
    budget = cascade.fpr_budget
    inserted = 0
    t_insert = 0.0
    for multiple in MILESTONES:
        target = multiple * initial
        while inserted < target:
            chunk = keys[inserted:inserted + batch]
            t0 = time.perf_counter()
            report = cascade.insert(chunk, bulk=True)
            # A chunk that crosses a growth boundary touches two levels —
            # barrier on every level's state so no async work leaks out of
            # the timed region.
            jax.block_until_ready([lvl.state for lvl in cascade.levels])
            t_insert += time.perf_counter() - t0
            if not np.asarray(report.ok).all():
                emit(f"expansion_insert_refused_{multiple}x", 0.0,
                     f"{int((~np.asarray(report.ok)).sum())}_keys")
            inserted += batch

        levels = len(cascade.levels)
        us_cum = t_insert * 1e6
        emit(f"expansion_insert_cascade_{multiple}x", us_cum / inserted * batch,
             f"{throughput_m_per_s(inserted, us_cum)};levels={levels}")

        # No false negatives over everything streamed so far (checked in
        # per-batch windows to keep query shapes bounded).
        false_negs = 0
        for start in range(0, inserted, 4 * batch):
            window = keys[start:start + 4 * batch]
            false_negs += int((~np.asarray(cascade.query(window).hits)).sum())
        fpr_c = float(np.asarray(cascade.query(neg).hits).mean())
        us_cq = bench(lambda: cascade.query(probe))

        static = _build_static(backend, inserted, keys[:inserted])
        us_sq = bench(lambda: static.query(probe))
        fpr_s = float(np.asarray(static.query(neg).hits).mean())

        ratio = us_cq / us_sq
        emit(f"expansion_query_cascade_{multiple}x", us_cq,
             f"{throughput_m_per_s(batch, us_cq)};{ratio:.2f}x_static"
             f";false_negatives={false_negs}")
        emit(f"expansion_query_static_{multiple}x", us_sq,
             throughput_m_per_s(batch, us_sq))
        emit(f"expansion_fpr_{multiple}x", 0.0,
             f"cascade={fpr_c:.2e};budget={budget:.2e};static={fpr_s:.2e}"
             f";bytes_ratio={cascade.table_bytes / static.table_bytes:.2f}")


if __name__ == "__main__":
    run(fast=True)
