"""Serving-SLO harness: deadline/backpressure sweep under synthetic load.

Drives the DESIGN.md §11 serving engine the way a fleet front-end would:
thousands of synthetic clients submit variable-size op streams whose keys
follow a zipfian popularity curve and whose arrivals are bursty (on/off
periods with Poisson arrivals inside each burst). The harness sweeps
deadline x batch-size x admission policy across >=3 registry backends and
reports p50/p99 *enqueue-to-ready* latency plus sustained ops/s per cell,
all emitted into ``BENCH_serving_slo.json``.

Timing model: the service runs on a **virtual clock** (injected via
``FilterService(clock=...)``). Arrival timestamps advance the clock, and
the *measured wall time* of every submit/poll/drain call is added on top —
so latencies combine genuine queueing/deadline waits (virtual) with
genuine dispatch compute (real), and ``ops/s`` is acknowledged ops over
the final clock reading. This keeps deadline behaviour deterministic per
seed while still charging real XLA execution cost.

Two scripted scenario cells ride the sweep:

* **hot swap under live traffic** — a sharded service is resharded
  (K -> K') mid-trace via :meth:`~repro.amq.FilterService.hot_swap`; the
  cell asserts *zero acknowledged-op loss* (every acked+routed insert
  still queries positive afterwards).
* **admission bound** — ``shed`` and ``error`` policies with
  ``max_pending`` far below the batch size; the cell asserts the observed
  queue depth never exceeded the configured bound.
"""

from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro import amq
from repro.amq.dispatch import batch_align, shape_ladder
from repro.amq.protocol import OP_QUERY, OpBatch
from repro.core import keys_from_numpy

from .common import emit, emit_json

ZIPF_A = 1.3           # key/client popularity skew
OPS_MIX = (0.70, 0.25, 0.05)     # query / insert / delete


class SimClock:
    """Virtual service clock the harness advances by hand."""

    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now


def _make_trace(*, n_events: int, n_clients: int, key_space: int,
                seed: int, deletes: bool = True):
    """(t_arrival, client, keys, ops) events: zipfian keys, bursty arrivals."""
    rng = np.random.default_rng(seed)
    universe = keys_from_numpy(np.unique(rng.integers(
        1, 2**63, size=key_space * 2, dtype=np.uint64))[:key_space])
    sizes = rng.integers(1, 17, size=n_events)
    clients = (rng.zipf(ZIPF_A, size=n_events) - 1) % n_clients
    # on/off burstiness: Poisson arrivals inside bursts, long gaps between.
    gaps = rng.exponential(0.0005, size=n_events)          # ~2k arrivals/s on
    burst_len = np.maximum(1, rng.poisson(40, size=n_events))
    off_at = np.cumsum(burst_len) % n_events
    gaps[off_at[off_at < n_events]] += rng.exponential(
        0.02, size=(off_at < n_events).sum())              # off periods
    t_arrival = np.cumsum(gaps)
    p = np.asarray(OPS_MIX if deletes else (OPS_MIX[0], 1 - OPS_MIX[0], 0.0))
    trace = []
    for i in range(n_events):
        m = int(sizes[i])
        picks = (rng.zipf(ZIPF_A, size=m) - 1) % key_space
        ops = rng.choice(3, size=m, p=p).astype(np.int32)
        trace.append((float(t_arrival[i]), f"c{clients[i]}",
                      universe[picks], ops))
    return trace


def _warm(handle, batch_size: int):
    """Compile every ladder rung with no-op queries before measuring.

    First-dispatch XLA compilation is seconds of wall time per rung; left
    in the trace it would dominate every latency percentile. Queries leave
    the filter contents untouched, so warmed cells start from a clean
    state with hot jit caches.
    """
    probe = jnp.zeros((1, 2), jnp.uint32)
    for rung in shape_ladder(batch_size, batch_align(handle)):
        handle.apply_ops(OpBatch.make(
            probe, jnp.full((1,), OP_QUERY, jnp.int32)).pad_to(rung))
    return handle


def _drive(svc, clock, trace, *, mid_trace=None):
    """Replay a trace through the service; returns (tickets, rejected, wall)."""
    tickets, rejected = [], 0
    wall0 = time.perf_counter()
    for i, (t, client, keys, ops) in enumerate(trace):
        if mid_trace is not None and i == len(trace) // 2:
            mid_trace(svc)
        clock.now = max(clock.now, t)
        t0 = time.perf_counter()
        try:
            tickets.append((svc.submit(keys, ops, client=client), keys, ops))
        except amq.QueueFullError:
            rejected += 1
        clock.now += time.perf_counter() - t0
    t0 = time.perf_counter()
    svc.drain()
    clock.now += time.perf_counter() - t0
    return tickets, rejected, time.perf_counter() - wall0


def _cell(snap, clock, *, rejected, wall_s, label):
    """CSV row + JSON record for one sweep cell."""
    p50_us = snap["ready"]["p50_s"] * 1e6
    p99_us = snap["ready"]["p99_s"] * 1e6
    acked = snap["dispatched_ops"]
    ops_per_s = acked / max(clock.now, 1e-9)
    emit(label, p99_us,
         f"p50={p50_us:.0f}us_sustained={ops_per_s / 1e3:.1f}k_ops_per_s")
    return {"label": label, "p50_us": p50_us, "p99_us": p99_us,
            "acked_ops": acked, "shed_ops": snap["shed_ops"],
            "rejected_submissions": rejected,
            "sustained_ops_per_s": ops_per_s,
            "wall_s": wall_s, "sim_s": clock.now,
            "padding_waste": snap["padding_waste"],
            "dispatch_kinds": snap["dispatch_kinds"],
            "queue_depth_max": snap["queue_depth_max"]}


def _backend_kw(backend):
    return {"partitions_per_shard": 2} if backend == "sharded-cuckoo" else {}


def run(fast: bool = False) -> None:
    n_events = 400 if fast else 2000
    n_clients = 256 if fast else 2048
    key_space = 1 << 12 if fast else 1 << 15
    capacity = 1 << 15 if fast else 1 << 18
    payload: dict = {"n_events": n_events, "n_clients": n_clients,
                     "key_space": key_space, "zipf_a": ZIPF_A,
                     "cells": []}

    backends = ("cuckoo", "sharded-cuckoo", "bloom")
    batch_sizes = (256,) if fast else (256, 1024)
    deadlines = (0.002,) if fast else (None, 0.002)

    # -- the main sweep: backend x batch x deadline ------------------------
    for backend in backends:
        deletes = amq.get(backend).capabilities.supports_delete
        trace = _make_trace(n_events=n_events, n_clients=n_clients,
                            key_space=key_space, seed=7, deletes=deletes)
        for batch_size in batch_sizes:
            for max_delay in deadlines:
                clock = SimClock()
                svc = amq.FilterService(
                    _warm(amq.make(backend, capacity=capacity,
                                   **_backend_kw(backend)), batch_size),
                    batch_size=batch_size, max_delay=max_delay, clock=clock)
                _, rejected, wall = _drive(svc, clock, trace)
                dl = "none" if max_delay is None else f"{max_delay * 1e3:g}ms"
                payload["cells"].append(_cell(
                    svc.stats(), clock, rejected=rejected, wall_s=wall,
                    label=f"slo_{backend}_bs{batch_size}_dl{dl}"))

    # -- admission policies keep the queue at its configured bound ---------
    bound = 64
    trace = _make_trace(n_events=n_events // 2, n_clients=n_clients,
                        key_space=key_space, seed=11)
    for admission in ("block", "shed", "error"):
        clock = SimClock()
        svc = amq.FilterService(
            _warm(amq.make("cuckoo", capacity=capacity), 256),
            batch_size=256, max_pending=bound, admission=admission,
            max_delay=0.002, clock=clock)
        _, rejected, wall = _drive(svc, clock, trace)
        snap = svc.stats()
        assert snap["queue_depth_max"] <= bound, \
            f"{admission}: queue depth {snap['queue_depth_max']} > {bound}"
        rec = _cell(snap, clock, rejected=rejected, wall_s=wall,
                    label=f"slo_admission_{admission}_bound{bound}")
        rec["max_pending"] = bound
        payload["cells"].append(rec)

    # -- hot swap (with K -> K' reshard) under live traffic ----------------
    clock = SimClock()
    svc = amq.FilterService(
        _warm(amq.make("sharded-cuckoo", capacity=capacity,
                       partitions_per_shard=2), 256),
        batch_size=256, max_delay=0.002, clock=clock)
    trace = _make_trace(n_events=n_events // 2, n_clients=n_clients,
                        key_space=key_space, seed=13)
    swap_info = {}

    def _swap(service):
        swap_info.update(service.hot_swap(
            _warm(service.handle.resharded(num_shards=1), 256)))

    tickets, rejected, wall = _drive(svc, clock, trace, mid_trace=_swap)
    # zero acknowledged-op loss: every acked+routed insert still present.
    acked = {}
    for ticket, keys, ops in tickets:
        ok, routed = ticket.result(), ticket.routed()
        for j in np.flatnonzero((ops == amq.OP_INSERT) & ok & routed):
            acked[tuple(keys[j])] = True
        for j in np.flatnonzero((ops == amq.OP_DELETE) & ok & routed):
            acked.pop(tuple(keys[j]), None)
    if acked:
        probe = np.asarray(list(acked), np.uint32)
        hits = svc.query(probe).result()
        assert hits.all(), \
            f"hot swap lost {int((~hits).sum())} acknowledged inserts"
    rec = _cell(svc.stats(), clock, rejected=rejected, wall_s=wall,
                label="slo_hot_swap_reshard_live")
    rec["swap"] = {k: swap_info[k] for k in
                   ("pause_s", "drained_ops", "migrated",
                    "old_backend", "new_backend")}
    rec["acked_inserts_verified"] = len(acked)
    rec["zero_acked_loss"] = True
    payload["cells"].append(rec)

    emit_json("serving_slo", payload)
