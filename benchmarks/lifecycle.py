"""Filter-state lifecycle costs: snapshot, restore, reshard, hot-swap.

Measures the operational primitives of DESIGN.md §10 on the serving-scale
configurations the lifecycle subsystem exists for:

* **snapshot** — device→host pull of the packed state (GB/s of table),
* **restore** — host→device placement + validation onto a fresh handle,
* **reshard** — the sharded backend's exact K→K′ partition relocation
  (snapshot → restore under a resharded config, zero membership change),
* **hot-swap pause** — wall-clock a loaded :class:`~repro.amq.FilterService`
  cannot accept dispatches while draining + migrating onto a new backend
  (the zero-downtime claim is that *only* this pause is paid — tickets
  issued before the swap stay readable and no acknowledged op is lost).

Emits CSV rows via benchmarks.common plus a machine-readable payload under
``BENCH_lifecycle.json`` (CI's bench-smoke artifact), seeding the perf
trajectory for snapshot/restore throughput and swap pause across commits.
"""

from __future__ import annotations

import time

import numpy as np

from repro import amq

from .common import emit, emit_json, rand_keys, throughput_m_per_s


def _mb_per_s(nbytes: int, us: float) -> str:
    return f"{nbytes / max(us, 1e-9):.1f}MB_per_s"


def _timed(fn, iters: int):
    best = float("inf")
    out = None
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def run(fast: bool = False) -> None:
    capacity = 1 << 14 if fast else 1 << 18
    n_keys = int(capacity * 0.8)
    iters = 3 if fast else 5
    keys = rand_keys(n_keys, seed=11)
    payload: dict = {"capacity": capacity, "n_keys": n_keys}

    # -- snapshot / restore on the core backend ------------------------------
    handle = amq.make("cuckoo", capacity=capacity)
    handle.insert(keys, bulk=True)
    snap, snap_us = _timed(handle.snapshot, iters)
    emit("lifecycle_snapshot_cuckoo", snap_us, _mb_per_s(snap.nbytes, snap_us))

    twin = amq.make("cuckoo", config=handle.config)
    _, restore_us = _timed(lambda: twin.restore(snap), iters)
    emit("lifecycle_restore_cuckoo", restore_us,
         _mb_per_s(snap.nbytes, restore_us))
    assert twin.count() == handle.count()
    payload["snapshot"] = {"bytes": snap.nbytes, "us": snap_us,
                           "restore_us": restore_us}

    # -- exact resharding (fixed partitions, K -> K') ------------------------
    sharded = amq.make("sharded-cuckoo", capacity=capacity,
                       partitions_per_shard=8)
    sharded.insert(keys)
    pre = np.asarray(sharded.query(keys).hits)

    def _reshard():
        return sharded.resharded(num_shards=1)

    moved, reshard_us = _timed(_reshard, iters)
    post = np.asarray(moved.query(keys).hits)
    assert (pre == post).all(), "reshard changed membership"
    ssnap = sharded.snapshot()
    emit("lifecycle_reshard_sharded", reshard_us,
         _mb_per_s(ssnap.nbytes, reshard_us))
    payload["reshard"] = {"bytes": ssnap.nbytes, "us": reshard_us,
                          "partitions": sharded.config.inner.partitions,
                          "membership_preserved": True}

    # -- hot-swap pause under a live service ---------------------------------
    svc = amq.FilterService(amq.make("cuckoo", capacity=capacity),
                            batch_size=1024)
    svc.insert(keys)          # acknowledged load the swap must carry over
    svc.query(keys[: 1024 // 2])   # leave a partial batch pending
    swap = svc.hot_swap(amq.make("cuckoo", config=svc.handle.config))
    pause_us = swap["pause_s"] * 1e6
    emit("lifecycle_hot_swap_pause", pause_us,
         f"drained={swap['drained_ops']}")
    survived = svc.query(keys).result()
    assert survived.all(), "hot swap lost acknowledged inserts"
    payload["hot_swap"] = {"pause_us": pause_us,
                           "drained_ops": swap["drained_ops"]}

    # Serving-rate context: how many op-batches the pause is worth.
    batch = rand_keys(1024, seed=13)
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        svc.query(batch).result()
    per_batch_us = (time.perf_counter() - t0) / reps * 1e6
    emit("lifecycle_pause_in_batches", pause_us / max(per_batch_us, 1e-9),
         f"{throughput_m_per_s(1024, per_batch_us)}_steady_state")
    payload["hot_swap"]["pause_in_batches"] = pause_us / max(per_batch_us,
                                                             1e-9)

    emit_json("lifecycle", payload)
