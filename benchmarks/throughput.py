"""Paper Fig. 3: insert / query+ / query- / delete throughput across filters.

Two memory regimes as in §5.2: cache-resident (small table) and
memory-resident (large table). All dynamic filters use 16-bit fingerprints;
the blocked Bloom filter gets the equivalent 16 bits/key.

Backends come from the unified AMQ registry (``repro.amq``): the loop
iterates every registered adapter and branches on *capability flags only* —
no per-filter special-case tuples. Sharded backends are skipped (this is
the single-device figure; the mesh scale-out has its own benchmark), and
serially-inserting structures (the GQF's Robin-Hood shifting — the property
the paper punishes it for) get their prefill capped in the large regime
rather than hand-naming "gqf".
"""

from __future__ import annotations

import functools

import jax

from repro import amq

from .common import bench, emit, rand_keys, throughput_m_per_s

REGIMES = {
    "small": 1 << 14,   # cache-resident analogue
    "large": 1 << 18,   # memory-resident analogue
}
LOAD = 0.95
BATCH = 1 << 13


def _bench_backends():
    """(name, adapter) pairs this figure measures, by capability."""
    for name in amq.names():
        ad = amq.get(name)
        if not ad.jit:
            # host-side backends (the Python oracle, mesh-sharded programs)
            # are measured by run_cpu_reference / the sharding benchmark
            continue
        if ad.capabilities.supports_sharding:
            continue
        yield name, ad


def run(fast: bool = False):
    regimes = {"small": REGIMES["small"]} if fast else REGIMES
    for regime, slots in regimes.items():
        capacity = int(slots * LOAD)
        n_fill = capacity - BATCH  # pre-fill, then measure one hot batch
        fill = rand_keys(max(n_fill, 1), seed=1)
        hot = rand_keys(BATCH, seed=2)
        neg = rand_keys(BATCH, seed=3, lo=2**63, hi=2**64)
        for name, ad in _bench_backends():
            caps = ad.capabilities
            if fast and caps.serial_insert:
                continue
            if caps.serial_insert and slots > REGIMES["small"]:
                # Serial shift chains (strict inter-key dependencies) make a
                # large sequential prefill prohibitive on one core — cap the
                # structure to the small regime and record the cap.
                handle = amq.make(name,
                                  capacity=int(REGIMES["small"] * LOAD))
                small_fill = fill[: handle.config.num_slots - BATCH]
                handle.insert(small_fill)
                emit(f"fig3_note_{regime}_{name}", 0.0,
                     "capped_to_small_capacity_serial_structure")
            else:
                handle = amq.make(name, capacity=capacity)
                handle.insert(fill)

            # Functional ops jitted here (donation-free: bench reuses one
            # state across iterations) — same uniform surface per backend.
            cfg = handle.config
            jins = jax.jit(functools.partial(ad.insert, cfg))
            jqry = jax.jit(functools.partial(ad.query, cfg))

            pre_state = handle.state  # measure against the pre-fill table
            us = bench(lambda s=pre_state: jins(s, hot))
            emit(f"fig3_insert_{regime}_{name}", us,
                 throughput_m_per_s(BATCH, us))
            if caps.supports_bulk:
                # bulk-build fast path (DESIGN.md §6) on the same hot batch
                jbulk = jax.jit(functools.partial(ad.insert_bulk, cfg))
                us = bench(lambda s=pre_state: jbulk(s, hot))
                emit(f"fig3_insert_bulk_{regime}_{name}", us,
                     throughput_m_per_s(BATCH, us))

            handle.insert(hot)  # now actually at full load
            full_state = handle.state
            us = bench(lambda s=full_state: jqry(s, hot))
            emit(f"fig3_query_pos_{regime}_{name}", us,
                 throughput_m_per_s(BATCH, us))
            us = bench(lambda s=full_state: jqry(s, neg))
            emit(f"fig3_query_neg_{regime}_{name}", us,
                 throughput_m_per_s(BATCH, us))

            if caps.supports_delete:
                jdel = jax.jit(functools.partial(ad.delete, cfg))
                us = bench(lambda s=full_state: jdel(s, hot))
                emit(f"fig3_delete_{regime}_{name}", us,
                     throughput_m_per_s(BATCH, us))


def run_cpu_reference(fast: bool = False):
    """PCF stand-in (pure Python) — the CPU baseline row of Fig. 3."""
    import time

    import numpy as np

    from repro.core.hashing import keys_from_numpy
    from repro.filters import PyCuckooConfig

    n = 1 << 10
    rng = np.random.default_rng(0)
    keys = keys_from_numpy(rng.integers(0, 2**63, size=n, dtype=np.uint64))
    # Same regime as the pre-registry baseline: a 1024-bucket table probed
    # well under load (this row measures per-op Python cost, not thrash).
    handle = amq.make("cpu-cuckoo", config=PyCuckooConfig(
        num_buckets=1 << 10, hash_kind="fmix32"))
    t0 = time.perf_counter()
    handle.insert(keys)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig3_insert_small_pcf_python", us, throughput_m_per_s(n, us))
    t0 = time.perf_counter()
    handle.query(keys)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig3_query_pos_small_pcf_python", us, throughput_m_per_s(n, us))
