"""Paper Fig. 3: insert / query+ / query- / delete throughput across filters.

Two memory regimes as in §5.2: cache-resident (small table) and
memory-resident (large table). All dynamic filters use 16-bit fingerprints;
the blocked Bloom filter gets the equivalent 16 bits/key.
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import CuckooConfig
from repro.core import cuckoo_filter as CF
from repro.filters import bcht as HT
from repro.filters import blocked_bloom as BB
from repro.filters import quotient as QF
from repro.filters import two_choice as TC

from .common import bench, emit, rand_keys, throughput_m_per_s

REGIMES = {
    "small": 1 << 14,   # cache-resident analogue
    "large": 1 << 18,   # memory-resident analogue
}
LOAD = 0.95
BATCH = 1 << 13


def _filters(capacity):
    return {
        "cuckoo": (CuckooConfig.for_capacity(capacity, LOAD,
                                             hash_kind="fmix32"),
                   CF.insert, CF.query, CF.delete, lambda c: c.init()),
        "bloom": (BB.BloomConfig.for_capacity(capacity, 16),
                  BB.insert, BB.query, None, lambda c: c.init()),
        "tcf": (TC.TCFConfig.for_capacity(capacity, LOAD),
                TC.insert, TC.query, TC.delete, lambda c: c.init()),
        "gqf": (QF.GQFConfig.for_capacity(capacity, LOAD),
                QF.insert, QF.query, QF.delete, lambda c: c.init()),
        "bcht": (HT.BCHTConfig.for_capacity(capacity, 0.9),
                 HT.insert, HT.query, HT.delete, lambda c: c.init()),
    }


def run(fast: bool = False):
    regimes = {"small": REGIMES["small"]} if fast else REGIMES
    for regime, slots in regimes.items():
        capacity = int(slots * LOAD)
        n_fill = capacity - BATCH  # pre-fill, then measure one hot batch
        fill = rand_keys(max(n_fill, 1), seed=1)
        hot = rand_keys(BATCH, seed=2)
        neg = rand_keys(BATCH, seed=3, lo=2**63, hi=2**64)
        for name, (cfg, ins, qry, dele, init) in _filters(capacity).items():
            if fast and name in ("gqf", "bcht"):
                continue
            if name == "gqf" and slots > REGIMES["small"]:
                # the GQF's Robin-Hood insert is *serial* (the property the
                # paper punishes it for); a 240k-key sequential prefill on
                # one interpreted CPU core is hours — cap its large regime.
                cfg = QF.GQFConfig.for_capacity(int(REGIMES["small"] * LOAD),
                                                LOAD)
                state = init(cfg)
                jins = jax.jit(functools.partial(ins, cfg))
                jqry = jax.jit(functools.partial(qry, cfg))
                small_fill = fill[: cfg.num_slots - BATCH]
                state = jax.block_until_ready(jins(state, small_fill)[0])
                emit(f"fig3_note_{regime}_gqf", 0.0,
                     "capped_to_small_capacity_serial_structure")
            else:
                state = init(cfg)
                jins = jax.jit(functools.partial(ins, cfg))
                jqry = jax.jit(functools.partial(qry, cfg))
                state = jax.block_until_ready(jins(state, fill)[0])

            us = bench(lambda s=state: jins(s, hot))
            emit(f"fig3_insert_{regime}_{name}", us,
                 throughput_m_per_s(BATCH, us))
            if name == "cuckoo":
                # bulk-build fast path (DESIGN.md §6) on the same hot batch
                jbulk = jax.jit(functools.partial(CF.insert_bulk, cfg))
                us = bench(lambda s=state: jbulk(s, hot))
                emit(f"fig3_insert_bulk_{regime}_{name}", us,
                     throughput_m_per_s(BATCH, us))
            out = jins(state, hot)
            state_full = out[0]

            us = bench(lambda: jqry(state_full, hot))
            emit(f"fig3_query_pos_{regime}_{name}", us,
                 throughput_m_per_s(BATCH, us))
            us = bench(lambda: jqry(state_full, neg))
            emit(f"fig3_query_neg_{regime}_{name}", us,
                 throughput_m_per_s(BATCH, us))

            if dele is not None:
                jdel = jax.jit(functools.partial(dele, cfg))
                us = bench(lambda s=state_full: jdel(s, hot))
                emit(f"fig3_delete_{regime}_{name}", us,
                     throughput_m_per_s(BATCH, us))


def run_cpu_reference(fast: bool = False):
    """PCF stand-in (pure Python) — the CPU baseline row of Fig. 3."""
    import time

    from repro.filters import PyCuckooFilter

    n = 1 << 10
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**63, size=n, dtype=np.uint64)
    pf = PyCuckooFilter(1 << 10, hash_kind="fmix32")
    t0 = time.perf_counter()
    pf.insert_batch(keys)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig3_insert_small_pcf_python", us, throughput_m_per_s(n, us))
    t0 = time.perf_counter()
    pf.query_batch(keys)
    us = (time.perf_counter() - t0) * 1e6
    emit("fig3_query_pos_small_pcf_python", us, throughput_m_per_s(n, us))
