"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json and emits, per (arch x shape x mesh):
compute/memory/collective terms, the dominant bottleneck, MODEL_FLOPS ratio,
and the projected roofline fraction (dominant-term bound vs compute bound).
"""

from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run(fast: bool = False):
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        # No dry-run artifacts (the default in CI): skip cleanly instead of
        # emitting a junk `roofline_missing` row into the suite's JSON —
        # the filter roofline rows (roofline_filters.py) carry the suite.
        import sys
        print("# roofline: no results/dryrun artifacts, skipping projection "
              "rows (run repro.launch.dryrun to produce them)",
              file=sys.stderr)
        return
    for f in files:
        d = json.load(open(f))
        name = os.path.basename(f)[:-5]
        if d.get("status") != "ok":
            emit(f"roofline_{name}", 0.0, f"ERROR={d.get('error', '?')[:60]}")
            continue
        r = d["roofline"]
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / step if step else 0.0
        emit(f"roofline_{name}", step * 1e6,
             f"dom={d['dominant'][:-2]}_comp={r['compute_s']:.2e}"
             f"_mem={r['memory_s']:.2e}_coll={r['collective_s']:.2e}"
             f"_roofline_frac={frac:.3f}"
             f"_useful={d['useful_flop_ratio']:.2f}"
             f"_fits16g={d['memory']['fits_16gb']}")
