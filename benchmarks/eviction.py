"""Paper Figs. 5 + 6: BFS vs DFS eviction at increasing load factors.

Methodology follows §5.4.1: pre-fill to 3/4 of the target load, then measure
only the contended final quarter — tail eviction-chain percentiles and batch
loop rounds (Fig. 5) and insertion throughput (Fig. 6), for both eviction
policies up to 0.95+ load. Each cell also lands as a structured JSON record
in ``BENCH_fig5_6.json`` (``common.emit_json``) so the committed baseline
can trend-compare the eviction behaviour, not just the wall clocks.
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import CuckooConfig
from repro.core import cuckoo_filter as CF

from .common import bench, emit, emit_json, rand_keys, throughput_m_per_s

SUITE = "fig5_6"


def run(fast: bool = False):
    # Fast mode shrinks the table, not the sweep: the bfs-vs-dfs contrast
    # lives at high load, so 0.95 stays in the CI cell set.
    slots = 1 << 14 if fast else 1 << 16
    loads = [0.75, 0.85, 0.95] if fast else [0.75, 0.85, 0.90, 0.95, 0.98]
    records = []
    for evic in ("dfs", "bfs"):
        cfg = CuckooConfig(
            num_buckets=slots // 16, fp_bits=16, bucket_size=16,
            policy="xor", eviction=evic, hash_kind="fmix32",
            max_evictions=256)
        jins = jax.jit(functools.partial(CF.insert, cfg))
        for load in loads:
            n = int(slots * load)
            pre, hot = 3 * n // 4, n - 3 * n // 4
            keys = rand_keys(n, seed=int(load * 100))
            state = cfg.init()
            state = jax.block_until_ready(jins(state, keys[:pre])[0])

            state2, ok, stats = jins(state, keys[pre:])
            ev = np.asarray(stats.evictions)
            rounds = int(np.asarray(stats.rounds))
            fails = int((~np.asarray(ok)).sum())
            p90, p95, p99 = np.percentile(ev, [90, 95, 99])
            emit(f"fig5_evictions_{evic}_load{int(load * 100)}", 0.0,
                 f"p90={p90:.0f}_p95={p95:.0f}_p99={p99:.0f}"
                 f"_rounds={rounds}_fail={fails}")

            us = bench(lambda s=state: jins(s, keys[pre:]))
            emit(f"fig6_insert_{evic}_load{int(load * 100)}", us,
                 throughput_m_per_s(hot, us))
            records.append({
                "eviction": evic, "load": load, "slots": slots,
                "hot_keys": hot, "rounds": rounds, "fails": fails,
                "evictions_p90": float(p90), "evictions_p95": float(p95),
                "evictions_p99": float(p99), "insert_us": us,
                "m_keys_per_s": hot / us,
            })
    emit_json(SUITE, {"slots": slots, "loads": loads, "records": records})
