"""Paper Figs. 5 + 6: BFS vs DFS eviction at increasing load factors.

Methodology follows §5.4.1: pre-fill to 3/4 of the target load, then measure
only the contended final quarter — tail eviction-chain percentiles (Fig. 5)
and insertion throughput (Fig. 6).
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import CuckooConfig
from repro.core import cuckoo_filter as CF

from .common import bench, emit, rand_keys, throughput_m_per_s

SLOTS = 1 << 16


def run(fast: bool = False):
    loads = [0.75, 0.85] if fast else [0.75, 0.85, 0.90, 0.95, 0.98]
    for evic in ("dfs", "bfs"):
        cfg = CuckooConfig(
            num_buckets=SLOTS // 16, fp_bits=16, bucket_size=16,
            policy="xor", eviction=evic, hash_kind="fmix32",
            max_evictions=256)
        jins = jax.jit(functools.partial(CF.insert, cfg))
        for load in loads:
            n = int(SLOTS * load)
            pre, hot = 3 * n // 4, n - 3 * n // 4
            keys = rand_keys(n, seed=int(load * 100))
            state = cfg.init()
            state = jax.block_until_ready(jins(state, keys[:pre])[0])

            state2, ok, stats = jins(state, keys[pre:])
            ev = np.asarray(stats.evictions)
            p90, p95, p99 = np.percentile(ev, [90, 95, 99])
            emit(f"fig5_evictions_{evic}_load{int(load * 100)}", 0.0,
                 f"p90={p90:.0f}_p95={p95:.0f}_p99={p99:.0f}"
                 f"_fail={int((~np.asarray(ok)).sum())}")

            us = bench(lambda s=state: jins(s, keys[pre:]))
            emit(f"fig6_insert_{evic}_load{int(load * 100)}", us,
                 throughput_m_per_s(hot, us))
