"""Paper Figs. 5 + 6: insertion engines vs load factor (DESIGN.md §14).

Methodology follows §5.4.1: pre-fill to 3/4 of the target load, then measure
only the contended final quarter — tail eviction-chain percentiles and batch
loop rounds (Fig. 5) and insertion throughput (Fig. 6). Four engines share
the sweep:

* ``dfs`` / ``bfs`` — the legacy round-loop (``insert_engine="legacy"``
  pinned, so these rows keep measuring the committed baseline's path even
  now that ``auto`` routes elsewhere);
* ``frontier`` — the batched BFS frontier search (incremental ``insert``);
* ``orient`` — the graph-orientation bulk build (``insert_bulk``).

Every cell lands as a structured JSON record in ``BENCH_fig5_6.json`` with
its failed-insert *rate*; any failure at load ≤ 0.95 raises (a suite error
makes every row go missing, which the CI ratchet's ``--fail-on-missing``
turns into a loud failure rather than a silently absent cell).
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import CuckooConfig
from repro.core import cuckoo_filter as CF

from .common import bench, emit, emit_json, rand_keys, throughput_m_per_s

SUITE = "fig5_6"

# label -> (eviction policy, insert_engine, bulk entry point?)
ENGINES = {
    "dfs": ("dfs", "legacy", False),
    "bfs": ("bfs", "legacy", False),
    "frontier": ("bfs", "frontier", False),
    "orient": ("bfs", "orientation", True),
}


def run(fast: bool = False):
    # Fast mode shrinks the table, not the sweep: the engine contrast
    # lives at high load, so 0.95 stays in the CI cell set.
    slots = 1 << 14 if fast else 1 << 16
    loads = [0.75, 0.85, 0.95] if fast else [0.75, 0.85, 0.90, 0.95, 0.98]
    records = []
    for label, (evic, engine, bulk) in ENGINES.items():
        cfg = CuckooConfig(
            num_buckets=slots // 16, fp_bits=16, bucket_size=16,
            policy="xor", eviction=evic, hash_kind="fmix32",
            max_evictions=256, insert_engine=engine)
        entry = CF.insert_bulk if bulk else CF.insert
        jins = jax.jit(functools.partial(entry, cfg))
        for load in loads:
            n = int(slots * load)
            pre, hot = 3 * n // 4, n - 3 * n // 4
            keys = rand_keys(n, seed=int(load * 100))
            state = cfg.init()
            state = jax.block_until_ready(jins(state, keys[:pre])[0])

            state2, ok, stats = jins(state, keys[pre:])
            ev = np.asarray(stats.evictions)
            rounds = int(np.asarray(stats.rounds))
            fails = int((~np.asarray(ok)).sum())
            fail_rate = fails / hot
            if load <= 0.95 and fails:
                raise RuntimeError(
                    f"engine {label!r} failed {fails}/{hot} inserts at "
                    f"load {load} — high-load engines must be failure-free "
                    f"up to 0.95 (DESIGN.md §14)")
            p90, p95, p99 = np.percentile(ev, [90, 95, 99])
            emit(f"fig5_evictions_{label}_load{int(load * 100)}", 0.0,
                 f"p90={p90:.0f}_p95={p95:.0f}_p99={p99:.0f}"
                 f"_rounds={rounds}_fail={fails}")

            us = bench(lambda s=state: jins(s, keys[pre:]))
            emit(f"fig6_insert_{label}_load{int(load * 100)}", us,
                 throughput_m_per_s(hot, us))
            records.append({
                "engine": label, "eviction": evic, "load": load,
                "slots": slots, "hot_keys": hot, "rounds": rounds,
                "fails": fails, "fail_rate": fail_rate,
                "evictions_p90": float(p90), "evictions_p95": float(p95),
                "evictions_p99": float(p99), "insert_us": us,
                "m_keys_per_s": hot / us,
            })
    emit_json(SUITE, {"slots": slots, "loads": loads, "records": records})
