"""Tiered GPU-hot / host-cold capacity benchmark (DESIGN.md §12).

Acceptance criteria this suite demonstrates:

* **beyond-budget capacity** — the tiered handle absorbs a keyset whose
  total count is >= 4x what a device filter sized to
  ``device_budget_bytes`` could hold, with zero false negatives and the
  device footprint held at or under the budget throughout;
* **hot-path neutrality** — query throughput over hot-resident keys
  (the short-circuit path that never touches host RAM) stays within 1.5x
  of an equally-loaded non-tiered cascade;
* **cold-path visibility** — uniform queries over the whole keyset (the
  worst case: most slots fall through to the batched host probe) are
  measured and reported, not hidden.

Rows: streaming insert with demotions, hot-resident query (tiered vs
plain cascade), uniform two-tier query, full tier snapshot+restore.
"""

from __future__ import annotations

import time

import numpy as np

from repro import amq

from .common import bench, emit, emit_json, rand_keys, throughput_m_per_s

_CHUNK = 8192


def run(fast: bool = False) -> None:
    budget = (32 if fast else 128) * 1024
    base = 2048 if fast else 4096
    # Keys a non-tiered device filter sized to the budget could hold, at
    # the default sizing's byte ceiling (fp16 -> 2 B/slot, load 0.95);
    # bucket rounding only shrinks the real figure, so 4x this
    # over-estimate is a conservative beyond-budget demonstration.
    eq_capacity = int(0.95 * budget / 2)
    n = 4 * eq_capacity + _CHUNK
    keys = np.asarray(rand_keys(n, seed=3))

    h = amq.make("cuckoo", capacity=base, tiered=True,
                 device_budget_bytes=budget)
    t0 = time.perf_counter()
    for i in range(0, n, _CHUNK):
        h.insert(keys[i:i + _CHUNK])
    insert_s = time.perf_counter() - t0
    calls = -(-n // _CHUNK)
    emit("tiering_insert_stream", insert_s * 1e6 / calls,
         throughput_m_per_s(n, insert_s * 1e6))

    assert h.device_bytes <= h.device_budget_bytes, (
        f"budget violated: {h.device_bytes} > {h.device_budget_bytes}")
    misses = int((~np.asarray(h.query(keys).hits)).sum())

    # Hot-resident probe: the newest-inserted keys live in the hot
    # cascade; their queries must short-circuit (no cold probes at all).
    hot_n = min(h.hot.count(), 4096)
    hot_keys = keys[-hot_n:]
    before = h.tier_stats()["cold_probes"]
    hot_us = bench(lambda: h.query(hot_keys).hits)
    hot_cold_probes = h.tier_stats()["cold_probes"] - before
    emit("tiering_hot_query", hot_us, throughput_m_per_s(hot_n, hot_us))

    # The equally-loaded non-tiered reference: a plain cascade holding as
    # many keys as the tiered handle keeps on device.
    ref = amq.make("cuckoo", capacity=base, auto_expand=True)
    pad = h.hot.count() - hot_n
    if pad > 0:
        ref.insert(np.asarray(rand_keys(pad, seed=11)))
    ref.insert(hot_keys)
    ref_us = bench(lambda: ref.query(hot_keys).hits)
    ratio = hot_us / ref_us if ref_us else float("inf")
    emit("cascade_hot_query_ref", ref_us,
         f"tiered/plain={ratio:.2f}x")

    # Uniform probe over the full keyset: most slots miss the hot tier
    # and ride the batched host probe — the honest worst case.
    uni = keys[:: max(1, n // 4096)]
    uni_us = bench(lambda: h.query(uni).hits)
    emit("tiering_uniform_query", uni_us,
         throughput_m_per_s(uni.shape[0], uni_us))

    t0 = time.perf_counter()
    snap = h.snapshot()
    h2 = amq.make("cuckoo", capacity=base, tiered=True, snapshot=snap)
    snap_s = time.perf_counter() - t0
    emit("tiering_snapshot_roundtrip", snap_s * 1e6,
         f"{snap.nbytes}B_{len(h2.cold)}cold")

    report = h.report()
    emit_json("tiering", {
        "device_budget_bytes": budget,
        "budget_equivalent_capacity": eq_capacity,
        "total_keys": h.count(),
        "capacity_ratio": h.count() / eq_capacity,
        "device_bytes": h.device_bytes,
        "host_bytes": h.host_bytes,
        "hot_levels": len(report.hot_levels),
        "cold_levels": len(report.cold_levels),
        "false_negatives": misses,
        "hot_query_ratio_vs_plain": ratio,
        "hot_query_cold_probes": hot_cold_probes,
        "expected_fpr": report.expected_fpr,
        "fpr_budget": report.fpr_budget,
    })
    assert misses == 0, f"{misses} false negatives across tiers"
    assert h.count() >= 4 * eq_capacity, (
        f"only {h.count()} keys for eq_capacity {eq_capacity}")


if __name__ == "__main__":
    import sys

    run("--fast" in sys.argv)
