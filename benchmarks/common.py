"""Benchmark utilities: timing, key generation, CSV emission.

CPU-container caveat (recorded in EXPERIMENTS.md): wall-clock numbers here
are XLA-CPU timings — they reproduce the paper's *relative* claims (orderings
and scaling behaviour between filters/policies), while absolute TPU
throughput is projected in the §Roofline analysis from the dry-run.
"""

from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import keys_from_numpy

ROWS: List[str] = []

# Machine-readable payloads keyed by suite name; benchmarks attach records
# with emit_json and run.py writes them out as BENCH_<suite>.json artifacts.
JSON_RECORDS: dict = {}


def emit_json(suite: str, record: dict) -> None:
    """Merge ``record`` into the suite's BENCH_<suite>.json payload."""
    JSON_RECORDS.setdefault(suite, {}).update(record)


def bench(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row)


def rand_keys(n: int, seed: int = 0, lo: int = 0, hi: int = 2**63):
    rng = np.random.default_rng(seed)
    return jnp.asarray(keys_from_numpy(
        rng.integers(lo, hi, size=n, dtype=np.uint64)))


def throughput_m_per_s(n: int, us: float) -> str:
    return f"{n / us:.2f}M_elem_per_s"
