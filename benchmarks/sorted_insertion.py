"""Paper §4.6.3 ablation: pre-sorting the batch by primary bucket index.

On GPU the paper found radix-sorting the batch gives coalesced access but
"fails to amortise" on HBM parts. On our TPU-functional substrate the
conflict-resolution machinery *already* sorts by claim address every round
(DESIGN.md §2 — the paper's rejected idea is our correctness backbone), so
this ablation measures the residual locality effect of a bucket-ordered
input batch — and the ``insert_bulk`` cells measure the real win: sorting
*once* and committing whole buckets per round (DESIGN.md §6) instead of
re-running the claim sort every round. The ``*_rounds`` rows make the
mechanism visible: the bulk path's round count must sit far below the
round-loop path's on the same batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CuckooConfig
from repro.core import cuckoo_filter as CF
from repro.core.cuckoo_filter import prepare_keys

from .common import bench, emit, rand_keys, throughput_m_per_s

SLOTS = 1 << 16
LOAD = 0.9
BATCH = 1 << 13


def run(fast: bool = False):
    cfg = CuckooConfig(num_buckets=SLOTS // 16, fp_bits=16, bucket_size=16,
                       policy="xor", eviction="bfs", hash_kind="fmix32")
    jins = jax.jit(functools.partial(CF.insert, cfg))
    n = int(SLOTS * LOAD)
    keys = rand_keys(n, seed=21)
    state = cfg.init()
    state = jax.block_until_ready(jins(state, keys[: n - BATCH])[0])
    hot = keys[n - BATCH:]

    us = bench(lambda s=state: jins(s, hot))
    emit("s463_insert_unsorted", us, throughput_m_per_s(BATCH, us))
    rounds_loop = int(jax.block_until_ready(jins(state, hot))[2].rounds)
    emit("s463_insert_unsorted_rounds", float(rounds_loop), "rounds")

    # pre-sort the hot batch by primary bucket (the paper's CUB radix sort)
    _, i1, _ = prepare_keys(cfg, hot)
    order = jnp.argsort(i1)
    hot_sorted = hot[order]
    us = bench(lambda s=state: jins(s, hot_sorted))
    emit("s463_insert_presorted", us, throughput_m_per_s(BATCH, us))

    # bulk-build fast path: sort once, commit whole buckets per round
    jbulk = jax.jit(functools.partial(CF.insert_bulk, cfg))
    us = bench(lambda s=state: jbulk(s, hot))
    emit("s463_insert_bulk", us, throughput_m_per_s(BATCH, us))
    rounds_bulk = int(jax.block_until_ready(jbulk(state, hot))[2].rounds)
    emit("s463_insert_bulk_rounds", float(rounds_bulk), "rounds")
    emit("s463_bulk_vs_unsorted_rounds", float(rounds_loop - rounds_bulk),
         f"bulk_{rounds_bulk}_vs_loop_{rounds_loop}")
