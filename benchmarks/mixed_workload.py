"""YCSB-style mixed workloads: fused apply_ops vs naive per-op dispatch.

The serving-traffic benchmark behind DESIGN.md §9: an interleaved stream of
queries, inserts, and deletes (read-mostly and write-heavy mixes modelled on
the YCSB workload suite) executed two ways against the same filter state —

* **fused**: one ``apply_ops`` dispatch over the whole :class:`OpBatch`
  (hashing shared across ops, one pass over the table, net-effect
  mutations);
* **naive split**: the pre-§9 execution model — partition the batch by op
  code and dispatch ``query`` / ``delete`` / ``insert`` as three separate
  jitted calls (three host round-trips, three hashing passes). The op
  masks are precomputed *outside* the timed region, so the split pays only
  its genuine dispatch/hashing tax.

Emits a `speedup` column (naive_us / fused_us) per mix plus a
machine-readable record for BENCH_mixed.json (op mix, Mops/s, load factor).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import amq
from repro.amq.protocol import OP_DELETE, OP_INSERT, OP_QUERY

from .common import bench, emit, emit_json, rand_keys, throughput_m_per_s

# (query, insert, delete) fractions.
MIXES = {
    "ycsb_50_40_10": (0.50, 0.40, 0.10),
    "read_heavy_95_5": (0.95, 0.05, 0.00),
}
LOAD_PREFILL = 0.5


def _stream(n: int, mix, present: np.ndarray, seed: int):
    """Build an op stream: queries/deletes hit stored keys, inserts fresh."""
    rng = np.random.default_rng(seed)
    ops = rng.choice(np.asarray([OP_QUERY, OP_INSERT, OP_DELETE], np.int32),
                     size=n, p=np.asarray(mix) / np.sum(mix))
    keys = present[rng.integers(0, present.shape[0], size=n)]
    fresh = np.asarray(rand_keys(n, seed=seed + 1, lo=2**63, hi=2**64))
    keys = np.where((ops == OP_INSERT)[:, None], fresh, keys)
    return jnp.asarray(keys, jnp.uint32), jnp.asarray(ops, jnp.int32)


def run(fast: bool = False):
    slots = 1 << 14 if fast else 1 << 16
    batch = 1 << 12 if fast else 1 << 13
    capacity = int(slots * 0.95)
    handle = amq.make("cuckoo", capacity=capacity)
    prefill = rand_keys(int(capacity * LOAD_PREFILL), seed=1)
    handle.insert(prefill)
    cfg, state = handle.config, handle.state
    ad = amq.get("cuckoo")

    fused = jax.jit(functools.partial(ad.apply_ops, cfg))
    jq = jax.jit(functools.partial(ad.query, cfg))
    ji = jax.jit(functools.partial(ad.insert, cfg))
    jd = jax.jit(functools.partial(ad.delete, cfg))

    for mix_name, mix in MIXES.items():
        keys, ops = _stream(batch, mix, np.asarray(prefill), seed=7)
        # Precomputed op masks: the naive split's only fair head start.
        qm = jnp.asarray(np.asarray(ops) == OP_QUERY)
        im = jnp.asarray(np.asarray(ops) == OP_INSERT)
        dm = jnp.asarray(np.asarray(ops) == OP_DELETE)

        def run_fused(s=state, k=keys, o=ops):
            return fused(s, k, o)

        def run_naive(s=state, k=keys, q=qm, i=im, d=dm):
            _, qr = jq(s, k, valid=q)             # dispatch 1
            s, dr = jd(s, k, valid=d)             # dispatch 2
            s, ir = ji(s, k, valid=i)             # dispatch 3
            return s, qr, dr, ir

        us_f = bench(run_fused)
        us_n = bench(run_naive)
        speedup = us_n / us_f if us_f else float("inf")
        emit(f"mixed_{mix_name}_fused", us_f, throughput_m_per_s(batch, us_f))
        emit(f"mixed_{mix_name}_naive_split", us_n,
             throughput_m_per_s(batch, us_n))
        emit(f"mixed_{mix_name}_speedup", 0.0, f"{speedup:.2f}x_fused_vs_split")
        emit_json("mixed", {mix_name: {
            "op_mix": {"query": mix[0], "insert": mix[1], "delete": mix[2]},
            "batch": batch,
            "load_factor": float(handle.load_factor),
            "fused_us_per_call": us_f,
            "naive_split_us_per_call": us_n,
            "fused_mops_per_s": batch / us_f,
            "naive_split_mops_per_s": batch / us_n,
            "speedup_fused_vs_split": speedup,
        }})
