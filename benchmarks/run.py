"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig3,...]
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (
    bucket_policy,
    eviction,
    expansion,
    fpr,
    kmer_case_study,
    roofline,
    sorted_insertion,
    throughput,
)
from .common import ROWS

SUITES = {
    "fig3": lambda fast: (throughput.run(fast),
                          throughput.run_cpu_reference(fast)),
    "fig4": fpr.run,
    "fig5_6": eviction.run,
    "fig7": bucket_policy.run,
    "fig8": kmer_case_study.run,
    "s463": sorted_insertion.run,
    "expansion": expansion.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(SUITES))
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        try:
            SUITES[name](args.fast)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{name}_SUITE_ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stderr)
            print(f"{name}_suite_error,0.0,{type(e).__name__}")
        print(f"# {name} done in {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
