"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
With ``--json-dir`` each suite additionally writes a machine-readable
``BENCH_<suite>.json`` (CSV rows parsed into records, plus any structured
payload the suite attached via ``common.emit_json`` — op mixes,
throughputs, load factors). CI's bench-smoke job uploads these as
artifacts, seeding the perf trajectory across commits.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig3,...]
                                            [--json-dir bench-json]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from . import (
    bucket_policy,
    eviction,
    expansion,
    fpr,
    kmer_case_study,
    lifecycle,
    mixed_workload,
    roofline,
    roofline_filters,
    serving_slo,
    sorted_insertion,
    throughput,
    tiering,
)
from .common import JSON_RECORDS, ROWS

SUITES = {
    "fig3": lambda fast: (throughput.run(fast),
                          throughput.run_cpu_reference(fast)),
    "fig4": fpr.run,
    "fig5_6": eviction.run,
    "fig7": bucket_policy.run,
    "fig8": kmer_case_study.run,
    "s463": sorted_insertion.run,
    "expansion": expansion.run,
    "mixed": mixed_workload.run,
    "lifecycle": lifecycle.run,
    "serving_slo": serving_slo.run,
    # One BENCH_roofline.json: the dryrun-projection rows (skipped cleanly
    # when no artifacts exist — the CI default) plus the filter roofline
    # suite's achieved-vs-model-minimal rows.
    "roofline": lambda fast: (roofline.run(fast), roofline_filters.run(fast)),
    "tiering": tiering.run,
}


def _parse_rows(rows) -> list:
    out = []
    for row in rows:
        name, us, derived = row.split(",", 2)
        out.append({"name": name, "us_per_call": float(us),
                    "derived": derived})
    return out


def _write_json(json_dir: pathlib.Path, name: str, fast: bool,
                elapsed_s: float, rows, error: str = "") -> None:
    payload = {
        "suite": name,
        "fast": fast,
        "elapsed_s": round(elapsed_s, 3),
        "rows": _parse_rows(rows),
        "data": JSON_RECORDS.get(name, {}),
    }
    if error:
        payload["error"] = error
    path = json_dir / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--json-dir", default=None,
                    help="directory for machine-readable BENCH_<suite>.json")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(SUITES))
    json_dir = None
    if args.json_dir is not None:
        json_dir = pathlib.Path(args.json_dir)
        json_dir.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        row_start = len(ROWS)
        error = ""
        try:
            SUITES[name](args.fast)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            error = f"{type(e).__name__}:{e}"
            print(f"{name}_SUITE_ERROR,0.0,{error}", file=sys.stderr)
            print(f"{name}_suite_error,0.0,{type(e).__name__}")
        elapsed = time.time() - t0
        if json_dir is not None:
            _write_json(json_dir, name, args.fast, elapsed,
                        ROWS[row_start:], error)
        print(f"# {name} done in {elapsed:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
