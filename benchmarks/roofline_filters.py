"""Achieved vs model-minimal bytes/s per filter op (DESIGN.md §13).

The paper's headline comparison is bandwidth, not wall-clock: each op must
move some minimal number of bytes (the :mod:`repro.kernels.roofline` model,
computed from the backend's static layout), and a kernel's quality is the
fraction of the machine's measured copy bandwidth it achieves on that
minimum. This suite reports, for query / insert / mixed on the cuckoo,
bloom, and bcht backends:

    achieved_bytes_per_s = model_min_bytes(batch) / wall_time
    frac_of_peak         = achieved_bytes_per_s / measured_copy_bandwidth

plus fused-vs-pre-fusion Pallas kernel row pairs for query *and* insert
(the committed baseline pins fused >= pre-fusion for both), and autotune
rows recording the block_keys sweep winner per op (query / insert /
bulk_insert). Everything lands in ``BENCH_roofline.json`` (rows + a
structured ``data`` payload with the model/HLO cross-check ratios — the
graph-orientation bulk engine included), which CI's bench-smoke job
ratchets on.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro import amq
from repro.core.cuckoo_filter import CuckooConfig, CuckooState
from repro.kernels import autotune, ops, roofline as RM
from repro.launch import filter_roofline as FR

from .common import bench, emit, emit_json, rand_keys

SUITE = "roofline"

# Mixed-stream op fractions per backend (bloom is append-only: no deletes).
_MIX = {"cuckoo": (0.80, 0.15, 0.05),
        "bloom": (0.80, 0.20, 0.0),
        "bcht": (0.80, 0.15, 0.05)}


def _mixed_batch(keys, mix, seed: int = 0) -> amq.OpBatch:
    n = keys.shape[0]
    q, i, d = mix
    codes = np.zeros((n,), np.int32)
    n_i = int(round(n * i))
    n_d = int(round(n * d))
    codes[:n_i] = amq.OP_INSERT
    codes[n_i:n_i + n_d] = amq.OP_DELETE
    np.random.default_rng(seed).shuffle(codes)
    return amq.OpBatch.make(keys, codes)


def _row(name: str, us: float, model_bytes: float, peak: float) -> dict:
    achieved = model_bytes / (us * 1e-6) if us > 0 else 0.0
    frac = achieved / peak if peak > 0 else 0.0
    emit(name, us,
         f"{achieved / 1e9:.3f}GB_per_s_model_min_frac_of_peak={frac:.4f}")
    return {"name": name, "us_per_call": us, "model_bytes": model_bytes,
            "achieved_bytes_per_s": achieved, "frac_of_peak": frac}


def run(fast: bool = False):
    n = 1 << 14 if fast else 1 << 16
    records = []

    # Bandwidth ceiling: measured device copy, not a datasheet number.
    peak = FR.measured_copy_bandwidth(1 << 23 if fast else 1 << 26,
                                      iters=3 if fast else 5)
    emit("roofline_peak_copy", 0.0, f"{peak / 1e9:.2f}GB_per_s_measured")

    # -- backend ops through the AMQ handle (the XLA core paths) ------------
    for backend in ("cuckoo", "bloom", "bcht"):
        handle = amq.make(backend, capacity=16 * n)
        config = handle.config
        keys = rand_keys(n, seed=17)
        mix = _MIX[backend]

        handle.insert(keys[: n // 2])               # half-load, then measure
        us = bench(lambda: handle.query(keys))
        records.append(_row(f"roofline_{backend}_query", us,
                            RM.min_batch_bytes(config, "query", n), peak))

        ins_keys = rand_keys(n, seed=23)
        us = bench(lambda: handle.insert(ins_keys))
        records.append(_row(f"roofline_{backend}_insert", us,
                            RM.min_batch_bytes(config, "insert", n), peak))

        # Backends without a native fused mixed path fall back to
        # segmented per-run dispatch — thousands of tiny host-looped
        # calls at full n (hundreds of seconds per call on CPU), so the
        # segmented row measures a much smaller stream. The model
        # denominator uses the same n_mix, so bytes/s stays honest.
        n_mix = n if handle.capabilities.supports_mixed else max(256, n // 64)
        batch = _mixed_batch(np.asarray(keys)[:n_mix], mix)
        us = bench(lambda: handle.apply_ops(batch))
        records.append(_row(
            f"roofline_{backend}_mixed", us,
            RM.min_batch_bytes(config, "apply_ops", n_mix, op_mix=mix),
            peak))

    # -- Pallas query kernels: fused SWAR vs the pre-fusion unpack variant --
    # Interpret mode off-TPU, so sizes stay modest; the committed baseline
    # pins fused <= pre-fusion us_per_call (the PR's fusion claim).
    kn = 1 << 12
    kcfg = CuckooConfig(num_buckets=1 << 10, fp_bits=16)
    kkeys = rand_keys(kn, seed=31)
    kstate = kcfg.init()
    kstate, _ = ops.cuckoo_insert_bulk(kcfg, kstate, kkeys[: kn // 2])
    kbytes = RM.min_batch_bytes(kcfg, "query", kn, table_resident=True)
    for fused, label in ((True, "fused"), (False, "prepr")):
        us = bench(lambda f=fused: ops.cuckoo_query(kcfg, kstate, kkeys,
                                                    fused=f))
        records.append(_row(f"roofline_query_kernel_{label}", us, kbytes,
                            peak))

    # -- Pallas insert kernels: fused SWAR free-slot scan vs pre-fusion -----
    # The insert wrapper donates its state, so each timed call gets a fresh
    # copy of the half-loaded table (copy cost identical for both rows).
    ikeys = rand_keys(kn, seed=37)
    itable = np.asarray(kstate.table)
    icount = int(kstate.count)
    ibytes = RM.min_batch_bytes(kcfg, "insert", kn, table_resident=True)

    def _ins(fused):
        st = CuckooState(jnp.asarray(itable), jnp.int32(icount))
        return ops.cuckoo_insert_direct(kcfg, st, ikeys, fused=fused)

    for fused, label in ((True, "fused"), (False, "prepr")):
        us = bench(lambda f=fused: _ins(f))
        records.append(_row(f"roofline_insert_kernel_{label}", us, ibytes,
                            peak))

    # -- autotune: the cached block_keys sweeps (tentpole observability) ----
    autotune.clear()
    tuned = {}
    for op in ("query", "insert", "bulk_insert"):
        tuned[op] = autotune.autotune(kcfg, op, n=kn,
                                      candidates=(512, 1024) if fast
                                      else (256, 512, 1024, 2048),
                                      iters=2 if fast else 3)
        emit(f"roofline_autotune_{op}", 0.0, f"block_keys={tuned[op]}")

    # -- model vs lowered-HLO cross-check (launch/filter_roofline.py) -------
    xcfg = CuckooConfig(num_buckets=1 << 10, fp_bits=16)
    cross = {op: FR.cross_check(xcfg, op, n=1024)
             for op in ("query", "insert", "apply_ops",
                        "orient_bulk_insert")}

    emit_json(SUITE, {
        "n": n,
        "peak_copy_bytes_per_s": peak,
        "autotuned_query_block_keys": int(tuned["query"]),
        "autotuned_insert_block_keys": int(tuned["insert"]),
        "autotuned_bulk_insert_block_keys": int(tuned["bulk_insert"]),
        "records": records,
        "hlo_cross_check": cross,
    })
