"""Paper Fig. 4: empirical FPR vs total memory at 95% load factor.

Populate with keys from [0, 2^32), query disjoint keys from [2^32, 2^64);
empirical FPR = positive fraction. Every jit-able backend in the AMQ
registry is measured, and each measurement is **asserted** against its
config's analytic ``expected_fpr`` (paper Eq. (4) for the cuckoo filter and
the §5.3-style formulas added to the baselines): measured FPR must stay
within a generous multiplicative band of the model, and exact structures
must measure exactly zero. Reproduces the Fig. 4 ordering: BBF worst, GQF
best, cuckoo close to GQF, TCF in between.
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro import amq

from .common import emit, rand_keys

LOAD = 0.95
N_NEG = 1 << 16


def check_fpr(name: str, measured: float, expected: float) -> None:
    """Assert the empirical FPR against the analytic model's shared band."""
    lo, hi = amq.fpr_tolerance(expected, N_NEG)
    if expected == 0.0:
        assert measured == 0.0, f"{name}: exact backend measured {measured}"
        return
    assert measured <= hi, \
        f"{name}: measured FPR {measured:.2e} > bound {hi:.2e}"
    assert measured >= lo, \
        f"{name}: measured FPR {measured:.2e} < bound {lo:.2e} " \
        "(model badly over-predicts)"


def _empirical_fpr(ad, cfg, capacity, seed=0):
    state = ad.init(cfg)
    pos = rand_keys(capacity, seed=seed, lo=0, hi=2**32)
    state = jax.block_until_ready(
        jax.jit(functools.partial(ad.insert, cfg))(state, pos)[0])
    neg = rand_keys(N_NEG, seed=seed + 7, lo=2**32, hi=2**64)
    _, result = jax.jit(functools.partial(ad.query, cfg))(state, neg)
    return float(np.asarray(result.hits).mean())


def run(fast: bool = False):
    sizes = [1 << 13, 1 << 15] if fast else [1 << 13, 1 << 15, 1 << 17]
    for slots in sizes:
        capacity = int(slots * LOAD)
        for name in amq.names():
            ad = amq.get(name)
            if not ad.jit or ad.capabilities.supports_sharding:
                continue
            if ad.capabilities.serial_insert and (fast or slots > 1 << 15):
                continue  # serial prefill; keep the suite bounded
            cfg = ad.make_config(capacity)
            fpr = _empirical_fpr(ad, cfg, capacity)
            expect = cfg.expected_fpr(LOAD)
            check_fpr(name, fpr, expect)
            emit(f"fig4_fpr_{name}_{slots}", 0.0,
                 f"fpr={fpr:.5f}_expected={expect:.5f}")
