"""Paper Fig. 4: empirical FPR vs total memory at 95% load factor.

Populate with keys from [0, 2^32), query disjoint keys from [2^32, 2^64);
empirical FPR = positive fraction. Validates paper Eq. (4) for the cuckoo
filter and reproduces the Fig. 4 ordering: BBF worst, GQF best, cuckoo close
to GQF, TCF in between.
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import CuckooConfig
from repro.core import cuckoo_filter as CF
from repro.filters import blocked_bloom as BB
from repro.filters import quotient as QF
from repro.filters import two_choice as TC

from .common import emit, rand_keys

LOAD = 0.95
N_NEG = 1 << 16


def _empirical_fpr(cfg, init, ins, qry, capacity, seed=0):
    state = init(cfg)
    pos = rand_keys(capacity, seed=seed, lo=0, hi=2**32)
    state = jax.block_until_ready(
        jax.jit(functools.partial(ins, cfg))(state, pos)[0])
    neg = rand_keys(N_NEG, seed=seed + 7, lo=2**32, hi=2**64)
    hits = jax.jit(functools.partial(qry, cfg))(state, neg)
    return float(np.asarray(hits).mean())


def run(fast: bool = False):
    sizes = [1 << 13, 1 << 15] if fast else [1 << 13, 1 << 15, 1 << 17]
    for slots in sizes:
        capacity = int(slots * LOAD)
        cuckoo = CuckooConfig.for_capacity(capacity, LOAD, hash_kind="fmix32")
        fpr = _empirical_fpr(cuckoo, lambda c: c.init(), CF.insert, CF.query,
                             capacity)
        expect = cuckoo.expected_fpr(LOAD)
        emit(f"fig4_fpr_cuckoo_{slots}", 0.0,
             f"fpr={fpr:.5f}_eq4={expect:.5f}")

        bloom = BB.BloomConfig.for_capacity(capacity, 16)
        fpr_b = _empirical_fpr(bloom, lambda c: c.init(), BB.insert,
                               BB.query, capacity)
        emit(f"fig4_fpr_bloom_{slots}", 0.0, f"fpr={fpr_b:.5f}")

        tcf = TC.TCFConfig.for_capacity(capacity, LOAD)
        fpr_t = _empirical_fpr(tcf, lambda c: c.init(), TC.insert, TC.query,
                               capacity)
        emit(f"fig4_fpr_tcf_{slots}", 0.0, f"fpr={fpr_t:.5f}")

        if not fast:
            gqf = QF.GQFConfig.for_capacity(capacity, LOAD)
            fpr_g = _empirical_fpr(gqf, lambda c: c.init(), QF.insert,
                                   QF.query, capacity)
            emit(f"fig4_fpr_gqf_{slots}", 0.0, f"fpr={fpr_g:.5f}")
