"""Paper Fig. 8 (§5.5): genomic 31-mer indexing case study.

Synthetic genome -> 2-bit pack -> rolling 31-mers (Pallas kernel) ->
insert / positive query / delete across the dynamic filters + bloom insert/
query. Skewed real-world-like key distribution (repeat structure).
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import CuckooConfig
from repro.core import cuckoo_filter as CF
from repro.data.kmer import kmer_keys, synthetic_genome
from repro.filters import blocked_bloom as BB
from repro.filters import two_choice as TC

from .common import bench, emit, throughput_m_per_s


def run(fast: bool = False):
    # 2^16 keeps the 7x-repeated full-batch inserts tractable on one
    # interpreted CPU core; the kernel/filter path is size-independent
    n_bases = (1 << 14) if fast else (1 << 16)
    bases = synthetic_genome(n_bases, seed=3)
    keys = kmer_keys(bases, k=31, canonical=True)
    n = keys.shape[0]
    emit("fig8_kmers_extracted", 0.0, f"n={n}_distinct~{min(n, 4**31)}")

    capacity = n
    configs = {
        "cuckoo": (CuckooConfig.for_capacity(capacity, 0.9,
                                             hash_kind="fmix32"),
                   CF.insert, CF.query, CF.delete, lambda c: c.init()),
        "tcf": (TC.TCFConfig.for_capacity(capacity, 0.9),
                TC.insert, TC.query, TC.delete, lambda c: c.init()),
        "bloom": (BB.BloomConfig.for_capacity(capacity, 16),
                  BB.insert, BB.query, None, lambda c: c.init()),
    }
    for name, (cfg, ins, qry, dele, init) in configs.items():
        jins = jax.jit(functools.partial(ins, cfg))
        jqry = jax.jit(functools.partial(qry, cfg))
        us = bench(lambda: jins(init(cfg), keys))
        emit(f"fig8_insert_{name}", us, throughput_m_per_s(n, us))
        state = jins(init(cfg), keys)[0]
        us = bench(lambda: jqry(state, keys))
        emit(f"fig8_query_{name}", us, throughput_m_per_s(n, us))
        if dele is not None:
            jdel = jax.jit(functools.partial(dele, cfg))
            us = bench(lambda s=state: jdel(s, keys))
            emit(f"fig8_delete_{name}", us, throughput_m_per_s(n, us))
