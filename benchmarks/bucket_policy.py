"""Paper Fig. 7: XOR vs OFFSET (choice-bit) bucket placement at 95% load.

Also quantifies §4.6.2's memory argument: the offset policy sizes exactly
while XOR rounds buckets up to a power of two.
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import CuckooConfig
from repro.core import cuckoo_filter as CF

from .common import bench, emit, rand_keys, throughput_m_per_s

LOAD = 0.95
BATCH = 1 << 13


def run(fast: bool = False):
    # capacity chosen just past a power of two — the offset policy's case
    capacity = int((1 << 16) * 1.10)
    for policy in ("xor", "offset"):
        cfg = CuckooConfig.for_capacity(capacity, LOAD, policy=policy,
                                        hash_kind="fmix32")
        emit(f"fig7_table_bytes_{policy}", 0.0,
             f"bytes={cfg.table_bytes}_buckets={cfg.num_buckets}")
        jins = jax.jit(functools.partial(CF.insert, cfg))
        jqry = jax.jit(functools.partial(CF.query, cfg))
        jdel = jax.jit(functools.partial(CF.delete, cfg))

        n = int(cfg.num_slots * LOAD)
        keys = rand_keys(n, seed=11)
        neg = rand_keys(BATCH, seed=13, lo=2**63, hi=2**64)
        state = cfg.init()
        state = jax.block_until_ready(jins(state, keys[:n - BATCH])[0])

        us = bench(lambda s=state: jins(s, keys[n - BATCH:]))
        emit(f"fig7_insert_{policy}", us, throughput_m_per_s(BATCH, us))
        state, _, _ = jins(state, keys[n - BATCH:])
        us = bench(lambda: jqry(state, keys[:BATCH]))
        emit(f"fig7_query_pos_{policy}", us, throughput_m_per_s(BATCH, us))
        us = bench(lambda: jqry(state, neg))
        emit(f"fig7_query_neg_{policy}", us, throughput_m_per_s(BATCH, us))
        us = bench(lambda s=state: jdel(s, keys[:BATCH]))
        emit(f"fig7_delete_{policy}", us, throughput_m_per_s(BATCH, us))
        # empirical FPR delta (offset trades ~1 bit of fingerprint)
        fpr = float(np.asarray(jqry(state, neg)).mean())
        emit(f"fig7_fpr_{policy}", 0.0,
             f"fpr={fpr:.5f}_eq4={cfg.expected_fpr(LOAD):.5f}")
