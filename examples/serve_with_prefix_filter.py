"""Serving example: batched generation with AMQ-guarded prefix caching.

Half the requests repeat earlier prompts; the cuckoo filter in front of the
prefix cache answers "never cached" in O(1) for fresh prompts (skipping the
probe) and stays in sync under LRU eviction via deletions.

    PYTHONPATH=src python examples/serve_with_prefix_filter.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine

cfg = get_config("gemma2_2b").reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))

BATCH, PROMPT, STEPS = 2, 24, 8
engine = ServeEngine(model, params, batch=BATCH, max_len=PROMPT + STEPS,
                     prefix_cache_entries=4,
                     # serving SLO knobs flow to the guard-filter service
                     # (DESIGN.md §11): 1ms deadline, bounded queue.
                     prefix_cache_service_kw={"max_delay": 0.001,
                                              "max_pending": 32})

rng = np.random.default_rng(0)
pool = [rng.integers(0, cfg.vocab_size, (BATCH, PROMPT)).astype(np.int32)
        for _ in range(6)]

# fill the 4-entry cache, re-serve two prompts (hits), then push three fresh
# prompts (LRU evictions + filter deletions), then repeat an evicted one.
sequence = [0, 1, 2, 3, 1, 2, 4, 5, 0, 1]
t0 = time.perf_counter()
for i in sequence:
    tokens, stats = engine.generate(pool[i], steps=STEPS)
dt = time.perf_counter() - t0
print(f"{len(sequence)} requests in {dt:.1f}s")
slo = stats.pop("filter_service")
print("prefix cache stats:", stats)
print(f"guard-filter SLO: p99 enqueue-to-ready "
      f"{slo['ready']['p99_s'] * 1e6:.0f}us over {slo['ready']['count']} "
      f"ops, dispatch causes {slo['dispatch_kinds']}")
assert stats["hits"] > 0, "repeat prompts must hit the prefix cache"
assert stats["filtered"] > 0, "fresh prompts must be filtered (neg lookup)"
if stats["evictions"]:
    print(f"LRU evicted {stats['evictions']} entries — filter deletions "
          "kept the AMQ in sync (a Bloom filter would rot here)")
