"""Quickstart: the unified AMQ API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro import amq
from repro.core import CuckooConfig, keys_from_numpy

# 1. One registry, every filter family. Pick a backend by name and size it
#    by capacity — paper defaults (16-bit fingerprints, 16-slot buckets,
#    XOR placement, BFS eviction) apply for "cuckoo".
filt = amq.make("cuckoo", capacity=100_000, load_factor=0.95)
print(f"{filt.name}: {filt.table_bytes / 1024:.0f} KiB, expected FPR at "
      f"95% load: {filt.expected_fpr(0.95):.5f}, caps={filt.capabilities}")

# 2. Insert a batch of 64-bit keys (uint32[n, 2] little-endian pairs).
#    bulk=True takes the bucket-sorted bulk-build fast path (DESIGN.md §6).
rng = np.random.default_rng(0)
raw = rng.integers(0, 2**63, size=95_000, dtype=np.uint64)
keys = jnp.asarray(keys_from_numpy(raw))
report = filt.insert(keys, bulk=True)
print(f"inserted {int(report.ok.sum())}/{len(raw)} "
      f"(load {filt.load_factor:.2%}, {int(report.rounds)} rounds, "
      f"max eviction chain {int(np.max(np.asarray(report.evictions)))})")

# 3. Query: no false negatives, bounded false positives.
assert bool(filt.query(keys).hits.all())
neg = jnp.asarray(keys_from_numpy(
    rng.integers(2**63, 2**64, size=50_000, dtype=np.uint64)))
print(f"empirical FPR: {float(filt.query(neg).hits.mean()):.5f}")

# 4. Delete — the paper's headline capability vs Bloom filters, and a
#    capability flag here: handles raise on unsupported ops instead of
#    silently corrupting (try backend='bloom').
filt.delete(keys[:10_000])
print(f"after deleting 10k: count={filt.count()}")

# 5. Same program, any backend: iterate the registry and branch on
#    capabilities, never on names.
demo = jnp.asarray(keys_from_numpy(
    rng.integers(0, 2**63, size=4_096, dtype=np.uint64)))
for name in amq.names():
    h = amq.make(name, capacity=8_192)
    caps = h.capabilities
    h.insert(demo)
    hits = float(np.asarray(h.query(demo).hits).mean())
    deleted = bool(caps.supports_delete) and bool(h.delete(demo).ok.any())
    print(f"  {name:15s} hits={hits:.3f} delete={'yes' if deleted else 'no'} "
          f"exact={caps.exact} bulk={caps.supports_bulk}")

# 6. Auto-expansion: streaming workloads need no a-priori sizing. Start at
#    1e5 and stream 1e6 keys — the handle grows as a geometric cascade of
#    levels (DESIGN.md §8): inserts land in the newest level, queries fan
#    over all of them in one fused pass, and the FPR budget is split across
#    levels so the aggregate stays bounded however far it grows.
stream = amq.make("cuckoo", capacity=100_000, auto_expand=True)
total = 1_000_000
chunk = 1 << 17
streamed = jnp.asarray(keys_from_numpy(
    rng.integers(0, 2**63, size=total, dtype=np.uint64)))
for start in range(0, total, chunk):
    stream.insert(streamed[start:start + chunk], bulk=True)
print(f"streamed {total} keys into an initial-1e5 cascade: "
      f"{len(stream.levels)} levels, aggregate load "
      f"{stream.load_factor:.2%}, fpr budget {stream.fpr_budget:.1e}")
assert bool(stream.query(streamed[:chunk]).hits.all())  # no false negatives

# 7. The classic config surface still exists (and sizes tables exactly with
#    the OFFSET policy — no power-of-two over-provisioning, paper §4.6.2);
#    pre-built configs drop straight into the registry.
flex = CuckooConfig.for_capacity(100_000, load_factor=0.95, policy="offset")
print(f"offset policy: {flex.table_bytes / 1024:.0f} KiB vs XOR "
      f"{filt.table_bytes / 1024:.0f} KiB")
exact = amq.make("cuckoo", config=flex)
print(f"handle from config: {exact.name}, {exact.table_bytes / 1024:.0f} KiB")

# 8. Pallas kernel path (TPU-target; interpret-mode on CPU): batch query
#    against a VMEM-resident table — kernels consume the same config/state.
from repro.kernels import cuckoo_query

live = keys[10_000:14_096]  # still stored (first 10k were deleted above)
hits = cuckoo_query(filt.config, filt.state, live)
print(f"kernel query: {int(hits.sum())}/4096 hits (expect 4096)")
assert int(hits.sum()) == 4096
