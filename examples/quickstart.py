"""Quickstart: the Cuckoo-TPU filter public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import CuckooConfig, CuckooFilter, keys_from_numpy

# 1. Size a filter for 100k items at 95% load, paper defaults (16-bit
#    fingerprints, 16-slot buckets, XOR placement, BFS eviction).
cfg = CuckooConfig.for_capacity(100_000, load_factor=0.95)
filt = CuckooFilter(cfg)
print(f"filter: {cfg.num_buckets} buckets x {cfg.bucket_size} slots, "
      f"{cfg.table_bytes / 1024:.0f} KiB, expected FPR at 95% load: "
      f"{cfg.expected_fpr(0.95):.5f}")

# 2. Insert a batch of 64-bit keys (uint32[n, 2] little-endian pairs).
#    insert_bulk sorts the batch by bucket once and commits whole buckets
#    per round (DESIGN.md §6) — the fast path for building a filter.
rng = np.random.default_rng(0)
raw = rng.integers(0, 2**63, size=95_000, dtype=np.uint64)
keys = jnp.asarray(keys_from_numpy(raw))
ok, stats = filt.insert_bulk(keys)
print(f"inserted {int(ok.sum())}/{len(raw)} "
      f"(load {filt.load_factor:.2%}, {int(stats.rounds)} rounds, "
      f"max eviction chain {int(np.max(np.asarray(stats.evictions)))})")

# 3. Query: no false negatives, bounded false positives.
assert bool(filt.query(keys).all())
neg = jnp.asarray(keys_from_numpy(
    rng.integers(2**63, 2**64, size=50_000, dtype=np.uint64)))
print(f"empirical FPR: {float(filt.query(neg).mean()):.5f}")

# 4. Delete — the paper's headline capability vs Bloom filters.
filt.delete(keys[:10_000])
print(f"after deleting 10k: count={int(filt.state.count)}")

# 5. The offset placement policy sizes tables exactly (no power-of-two
#    over-provisioning), for one bit of fingerprint (paper §4.6.2).
flex = CuckooConfig.for_capacity(100_000, load_factor=0.95, policy="offset")
print(f"offset policy: {flex.table_bytes / 1024:.0f} KiB vs XOR "
      f"{cfg.table_bytes / 1024:.0f} KiB")

# 6. Pallas kernel path (TPU-target; interpret-mode on CPU): batch query
#    against a VMEM-resident table.
from repro.kernels import cuckoo_query

live = keys[10_000:14_096]  # still stored (first 10k were deleted above)
hits = cuckoo_query(cfg, filt.state, live)
print(f"kernel query: {int(hits.sum())}/4096 hits (expect 4096)")
assert int(hits.sum()) == 4096
