"""End-to-end training driver: LM + streaming filter dedup + checkpoints.

Trains a reduced-config model for a few hundred steps on CPU with the
cuckoo-filter dedup stage masking duplicate sequences, checkpointing and
surviving a simulated mid-run failure. Use --full-100m for a ~100M-parameter
run (sized for a real accelerator; slow on CPU).

    PYTHONPATH=src python examples/train_lm_dedup.py [--steps 200]

``--device-budget-bytes N`` switches the dedup stage to the tiered
GPU-hot / host-cold filter (DESIGN.md §12): the dedup keyset may grow
several times past the device budget — old filter levels freeze into host
RAM and are probed off the hot path — demonstrating corpus dedup beyond
device memory:

    PYTHONPATH=src python examples/train_lm_dedup.py \\
        --steps 400 --device-budget-bytes 4096
"""

import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import CuckooConfig
from repro.data import DataConfig, DedupConfig, dedup_batch, make_batch
from repro.models import build_model
from repro.train import (
    AdamWConfig,
    TrainingRunner,
    checkpoint,
    init_train_state,
    make_train_step,
)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full-100m", action="store_true")
ap.add_argument("--device-budget-bytes", type=int, default=None,
                help="cap the dedup filter's device footprint; older "
                     "levels tier out to host RAM (DESIGN.md §12)")
args = ap.parse_args()

cfg = get_config("mamba2_130m")
if args.full_100m:
    cfg = dataclasses.replace(cfg, num_layers=12)   # ~100M params
    batch, seq = 8, 1024
else:
    cfg = cfg.reduced()
    batch, seq = 8, 128

model = build_model(cfg)
opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
params, opt_state = init_train_state(model, opt_cfg, jax.random.key(0))
n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
print(f"model: {cfg.name} ({n / 1e6:.1f}M params)")

data_cfg = DataConfig(vocab_size=cfg.vocab_size, batch=batch, seq_len=seq,
                      duplicate_fraction=0.3)
dup_total = 0

if args.device_budget_bytes is not None:
    # Beyond-HBM mode: the dedup keyset is allowed to outgrow the device
    # budget — the tiered handle freezes old levels into host RAM and
    # probes them off the padded hot path (DESIGN.md §12).
    from repro.data import make_deduper

    deduper = make_deduper(1024, "cuckoo", service_batch=batch,
                           device_budget_bytes=args.device_budget_bytes)

    def data_fn(step):
        global dup_total
        batch_, stats = deduper.dedup(make_batch(data_cfg, step))
        dup_total += int(stats["duplicates"])
        return batch_
else:
    dcfg = DedupConfig(CuckooConfig.for_capacity(args.steps * batch + 4096,
                                                 hash_kind="fmix32"))
    filter_state = dcfg.filter.init()
    dedup = jax.jit(lambda s, b: dedup_batch(dcfg, s, b))

    def data_fn(step):
        global filter_state, dup_total
        batch_ = make_batch(data_cfg, step)
        filter_state, batch_, stats = dedup(filter_state, batch_)
        dup_total += int(stats["duplicates"])
        return batch_


step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))

ckpt_dir = tempfile.mkdtemp(prefix="repro_example_")
fail_at = args.steps // 2
print(f"training {args.steps} steps; injecting a failure at {fail_at} "
      "to demonstrate checkpoint/restart...")
runner = TrainingRunner(train_step=step_fn, data_fn=data_fn,
                        ckpt_dir=ckpt_dir, ckpt_every=25,
                        fail_at_step=fail_at)
try:
    runner.run(params, opt_state, num_steps=args.steps, log_every=25)
except RuntimeError as e:
    print(f"  !! {e} — restarting from checkpoint")

runner2 = TrainingRunner(train_step=step_fn, data_fn=data_fn,
                         ckpt_dir=ckpt_dir, ckpt_every=25)
params, opt_state, start = runner2.resume(params, opt_state)
print(f"  resumed at step {start}")
params, opt_state, monitor = runner2.run(params, opt_state,
                                         num_steps=args.steps,
                                         start_step=start, log_every=25)
print(f"done. duplicates masked: {dup_total}; "
      f"straggler stats: {monitor.summary()}")
if args.device_budget_bytes is not None:
    deduper.flush()
    h = deduper.handle
    ts = h.tier_stats()
    print(f"tiered dedup: {h.count()} keys over a "
          f"{ts['device_budget_bytes']}B device budget "
          f"(device {ts['device_bytes']}B + host {ts['host_bytes']}B; "
          f"{ts['cold_levels']} cold levels, "
          f"{ts['cold_probe_keys']} cold-probed keys)")
print(f"final checkpoint: step {checkpoint.latest_step(ckpt_dir)} "
      f"in {ckpt_dir}")
