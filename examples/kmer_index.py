"""Genomic k-mer indexing case study (paper §5.5) end to end.

Synthetic genome -> 2-bit pack -> canonical 31-mers (Pallas kernel) ->
cuckoo filter membership, with deletion demonstrating contamination removal
(the dynamic-AMQ workflow of NGSReadsTreatment / Cleanifier).

    PYTHONPATH=src python examples/kmer_index.py
"""

import numpy as np

from repro.core import CuckooConfig, CuckooFilter
from repro.data.kmer import kmer_keys, synthetic_genome

K = 31
N_BASES = 200_000

print(f"generating {N_BASES} bases of synthetic genome...")
genome = synthetic_genome(N_BASES, seed=42)
keys = kmer_keys(genome, k=K, canonical=True)
print(f"extracted {keys.shape[0]} canonical {K}-mers")

cfg = CuckooConfig.for_capacity(keys.shape[0], load_factor=0.9)
index = CuckooFilter(cfg)
ok, _ = index.insert(keys)
print(f"indexed {int(ok.sum())} k-mers "
      f"({cfg.table_bytes / 2**20:.1f} MiB filter, "
      f"load {index.load_factor:.2%})")

# membership of reads from the same genome: every k-mer must hit
read = genome[1000:1200]
read_keys = kmer_keys(read, k=K, canonical=True)
hits = index.query(read_keys)
print(f"read lookup: {int(hits.sum())}/{read_keys.shape[0]} k-mers found "
      "(expect all)")
assert bool(hits.all())

# contamination: foreign sequence k-mers should mostly miss
foreign = synthetic_genome(5_000, seed=777)
fk = kmer_keys(foreign, k=K, canonical=True)
fpr = float(index.query(fk).mean())
print(f"foreign-genome hit rate: {fpr:.5f} (~filter FPR)")

# deletion: remove a contaminating region from the index (Bloom can't!)
region = genome[50_000:60_000]
rk = kmer_keys(region, k=K, canonical=True)
removed = index.delete(rk)
print(f"removed {int(removed.sum())} k-mers of a contaminating region; "
      f"count={int(index.state.count)}")
post = index.query(rk)
print(f"region k-mers still positive after removal: "
      f"{float(post.mean()):.4f} (residual = shared k-mers elsewhere in "
      "the genome + FPR)")
