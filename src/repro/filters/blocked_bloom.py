"""Blocked Bloom filter — the paper's append-only GPU baseline (GBBF).

One block per key (cache-line sized on GPU; one VREG-friendly row here), k
bits set inside the block. Insert-only; queries are a single block gather +
bit tests. This is the structure whose query throughput the paper's Cuckoo
filter "rivals" — our benchmark reproduces that comparison.

Block layout: ``uint32[num_blocks, words_per_block]``. The k bit positions
are derived from the key's 64-bit hash by splitting it into 8-bit chunks
(re-mixed when more are needed), matching the cuCollections/WarpCore recipe
of cheap per-block bit derivation.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hashing import fmix32, hash_key
from .common import scatter_or

_U32 = np.uint32


class BloomState(NamedTuple):
    table: jnp.ndarray  # uint32[num_blocks * words_per_block]
    count: jnp.ndarray  # int32[] inserted keys (for load accounting)


@dataclasses.dataclass(frozen=True)
class BloomConfig:
    num_blocks: int
    words_per_block: int = 16   # 512-bit blocks (GPU cache-line style)
    k: int = 8                  # bits set per key
    hash_kind: str = "fmix32"
    seed: int = 0
    bits_per_key: int = 16      # nominal budget (defines num_slots/FPR math)

    @property
    def block_bits(self) -> int:
        return self.words_per_block * 32

    @property
    def num_words(self) -> int:
        return self.num_blocks * self.words_per_block

    @property
    def table_bytes(self) -> int:
        return self.num_words * 4

    @property
    def num_slots(self) -> int:
        """Nominal key capacity: total bits / the per-key bit budget."""
        return max(1, (self.num_blocks * self.block_bits) // self.bits_per_key)

    def expected_fpr(self, load_factor: float) -> float:
        """Standard Bloom estimate at ``load_factor`` of nominal capacity:
        eps ~= (1 - e^(-k * alpha / bits_per_key * ... ))^k with
        n/m = alpha / bits_per_key. Blocking adds a small penalty (skewed
        per-block occupancy) absorbed by benchmark tolerances.
        """
        ratio = self.k * load_factor / self.bits_per_key
        return (1.0 - math.exp(-ratio)) ** self.k

    def init(self) -> BloomState:
        return BloomState(jnp.zeros((self.num_words,), jnp.uint32),
                          jnp.zeros((), jnp.int32))

    @staticmethod
    def for_capacity(capacity: int, bits_per_key: int = 16, **kw) -> "BloomConfig":
        words_per_block = kw.pop("words_per_block", 16)
        total_bits = capacity * bits_per_key
        blocks = max(1, int(np.ceil(total_bits / (words_per_block * 32))))
        return BloomConfig(num_blocks=blocks, words_per_block=words_per_block,
                           bits_per_key=bits_per_key, **kw)


def _bit_positions(config: BloomConfig, keys: jnp.ndarray):
    """-> (block int32[n], word_in_block int32[n,k], bit_mask uint32[n,k])."""
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    block = (lo % _U32(config.num_blocks)).astype(jnp.int32)
    # k in-block bit indices, peeled from the upper hash word and re-mixed.
    idx = []
    h = hi
    bits_needed = max(1, (config.block_bits - 1).bit_length())
    per_word = 32 // bits_needed
    for j in range(config.k):
        if j % max(per_word, 1) == 0 and j > 0:
            h = fmix32(h + _U32(j))
        shift = _U32((j % max(per_word, 1)) * bits_needed)
        idx.append((h >> shift) % _U32(config.block_bits))
    pos = jnp.stack(idx, axis=-1)                       # uint32[n, k]
    word = (pos >> _U32(5)).astype(jnp.int32)           # /32
    mask = _U32(1) << (pos & _U32(31))
    return block, word, mask


def insert(config: BloomConfig, state: BloomState, keys: jnp.ndarray,
           valid: Optional[jnp.ndarray] = None
           ) -> Tuple[BloomState, jnp.ndarray]:
    block, word, mask = _bit_positions(config, keys)
    addr = (block[:, None] * config.words_per_block + word).reshape(-1)
    n = keys.shape[0]
    ok = jnp.ones((n,), bool) if valid is None else valid.astype(bool)
    vmask = None if valid is None else jnp.repeat(ok, config.k)
    table = scatter_or(state.table, addr, mask.reshape(-1), vmask)
    # append-only: every valid key succeeds
    return BloomState(table, state.count + jnp.sum(ok, dtype=jnp.int32)), ok


def query(config: BloomConfig, state: BloomState, keys: jnp.ndarray) -> jnp.ndarray:
    block, word, mask = _bit_positions(config, keys)
    addr = block[:, None] * config.words_per_block + word
    words = state.table[addr]                            # [n, k]
    return jnp.all((words & mask) == mask, axis=-1)


class BlockedBloomFilter:
    """OO wrapper mirroring core.CuckooFilter (no deletion support)."""

    def __init__(self, config: BloomConfig):
        self.config = config
        self.state = config.init()
        self._insert = jax.jit(functools.partial(insert, config))
        self._query = jax.jit(functools.partial(query, config))

    def insert(self, keys):
        self.state, ok = self._insert(self.state, keys)
        return ok

    def query(self, keys):
        return self._query(self.state, keys)
