"""Baseline AMQ structures evaluated by the paper (§5.1).

Each module provides ``*Config`` (static, hashable), a state NamedTuple,
functional ``insert/query[/delete]`` and an OO wrapper. The registry maps the
benchmark names used in benchmarks/throughput.py to constructors.
"""

from .bcht import BCHTConfig, BucketedCuckooHashTable  # noqa: F401
from .blocked_bloom import BlockedBloomFilter, BloomConfig  # noqa: F401
from .cpu_reference import PyCuckooFilter  # noqa: F401
from .quotient import GQFConfig, QuotientFilter  # noqa: F401
from .two_choice import TCFConfig, TwoChoiceFilter  # noqa: F401
