"""Baseline AMQ structures evaluated by the paper (§5.1).

Each module provides ``*Config`` (static, hashable), a state NamedTuple,
functional ``insert/query[/delete]`` and an OO wrapper. All of them are also
registered behind the unified AMQ protocol: ``repro.amq.make("bloom"|"tcf"|
"gqf"|"bcht", capacity=...)`` returns a uniform FilterHandle, and
``repro.amq.names()`` enumerates every backend (this is the registry
benchmarks/throughput.py iterates — no per-filter special cases).

The registry itself lives in :mod:`repro.amq`; it is re-exported here
lazily (``repro.filters.amq`` / ``repro.filters.make``) so importing this
package never cycles through the adapters, which import these modules.
"""

from ..amq.protocol import (  # noqa: F401
    Capabilities,
    CascadeReport,
    DeleteReport,
    InsertReport,
    LevelStats,
    MixedReport,
    OpBatch,
    QueryResult,
)
from .bcht import BCHTConfig, BucketedCuckooHashTable  # noqa: F401
from .blocked_bloom import BlockedBloomFilter, BloomConfig  # noqa: F401
from .cpu_reference import PyCuckooConfig, PyCuckooFilter  # noqa: F401
from .quotient import GQFConfig, QuotientFilter  # noqa: F401
from .two_choice import TCFConfig, TwoChoiceFilter  # noqa: F401


def __getattr__(name):
    if name == "amq":
        from .. import amq

        return amq
    if name in ("make", "get", "names", "register"):
        from ..amq import registry

        return getattr(registry, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
