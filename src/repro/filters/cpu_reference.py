"""Pure-Python partitioned Cuckoo filter — PCF stand-in + differential oracle.

The paper's CPU baseline is the partitioned multi-threaded Cuckoo filter of
Schmidt et al. (VLDB'21). This sequential implementation mirrors the same
partial-key algorithm (and reuses the *identical* hash/tag/bucket derivation
as the JAX filter, so the two can be compared slot-for-slot in tests) and
serves as the CPU reference point for the benchmark speedup numbers.
"""

from __future__ import annotations

import dataclasses
import random
from typing import List

import numpy as np

from ..core.hashing import fmix32_py, keys_to_numpy, xxhash64_py  # noqa: F401
# keys_to_numpy (re-exported above) replaces this module's old keys_to_u64:
# the host-side key normalization now lives in one place, shared with the
# AMQ adapters and the service front-end. The old name is gone on purpose —
# repro.core.hashing.keys_to_u64 is a *different* function (a jax U64 lane
# pair), and two public names with clashing semantics invited misuse.

_M32 = 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class PyCuckooConfig:
    """AMQ-protocol config for the sequential oracle (mirrors CuckooConfig)."""

    num_buckets: int
    fp_bits: int = 16
    bucket_size: int = 16
    hash_kind: str = "xxhash64"
    max_evictions: int = 64
    seed: int = 0

    @property
    def num_slots(self) -> int:
        return self.num_buckets * self.bucket_size

    @property
    def table_bytes(self) -> int:
        return (self.num_slots * self.fp_bits + 7) // 8

    def expected_fpr(self, load_factor: float) -> float:
        """Same partial-key analysis as CuckooConfig (paper Eq. 4)."""
        f = self.fp_bits
        return 1.0 - (1.0 - 2.0 ** -f) ** (2 * self.bucket_size * load_factor)

    def init(self) -> "PyCuckooFilter":
        return PyCuckooFilter(self.num_buckets, self.fp_bits,
                              self.bucket_size, self.hash_kind,
                              self.max_evictions, self.seed)

    @staticmethod
    def for_capacity(capacity: int, load_factor: float = 0.95,
                     fp_bits: int = 16, bucket_size: int = 16,
                     **kw) -> "PyCuckooConfig":
        buckets = max(2, int(np.ceil(capacity / (load_factor * bucket_size))))
        buckets = 1 << int(np.ceil(np.log2(buckets)))  # xor placement
        return PyCuckooConfig(num_buckets=buckets, fp_bits=fp_bits,
                              bucket_size=bucket_size, **kw)


class PyCuckooFilter:
    """Sequential reference with the same layout/derivation as CuckooConfig."""

    def __init__(self, num_buckets: int, fp_bits: int = 16, bucket_size: int = 16,
                 hash_kind: str = "xxhash64", max_evictions: int = 64, seed: int = 0):
        assert num_buckets & (num_buckets - 1) == 0, "xor policy: power of two"
        self.num_buckets = num_buckets
        self.fp_bits = fp_bits
        self.bucket_size = bucket_size
        self.hash_kind = hash_kind
        self.max_evictions = max_evictions
        self.seed = seed
        self.buckets: List[List[int]] = [[0] * bucket_size
                                         for _ in range(num_buckets)]
        self.count = 0
        self._rng = random.Random(12345)

    # -- identical derivation to core.cuckoo_filter.prepare_keys ------------
    def _hash(self, key: int):
        if self.hash_kind == "xxhash64":
            h = xxhash64_py(key, self.seed)
            return (h >> 32) & _M32, h & _M32
        # fmix32_pair
        hi_in, lo_in = (key >> 32) & _M32, key & _M32
        if self.seed:
            hi_in ^= (self.seed >> 32) & _M32
            lo_in ^= self.seed & _M32
        a = fmix32_py(lo_in ^ fmix32_py(hi_in ^ 0x9E3779B9))
        b = fmix32_py((hi_in ^ fmix32_py((lo_in + 0x85EBCA6B) & _M32) ^ a) & _M32)
        return b, a

    def _prepare(self, key: int):
        hi, lo = self._hash(key)
        tag = hi & ((1 << self.fp_bits) - 1)
        tag = tag or 1
        i1 = lo & (self.num_buckets - 1)
        i2 = self._alt(i1, tag)
        return tag, i1, i2

    def _alt(self, bucket: int, tag: int) -> int:
        return bucket ^ (fmix32_py(tag) & (self.num_buckets - 1))

    # -- operations ----------------------------------------------------------
    def insert(self, key: int) -> bool:
        tag, i1, i2 = self._prepare(key)
        for b in (i1, i2):
            bucket = self.buckets[b]
            for s in range(self.bucket_size):
                if bucket[s] == 0:
                    bucket[s] = tag
                    self.count += 1
                    return True
        b = self._rng.choice((i1, i2))
        for _ in range(self.max_evictions):
            s = self._rng.randrange(self.bucket_size)
            tag, self.buckets[b][s] = self.buckets[b][s], tag
            b = self._alt(b, tag)
            bucket = self.buckets[b]
            for s2 in range(self.bucket_size):
                if bucket[s2] == 0:
                    bucket[s2] = tag
                    self.count += 1
                    return True
        return False

    def query(self, key: int) -> bool:
        tag, i1, i2 = self._prepare(key)
        return tag in self.buckets[i1] or tag in self.buckets[i2]

    def delete(self, key: int) -> bool:
        tag, i1, i2 = self._prepare(key)
        for b in (i1, i2):
            bucket = self.buckets[b]
            for s in range(self.bucket_size):
                if bucket[s] == tag:
                    bucket[s] = 0
                    self.count -= 1
                    return True
        return False

    # -- batch conveniences (numpy uint64 in/out) ----------------------------
    def insert_batch(self, keys: np.ndarray) -> np.ndarray:
        return np.array([self.insert(int(k)) for k in keys], bool)

    def query_batch(self, keys: np.ndarray) -> np.ndarray:
        return np.array([self.query(int(k)) for k in keys], bool)

    def delete_batch(self, keys: np.ndarray) -> np.ndarray:
        return np.array([self.delete(int(k)) for k in keys], bool)
