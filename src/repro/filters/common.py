"""Shared machinery for the baseline filters.

``scatter_or`` is the workhorse: a deterministic batched bitwise-OR scatter
(duplicate addresses merged with a segmented scan), the TPU-functional
equivalent of the GPU baselines' ``atomicOr``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_U32 = np.uint32


def scatter_or(table: jnp.ndarray, addr: jnp.ndarray, val: jnp.ndarray,
               valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """table[addr] |= val with duplicate-address merging.

    addr: int32[k] flat indices (may repeat); val: uint32[k];
    valid: optional bool[k] mask.
    """
    invalid = table.shape[0]
    if addr.shape[0] == 0:  # static: nothing to scatter (n=0 batches)
        return table
    if valid is not None:
        addr = jnp.where(valid, addr, invalid)
    order = jnp.argsort(addr, stable=True)
    sa = addr[order]
    sv = val[order]
    seg_start = jnp.concatenate([jnp.ones((1,), bool), sa[1:] != sa[:-1]])

    def combine(a, b):
        # Segmented inclusive OR-scan over (segment-start flag, value).
        flag_a, val_a = a
        flag_b, val_b = b
        return flag_a | flag_b, jnp.where(flag_b, val_b, val_a | val_b)

    _, acc = jax.lax.associative_scan(combine, (seg_start, sv))
    is_last = jnp.concatenate([seg_start[1:], jnp.ones((1,), bool)])
    # Merge with the existing table contents; each surviving addr is unique.
    safe = jnp.minimum(sa, invalid - 1)
    merged = table[safe] | acc
    waddr = jnp.where(is_last & (sa != invalid), sa, invalid)
    return table.at[waddr].set(merged, mode="drop")


def resolve_claims_single(addr: jnp.ndarray, invalid: int) -> jnp.ndarray:
    """Single-address claim election: True where this entry owns ``addr``.

    Lowest batch index wins (same rule as the core filter; see
    core.cuckoo_filter._resolve_claims).
    """
    n = addr.shape[0]
    order = jnp.argsort(addr, stable=True)
    sa = addr[order]
    first = jnp.concatenate([jnp.ones((1,), bool), sa[1:] != sa[:-1]])
    win_sorted = first & (sa != invalid)
    return jnp.zeros((n,), bool).at[order].set(win_sorted)
