"""GPU Counting Quotient Filter analogue — Robin Hood remainder table.

The GQF (McCoy et al.) stores r-bit remainders in sorted, contiguous runs via
Robin Hood hashing; keeping runs contiguous requires *shifting elements* on
update, which "creates strict serial dependencies between threads, making the
GQF fundamentally latency-bound" (paper §3). We reproduce exactly that
structural property with a Robin Hood table that stores, per slot, the
remainder plus its probe distance:

    slot = [dist : DIST_BITS | remainder : r]      (0 == empty)

* insert: probe from the home slot; displace any richer (smaller-dist)
  entry and carry it forward — a shift chain, executed sequentially per key
  inside a ``lax.fori_loop`` (the batch cannot be resolved in parallel
  because every displacement depends on the previous one — the very
  serialisation the paper identifies).
* query: bounded vectorized window scan using the Robin Hood invariant
  (stop once scanned distance exceeds the slot's stored distance).
* delete: backward-shift compaction, again sequential.

FPR matches a quotient filter with r remainder bits (the lowest of the
tested structures, cf. paper Fig. 4 — validated in benchmarks/fpr.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hashing import hash_key

_U32 = np.uint32

DIST_BITS = 8  # max probe distance 255 (insert fails beyond)


class GQFState(NamedTuple):
    table: jnp.ndarray  # uint32[num_slots]: dist<<r | remainder, 0 = empty
    count: jnp.ndarray  # int32[]


@dataclasses.dataclass(frozen=True)
class GQFConfig:
    num_slots: int
    remainder_bits: int = 16
    hash_kind: str = "fmix32"
    seed: int = 0
    max_probe: int = 64  # also the query window size

    @property
    def rmask(self) -> int:
        return (1 << self.remainder_bits) - 1

    @property
    def table_bytes(self) -> int:
        return self.num_slots * 4

    def expected_fpr(self, load_factor: float) -> float:
        """Quotient-filter estimate: a negative key collides iff some stored
        key shares its home slot *and* its r-bit remainder; the expected run
        length at its home slot is alpha, so eps ~= 1 - (1 - 2^-r)^alpha
        ~= alpha * 2^-r — the lowest of the pack (paper Fig. 4)."""
        return 1.0 - (1.0 - 2.0 ** -self.remainder_bits) ** load_factor

    def init(self) -> GQFState:
        return GQFState(jnp.zeros((self.num_slots,), jnp.uint32),
                        jnp.zeros((), jnp.int32))

    @staticmethod
    def for_capacity(capacity: int, load_factor: float = 0.95,
                     remainder_bits: int = 16, **kw) -> "GQFConfig":
        return GQFConfig(num_slots=max(4, int(np.ceil(capacity / load_factor))),
                         remainder_bits=remainder_bits, **kw)


def _prepare(config: GQFConfig, keys: jnp.ndarray):
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    rem = hi & _U32(config.rmask)
    rem = jnp.where(rem == 0, _U32(1), rem)        # 0 reserved for EMPTY
    home = (lo % _U32(config.num_slots)).astype(jnp.int32)
    return rem, home


def _dist(config: GQFConfig, slotval: jnp.ndarray) -> jnp.ndarray:
    return slotval >> _U32(config.remainder_bits)


def _pack(config: GQFConfig, rem: jnp.ndarray, dist: jnp.ndarray) -> jnp.ndarray:
    return (dist.astype(jnp.uint32) << _U32(config.remainder_bits)) | rem


def insert(config: GQFConfig, state: GQFState, keys: jnp.ndarray,
           valid: Optional[jnp.ndarray] = None
           ) -> Tuple[GQFState, jnp.ndarray]:
    """Sequential Robin Hood insertion (the GQF's serial shifting)."""
    n = keys.shape[0]
    if n == 0:  # static: fori_loop still traces its body on size-0 gathers
        return state, jnp.zeros((0,), bool)
    m = config.num_slots
    rem, home = _prepare(config, keys)
    valid0 = jnp.ones((n,), bool) if valid is None else valid.astype(bool)

    def insert_one(i, carry):
        table, count, ok = carry

        def probe(pcarry):
            table, pos, cur, dist, live, placed = pcarry
            slot = table[pos]
            empty = slot == 0
            s_dist = _dist(config, slot)
            rich = s_dist < dist          # Robin Hood: displace richer entry
            take = empty | rich
            newval = _pack(config, cur & _U32(config.rmask), dist)
            table = jax.lax.cond(
                take & live,
                lambda t: t.at[pos].set(newval), lambda t: t, table)
            placed = placed | (empty & live)
            # carry the displaced entry forward
            cur = jnp.where(rich & ~empty, slot & _U32(config.rmask), cur)
            dist = jnp.where(rich & ~empty, s_dist, dist)
            live = live & ~empty & (dist < config.max_probe)
            pos = (pos + 1) % m
            dist = dist + 1
            return table, pos, cur, dist, live, placed

        def probe_cond(pcarry):
            return pcarry[4]  # live

        table, _, _, _, _, placed = jax.lax.while_loop(
            probe_cond, probe,
            (table, home[i], rem[i], jnp.zeros((), jnp.uint32),
             valid0[i], jnp.zeros((), bool)))
        count = count + placed.astype(jnp.int32)
        ok = ok.at[i].set(placed)
        return table, count, ok

    table, count, ok = jax.lax.fori_loop(
        0, n, insert_one,
        (state.table, state.count, jnp.zeros((n,), bool)))
    return GQFState(table, count), ok


def query(config: GQFConfig, state: GQFState, keys: jnp.ndarray) -> jnp.ndarray:
    """Vectorized bounded-window probe using the Robin Hood invariant."""
    rem, home = _prepare(config, keys)
    w = config.max_probe
    idx = (home[:, None] + jnp.arange(w, dtype=jnp.int32)) % config.num_slots
    window = state.table[idx]                                   # [n, w]
    d = jnp.arange(w, dtype=jnp.uint32)[None, :]
    match = (window & _U32(config.rmask)) == rem[:, None]
    match &= _dist(config, window) == d                          # same run
    # stop scanning once a slot is empty or poorer than our distance
    alive = jnp.cumprod(
        jnp.concatenate([jnp.ones((keys.shape[0], 1), jnp.int32),
                         ((window != 0) & (_dist(config, window) >= d))
                         .astype(jnp.int32)[:, :-1]], axis=1), axis=1)
    return jnp.any(match & (alive > 0), axis=-1)


def delete(config: GQFConfig, state: GQFState, keys: jnp.ndarray,
           valid: Optional[jnp.ndarray] = None
           ) -> Tuple[GQFState, jnp.ndarray]:
    """Sequential delete + backward-shift compaction."""
    n = keys.shape[0]
    if n == 0:  # static: fori_loop still traces its body on size-0 gathers
        return state, jnp.zeros((0,), bool)
    m = config.num_slots
    rem, home = _prepare(config, keys)
    valid0 = jnp.ones((n,), bool) if valid is None else valid.astype(bool)
    w = config.max_probe

    def delete_one(i, carry):
        table, count, ok = carry
        # locate the entry within the probe window
        idx = (home[i] + jnp.arange(w, dtype=jnp.int32)) % m
        window = table[idx]
        d = jnp.arange(w, dtype=jnp.uint32)
        match = ((window & _U32(config.rmask)) == rem[i]) & \
                (_dist(config, window) == d)
        found = jnp.any(match) & valid0[i]
        at = jnp.argmax(match).astype(jnp.int32)
        pos = (home[i] + at) % m

        def compact(ccarry):
            table, pos, live = ccarry
            nxt = (pos + 1) % m
            nslot = table[nxt]
            movable = (nslot != 0) & (_dist(config, nslot) > 0)
            moved = _pack(config, nslot & _U32(config.rmask),
                          _dist(config, nslot) - 1)
            table = jax.lax.cond(
                movable & live,
                lambda t: t.at[pos].set(moved), lambda t: t, table)
            table = jax.lax.cond(
                ~movable & live,
                lambda t: t.at[pos].set(jnp.zeros((), jnp.uint32)),
                lambda t: t, table)
            live = live & movable
            return table, nxt, live

        table, _, _ = jax.lax.while_loop(
            lambda c: c[2], compact, (table, pos, found))
        count = count - found.astype(jnp.int32)
        ok = ok.at[i].set(found)
        return table, count, ok

    table, count, ok = jax.lax.fori_loop(
        0, n, delete_one, (state.table, state.count, jnp.zeros((n,), bool)))
    return GQFState(table, count), ok


class QuotientFilter:
    def __init__(self, config: GQFConfig):
        self.config = config
        self.state = config.init()
        self._insert = jax.jit(functools.partial(insert, config))
        self._query = jax.jit(functools.partial(query, config))
        self._delete = jax.jit(functools.partial(delete, config))

    def insert(self, keys):
        self.state, ok = self._insert(self.state, keys)
        return ok

    def query(self, keys):
        return self._query(self.state, keys)

    def delete(self, keys):
        self.state, ok = self._delete(self.state, keys)
        return ok
