"""Two-Choice Filter (TCF) — dynamic GPU baseline (McCoy et al., PPoPP'23).

Power-of-two-choices: each key has two candidate blocks; it is inserted into
the *emptier* one. No eviction chains — if both blocks are full the key
overflows into a small stash. Deletion removes a matching tag from either
block or the stash.

The GPU TCF leans on cooperative groups to sort blocks in shared memory; our
batch version keeps the data-structure semantics (two choices + stash) and
resolves intra-batch races with the same word-claim election as the core
filter. Its FPR is worse than the cuckoo filter's at equal space because load
balancing needs larger blocks (paper Fig. 4 discussion).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import layout as L
from ..core.hashing import fmix32, hash_key
from .common import resolve_claims_single

_U32 = np.uint32


class TCFState(NamedTuple):
    table: jnp.ndarray   # uint32[num_blocks * words_per_block] packed tags
    stash: jnp.ndarray   # uint32[stash_size] packed (block << fp_bits | tag)
    count: jnp.ndarray   # int32[]


@dataclasses.dataclass(frozen=True)
class TCFConfig:
    num_blocks: int
    fp_bits: int = 16
    block_size: int = 32          # tags per block (TCF favours large blocks)
    stash_size: int = 128
    hash_kind: str = "fmix32"
    seed: int = 0
    max_rounds: int = 16

    @property
    def layout(self) -> L.BucketLayout:
        return L.BucketLayout(self.num_blocks, self.block_size, self.fp_bits)

    @property
    def num_slots(self) -> int:
        return self.num_blocks * self.block_size

    @property
    def table_bytes(self) -> int:
        return self.layout.table_bytes + self.stash_size * 4

    def expected_fpr(self, load_factor: float) -> float:
        """Two candidate blocks of ``block_size`` tags each scanned per
        query: eps ~= 1 - (1 - 2^-f)^(2 b alpha) — same form as the cuckoo
        filter's Eq. (4) but with the TCF's larger blocks (the paper's
        Fig. 4 point: load balancing needs big blocks, costing FPR)."""
        f = self.fp_bits
        return 1.0 - (1.0 - 2.0 ** -f) ** (2 * self.block_size * load_factor)

    def init(self) -> TCFState:
        return TCFState(self.layout.empty_table(),
                        jnp.zeros((self.stash_size,), jnp.uint32),
                        jnp.zeros((), jnp.int32))

    @staticmethod
    def for_capacity(capacity: int, load_factor: float = 0.95,
                     fp_bits: int = 16, block_size: int = 32, **kw) -> "TCFConfig":
        blocks = max(2, int(np.ceil(capacity / (load_factor * block_size))))
        return TCFConfig(num_blocks=blocks, fp_bits=fp_bits,
                         block_size=block_size, **kw)


def _prepare(config: TCFConfig, keys: jnp.ndarray):
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    fp = hi & _U32((1 << config.fp_bits) - 1)
    tag = jnp.where(fp == 0, _U32(1), fp)
    b1 = lo % _U32(config.num_blocks)
    b2 = fmix32(lo ^ _U32(0xB5297A4D)) % _U32(config.num_blocks)
    return tag, b1, b2


def _stash_entry(config: TCFConfig, block: jnp.ndarray, tag: jnp.ndarray):
    return ((block.astype(jnp.uint32) << _U32(config.fp_bits))
            | tag.astype(jnp.uint32)) | _U32(1 << 31)  # bit31 = occupied


def insert(config: TCFConfig, state: TCFState, keys: jnp.ndarray,
           valid: Optional[jnp.ndarray] = None
           ) -> Tuple[TCFState, jnp.ndarray]:
    lay = config.layout
    n = keys.shape[0]
    invalid = lay.num_words + config.stash_size
    tag, b1, b2 = _prepare(config, keys)
    pending0 = jnp.ones((n,), bool) if valid is None else valid.astype(bool)

    def round_fn(carry):
        table, stash, count, pending, success, rnd = carry
        tags1 = L.bucket_tags(table, b1, lay)
        tags2 = L.bucket_tags(table, b2, lay)
        n_free1 = jnp.sum(tags1 == 0, axis=-1)
        n_free2 = jnp.sum(tags2 == 0, axis=-1)
        # Power of two choices: pick the emptier block.
        pick2 = n_free2 > n_free1
        blk = jnp.where(pick2, b2, b1)
        tags = jnp.where(pick2[:, None], tags2, tags1)
        has_room = (jnp.maximum(n_free1, n_free2) > 0)

        start = L.scan_start(tag, lay)
        found, slot = L.first_true_circular(tags == 0, start)
        widx, sw = L.slot_to_word(slot, lay)
        words = L.gather_bucket_words(table, blk, lay)
        word = jnp.take_along_axis(words, widx[:, None], axis=1)[:, 0]
        desired = L.replace_tag(word, sw, tag, lay.fp_bits)
        addr = L.word_addr(blk, widx, lay)

        # Both blocks full -> claim a stash slot instead.
        stash_free = stash == 0
        sstart = (fmix32(tag + rnd.astype(jnp.uint32))
                  % _U32(config.stash_size)).astype(jnp.int32)
        sfound, sslot = L.first_true_circular(
            jnp.broadcast_to(stash_free, (n, config.stash_size)), sstart)
        use_stash = pending & ~has_room & sfound
        use_table = pending & has_room & found

        claim = jnp.where(use_table, addr,
                          jnp.where(use_stash, lay.num_words + sslot, invalid))
        win = resolve_claims_single(claim, invalid)
        commit_t = use_table & win
        commit_s = use_stash & win

        table = table.at[jnp.where(commit_t, addr, lay.num_words)].set(
            desired, mode="drop")
        sval = _stash_entry(config, blk, tag)
        stash = stash.at[jnp.where(commit_s, sslot, config.stash_size)].set(
            sval, mode="drop")

        done = commit_t | commit_s
        # Keys with no room anywhere (stash full) fail out.
        dead = pending & ~has_room & ~sfound
        pending = pending & ~done & ~dead
        success = success | done
        count = count + jnp.sum(done, dtype=jnp.int32)
        return table, stash, count, pending, success, rnd + 1

    def cond_fn(carry):
        return jnp.any(carry[3]) & (carry[5] < config.max_rounds)

    carry0 = (state.table, state.stash, state.count, pending0,
              jnp.zeros((n,), bool), jnp.zeros((), jnp.int32))
    table, stash, count, pending, success, _ = jax.lax.while_loop(
        cond_fn, round_fn, carry0)
    return TCFState(table, stash, count), success & ~pending


def query(config: TCFConfig, state: TCFState, keys: jnp.ndarray) -> jnp.ndarray:
    lay = config.layout
    tag, b1, b2 = _prepare(config, keys)
    hit1 = jnp.any(L.bucket_tags(state.table, b1, lay) == tag[:, None], axis=-1)
    hit2 = jnp.any(L.bucket_tags(state.table, b2, lay) == tag[:, None], axis=-1)
    # Stash: compare against both candidate blocks' entries.
    e1 = _stash_entry(config, b1, tag)
    e2 = _stash_entry(config, b2, tag)
    hs = jnp.any((state.stash[None, :] == e1[:, None])
                 | (state.stash[None, :] == e2[:, None]), axis=-1)
    return hit1 | hit2 | hs


def delete(config: TCFConfig, state: TCFState, keys: jnp.ndarray,
           valid: Optional[jnp.ndarray] = None
           ) -> Tuple[TCFState, jnp.ndarray]:
    lay = config.layout
    n = keys.shape[0]
    invalid = lay.num_words + config.stash_size
    tag, b1, b2 = _prepare(config, keys)
    pending0 = jnp.ones((n,), bool) if valid is None else valid.astype(bool)
    max_rounds = 2 * config.block_size + 2

    def round_fn(carry):
        table, stash, count, pending, success, rnd = carry
        words1 = L.gather_bucket_words(table, b1, lay)
        words2 = L.gather_bucket_words(table, b2, lay)
        tags1 = L.unpack_words(words1, lay.fp_bits)
        tags2 = L.unpack_words(words2, lay.fp_bits)
        start = L.scan_start(tag, lay)
        f1, s1 = L.first_true_circular(tags1 == tag[:, None], start)
        f2, s2 = L.first_true_circular(tags2 == tag[:, None], start)
        blk = jnp.where(f1, b1, b2)
        slot = jnp.where(f1, s1, s2)
        words = jnp.where(f1[:, None], words1, words2)
        found = f1 | f2

        widx, sw = L.slot_to_word(slot, lay)
        word = jnp.take_along_axis(words, widx[:, None], axis=1)[:, 0]
        desired = L.replace_tag(word, sw, jnp.zeros((n,), jnp.uint32),
                                lay.fp_bits)
        addr = L.word_addr(blk, widx, lay)

        # Stash fallback.
        e1 = _stash_entry(config, b1, tag)
        e2 = _stash_entry(config, b2, tag)
        smatch = (stash[None, :] == e1[:, None]) | (stash[None, :] == e2[:, None])
        sfound = jnp.any(smatch, axis=-1)
        sslot = jnp.argmax(smatch, axis=-1).astype(jnp.int32)

        use_table = pending & found
        use_stash = pending & ~found & sfound
        pending = pending & (found | sfound)

        claim = jnp.where(use_table, addr,
                          jnp.where(use_stash, lay.num_words + sslot, invalid))
        win = resolve_claims_single(claim, invalid)
        commit_t = use_table & win
        commit_s = use_stash & win
        table = table.at[jnp.where(commit_t, addr, lay.num_words)].set(
            desired, mode="drop")
        stash = stash.at[jnp.where(commit_s, sslot, config.stash_size)].set(
            jnp.zeros((n,), jnp.uint32), mode="drop")
        done = commit_t | commit_s
        success = success | done
        pending = pending & ~done
        count = count - jnp.sum(done, dtype=jnp.int32)
        return table, stash, count, pending, success, rnd + 1

    def cond_fn(carry):
        return jnp.any(carry[3]) & (carry[5] < max_rounds)

    carry0 = (state.table, state.stash, state.count, pending0,
              jnp.zeros((n,), bool), jnp.zeros((), jnp.int32))
    table, stash, count, _, success, _ = jax.lax.while_loop(
        cond_fn, round_fn, carry0)
    return TCFState(table, stash, count), success


class TwoChoiceFilter:
    def __init__(self, config: TCFConfig):
        self.config = config
        self.state = config.init()
        self._insert = jax.jit(functools.partial(insert, config))
        self._query = jax.jit(functools.partial(query, config))
        self._delete = jax.jit(functools.partial(delete, config))

    def insert(self, keys):
        self.state, ok = self._insert(self.state, keys)
        return ok

    def query(self, keys):
        return self._query(self.state, keys)

    def delete(self, keys):
        self.state, ok = self._delete(self.state, keys)
        return ok
