"""Bucketed Cuckoo Hash Table (BCHT) — exact-membership baseline (Awad et al.).

Stores *full 64-bit keys* (as lo/hi uint32 pairs) instead of fingerprints, so
membership answers are exact (zero FPR) — at ~8 bytes/slot vs 2 for the
16-bit filter, the paper's "order-of-magnitude more memory" point (§5.2).

Same batch-synchronous cuckoo machinery as the core filter, but claims are
slot-granular (a slot spans two words in parallel arrays plus a presence
bitmap, all owned by the claim winner). DFS eviction only — the BFS
heuristic is a filter-side contribution; the baseline mirrors the reference
hash table.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hashing import fmix32, hash_key
from .common import resolve_claims_single

_U32 = np.uint32


class BCHTState(NamedTuple):
    key_lo: jnp.ndarray   # uint32[num_buckets, bucket_size]
    key_hi: jnp.ndarray   # uint32[num_buckets, bucket_size]
    used: jnp.ndarray     # bool[num_buckets, bucket_size]
    count: jnp.ndarray    # int32[]


@dataclasses.dataclass(frozen=True)
class BCHTConfig:
    num_buckets: int          # power of two
    bucket_size: int = 16
    seed: int = 0
    max_evictions: int = 64
    max_rounds: int = 320

    def __post_init__(self):
        if self.num_buckets & (self.num_buckets - 1):
            raise ValueError("BCHT requires power-of-two buckets")

    @property
    def mask(self) -> int:
        return self.num_buckets - 1

    @property
    def num_slots(self) -> int:
        return self.num_buckets * self.bucket_size

    @property
    def table_bytes(self) -> int:
        return self.num_slots * 9  # 8B key + 1b used (rounded up)

    def expected_fpr(self, load_factor: float) -> float:
        """Exact membership (full 64-bit keys stored): zero false positives
        — the "order-of-magnitude more memory" trade (paper §5.2)."""
        del load_factor
        return 0.0

    def init(self) -> BCHTState:
        shape = (self.num_buckets, self.bucket_size)
        return BCHTState(jnp.zeros(shape, jnp.uint32),
                         jnp.zeros(shape, jnp.uint32),
                         jnp.zeros(shape, bool),
                         jnp.zeros((), jnp.int32))

    @staticmethod
    def for_capacity(capacity: int, load_factor: float = 0.9,
                     bucket_size: int = 16, **kw) -> "BCHTConfig":
        buckets = max(2, int(np.ceil(capacity / (load_factor * bucket_size))))
        buckets = 1 << int(np.ceil(np.log2(buckets)))
        return BCHTConfig(num_buckets=buckets, bucket_size=bucket_size, **kw)


def _buckets(config: BCHTConfig, lo: jnp.ndarray, hi: jnp.ndarray):
    """Two bucket choices from the full key (involution via XOR of key mix)."""
    mixed = fmix32(lo ^ fmix32(hi ^ _U32(config.seed & 0xFFFFFFFF)))
    i1 = mixed & _U32(config.mask)
    delta = fmix32(hi ^ fmix32(lo)) & _U32(config.mask)
    delta = jnp.where(delta == 0, _U32(1), delta)
    return i1, i1 ^ delta, delta


def _alt(config: BCHTConfig, bucket, lo, hi):
    _, _, delta = _buckets(config, lo, hi)
    return bucket ^ delta


def insert(config: BCHTConfig, state: BCHTState, keys: jnp.ndarray,
           valid: Optional[jnp.ndarray] = None
           ) -> Tuple[BCHTState, jnp.ndarray]:
    n = keys.shape[0]
    b = config.bucket_size
    invalid = config.num_slots
    klo, khi = keys[..., 0].astype(jnp.uint32), keys[..., 1].astype(jnp.uint32)
    i1, i2, _ = _buckets(config, klo, khi)
    pending0 = jnp.ones((n,), bool) if valid is None else valid.astype(bool)

    def round_fn(carry):
        (key_lo, key_hi, used, count, cur_lo, cur_hi, cur_bucket,
         evict_mode, pending, success, n_evict, rnd) = carry
        failed = pending & (n_evict >= config.max_evictions) & evict_mode
        pending = pending & ~failed

        bucketA = jnp.where(evict_mode, cur_bucket, i1)
        usedA = used[bucketA.astype(jnp.int32)]        # [n, b]
        usedB = used[i2.astype(jnp.int32)]
        start = (fmix32(cur_lo) % _U32(b)).astype(jnp.int32)
        idx = (start[:, None] + jnp.arange(b, dtype=jnp.int32)) % b
        freeA = jnp.take_along_axis(~usedA, idx, axis=1)
        freeB = jnp.take_along_axis(~usedB, idx, axis=1)
        foundA = jnp.any(freeA, axis=1)
        foundB = jnp.any(freeB, axis=1) & ~evict_mode
        slotA = jnp.take_along_axis(idx, jnp.argmax(freeA, axis=1)[:, None], axis=1)[:, 0]
        slotB = jnp.take_along_axis(idx, jnp.argmax(freeB, axis=1)[:, None], axis=1)[:, 0]

        direct = foundA | foundB
        d_bucket = jnp.where(foundA, bucketA, i2)
        d_slot = jnp.where(foundA, slotA, slotB)
        d_addr = d_bucket.astype(jnp.int32) * b + d_slot

        # eviction action
        vic = (fmix32(cur_lo ^ (rnd.astype(jnp.uint32) * _U32(0x9E3779B9)))
               % _U32(b)).astype(jnp.int32)
        e_addr = bucketA.astype(jnp.int32) * b + vic

        addr = jnp.where(pending & direct, d_addr,
                         jnp.where(pending, e_addr, invalid))
        win = resolve_claims_single(addr, invalid)
        commit = pending & win

        commit_direct = commit & direct
        commit_evict = commit & ~direct

        waddr = jnp.where(commit, addr, invalid)
        # gather the evicted key before overwriting
        vb, vs = e_addr // b, e_addr % b
        ev_lo = key_lo[vb, vs]
        ev_hi = key_hi[vb, vs]

        flat_lo = key_lo.reshape(-1).at[waddr].set(cur_lo, mode="drop")
        flat_hi = key_hi.reshape(-1).at[waddr].set(cur_hi, mode="drop")
        flat_used = used.reshape(-1).at[waddr].set(True, mode="drop")
        key_lo = flat_lo.reshape(key_lo.shape)
        key_hi = flat_hi.reshape(key_hi.shape)
        used = flat_used.reshape(used.shape)

        success = success | commit_direct
        pending = pending & ~commit_direct
        count = count + jnp.sum(commit_direct, dtype=jnp.int32)

        new_bucket = _alt(config, bucketA, ev_lo, ev_hi)
        cur_lo = jnp.where(commit_evict, ev_lo, cur_lo)
        cur_hi = jnp.where(commit_evict, ev_hi, cur_hi)
        cur_bucket = jnp.where(commit_evict, new_bucket, cur_bucket)
        evict_mode = evict_mode | commit_evict
        n_evict = n_evict + commit_evict.astype(jnp.int32)
        return (key_lo, key_hi, used, count, cur_lo, cur_hi, cur_bucket,
                evict_mode, pending, success, n_evict, rnd + 1)

    def cond_fn(carry):
        return jnp.any(carry[8]) & (carry[11] < config.max_rounds)

    carry0 = (state.key_lo, state.key_hi, state.used, state.count,
              klo, khi, i1, jnp.zeros((n,), bool), pending0,
              jnp.zeros((n,), bool), jnp.zeros((n,), jnp.int32),
              jnp.zeros((), jnp.int32))
    out = jax.lax.while_loop(cond_fn, round_fn, carry0)
    key_lo, key_hi, used, count = out[0], out[1], out[2], out[3]
    pending, success = out[8], out[9]
    return BCHTState(key_lo, key_hi, used, count), success & ~pending


def query(config: BCHTConfig, state: BCHTState, keys: jnp.ndarray) -> jnp.ndarray:
    klo, khi = keys[..., 0].astype(jnp.uint32), keys[..., 1].astype(jnp.uint32)
    i1, i2, _ = _buckets(config, klo, khi)

    def hit(bucket):
        bi = bucket.astype(jnp.int32)
        return jnp.any((state.key_lo[bi] == klo[:, None])
                       & (state.key_hi[bi] == khi[:, None])
                       & state.used[bi], axis=1)

    return hit(i1) | hit(i2)


def delete(config: BCHTConfig, state: BCHTState, keys: jnp.ndarray,
           valid: Optional[jnp.ndarray] = None
           ) -> Tuple[BCHTState, jnp.ndarray]:
    n = keys.shape[0]
    b = config.bucket_size
    invalid = config.num_slots
    klo, khi = keys[..., 0].astype(jnp.uint32), keys[..., 1].astype(jnp.uint32)
    i1, i2, _ = _buckets(config, klo, khi)
    pending_init = jnp.ones((n,), bool) if valid is None else valid.astype(bool)
    max_rounds = b + 2

    def round_fn(carry):
        key_lo, key_hi, used, count, pending, success, rnd = carry

        def match(bucket):
            bi = bucket.astype(jnp.int32)
            m = ((key_lo[bi] == klo[:, None]) & (key_hi[bi] == khi[:, None])
                 & used[bi])
            return jnp.any(m, axis=1), jnp.argmax(m, axis=1).astype(jnp.int32)

        f1, s1 = match(i1)
        f2, s2 = match(i2)
        found = f1 | f2
        bucket = jnp.where(f1, i1, i2)
        slot = jnp.where(f1, s1, s2)
        addr = bucket.astype(jnp.int32) * b + slot
        pending = pending & found
        addr = jnp.where(pending, addr, invalid)
        win = resolve_claims_single(addr, invalid)
        commit = pending & win
        waddr = jnp.where(commit, addr, invalid)
        used = used.reshape(-1).at[waddr].set(False, mode="drop").reshape(used.shape)
        success = success | commit
        pending = pending & ~commit
        count = count - jnp.sum(commit, dtype=jnp.int32)
        return key_lo, key_hi, used, count, pending, success, rnd + 1

    def cond_fn(carry):
        return jnp.any(carry[4]) & (carry[6] < max_rounds)

    carry0 = (state.key_lo, state.key_hi, state.used, state.count,
              pending_init, jnp.zeros((n,), bool),
              jnp.zeros((), jnp.int32))
    key_lo, key_hi, used, count, _, success, _ = jax.lax.while_loop(
        cond_fn, round_fn, carry0)
    return BCHTState(key_lo, key_hi, used, count), success


class BucketedCuckooHashTable:
    def __init__(self, config: BCHTConfig):
        self.config = config
        self.state = config.init()
        self._insert = jax.jit(functools.partial(insert, config))
        self._query = jax.jit(functools.partial(query, config))
        self._delete = jax.jit(functools.partial(delete, config))

    def insert(self, keys):
        self.state, ok = self._insert(self.state, keys)
        return ok

    def query(self, keys):
        return self._query(self.state, keys)

    def delete(self, keys):
        self.state, ok = self._delete(self.state, keys)
        return ok
