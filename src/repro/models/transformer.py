"""Block assembly + layer stacks.

A *block* = pre-norm mixer (attention / MLA / SSD / RG-LRU) + pre-norm
FFN (dense GLU or MoE), both residual. Layers are grouped into *segments*
(ModelConfig.segments()): each segment is a repeating period of identical
layer kinds, scanned with ``lax.scan`` over stacked parameters — one period
is traced/compiled once regardless of depth (compile-time and HLO-size
discipline for the 61-layer 671B config), and remat is applied per period.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import moe as M
from . import rglru as R
from . import ssm as S
from .layers import mlp_apply, mlp_init, rmsnorm_apply, rmsnorm_init


def _mixer_kind(kind: str) -> str:
    return kind.split("+")[0]


def _ffn_kind(kind: str) -> str:
    parts = kind.split("+")
    return parts[1] if len(parts) > 1 else "none"


def block_init(key, cfg, kind: str):
    mixer, ffn = _mixer_kind(kind), _ffn_kind(kind)
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"ln1": rmsnorm_init(cfg.d_model)}
    if mixer in ("attn", "attn_local"):
        p["mixer"] = A.gqa_init(k1, cfg, {})
    elif mixer == "mla":
        p["mixer"] = A.mla_init(k1, cfg)
    elif mixer == "ssm":
        p["mixer"] = S.ssm_init(k1, cfg)
    elif mixer == "rglru":
        p["mixer"] = R.rglru_init(k1, cfg)
    else:  # pragma: no cover
        raise ValueError(mixer)
    if ffn == "mlp":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = mlp_init(k2, cfg.d_model, cfg.d_ff, act=cfg.act)
    elif ffn == "moe":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = M.moe_init(k2, cfg)
    return p


def block_apply(p, cfg, kind: str, x, *, positions, cache=None,
                cache_pos=None, update_cache=False):
    mixer, ffn = _mixer_kind(kind), _ffn_kind(kind)
    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    kw = dict(cache=cache, cache_pos=cache_pos, update_cache=update_cache)
    if mixer == "attn":
        out, new_cache = A.gqa_apply(
            p["mixer"], cfg, h, positions=positions, window=None,
            causal=cfg.causal, attn_softcap=cfg.attn_softcap, **kw)
    elif mixer == "attn_local":
        out, new_cache = A.gqa_apply(
            p["mixer"], cfg, h, positions=positions,
            window=cfg.sliding_window, causal=cfg.causal,
            attn_softcap=cfg.attn_softcap, **kw)
    elif mixer == "mla":
        out, new_cache = A.mla_apply(p["mixer"], cfg, h, positions=positions,
                                     **kw)
    elif mixer == "ssm":
        out, new_cache = S.ssm_apply(p["mixer"], cfg, h, cache=cache,
                                     update_cache=update_cache)
    elif mixer == "rglru":
        out, new_cache = R.rglru_apply(p["mixer"], cfg, h, cache=cache,
                                       update_cache=update_cache)
    else:  # pragma: no cover
        raise ValueError(mixer)
    x = x + out

    if ffn == "mlp":
        x = x + mlp_apply(p["ffn"], rmsnorm_apply(p["ln2"], x, cfg.norm_eps),
                          act=cfg.act)
    elif ffn == "moe":
        x = x + M.moe_apply(p["ffn"], cfg,
                            rmsnorm_apply(p["ln2"], x, cfg.norm_eps),
                            capacity_factor=cfg.capacity_factor)
    return x, new_cache


def _empty_cache(cfg, kind: str, batch: int, max_len: int):
    """ShapeDtype-complete empty cache for one layer (decode lowering)."""
    mixer = _mixer_kind(kind)
    hd = cfg.head_dim_()
    if mixer == "attn":
        shape = (batch, max_len, cfg.num_kv_heads, hd)
        return A.KVCache(jnp.zeros(shape, jnp.bfloat16),
                         jnp.zeros(shape, jnp.bfloat16))
    if mixer == "attn_local":
        w = min(cfg.sliding_window, max_len)
        shape = (batch, w, cfg.num_kv_heads, hd)
        return A.KVCache(jnp.zeros(shape, jnp.bfloat16),
                         jnp.zeros(shape, jnp.bfloat16))
    if mixer == "mla":
        return A.MLACache(
            jnp.zeros((batch, max_len, cfg.mla_kv_lora_rank), jnp.bfloat16),
            jnp.zeros((batch, max_len, cfg.mla_qk_rope_dim), jnp.bfloat16))
    if mixer == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        conv_dim = d_in + 2 * cfg.ssm_state
        return S.SSMCache(
            jnp.zeros((batch, cfg.conv1d_width - 1, conv_dim), jnp.bfloat16),
            jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), jnp.float32))
    if mixer == "rglru":
        W = cfg.rglru_width or cfg.d_model
        return R.RGLRUCache(
            jnp.zeros((batch, W), jnp.float32),
            jnp.zeros((batch, cfg.conv1d_width - 1, W), jnp.bfloat16))
    raise ValueError(mixer)  # pragma: no cover


# ---------------------------------------------------------------------------
# Stack: segments of scanned periods
# ---------------------------------------------------------------------------

def stack_init(key, cfg):
    """Returns a list of segment params; each leaf has leading dim = reps."""
    segs = cfg.segments()
    out = []
    for si, (period, reps) in enumerate(segs):
        kseg = jax.random.fold_in(key, si)

        def one_rep(k, _period=period):
            ks = jax.random.split(k, len(_period))
            return [block_init(ks[j], cfg, kind)
                    for j, kind in enumerate(_period)]

        out.append(jax.vmap(one_rep)(jax.random.split(kseg, reps)))
    return out


_REMAT_POLICIES = {
    # "full": recompute everything in the backward pass — ~8ND total FLOPs
    # instead of 6ND, but per-layer activation residency drops to the scan
    # carry only. The memory-lean default for the big configs.
    "full": None,
    # "dots": save matmul outputs (XLA's dots_with_no_batch_dims) — faster
    # backward, much higher residency. A §Perf knob for the small configs.
    "dots": "dots_with_no_batch_dims_saveable",
}


def stack_apply(params, cfg, x, *, positions, remat: bool = True):
    """Train/prefill forward through all segments (no caches)."""
    for (period, reps), seg_params in zip(cfg.segments(), params):

        def seg_step(h, layer_params, _period=period):
            for j, kind in enumerate(_period):
                h, _ = block_apply(layer_params[j], cfg, kind, h,
                                   positions=positions)
            return h, None

        if remat and cfg.remat_policy != "none":
            policy_name = _REMAT_POLICIES.get(cfg.remat_policy)
            policy = (getattr(jax.checkpoint_policies, policy_name)
                      if policy_name else None)
            seg_step = jax.checkpoint(seg_step, policy=policy)
        x, _ = jax.lax.scan(seg_step, x, seg_params)
    return x


def stack_prefill(params, cfg, x, *, positions):
    """Forward + build per-layer caches. Returns (x, caches)."""
    caches = []
    for (period, reps), seg_params in zip(cfg.segments(), params):

        def seg_step(h, layer_params, _period=period):
            new = []
            for j, kind in enumerate(_period):
                h, c = block_apply(layer_params[j], cfg, kind, h,
                                   positions=positions, update_cache=True)
                new.append(c)
            return h, tuple(new)

        x, seg_caches = jax.lax.scan(seg_step, x, seg_params)
        caches.append(seg_caches)
    return x, caches


def stack_decode(params, cfg, x, caches, *, positions, cache_pos):
    """Single-token step updating caches. Returns (x, caches')."""
    new_caches = []
    for (period, reps), seg_params, seg_caches in zip(
            cfg.segments(), params, caches):

        def seg_step(h, inp, _period=period):
            layer_params, layer_caches = inp
            new = []
            for j, kind in enumerate(_period):
                h, c = block_apply(layer_params[j], cfg, kind, h,
                                   positions=positions,
                                   cache=layer_caches[j],
                                   cache_pos=cache_pos)
                new.append(c)
            return h, tuple(new)

        x, seg_new = jax.lax.scan(seg_step, x, (seg_params, seg_caches))
        new_caches.append(seg_new)
    return x, new_caches


def init_caches(cfg, batch: int, max_len: int):
    """Empty decode caches matching stack_decode's expected structure."""
    out = []
    for period, reps in cfg.segments():
        seg = []
        for kind in period:
            one = _empty_cache(cfg, kind, batch, max_len)
            seg.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), one))
        out.append(tuple(seg))
    return out
