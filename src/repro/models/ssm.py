"""Mamba-2 (SSD — state-space duality) block, chunked-parallel form.

Follows the minimal SSD reference of Dao & Gu (arXiv:2405.21060): scalar
per-head decay ``a``, shared B/C projections (like MQA), short causal conv on
the (x, B, C) stream, chunked algorithm =

  1. intra-chunk (quadratic in chunk length L, "attention-like"):
     ``Y_diag = (C Bᵀ ⊙ decay) X``
  2. chunk states + inter-chunk linear recurrence over chunk index
     (``lax.scan`` over n_chunks — tiny sequential dimension)
  3. state-to-output correction ``Y_off = C h_prev ⊙ decay_out``

Decode is the O(1) recurrent step on the [B, H, P, N] state.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_apply, dense_init, rmsnorm_apply, rmsnorm_init


class SSMCache(NamedTuple):
    conv: jnp.ndarray    # [B, conv_w - 1, conv_dim]  rolling conv window
    state: jnp.ndarray   # [B, H, P, N]               SSM state


def _segsum(x):
    """log-space segment sums: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    L = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    ii = jnp.arange(L)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssm_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = cfg.ssm_heads
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    ks = jax.random.split(key, 4)
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * N + H, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv1d_width, conv_dim),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),                # a = -exp(A_log)
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": rmsnorm_init(d_in),
        "out_proj": dense_init(ks[2], d_in, d, dtype=dtype),
    }


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width W: xBC [B, S, C]."""
    W = w.shape[0]
    pads = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + xBC.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def ssm_apply(p, cfg, x, *, cache: SSMCache | None = None,
              update_cache: bool = False):
    """x: [B, S, d]. Returns (y, new_cache | None). S==1 + cache = decode."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    L = min(cfg.ssm_chunk, S)
    while S % L:  # largest divisor of S not exceeding the chunk size
        L -= 1

    zxbcdt = dense_apply(p["in_proj"], x)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    a = -jnp.exp(p["A_log"])                                      # [H]

    xBC = jnp.concatenate([xs, Bc, Cc], axis=-1)
    new_cache = None
    if cache is not None and S == 1:
        # decode: conv over rolling window, then one recurrent state step
        W = cfg.conv1d_width
        window = jnp.concatenate([cache.conv, xBC], axis=1)       # [B, W, C]
        conv = jax.nn.silu(jnp.sum(window * p["conv_w"], axis=1,
                                   keepdims=True) + p["conv_b"])
        xs_c, B_c, C_c = jnp.split(conv, [d_in, d_in + N], axis=-1)
        xh = xs_c.reshape(B, 1, H, P)[:, 0]                        # [B,H,P]
        dA = jnp.exp(dt[:, 0] * a)                                 # [B,H]
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         B_c[:, 0].astype(jnp.float32),
                         xh.astype(jnp.float32))
        state = cache.state * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state, C_c[:, 0].astype(jnp.float32))
        y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B, 1, d_in)
        new_cache = SSMCache(window[:, 1:], state)
    else:
        conv = _causal_conv(xBC, p["conv_w"], p["conv_b"])
        xs_c, B_c, C_c = jnp.split(conv, [d_in, d_in + N], axis=-1)
        nc = S // L
        # chunk-major xs for a scan over chunks: only ONE chunk's quadratic
        # [B, H, L, L] decay matrix is ever live (the all-chunks form
        # materializes B*S*H*L fp32 — 100s of GiB at train_4k scale).
        # Keep scanned xs in bf16; fp32 casts happen inside the chunk body.
        xh = xs_c.reshape(B, nc, L, H, P)
        Bb = B_c.reshape(B, nc, L, N)
        Cb = C_c.reshape(B, nc, L, N)
        dtb = dt.reshape(B, nc, L, H)
        dA = dtb * a                                               # [B,nc,L,H]

        init = (cache.state if cache is not None
                else jnp.zeros((B, H, P, N), jnp.float32))

        def chunk_step(h, inp):
            xh_c, Bb_c, Cb_c, dt_c, dA_c = inp                    # [B,L,...]
            xh_c = xh_c.astype(jnp.float32)
            Bb_c = Bb_c.astype(jnp.float32)
            Cb_c = Cb_c.astype(jnp.float32)
            # 1. intra-chunk (quadratic in L)
            Lmat = jnp.exp(_segsum(dA_c.transpose(0, 2, 1)))      # [B,H,L,L]
            scores = jnp.einsum("bln,bmn->blm", Cb_c, Bb_c)       # [B,L,L]
            y = jnp.einsum("bhlm,blm,bmh,bmhp->blhp",
                           Lmat, scores, dt_c, xh_c)
            # 2. contribution of the incoming state
            decay_out = jnp.exp(jnp.cumsum(dA_c, axis=1))         # [B,L,H]
            y = y + jnp.einsum("bln,bhpn,blh->blhp", Cb_c, h, decay_out)
            # 3. state update
            decay_states = jnp.exp(
                jnp.cumsum(dA_c[:, ::-1], axis=1)[:, ::-1] - dA_c)
            states = jnp.einsum("blh,blh,bln,blhp->bhpn",
                                decay_states, dt_c, Bb_c, xh_c)
            chunk_decay = jnp.exp(jnp.sum(dA_c, axis=1))          # [B,H]
            h_new = h * chunk_decay[..., None, None] + states
            return h_new, y.astype(jnp.bfloat16)

        # remat the chunk body: the backward pass otherwise saves every
        # chunk's [B, H, L, L] decay matrix (terabytes at train_4k scale).
        final_state, Y = jax.lax.scan(
            jax.checkpoint(chunk_step), init,
            (xh.transpose(1, 0, 2, 3, 4), Bb.transpose(1, 0, 2, 3),
             Cb.transpose(1, 0, 2, 3), dtb.transpose(1, 0, 2, 3),
             dA.transpose(1, 0, 2, 3)))
        Y = Y.transpose(1, 0, 2, 3, 4)                             # [B,nc,L,H,P]
        y = (Y + (p["D"][None, None, None, :, None]
                  * xh.astype(jnp.float32)).astype(jnp.bfloat16)
             ).reshape(B, S, d_in)
        if update_cache:
            W = cfg.conv1d_width
            new_cache = SSMCache(xBC[:, -(W - 1):].astype(jnp.bfloat16)
                                 if S >= W - 1 else
                                 jnp.pad(xBC, ((0, 0), (W - 1 - S, 0), (0, 0))),
                                 final_state)

    # gated RMSNorm + output projection (Mamba-2 block epilogue)
    y = rmsnorm_apply(p["norm"], y.astype(x.dtype) * jax.nn.silu(z))
    return dense_apply(p["out_proj"], y), new_cache
