"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          # input gate
    a_t = a ** (c * r_t),  a = sigmoid(Lambda)  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` over time (the linear
recurrence h_t = a_t h_{t-1} + b_t is associative); decode is a single step.
The full recurrent block is Griffin's: parallel (gelu gate) x (conv1d ->
RG-LRU) branches merged by an output projection.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_apply, dense_init

_C = 8.0


class RGLRUCache(NamedTuple):
    h: jnp.ndarray      # [B, W] recurrent state
    conv: jnp.ndarray   # [B, conv_w - 1, W] conv window


def rglru_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    W = cfg.rglru_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so that a in (0.9, 0.999) (Griffin appendix)
    lam = jax.random.uniform(ks[0], (W,), jnp.float32, 2.2, 6.9)
    return {
        "gate_proj": dense_init(ks[1], d, W, dtype=dtype),
        "x_proj": dense_init(ks[2], d, W, dtype=dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv1d_width, W),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "wa": dense_init(ks[4], W, W, dtype=dtype, bias=True),
        "wx": dense_init(ks[5], W, W, dtype=dtype, bias=True),
        "lambda": lam,
        "out_proj": dense_init(jax.random.fold_in(key, 7), W, d, dtype=dtype),
    }


def _rglru_scan(x, a_gate, i_gate, lam, h0):
    """x, gates: [B, S, W] fp32. h0: [B, W]. Returns (y [B,S,W], h_last)."""
    log_a_max = jnp.log(jax.nn.sigmoid(lam))            # [W], < 0
    log_a = _C * a_gate * log_a_max                     # [B,S,W]
    a = jnp.exp(log_a)
    gated_x = i_gate * x
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * gated_x

    # fold h0 into the first step: h_1 = a_1 h_0 + b_1
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh, hh[:, -1]


def rglru_apply(p, cfg, x, *, cache: RGLRUCache | None = None,
                update_cache: bool = False):
    """x: [B, S, d] -> (y, cache'). S==1 + cache = decode."""
    B, S, d = x.shape
    W = cfg.rglru_width or d
    Wc = cfg.conv1d_width

    gate = jax.nn.gelu(dense_apply(p["gate_proj"], x))          # branch 1
    xb = dense_apply(p["x_proj"], x)                            # branch 2

    new_cache = None
    if cache is not None and S == 1:
        window = jnp.concatenate([cache.conv, xb], axis=1)      # [B, Wc, W]
        conv = jnp.sum(window * p["conv_w"], axis=1, keepdims=True) \
            + p["conv_b"]
        cf = conv.astype(jnp.float32)
        r = jax.nn.sigmoid(dense_apply(p["wa"], conv).astype(jnp.float32))
        i = jax.nn.sigmoid(dense_apply(p["wx"], conv).astype(jnp.float32))
        log_a = _C * r * jnp.log(jax.nn.sigmoid(p["lambda"]))
        a = jnp.exp(log_a)
        h = a[:, 0] * cache.h + (jnp.sqrt(jnp.maximum(1 - jnp.square(a[:, 0]),
                                                      1e-12))
                                 * (i[:, 0] * cf[:, 0]))
        y = h[:, None]
        new_cache = RGLRUCache(h, window[:, 1:])
    else:
        pads = jnp.pad(xb, ((0, 0), (Wc - 1, 0), (0, 0)))
        conv = sum(pads[:, j:j + S] * p["conv_w"][j] for j in range(Wc)) \
            + p["conv_b"]
        r = jax.nn.sigmoid(dense_apply(p["wa"], conv).astype(jnp.float32))
        i = jax.nn.sigmoid(dense_apply(p["wx"], conv).astype(jnp.float32))
        h0 = (cache.h if cache is not None
              else jnp.zeros((B, W), jnp.float32))
        y, h_last = _rglru_scan(conv.astype(jnp.float32), r, i,
                                p["lambda"], h0)
        if update_cache:
            keep = xb[:, -(Wc - 1):] if S >= Wc - 1 else \
                jnp.pad(xb, ((0, 0), (Wc - 1 - S, 0), (0, 0)))
            new_cache = RGLRUCache(h_last, keep.astype(jnp.bfloat16))

    out = (y.astype(x.dtype) * gate)
    return dense_apply(p["out_proj"], out), new_cache
