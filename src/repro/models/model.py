"""Model facade: init / loss / prefill / decode for every assigned arch."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import transformer as T
from .layers import (
    cross_entropy_loss,
    dense_apply,
    dense_init,
    embed_apply,
    embed_init,
    embed_logits,
    rmsnorm_apply,
    rmsnorm_init,
    softcap,
)


class Model:
    """Functional model: all methods are pure and jit/pjit-compatible."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters -----------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p: Dict[str, Any] = {}
        if cfg.frontend != "frames":
            p["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model)
        p["stack"] = T.stack_init(ks[1], cfg)
        p["final_norm"] = rmsnorm_init(cfg.d_model)
        if not cfg.tie_embeddings or cfg.frontend == "frames":
            p["head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size,
                                   dtype=jnp.bfloat16)
        if cfg.mtp_heads:
            kinds = cfg.layer_kinds()
            p["mtp"] = {
                "norm": rmsnorm_init(cfg.d_model),
                "block": T.block_init(ks[3], cfg, kinds[-1]),
            }
        return p

    # -- shared pieces ----------------------------------------------------
    def _embed(self, p, batch):
        cfg = self.cfg
        if cfg.frontend == "frames":
            x = batch["frames"].astype(jnp.bfloat16)
        else:
            x = embed_apply(p["embed"], batch["tokens"])
            if cfg.embed_scale:
                x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        return x

    def _logits(self, p, x):
        cfg = self.cfg
        x = rmsnorm_apply(p["final_norm"], x, cfg.norm_eps)
        if "head" in p:
            logits = dense_apply(p["head"], x).astype(jnp.float32)
        else:
            logits = embed_logits(p["embed"], x)
        return softcap(logits, cfg.final_softcap)

    # -- training forward + loss -------------------------------------------
    def forward(self, p, batch, *, remat: bool = True) -> jnp.ndarray:
        cfg = self.cfg
        x = self._embed(p, batch)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = T.stack_apply(p["stack"], cfg, x, positions=positions,
                          remat=remat)
        return x

    def _chunked_ce(self, p, x, labels, mask=None) -> jnp.ndarray:
        """Seq-chunked CE: never materializes [B, S, V] fp32 logits.

        The readout chunk is rematted, so backward recomputes each chunk's
        logits from (x_chunk, embed) — residency is one [B, c, V] slab.
        """
        cfg = self.cfg
        B, S = labels.shape
        c = min(cfg.loss_chunk, S)
        while S % c:
            c -= 1
        nc = S // c

        def chunk(xc, yc, mc):
            logits = self._logits(p, xc)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
            nll = (logz - gold) * mc
            return jnp.sum(nll), jnp.sum(mc)

        chunk = jax.checkpoint(chunk)

        def body(carry, inp):
            tot, cnt = carry
            s, n = chunk(*inp)
            return (tot + s, cnt + n), None

        xs = (x.reshape(B, nc, c, -1).transpose(1, 0, 2, 3),
              labels.reshape(B, nc, c).transpose(1, 0, 2),
              (jnp.ones((B, S), jnp.float32) if mask is None
               else mask.astype(jnp.float32)).reshape(B, nc, c)
              .transpose(1, 0, 2))
        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), xs)
        return tot / jnp.maximum(cnt, 1.0)

    def loss(self, p, batch, *, remat: bool = True) -> jnp.ndarray:
        """batch: tokens [B, S+1] (causal LM) or frames+labels (encoder)."""
        cfg = self.cfg
        if cfg.frontend == "frames":
            x = self.forward(p, batch, remat=remat)
            return self._chunked_ce(p, x, batch["labels"], batch.get("mask"))
        tokens = batch["tokens"]
        inp = {"tokens": tokens[:, :-1]}
        labels = tokens[:, 1:]
        mask = batch.get("mask")
        if mask is not None and mask.ndim == 1:  # per-sequence dedup mask
            mask = jnp.broadcast_to(mask[:, None], labels.shape)
        x = self.forward(p, inp, remat=remat)
        total = self._chunked_ce(p, x, labels, mask)
        if cfg.mtp_heads and "mtp" in p:
            # Multi-token prediction (DeepSeek-V3 style, simplified): one
            # extra block on the trunk output predicts token t+2.
            B, S = labels.shape
            pos = jnp.broadcast_to(jnp.arange(S), (B, S))
            h, _ = T.block_apply(p["mtp"]["block"], cfg,
                                 cfg.layer_kinds()[-1],
                                 rmsnorm_apply(p["mtp"]["norm"], x),
                                 positions=pos)
            total = total + 0.1 * self._chunked_ce(
                p, h[:, :-1], labels[:, 1:])
        return total

    # -- serving ------------------------------------------------------------
    def prefill(self, p, batch) -> Tuple[jnp.ndarray, Any]:
        """Full-sequence forward building caches.

        Returns (next-token logits [B, V], caches).
        """
        cfg = self.cfg
        if cfg.frontend == "frames":
            raise ValueError("encoder-only arch has no autoregressive serve")
        x = self._embed(p, batch)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, caches = T.stack_prefill(p["stack"], cfg, x, positions=positions)
        logits = self._logits(p, x[:, -1:])[:, 0]
        return logits, caches

    def decode_step(self, p, token, caches, pos):
        """token: int32[B]; pos: int32[] absolute position of this token."""
        cfg = self.cfg
        x = embed_apply(p["embed"], token[:, None])
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        B = x.shape[0]
        positions = jnp.broadcast_to(pos[None], (B, 1)).astype(jnp.int32)
        x, caches = T.stack_decode(p["stack"], cfg, x, caches,
                                   positions=positions, cache_pos=pos)
        logits = self._logits(p, x)[:, 0]
        return logits, caches

    def init_caches(self, batch: int, max_len: int):
        return T.init_caches(self.cfg, batch, max_len)

    # -- encoder-only forward (hubert) ------------------------------------
    def encode(self, p, frames) -> jnp.ndarray:
        x = self.forward(p, {"frames": frames}, remat=False)
        return self._logits(p, x)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
