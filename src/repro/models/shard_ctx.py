"""Sharding-hint context for model internals.

The SPMD partitioner occasionally replicates large intermediates when no
mesh axis divides a tensor dim (e.g. qwen's 20 KV heads on a 16x16 mesh
replicated the attention scores across all devices — §Perf qwen iteration).
Model code calls :func:`hint` at such points; the launcher installs the
mesh's data-parallel axis names via :func:`set_dp_axes` (a no-op context by
default, so library users are unaffected).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_DP_AXES: Optional[Tuple[str, ...]] = None


def set_dp_axes(axes: Optional[Tuple[str, ...]]):
    global _DP_AXES
    _DP_AXES = tuple(axes) if axes else None


def dp_axes() -> Optional[Tuple[str, ...]]:
    return _DP_AXES


def hint_batch_leading(x):
    """Constrain dim 0 to the data-parallel axes (rest unconstrained)."""
    if _DP_AXES is None:
        return x
    try:
        spec = P(_DP_AXES, *(None,) * (x.ndim - 1))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # no mesh context (plain jit on local devices)
        return x
