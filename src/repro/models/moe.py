"""Mixture-of-Experts: top-k routing with capacity-bounded sort dispatch.

Covers both assigned MoE archs:
* Mixtral-8x22B — 8 experts, top-2, softmax routing over selected experts.
* DeepSeek-V3   — 256 routed experts top-8 (sigmoid scores, normalized over
  the selected set, aux-loss-free style) + 1 shared expert.

Dispatch is the TPU-standard sort-based grouped-GEMM pattern: flatten the
(token, choice) assignments, argsort by expert, pack into a capacity-bounded
``[E, C, d]`` buffer (overflow dropped — tracked as a metric), run the expert
GLU as grouped einsums (expert dim shards over the ``model``/EP axis under
pjit), and combine with routing weights on the way back. Shapes are static —
no data-dependent shapes anywhere (straggler discipline, DESIGN.md §5).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _ACTS, dense_init


def moe_init(key, cfg, dtype=jnp.bfloat16):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale),
        "wgate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
                  * scale).astype(dtype),
        "wup": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
                * scale).astype(dtype),
        "wdown": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
                  / np.sqrt(ff)).astype(dtype),
    }
    if cfg.num_shared_experts:
        sff = cfg.moe_d_ff * cfg.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": dense_init(kk[0], d, sff, dtype=dtype),
            "up": dense_init(kk[1], d, sff, dtype=dtype),
            "down": dense_init(kk[2], sff, d, dtype=dtype),
        }
    return p


def moe_apply(p, cfg, x, *, capacity_factor: float = 1.25):
    """x: [B, S, d] -> [B, S, d]. Static-shape top-k dispatch."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    tokens = x.reshape(T, d)

    logits = tokens.astype(jnp.float32) @ p["router"]          # [T, E]
    if cfg.router_fn == "sigmoid":                              # deepseek
        scores = jax.nn.sigmoid(logits)
        vals, idx = jax.lax.top_k(scores, k)
        weights = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    else:                                                       # mixtral
        vals, idx = jax.lax.top_k(logits, k)
        weights = jax.nn.softmax(vals, axis=-1)

    # --- sort-based dispatch ------------------------------------------------
    # Floor keeps tiny (decode-sized) batches dropless so decode agrees with
    # the full forward; large batches are governed by capacity_factor.
    cap = max(int(np.ceil(T * k / E * capacity_factor)), min(T, 8))
    e_flat = idx.reshape(-1)                                    # [T*k]
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w_flat = weights.reshape(-1)

    order = jnp.argsort(e_flat, stable=True)
    e_s = e_flat[order]
    t_s = t_flat[order]
    first = jnp.searchsorted(e_s, e_s, side="left")
    rank = jnp.arange(T * k, dtype=jnp.int32) - first
    kept = rank < cap
    slot = jnp.where(kept, e_s * cap + rank, E * cap)

    buf = jnp.zeros((E * cap, d), x.dtype).at[slot].set(
        tokens[t_s], mode="drop").reshape(E, cap, d)

    # --- grouped expert GLU (E shards over the EP axis under pjit) ----------
    act = _ACTS[cfg.act]
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["wgate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wup"])
    y = jnp.einsum("ecf,efd->ecd", h, p["wdown"]).reshape(E * cap, d)

    # --- combine -------------------------------------------------------------
    safe_slot = jnp.minimum(slot, E * cap - 1)
    per_assign = jnp.where(kept[:, None], y[safe_slot], 0)      # sorted order
    w_s = w_flat[order]
    contrib = per_assign * w_s[:, None].astype(per_assign.dtype)
    out = jnp.zeros((T, d), x.dtype).at[t_s].add(contrib)

    if "shared" in p:
        sp = p["shared"]
        hs = act(tokens @ sp["gate"]["w"]) * (tokens @ sp["up"]["w"])
        out = out + hs @ sp["down"]["w"]

    return out.reshape(B, S, d)
