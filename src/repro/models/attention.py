"""Attention: GQA/MQA with RoPE, sliding windows, softcap, QK-norm, and MLA.

Training/prefill use a double-chunked online-softmax attention (flash-style
``lax.scan`` over query and KV chunks) so activation memory is bounded by
``chunk_q x chunk_k`` regardless of sequence length — required for the 32k
prefill shapes. Decode (q_len == 1) is a single masked einsum over the cache.

Sliding-window layers pass ``window``; bidirectional encoders (HuBERT) pass
``causal=False``. Gemma-2 style attention-logit softcapping and Chameleon
QK-norm are supported inline.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope, dense_apply, dense_init, rmsnorm_apply, rmsnorm_init, softcap

NEG_INF = -1e30


def _mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """bool[..., Q, K] allowed-attention mask from absolute positions."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        m &= k <= q
    if window is not None:
        m &= (q - k) < window
    return m


def flash_attention(q, k, v, *, causal=True, window=None, attn_softcap=None,
                    q_offset=0, chunk_q=512, chunk_k=1024, scale=None):
    """Online-softmax attention.

    q: [B, Sq, H, D]; k, v: [B, Sk, KVH, D] with H % KVH == 0.
    Returns [B, Sq, H, D]. Memory: O(chunk_q * chunk_k) scores per step.
    """
    B, Sq0, H, D = q.shape
    Sk0, KVH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]            # may differ from D (MLA: qk 192, v 128)
    g = H // KVH
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    chunk_q = min(chunk_q, Sq0)
    chunk_k = min(chunk_k, Sk0)
    # pad to chunk multiples; padded keys are masked out, padded q rows are
    # sliced off at the end.
    pq = (-Sq0) % chunk_q
    pk = (-Sk0) % chunk_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sq, Sk = Sq0 + pq, Sk0 + pk
    nq = Sq // chunk_q
    nk = Sk // chunk_k

    # [B, KVH, g, nq, Cq, D] queries; [B, KVH, nk, Ck, D] keys/values.
    from . import shard_ctx

    qr = q.reshape(B, nq, chunk_q, KVH, g, D).transpose(0, 3, 4, 1, 2, 5)
    kr = k.reshape(B, nk, chunk_k, KVH, D).transpose(0, 3, 1, 2, 4)
    vr = v.reshape(B, nk, chunk_k, KVH, Dv).transpose(0, 3, 1, 2, 4)
    # keep batch sharded over dp: without the hint the partitioner
    # replicates score chunks when KVH doesn't divide a mesh axis
    qr = shard_ctx.hint_batch_leading(qr)
    kr = shard_ctx.hint_batch_leading(kr)
    vr = shard_ctx.hint_batch_leading(vr)

    def q_step(_, qi):
        qc, qpos = qi     # [B, KVH, g, Cq, D], [Cq]

        def kv_step(carry, ki):
            acc, m, l = carry
            kc, vc, kpos = ki
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            s = softcap(s, attn_softcap) if attn_softcap else s
            allowed = _mask(qpos, kpos, causal=causal, window=window)
            allowed &= (kpos < Sk0)[..., None, :]   # padded keys
            s = jnp.where(allowed, s, NEG_INF)
            # clamp the running max so fully-masked lanes give
            # exp(NEG_INF - clamp) == 0 — avoids materializing an extra
            # score-sized bool mask + multiply per step (§Perf qwen iter 2)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.maximum(m_new, NEG_INF * 1e-10)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.minimum(m - m_new, 0.0))
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, vc.astype(jnp.float32))
            return (acc, m_new, l), None

        kpos_all = jnp.arange(Sk).reshape(nk, chunk_k)
        acc0 = jnp.zeros((B, KVH, g, chunk_q, Dv), jnp.float32)
        m0 = jnp.full((B, KVH, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, g, chunk_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kr.transpose(2, 0, 1, 3, 4), vr.transpose(2, 0, 1, 3, 4),
             kpos_all))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, (out, qpos)

    qpos_all = q_offset + jnp.arange(Sq).reshape(nq, chunk_q)
    _, (out, _) = jax.lax.scan(
        q_step, None, (qr.transpose(3, 0, 1, 2, 4, 5), qpos_all))
    # out: [nq, B, KVH, g, Cq, Dv] -> [B, nq, Cq, KVH, g, Dv] -> [B, Sq, H, Dv]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, Dv)
    return out[:, :Sq0]


def decode_attention(q, k_cache, v_cache, cur_len, *, window=None,
                     attn_softcap=None, scale=None):
    """Single-token attention over a (padded) cache.

    q: [B, 1, H, D]; k_cache/v_cache: [B, S, KVH, D]; cur_len: int32[] —
    number of valid cache positions *including* the new token.
    """
    B, _, H, D = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    g = H // KVH
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qr = q.reshape(B, KVH, g, D)
    # bf16 operands + f32 accumulation: avoids materializing f32 copies of
    # the cache (§Perf recurrentgemma iter 3 / MLA iter 2)
    s = jnp.einsum("bkgd,bskd->bkgs", qr.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, attn_softcap) if attn_softcap else s
    pos = jnp.arange(S)
    valid = pos < cur_len
    if window is not None:
        valid &= pos >= (cur_len - window)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray       # [B, S, KVH, D] (S = window for SWA ring buffers)
    v: jnp.ndarray


def gqa_init(key, cfg, layer_cfg, dtype=jnp.bfloat16):
    """cfg: ModelConfig; layer_cfg: dict(window=..., softcap=...)."""
    d, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_()
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype=dtype, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, KVH * hd, dtype=dtype, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, KVH * hd, dtype=dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], H * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = rmsnorm_init(hd)
        p["knorm"] = rmsnorm_init(hd)
    return p


def gqa_apply(p, cfg, x, *, positions, window=None, cache: Optional[KVCache] = None,
              cache_pos=None, causal=True, attn_softcap=None,
              update_cache=False):
    """Returns (out, new_cache | None).

    Train/prefill: cache is None (or update_cache=True to build one).
    Decode: x is [B, 1, d]; cache holds past KV; cache_pos = write index.
    """
    B, S, _ = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_()
    q = dense_apply(p["wq"], x).reshape(B, S, H, hd)
    k = dense_apply(p["wk"], x).reshape(B, S, KVH, hd)
    v = dense_apply(p["wv"], x).reshape(B, S, KVH, hd)
    if "qnorm" in p:
        q = rmsnorm_apply(p["qnorm"], q)
        k = rmsnorm_apply(p["knorm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and S == 1:
        # decode: append to cache (ring-buffer write for SWA layers)
        Sc = cache.k.shape[1]
        write = cache_pos % Sc if window is not None else cache_pos
        kc = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, write, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, write, 0, 0))
        new_cache = KVCache(kc, vc)
        if window is not None:
            # ring buffer: all Sc slots valid once cache_pos >= Sc; masking by
            # recency is positional — use cur_len=min(pos+1, Sc), window=None
            cur = jnp.minimum(cache_pos + 1, Sc)
            out = decode_attention(q, kc, vc, cur, window=None,
                                   attn_softcap=attn_softcap)
        else:
            out = decode_attention(q, kc, vc, cache_pos + 1, window=None,
                                   attn_softcap=attn_softcap)
    else:
        out = flash_attention(q, k, v, causal=causal, window=window,
                              attn_softcap=attn_softcap,
                              chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k)
        if update_cache:
            if window is not None and k.shape[1] >= window:
                # SWA ring buffer: token t lives at slot t % window, so roll
                # the kept tail to align the decode-time write phase.
                shift = k.shape[1] % window
                new_cache = KVCache(
                    jnp.roll(k[:, -window:], shift, axis=1).astype(jnp.bfloat16),
                    jnp.roll(v[:, -window:], shift, axis=1).astype(jnp.bfloat16))
            else:
                new_cache = KVCache(k.astype(jnp.bfloat16),
                                    v.astype(jnp.bfloat16))
    out = out.reshape(B, S, H * hd).astype(x.dtype)
    return dense_apply(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 Multi-head Latent Attention)
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jnp.ndarray     # [B, S, kv_lora_rank]   compressed latent
    k_rope: jnp.ndarray   # [B, S, qk_rope_dim]    shared rope key


def mla_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    H = cfg.num_heads
    rq, rkv = cfg.mla_q_lora_rank, cfg.mla_kv_lora_rank
    dn, dr, dv = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_dim
    ks = jax.random.split(key, 8)
    return {
        "wdq": dense_init(ks[0], d, rq, dtype=dtype),
        "q_norm": rmsnorm_init(rq),
        "wuq": dense_init(ks[1], rq, H * (dn + dr), dtype=dtype),
        "wdkv": dense_init(ks[2], d, rkv, dtype=dtype),
        "kv_norm": rmsnorm_init(rkv),
        "wkr": dense_init(ks[3], d, dr, dtype=dtype),
        "wuk": dense_init(ks[4], rkv, H * dn, dtype=dtype),
        "wuv": dense_init(ks[5], rkv, H * dv, dtype=dtype),
        "wo": dense_init(ks[6], H * dv, d, dtype=dtype),
    }


def mla_apply(p, cfg, x, *, positions, cache: Optional[MLACache] = None,
              cache_pos=None, update_cache=False):
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_dim

    q = dense_apply(p["wuq"], rmsnorm_apply(p["q_norm"],
                                            dense_apply(p["wdq"], x)))
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rmsnorm_apply(p["kv_norm"], dense_apply(p["wdkv"], x))  # [B,S,rkv]
    k_rope = apply_rope(dense_apply(p["wkr"], x)[:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]        # [B,S,dr]

    new_cache = None
    if cache is not None and S == 1:
        c_kv = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache_pos, 0))
        k_rope = jax.lax.dynamic_update_slice(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, cache_pos, 0))
        new_cache = MLACache(c_kv, k_rope)
    elif update_cache:
        new_cache = MLACache(c_kv.astype(jnp.bfloat16),
                             k_rope.astype(jnp.bfloat16))

    scale = 1.0 / np.sqrt(dn + dr)
    if cache is not None and S == 1:
        if cfg.mla_absorb:
            # Absorbed decode (§Perf iteration, DeepSeek-V2's own serving
            # form): fold W_uk into q and W_uv into the output projection so
            # attention runs in the rank-rkv latent space. The naive path
            # re-expands the ENTIRE cached latent to per-head K/V every
            # step: 2*2*S*rkv*(H*dn) flops/layer vs 4*H*S*rkv absorbed —
            # ~dn x fewer (128x here).
            rkv = cfg.mla_kv_lora_rank
            f32 = jnp.float32
            wuk = p["wuk"]["w"].reshape(rkv, H, dn)
            # bf16 operands + f32 accumulation: materializing f32 copies of
            # the [B, S, rkv] cache was 75% of this cell's HBM traffic
            # (§Perf iteration 2).
            q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wuk,
                               preferred_element_type=f32)       # [B,H,rkv]
            s = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(c_kv.dtype), c_kv,
                            preferred_element_type=f32)
                 + jnp.einsum("bhp,bsp->bhs", q_rope[:, 0], k_rope,
                              preferred_element_type=f32)) * scale
            Sk = c_kv.shape[1]
            valid = jnp.arange(Sk) < (cache_pos + 1)
            s = jnp.where(valid[None, None, :], s, NEG_INF)
            pr = jax.nn.softmax(s, axis=-1).astype(c_kv.dtype)
            ctx = jnp.einsum("bhs,bsr->bhr", pr, c_kv,
                             preferred_element_type=f32)         # [B,H,rkv]
            wuv = p["wuv"]["w"].reshape(rkv, H, dv)
            out = jnp.einsum("bhr,rhv->bhv", ctx.astype(wuv.dtype), wuv,
                             preferred_element_type=f32)[:, None]
        else:
            # naive decode: expand cached latents to per-head K/V (baseline)
            Sk = c_kv.shape[1]
            k_nope = dense_apply(p["wuk"], c_kv).reshape(B, Sk, H, dn)
            vfull = dense_apply(p["wuv"], c_kv).reshape(B, Sk, H, dv)
            k = jnp.concatenate(
                [k_nope,
                 jnp.broadcast_to(k_rope[:, :, None, :], (B, Sk, H, dr))],
                axis=-1)
            qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
            out = decode_attention(qfull, k, vfull, cache_pos + 1,
                                   scale=scale)
    else:
        # train/prefill: expanded form (the einsum order is compute-optimal
        # when every position is a query)
        Sk = c_kv.shape[1]
        k_nope = dense_apply(p["wuk"], c_kv).reshape(B, Sk, H, dn)
        vfull = dense_apply(p["wuv"], c_kv).reshape(B, Sk, H, dv)
        k = jnp.concatenate(
            [k_nope,
             jnp.broadcast_to(k_rope[:, :, None, :], (B, Sk, H, dr))],
            axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(qfull, k, vfull, causal=True, scale=scale,
                              chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k)
    out = out.reshape(B, S, H * dv).astype(x.dtype)
    return dense_apply(p["wo"], out), new_cache
