"""Model zoo: composable blocks covering all 10 assigned architectures."""

from .model import Model, build_model  # noqa: F401
