"""Shared model layers: norms, GLU MLPs, embeddings, RoPE, softcap.

Plain functional modules: ``<layer>_init(key, ...) -> params`` and
``<layer>_apply(params, x, ...)``. Params are nested dicts of arrays;
weights default to bf16 with fp32 norm scales (production mixed precision).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _dense_init(key, in_dim, out_dim, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def dense_init(key, in_dim, out_dim, *, dtype=jnp.bfloat16, bias=False,
               scale=None):
    p = {"w": _dense_init(key, in_dim, out_dim, dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(dim):
    return {"scale": jnp.zeros((dim,), jnp.float32)}  # (1 + scale) * x


def rmsnorm_apply(p, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(dt)


def softcap(x, cap: Optional[float]):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def mlp_init(key, d_model, d_ff, *, act="silu", gated=True, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
         "down": dense_init(ks[1], d_ff, d_model, dtype=dtype)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(p, x, *, act="silu"):
    up = dense_apply(p["up"], x)
    if "gate" in p:
        up = _ACTS[act](dense_apply(p["gate"], x)) * up
    else:
        up = _ACTS[act](up)
    return dense_apply(p["down"], up)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d_model, dtype=jnp.bfloat16):
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * 1.0).astype(dtype)}


def embed_apply(p, tokens):
    return p["table"][tokens]


def embed_logits(p, x, *, scale=None):
    """Tied-readout logits (fp32 accumulate)."""
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                        p["table"].astype(jnp.float32))
    if scale is not None:
        logits = logits * scale
    return logits


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))                  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs     # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                           # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None):
    """Mean next-token CE; logits fp32 [..., V], labels int32 [...]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
