"""H2O-Danube3-4B [arXiv:2401.16818] — llama+mistral mix, SWA (per assignment)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o_danube3_4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10000.0,
    tie_embeddings=False,
))
