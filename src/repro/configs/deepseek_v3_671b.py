"""DeepSeek-V3 671B [arXiv:2412.19437; hf] — MLA + 1 shared/256 routed top-8 MoE + MTP."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek_v3_671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,           # MLA: per-head K/V expanded from the latent
    d_ff=2048,                  # per assignment (expert width; first 3 dense)
    vocab_size=129280,
    attention="mla",
    mla_q_lora_rank=1536,
    mla_kv_lora_rank=512,
    mla_qk_rope_dim=64,
    mla_qk_nope_dim=128,
    mla_v_dim=128,
    head_dim=192,               # qk_nope + qk_rope
    moe=True,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    router_fn="sigmoid",        # aux-loss-free sigmoid routing
    mtp_heads=1,                # multi-token prediction module
    rope_theta=10000.0,
    tie_embeddings=False,
))
