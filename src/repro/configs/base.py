"""Model configuration schema + architecture registry.

One ``<arch>.py`` per assigned architecture registers a full-size
:class:`ModelConfig` (exact public-literature dimensions) and each config can
produce a ``reduced()`` variant for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # --- attention variants -------------------------------------------------
    attention: str = "gqa"      # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None      # SWA width (all local layers)
    # pattern of ("local"|"global") repeated over layers, e.g. gemma3 5:1
    local_global_pattern: Optional[Tuple[str, ...]] = None
    attn_softcap: Optional[float] = None      # gemma2: 50.0
    final_softcap: Optional[float] = None     # gemma2: 30.0
    qk_norm: bool = False
    causal: bool = True                       # False = encoder (hubert)

    # --- MLA (deepseek) ------------------------------------------------------
    mla_q_lora_rank: int = 0
    mla_kv_lora_rank: int = 0
    mla_qk_rope_dim: int = 0
    mla_qk_nope_dim: int = 0
    mla_v_dim: int = 0
    mla_absorb: bool = True      # absorbed decode (§Perf); False = naive

    # --- MoE -----------------------------------------------------------------
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0               # deepseek: 3 dense layers
    router_fn: str = "softmax"                # softmax | sigmoid
    capacity_factor: float = 1.25

    # --- SSM (mamba2) ----------------------------------------------------
    ssm: bool = False
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv1d_width: int = 4

    # --- hybrid (recurrentgemma) ----------------------------------------
    # block pattern tuple of "rglru"|"attn" repeated across layers
    block_pattern: Optional[Tuple[str, ...]] = None
    rglru_width: int = 0

    # --- heads / embedding -----------------------------------------------
    mtp_heads: int = 0                        # deepseek MTP modules
    tie_embeddings: bool = True
    embed_scale: bool = False                 # gemma: scale embeds by sqrt(d)
    act: str = "silu"
    norm_eps: float = 1e-6

    # --- modality frontend stub -------------------------------------------
    frontend: str = "none"                    # none | frames (audio stub)

    # --- compute tiling -----------------------------------------------------
    chunk_q: int = 512
    chunk_k: int = 1024
    loss_chunk: int = 512        # seq-chunked CE (never materialize full
    #                              fp32 logits — see model.Model.loss)
    remat_policy: str = "full"   # full | dots | none

    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    # --- layer-kind derivation ------------------------------------------
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind: attn / attn_local / mla / ssm / rglru (+_moe)."""
        kinds = []
        for i in range(self.num_layers):
            if self.ssm:
                kind = "ssm"
            elif self.block_pattern:
                kind = self.block_pattern[i % len(self.block_pattern)]
                if kind == "attn":
                    kind = "attn_local" if self.sliding_window else "attn"
            elif self.attention == "mla":
                kind = "mla"
            elif self.local_global_pattern:
                kind = ("attn_local"
                        if self.local_global_pattern[
                            i % len(self.local_global_pattern)] == "local"
                        else "attn")
            elif self.sliding_window:
                kind = "attn_local"
            else:
                kind = "attn"
            if self.moe and i >= self.first_dense_layers:
                kind += "+moe"
            elif self.d_ff > 0:
                kind += "+mlp"
            kinds.append(kind)
        return tuple(kinds)

    def segments(self) -> Tuple[Tuple[str, int], ...]:
        """Group consecutive identical layer-kind *periods* for lax.scan.

        Returns ((period_kinds..., repeat), ...) where each segment scans
        ``repeat`` times over a stacked period of len(period) layers.
        """
        kinds = self.layer_kinds()
        # find smallest period that tiles a maximal prefix run
        segs = []
        i = 0
        n = len(kinds)
        while i < n:
            best = (1, 1)  # (period_len, repeats)
            for plen in range(1, min(8, n - i) + 1):
                period = kinds[i:i + plen]
                reps = 1
                while (i + (reps + 1) * plen <= n
                       and kinds[i + reps * plen: i + (reps + 1) * plen]
                       == period):
                    reps += 1
                if plen * reps > best[0] * best[1]:
                    best = (plen, reps)
            plen, reps = best
            segs.append((kinds[i:i + plen], reps))
            i += plen * reps
        return tuple(segs)

    # --- parameter count (for roofline MODEL_FLOPS) ----------------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.head_dim_()
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for kind in self.layer_kinds():
            if kind.startswith("mla"):
                rq, rkv = self.mla_q_lora_rank, self.mla_kv_lora_rank
                dn, dr, dv = (self.mla_qk_nope_dim, self.mla_qk_rope_dim,
                              self.mla_v_dim)
                total += d * rq + rq * self.num_heads * (dn + dr)
                total += d * rkv + d * dr
                total += rkv * self.num_heads * (dn + dv)
                total += self.num_heads * dv * d
            elif kind.startswith("attn"):
                total += d * self.num_heads * hd * 2  # wq, wo
                total += d * self.num_kv_heads * hd * 2
            elif kind.startswith("ssm"):
                d_in = self.ssm_expand * d
                total += d * (2 * d_in + 2 * self.ssm_state + self.ssm_heads)
                total += d_in * d
            elif kind.startswith("rglru"):
                W = self.rglru_width or d
                total += 2 * d * W + 2 * W * W + W * d
            if kind.endswith("+moe"):
                e = self.num_experts if not active_only else \
                    self.experts_per_token
                total += 3 * (e + self.num_shared_experts) * d * self.moe_d_ff
                total += d * self.num_experts  # router
            elif kind.endswith("+mlp"):
                total += 3 * d * self.d_ff
        return total

    # --- smoke-test reduction ---------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        pattern_len = len(self.local_global_pattern or self.block_pattern
                          or (1,))
        layers = max(2, min(2 * pattern_len, 6))
        if self.first_dense_layers:
            layers = max(layers, self.first_dense_layers + 1)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=layers,
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=512,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else None,
            mla_q_lora_rank=min(self.mla_q_lora_rank, 64) or 0,
            mla_kv_lora_rank=min(self.mla_kv_lora_rank, 32) or 0,
            # qk dim (24) deliberately != v dim (32): catches qk/v head-dim
            # conflation bugs the full-size MLA config exposes
            mla_qk_rope_dim=8 if self.mla_qk_rope_dim else 0,
            mla_qk_nope_dim=16 if self.mla_qk_nope_dim else 0,
            mla_v_dim=32 if self.mla_v_dim else 0,
            num_experts=min(self.num_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=64 if self.moe else 0,
            capacity_factor=8.0 if self.moe else self.capacity_factor,
            ssm_state=min(self.ssm_state, 32) if self.ssm else 0,
            ssm_head_dim=16 if self.ssm else 64,
            ssm_chunk=32,
            rglru_width=64 if self.rglru_width else 0,
            chunk_q=32,
            chunk_k=64,
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCHS = (
    "deepseek_v3_671b",
    "mixtral_8x22b",
    "h2o_danube3_4b",
    "qwen1_5_4b",
    "gemma2_2b",
    "gemma3_4b",
    "hubert_xlarge",
    "chameleon_34b",
    "recurrentgemma_9b",
    "mamba2_130m",
)

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    """Look up an architecture config by registry name (hyphen or underscore)."""
    key = name.replace("-", "_")
    if key not in _REGISTRY:
        importlib.import_module(f"repro.configs.{key}")
    return _REGISTRY[key]


def all_configs() -> Dict[str, ModelConfig]:
    for a in ARCHS:
        get_config(a)
    return dict(_REGISTRY)
