"""Gemma-3 4B [hf:google/gemma-3-4b-pt] — 5:1 local:global, 128k-capable, QK-norm."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3_4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    local_global_pattern=("local",) * 5 + ("global",),
    sliding_window=1024,
    qk_norm=True,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
))
