"""Mixtral-8x22B [arXiv:2401.04088; hf] — 8 experts top-2, SWA (per assignment)."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral_8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,        # Mistral-lineage SWA
    moe=True,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=16384,
    router_fn="softmax",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
))
