"""Chameleon-34B [arXiv:2405.09818] — early-fusion token backbone, QK-norm.

VQ image tokenization is stubbed: inputs are already fused token ids over
the shared 65536 vocab (text + image codebook), per the assignment's
"backbone only" rule.
"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chameleon_34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    rope_theta=10000.0,
    tie_embeddings=False,
))
