"""RecurrentGemma-9B [arXiv:2402.19427] — Griffin: RG-LRU + local attn, 2:1."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma_9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,             # MQA in the attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"),
    sliding_window=2048,
    rglru_width=4096,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
))
