"""HuBERT X-Large [arXiv:2106.07447] — encoder-only; conv frontend stubbed.

The modality frontend (strided conv feature extractor) is a stub:
``input_specs()`` feeds precomputed frame embeddings [B, S, d_model];
vocab=504 is the masked-prediction codebook. No decode step exists
(encoder-only) — decode/long shapes are skipped per DESIGN.md §4.
"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert_xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,               # bidirectional encoder
    act="gelu",
    frontend="frames",
    tie_embeddings=False,
    rope_theta=10000.0,
))
