"""Architecture configs (one module per assigned arch) + registry."""

from .base import ARCHS, ModelConfig, all_configs, get_config, register  # noqa: F401
