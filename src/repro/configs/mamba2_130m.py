"""Mamba-2 130M [arXiv:2405.21060] — attention-free SSD."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2_130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=1,                # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                     # no separate MLP: in-proj expands 2x
    vocab_size=50280,
    attention="none",
    ssm=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,            # -> 24 SSD heads
    ssm_chunk=256,
    conv1d_width=4,
    tie_embeddings=True,
))
