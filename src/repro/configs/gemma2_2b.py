"""Gemma-2 2B [arXiv:2408.00118; hf] — alternating local/global attn, softcaps."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2_2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    local_global_pattern=("local", "global"),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
))
