"""Core library: the Cuckoo-GPU paper's contribution, adapted to TPU/JAX.

Public surface:

* :class:`CuckooConfig` / :class:`CuckooState` — static config + state pytree.
* :func:`insert` / :func:`query` / :func:`delete` — batch functional ops.
* :func:`insert_bulk` — bucket-sorted bulk-build insertion fast path.
* :class:`CuckooFilter` — convenience OO wrapper.
* ``sharded_filter`` — mesh-partitioned filter (PCF partitioning scheme).
* AMQ protocol types (``Capabilities``, ``InsertReport``, ``QueryResult``,
  ``DeleteReport``) re-exported from :mod:`repro.amq.protocol` — the unified
  contract every backend implements (``repro.amq.make`` is the front door).
"""

from ..amq.protocol import (  # noqa: F401
    AMQConfig,
    Capabilities,
    CascadeReport,
    DeleteReport,
    InsertReport,
    LevelStats,
    MixedReport,
    OpBatch,
    QueryResult,
)
from .cuckoo_filter import (  # noqa: F401
    CuckooConfig,
    CuckooFilter,
    CuckooState,
    InsertStats,
    apply_ops,
    delete,
    insert,
    insert_bulk,
    prepare_keys,
    query,
)
from .hashing import (  # noqa: F401
    hash_key,
    keys_from_numpy,
    keys_to_numpy,
    normalize_keys,
)
from .layout import BucketLayout  # noqa: F401
from .policies import OffsetPolicy, XorPolicy, make_policy  # noqa: F401
