"""Mesh-sharded Cuckoo filter — the distributed scale-out layer.

Partitioning scheme (DESIGN.md §5, refined in §10): the key space is hashed
into a *fixed* number of independent sub-filter **partitions** (default: one
per device), and each device along a mesh axis owns a contiguous block of
whole partitions. Both cuckoo candidate buckets of a key live in the same
partition, so eviction chains never cross devices — the PCF partitioning of
Schmidt et al. promoted to the accelerator mesh. Aggregate filter bandwidth
scales linearly with devices (the TPU analogue of the paper's "saturate
global memory bandwidth": here we saturate *n_devices x* HBM bandwidth).

Fixing the partition count (rather than hashing modulo the device count)
is what makes the filter's *lifecycle* operations exact (DESIGN.md §10):
key→partition never changes, so a K→K′ reshard or a migration to a new
mesh relocates whole partitions — every packed word moves verbatim and
membership answers are bit-for-bit preserved (:meth:`ShardedCuckooConfig.
resharded`, :meth:`ShardedCuckooFilter.resharded`). Create filters with
``partitions_per_shard > 1`` to leave resharding headroom.

Routing is a fixed-capacity all-to-all (no data-dependent shapes — a
straggler-mitigation requirement at scale, DESIGN.md §5): each device sorts
its local keys by destination shard into ``[num_shards, capacity]`` bins,
exchanges bins with one ``lax.all_to_all``, applies the local filter op with
a validity mask, and routes results back with the inverse exchange. Keys
beyond a bin's capacity are reported in the ``routed`` mask so callers can
retry them next step (they are never silently dropped).

All ops run inside ``shard_map`` over the chosen axis and are jit-compatible;
the sharded state is an ordinary pytree (stacked per-shard tables), so it
checkpoints/restores like model state.

The sharded filter also composes with the auto-expanding cascade
(``repro.amq.cascade``, DESIGN.md §8) as a *cascade of shards*: each
cascade level is an independently mesh-sharded filter, so aggregate
capacity grows geometrically while every level keeps the linear
n-devices-× bandwidth scaling above. :meth:`ShardedCuckooConfig.grown`
is the growth hook — it scales per-shard capacity while pinning the mesh
topology (shard count, axis, routing overprovision) so all levels of one
cascade exchange keys over the same all-to-all pattern.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat
from .cuckoo_filter import CuckooConfig, CuckooState
from .cuckoo_filter import apply_ops as _apply_ops
from .cuckoo_filter import delete as _delete
from .cuckoo_filter import insert as _insert
from .cuckoo_filter import insert_bulk as _insert_bulk
from .cuckoo_filter import query as _query
from .hashing import fmix32, normalize_keys

_U32 = np.uint32
_SHARD_SALT = _U32(0x51ED270C)


class ShardedCuckooState(NamedTuple):
    table: jnp.ndarray  # uint32[num_partitions, num_words] (sharded over axis)
    count: jnp.ndarray  # int32[num_partitions]


@dataclasses.dataclass(frozen=True)
class ShardedCuckooConfig:
    """Mesh-sharded filter config: fixed partitions mapped onto devices.

    The unit of distribution is the *partition* — an independent sub-filter
    (``shard`` is its per-partition :class:`CuckooConfig`) owned by exactly
    one device. ``num_partitions`` (default: ``num_shards``) is fixed at
    creation and is what the routing hash is taken modulo, so it is baked
    into the stored state; ``num_shards`` is merely how many devices the
    partitions are currently spread over (device d owns the contiguous
    partition range ``[d*P/K, (d+1)*P/K)``). Because key→partition never
    changes, a K→K′ reshard (or a move to a new mesh) relocates whole
    partitions — every packed word moves exactly, zero membership change
    (:meth:`resharded`). Create with ``partitions_per_shard > 1`` to leave
    resharding headroom (K′ must divide ``num_partitions``).
    """

    shard: CuckooConfig          # per-partition filter config
    num_shards: int
    axis_name: str = "data"
    capacity_factor: float = 2.0  # bin capacity overprovision vs n/partitions
    num_partitions: Optional[int] = None  # default: one per shard

    def __post_init__(self):
        p, k = self.partitions, self.num_shards
        if p % k:
            raise ValueError(
                f"num_partitions={p} must be divisible by "
                f"num_shards={k} (each device owns P/K whole partitions)")

    @property
    def partitions(self) -> int:
        return self.num_partitions or self.num_shards

    @property
    def partitions_per_shard(self) -> int:
        return self.partitions // self.num_shards

    def bin_capacity(self, local_batch: int) -> int:
        cap = int(np.ceil(
            local_batch / self.partitions * self.capacity_factor))
        return max(8, cap)

    def init(self) -> ShardedCuckooState:
        lay = self.shard.layout
        return ShardedCuckooState(
            jnp.zeros((self.partitions, lay.num_words), jnp.uint32),
            jnp.zeros((self.partitions,), jnp.int32))

    @property
    def total_slots(self) -> int:
        return self.partitions * self.shard.num_slots

    @property
    def batch_align(self) -> int:
        """Required batch-width divisor: ops split across ``num_shards``.

        Front-ends that choose dispatch shapes (the serving engine's shape
        ladder, DESIGN.md §11) read this to keep every padded batch legal
        for the per-device ``shard_map`` split.
        """
        return self.num_shards

    # -- AMQ protocol surface (repro.amq.protocol.AMQConfig) ----------------
    @property
    def num_slots(self) -> int:
        return self.total_slots

    @property
    def table_bytes(self) -> int:
        return self.partitions * self.shard.table_bytes

    def expected_fpr(self, load_factor: float) -> float:
        """Partitions are independent same-config filters: FPR is theirs."""
        return self.shard.expected_fpr(load_factor)

    @staticmethod
    def for_capacity(capacity: int, num_shards: int, load_factor: float = 0.95,
                     axis_name: str = "data", **kw) -> "ShardedCuckooConfig":
        cf = kw.pop("capacity_factor", 2.0)
        pps = kw.pop("partitions_per_shard", 1)
        partitions = num_shards * pps
        per_partition = int(np.ceil(capacity / partitions))
        return ShardedCuckooConfig(
            CuckooConfig.for_capacity(per_partition, load_factor, **kw),
            num_shards, axis_name, cf, partitions)

    def grown(self, factor: float, *, fp_bits: Optional[int] = None
              ) -> "ShardedCuckooConfig":
        """Next cascade level's config: ``factor``-times the capacity.

        Scales the per-partition filter while keeping the mesh topology
        (``num_shards``, ``num_partitions``, ``axis_name``,
        ``capacity_factor``) fixed, so all levels of a cascade share one
        all-to-all routing pattern. ``fp_bits`` optionally tightens the
        level's fingerprints to meet a smaller FPR share (DESIGN.md §8).

        Every per-partition field other than the sizing ones is carried
        over verbatim via ``dataclasses.replace`` — a grown level keeps the
        parent's eviction policy, insert-engine routing, frontier depth,
        etc. without this method having to enumerate (and silently drop)
        new ``CuckooConfig`` knobs.
        """
        sized = CuckooConfig.for_capacity(
            int(np.ceil(self.shard.num_slots * factor)),
            load_factor=1.0,  # num_slots is already post-load sizing
            fp_bits=self.shard.fp_bits if fp_bits is None else fp_bits,
            bucket_size=self.shard.bucket_size,
            policy=self.shard.policy)
        grown_shard = dataclasses.replace(
            self.shard, num_buckets=sized.num_buckets,
            fp_bits=sized.fp_bits)
        return ShardedCuckooConfig(
            grown_shard,
            self.num_shards, self.axis_name, self.capacity_factor,
            self.num_partitions)

    def resharded(self, num_shards: int, *,
                  axis_name: Optional[str] = None) -> "ShardedCuckooConfig":
        """The same filter spread over ``num_shards`` devices — exactly.

        Only the partition→device mapping changes; the partition count,
        per-partition filter, and therefore every stored word stay fixed,
        so a state restored under the resharded config answers every query
        identically (DESIGN.md §10). ``num_shards`` must divide
        ``num_partitions``.
        """
        p = self.partitions
        if p % num_shards:
            raise ValueError(
                f"cannot reshard {p} partitions onto {num_shards} shards: "
                "each device must own whole partitions (create the filter "
                "with partitions_per_shard > 1 for resharding headroom)")
        return ShardedCuckooConfig(
            self.shard, num_shards,
            self.axis_name if axis_name is None else axis_name,
            self.capacity_factor, p)


def partition_of(config: ShardedCuckooConfig,
                 keys: jnp.ndarray) -> jnp.ndarray:
    """Owner partition per key — a hash independent of in-partition hashes.

    Taken modulo the *fixed* partition count, never the device count, so
    key placement survives resharding.
    """
    mix = fmix32(keys[..., 0] ^ fmix32(keys[..., 1] ^ _SHARD_SALT))
    return (mix % _U32(config.partitions)).astype(jnp.int32)


def shard_of(config: ShardedCuckooConfig, keys: jnp.ndarray) -> jnp.ndarray:
    """Owner device per key: its partition's current home."""
    return partition_of(config, keys) // config.partitions_per_shard


def _route(config: ShardedCuckooConfig, keys: jnp.ndarray, cap: int,
           valid: Optional[jnp.ndarray] = None):
    """Local routing: sort keys into [num_partitions, cap] bins.

    ``valid`` masks caller-side padding keys: they are given the ``P``
    sentinel destination, sort past every real partition group, and never
    claim a bin slot (so they cannot crowd out live keys).

    Returns (bins uint32[P, cap, 2], bin_valid bool[P, cap],
             order, dest_sorted, idx_in_group, routed_sorted, slot).

    ``slot`` is the flat bin address per *sorted* key (``P*cap`` sentinel =
    unrouted); extra per-key channels (the mixed batch's op codes) are
    binned with the same scatter so they travel the identical all-to-all.
    Partitions are contiguous per device, so reshaping the leading ``P``
    axis to ``[num_shards, P/K * cap]`` is exactly the per-device exchange
    layout.
    """
    P = config.partitions
    n = keys.shape[0]
    dest = partition_of(config, keys)
    if valid is not None:
        dest = jnp.where(valid.astype(bool), dest, P)
    order = jnp.argsort(dest, stable=True)
    dest_s = dest[order]
    keys_s = keys[order]
    first_of_group = jnp.searchsorted(dest_s, dest_s, side="left")
    idx_in_group = jnp.arange(n, dtype=jnp.int32) - first_of_group
    routed = (idx_in_group < cap) & (dest_s < P)
    slot = jnp.where(routed, dest_s * cap + idx_in_group, P * cap)
    bins = jnp.zeros((P * cap, 2), jnp.uint32).at[slot].set(keys_s, mode="drop")
    bin_valid = jnp.zeros((P * cap,), bool).at[slot].set(routed, mode="drop")
    return (bins.reshape(P, cap, 2), bin_valid.reshape(P, cap),
            order, dest_s, idx_in_group, routed, slot)


def _unroute(order, dest_s, idx_in_group, routed, back, fill=False):
    """Inverse of _route for a per-key result channel ``back[S, cap]``."""
    n = order.shape[0]
    got = back[dest_s, jnp.minimum(idx_in_group, back.shape[1] - 1)]
    got = jnp.where(routed, got, fill)
    return jnp.zeros((n,), back.dtype).at[order].set(got)


def _make_sharded_op(config: ShardedCuckooConfig, op: str, local_batch: int,
                     dedup_within_batch: bool = False):
    """Build the per-device function for one op (runs under shard_map).

    Each device owns ``p_local = P/K`` whole partitions; the filter op is
    vmapped over them. Keys are binned per destination *partition*, the
    ``P``-row bin stack reshaped to ``[K, p_local*cap]`` is exchanged with
    one all-to-all (partitions are contiguous per device), and each
    receiver regroups its ``K`` incoming blocks into per-partition key
    streams.

    ``dedup_within_batch`` is globally correct because duplicates of a key
    hash to the same owner partition: per-partition first-occurrence dedup
    IS whole-batch dedup.

    ``op == "apply_ops"`` is the mixed-batch path: the per-key op codes are
    binned with the same scatter as the keys and travel the same
    all-to-all, so every partition replays its slice of the interleaved
    stream with ``cuckoo_filter.apply_ops``. In-batch order is preserved
    end-to-end: all copies of a key land on its owner partition, the
    routing sort is stable, and the regrouped exchange concatenates source
    devices in mesh order — so same-key operations arrive in global batch
    order.
    """
    cap = config.bin_capacity(local_batch)
    ax = config.axis_name
    K = config.num_shards
    p_local = config.partitions_per_shard

    def regroup(x):
        # [K, p_local*cap, ...] received blocks -> [p_local, K*cap, ...]
        # per-partition streams (source-device-major, preserving order).
        x = x.reshape((K, p_local, cap) + x.shape[2:])
        x = jnp.moveaxis(x, 1, 0)
        return x.reshape((p_local, K * cap) + x.shape[3:])

    def ungroup(x):
        # inverse of regroup for result channels.
        x = x.reshape((p_local, K, cap) + x.shape[2:])
        x = jnp.moveaxis(x, 1, 0)
        return x.reshape((K, p_local * cap) + x.shape[3:])

    def exchange(x):
        return jax.lax.all_to_all(x, ax, split_axis=0, concat_axis=0,
                                  tiled=False)

    def per_partition(table, count, keys, valid, ops):
        state = CuckooState(table, count)
        if op == "apply_ops":
            state, ok, _ = _apply_ops(config.shard, state, keys, ops,
                                      valid=valid)
        elif op == "insert":
            state, ok, _ = _insert(config.shard, state, keys, valid=valid,
                                   dedup_within_batch=dedup_within_batch)
        elif op == "insert_bulk":
            # The all-to-all already binned keys by owner partition; the
            # bulk path's bucket-major sort composes on top of that binning
            # (DESIGN.md §6) — whole-bucket commits, residue to the loop.
            state, ok, _ = _insert_bulk(config.shard, state, keys,
                                        valid=valid,
                                        dedup_within_batch=dedup_within_batch)
        elif op == "delete":
            state, ok = _delete(config.shard, state, keys, valid=valid)
        elif op == "query":
            ok = _query(config.shard, state, keys) & valid
        else:  # pragma: no cover
            raise ValueError(op)
        return state.table, state.count, ok

    def fn(table, count, keys, valid, ops=None):
        # table: [p_local, num_words] local partitions; keys: [local_batch, 2]
        bins, bin_valid, order, dest_s, idxg, routed, slot = _route(
            config, keys, cap, valid)
        part_keys = regroup(exchange(bins.reshape(K, p_local * cap, 2)))
        part_valid = regroup(exchange(bin_valid.reshape(K, p_local * cap)))

        if op == "apply_ops":
            P = config.partitions
            bin_ops = jnp.zeros((P * cap,), jnp.int32).at[slot].set(
                ops.astype(jnp.int32)[order], mode="drop")
            part_ops = regroup(exchange(bin_ops.reshape(K, p_local * cap)))
        else:
            part_ops = jnp.zeros((p_local, K * cap), jnp.int32)

        table, count, ok = jax.vmap(per_partition)(
            table, count, part_keys, part_valid, part_ops)

        back = exchange(ungroup(ok)).reshape(config.partitions, cap)
        result = _unroute(order, dest_s, idxg, routed, back)
        routed_out = jnp.zeros((keys.shape[0],), bool).at[order].set(routed)
        return table, count, result, routed_out

    return fn


class ShardedCuckooFilter:
    """Driver: owns the mesh-placed state and jitted sharded ops.

    ``mesh`` must contain ``config.axis_name`` with size ``num_shards``.
    Keys arrive sharded along the same axis (global batch split across
    devices); results come back in the same layout.
    """

    def __init__(self, config: ShardedCuckooConfig, mesh: Mesh,
                 local_batch: int,
                 state: Optional[ShardedCuckooState] = None):
        if mesh.shape[config.axis_name] != config.num_shards:
            raise ValueError(
                f"mesh axis {config.axis_name} has size "
                f"{mesh.shape[config.axis_name]}, want {config.num_shards}")
        self.config = config
        self.mesh = mesh
        self.local_batch = local_batch
        self._ops = {}  # (op, dedup) -> jitted shard_map — built lazily
        self.state = jax.device_put(
            config.init() if state is None else state,
            NamedSharding(mesh, P(config.axis_name)))

    def _op(self, op: str, dedup: bool = False):
        key = (op, dedup)
        if key not in self._ops:
            ax = self.config.axis_name
            fn = _make_sharded_op(self.config, op, self.local_batch,
                                  dedup_within_batch=dedup)
            n_in = 5 if op == "apply_ops" else 4
            mapped = compat.shard_map(
                fn, mesh=self.mesh,
                in_specs=(P(ax),) * n_in,
                out_specs=(P(ax), P(ax), P(ax), P(ax)),
            )
            self._ops[key] = jax.jit(mapped)
        return self._ops[key]

    def _run(self, op, keys, valid=None, dedup=False, ops=None):
        keys = normalize_keys(keys)
        if valid is None:
            valid = jnp.ones((keys.shape[0],), bool)
        args = (self.state.table, self.state.count, keys, valid)
        if op == "apply_ops":
            args += (ops,)
        table, count, result, routed = self._op(op, dedup)(*args)
        if op != "query":
            self.state = ShardedCuckooState(table, count)
        return result, routed

    def insert(self, keys, bulk: bool = False, *,
               dedup_within_batch: bool = False,
               valid: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """-> (ok, routed): ok[i] requires routed[i]; retry ~routed keys.

        ``bulk=True`` routes through the bucket-sorted bulk-build fast path
        (core.cuckoo_filter.insert_bulk) on every shard. ``valid`` masks
        caller padding (masked keys report ``routed=False``).
        """
        return self._run("insert_bulk" if bulk else "insert", keys,
                         valid, dedup_within_batch)

    def query(self, keys, valid: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self._run("query", keys, valid)

    def delete(self, keys, valid: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self._run("delete", keys, valid)

    def apply_ops(self, keys, ops, valid: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Mixed-batch pass: -> (ok, routed), ok per that slot's op code.

        Op codes travel the same all-to-all as their keys, so every shard
        replays its slice of the interleaved stream in global batch order
        (see _make_sharded_op).
        """
        return self._run("apply_ops", keys, valid,
                         ops=jnp.asarray(ops, jnp.int32))

    @property
    def total_count(self) -> int:
        return int(jnp.sum(self.state.count))

    def resharded(self, mesh: Mesh,
                  num_shards: Optional[int] = None) -> "ShardedCuckooFilter":
        """Exact K→K′ / new-mesh migration: relocate partitions, keep state.

        Returns a new driver on ``mesh`` whose state arrays are the *same
        values* re-placed over the new device set (key→partition is fixed,
        so membership is bit-for-bit preserved — DESIGN.md §10). The new
        shard count must divide ``num_partitions``.
        """
        k = num_shards or mesh.shape[self.config.axis_name]
        # keep the *global* batch: per-device batches scale inversely with K
        return ShardedCuckooFilter(
            self.config.resharded(k), mesh,
            max(1, self.local_batch * self.config.num_shards // k),
            state=ShardedCuckooState(*map(jnp.asarray, self.state)))
