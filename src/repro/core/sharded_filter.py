"""Mesh-sharded Cuckoo filter — the distributed scale-out layer.

Partitioning scheme (DESIGN.md §5): one *independent* sub-filter per device
along a mesh axis, shard chosen by a dedicated hash of the key. Both cuckoo
candidate buckets of a key live in the same shard, so eviction chains never
cross devices — the PCF partitioning of Schmidt et al. promoted to the
accelerator mesh. Aggregate filter bandwidth scales linearly with devices
(the TPU analogue of the paper's "saturate global memory bandwidth": here we
saturate *n_devices x* HBM bandwidth).

Routing is a fixed-capacity all-to-all (no data-dependent shapes — a
straggler-mitigation requirement at scale, DESIGN.md §5): each device sorts
its local keys by destination shard into ``[num_shards, capacity]`` bins,
exchanges bins with one ``lax.all_to_all``, applies the local filter op with
a validity mask, and routes results back with the inverse exchange. Keys
beyond a bin's capacity are reported in the ``routed`` mask so callers can
retry them next step (they are never silently dropped).

All ops run inside ``shard_map`` over the chosen axis and are jit-compatible;
the sharded state is an ordinary pytree (stacked per-shard tables), so it
checkpoints/restores like model state.

The sharded filter also composes with the auto-expanding cascade
(``repro.amq.cascade``, DESIGN.md §8) as a *cascade of shards*: each
cascade level is an independently mesh-sharded filter, so aggregate
capacity grows geometrically while every level keeps the linear
n-devices-× bandwidth scaling above. :meth:`ShardedCuckooConfig.grown`
is the growth hook — it scales per-shard capacity while pinning the mesh
topology (shard count, axis, routing overprovision) so all levels of one
cascade exchange keys over the same all-to-all pattern.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat
from .cuckoo_filter import CuckooConfig, CuckooState
from .cuckoo_filter import apply_ops as _apply_ops
from .cuckoo_filter import delete as _delete
from .cuckoo_filter import insert as _insert
from .cuckoo_filter import insert_bulk as _insert_bulk
from .cuckoo_filter import query as _query
from .hashing import fmix32

_U32 = np.uint32
_SHARD_SALT = _U32(0x51ED270C)


class ShardedCuckooState(NamedTuple):
    table: jnp.ndarray  # uint32[num_shards, num_words]  (sharded over axis)
    count: jnp.ndarray  # int32[num_shards]


@dataclasses.dataclass(frozen=True)
class ShardedCuckooConfig:
    shard: CuckooConfig          # per-shard filter config
    num_shards: int
    axis_name: str = "data"
    capacity_factor: float = 2.0  # bin capacity overprovision vs n/num_shards

    def bin_capacity(self, local_batch: int) -> int:
        cap = int(np.ceil(local_batch / self.num_shards * self.capacity_factor))
        return max(8, cap)

    def init(self) -> ShardedCuckooState:
        lay = self.shard.layout
        return ShardedCuckooState(
            jnp.zeros((self.num_shards, lay.num_words), jnp.uint32),
            jnp.zeros((self.num_shards,), jnp.int32))

    @property
    def total_slots(self) -> int:
        return self.num_shards * self.shard.num_slots

    # -- AMQ protocol surface (repro.amq.protocol.AMQConfig) ----------------
    @property
    def num_slots(self) -> int:
        return self.total_slots

    @property
    def table_bytes(self) -> int:
        return self.num_shards * self.shard.table_bytes

    def expected_fpr(self, load_factor: float) -> float:
        """Shards are independent same-config filters: FPR is the shard's."""
        return self.shard.expected_fpr(load_factor)

    @staticmethod
    def for_capacity(capacity: int, num_shards: int, load_factor: float = 0.95,
                     axis_name: str = "data", **kw) -> "ShardedCuckooConfig":
        per_shard = int(np.ceil(capacity / num_shards))
        cf = kw.pop("capacity_factor", 2.0)
        return ShardedCuckooConfig(
            CuckooConfig.for_capacity(per_shard, load_factor, **kw),
            num_shards, axis_name, cf)

    def grown(self, factor: float, *, fp_bits: Optional[int] = None
              ) -> "ShardedCuckooConfig":
        """Next cascade level's config: ``factor``-times the capacity.

        Scales the per-shard filter while keeping the mesh topology
        (``num_shards``, ``axis_name``, ``capacity_factor``) fixed, so all
        levels of a cascade share one all-to-all routing pattern.
        ``fp_bits`` optionally tightens the level's fingerprints to meet a
        smaller FPR share (DESIGN.md §8).
        """
        return ShardedCuckooConfig(
            CuckooConfig.for_capacity(
                int(np.ceil(self.shard.num_slots * factor)),
                load_factor=1.0,  # num_slots is already post-load sizing
                fp_bits=self.shard.fp_bits if fp_bits is None else fp_bits,
                bucket_size=self.shard.bucket_size,
                policy=self.shard.policy,
                hash_kind=self.shard.hash_kind,
                eviction=self.shard.eviction,
                max_evictions=self.shard.max_evictions,
                max_rounds=self.shard.max_rounds,
                seed=self.shard.seed),
            self.num_shards, self.axis_name, self.capacity_factor)


def shard_of(config: ShardedCuckooConfig, keys: jnp.ndarray) -> jnp.ndarray:
    """Owner shard per key — a hash independent of the in-shard hashes."""
    mix = fmix32(keys[..., 0] ^ fmix32(keys[..., 1] ^ _SHARD_SALT))
    return (mix % _U32(config.num_shards)).astype(jnp.int32)


def _route(config: ShardedCuckooConfig, keys: jnp.ndarray, cap: int,
           valid: Optional[jnp.ndarray] = None):
    """Local routing: sort keys into [num_shards, cap] bins.

    ``valid`` masks caller-side padding keys: they are given the ``S``
    sentinel destination, sort past every real shard group, and never claim
    a bin slot (so they cannot crowd out live keys).

    Returns (bins uint32[S, cap, 2], bin_valid bool[S, cap],
             order, dest_sorted, idx_in_group, routed_sorted, slot).

    ``slot`` is the flat bin address per *sorted* key (``S*cap`` sentinel =
    unrouted); extra per-key channels (the mixed batch's op codes) are
    binned with the same scatter so they travel the identical all-to-all.
    """
    S = config.num_shards
    n = keys.shape[0]
    dest = shard_of(config, keys)
    if valid is not None:
        dest = jnp.where(valid.astype(bool), dest, S)
    order = jnp.argsort(dest, stable=True)
    dest_s = dest[order]
    keys_s = keys[order]
    first_of_group = jnp.searchsorted(dest_s, dest_s, side="left")
    idx_in_group = jnp.arange(n, dtype=jnp.int32) - first_of_group
    routed = (idx_in_group < cap) & (dest_s < S)
    slot = jnp.where(routed, dest_s * cap + idx_in_group, S * cap)
    bins = jnp.zeros((S * cap, 2), jnp.uint32).at[slot].set(keys_s, mode="drop")
    bin_valid = jnp.zeros((S * cap,), bool).at[slot].set(routed, mode="drop")
    return (bins.reshape(S, cap, 2), bin_valid.reshape(S, cap),
            order, dest_s, idx_in_group, routed, slot)


def _unroute(order, dest_s, idx_in_group, routed, back, fill=False):
    """Inverse of _route for a per-key result channel ``back[S, cap]``."""
    n = order.shape[0]
    got = back[dest_s, jnp.minimum(idx_in_group, back.shape[1] - 1)]
    got = jnp.where(routed, got, fill)
    return jnp.zeros((n,), back.dtype).at[order].set(got)


def _make_sharded_op(config: ShardedCuckooConfig, op: str, local_batch: int,
                     dedup_within_batch: bool = False):
    """Build the per-device function for one op (runs under shard_map).

    ``dedup_within_batch`` is globally correct because duplicates of a key
    hash to the same owner shard: per-shard first-occurrence dedup IS
    whole-batch dedup.

    ``op == "apply_ops"`` is the mixed-batch path: the per-key op codes are
    binned with the same scatter as the keys and travel the same
    all-to-all, so every shard replays its slice of the interleaved stream
    with ``cuckoo_filter.apply_ops``. In-batch order is preserved
    end-to-end: all copies of a key land on its owner shard, the routing
    sort is stable, and the exchange concatenates source devices in mesh
    order — so same-key operations arrive in global batch order.
    """
    cap = config.bin_capacity(local_batch)
    ax = config.axis_name

    def fn(table, count, keys, valid, ops=None):
        # table: [1, num_words] local shard; keys: [local_batch, 2]
        state = CuckooState(table[0], count[0])
        bins, bin_valid, order, dest_s, idxg, routed, slot = _route(
            config, keys, cap, valid)
        recv = jax.lax.all_to_all(bins, ax, split_axis=0, concat_axis=0,
                                  tiled=False)
        recv_valid = jax.lax.all_to_all(bin_valid, ax, split_axis=0,
                                        concat_axis=0, tiled=False)
        flat_keys = recv.reshape(-1, 2)
        flat_valid = recv_valid.reshape(-1)

        if op == "apply_ops":
            S = config.num_shards
            bin_ops = jnp.zeros((S * cap,), jnp.int32).at[slot].set(
                ops.astype(jnp.int32)[order], mode="drop")
            recv_ops = jax.lax.all_to_all(bin_ops.reshape(S, cap), ax,
                                          split_axis=0, concat_axis=0,
                                          tiled=False)
            state, ok, _ = _apply_ops(config.shard, state, flat_keys,
                                      recv_ops.reshape(-1),
                                      valid=flat_valid)
        elif op == "insert":
            state, ok, _ = _insert(config.shard, state, flat_keys,
                                   valid=flat_valid,
                                   dedup_within_batch=dedup_within_batch)
        elif op == "insert_bulk":
            # The all-to-all already binned keys by owner shard; the bulk
            # path's bucket-major sort composes on top of that binning
            # (DESIGN.md §6) — whole-bucket commits, residue to the loop.
            state, ok, _ = _insert_bulk(config.shard, state, flat_keys,
                                        valid=flat_valid,
                                        dedup_within_batch=dedup_within_batch)
        elif op == "delete":
            state, ok = _delete(config.shard, state, flat_keys,
                                valid=flat_valid)
        elif op == "query":
            ok = _query(config.shard, state, flat_keys) & flat_valid
        else:  # pragma: no cover
            raise ValueError(op)

        back = jax.lax.all_to_all(
            ok.reshape(config.num_shards, cap), ax,
            split_axis=0, concat_axis=0, tiled=False)
        result = _unroute(order, dest_s, idxg, routed, back)
        routed_out = jnp.zeros((keys.shape[0],), bool).at[order].set(routed)
        return state.table[None], state.count[None], result, routed_out

    return fn


class ShardedCuckooFilter:
    """Driver: owns the mesh-placed state and jitted sharded ops.

    ``mesh`` must contain ``config.axis_name`` with size ``num_shards``.
    Keys arrive sharded along the same axis (global batch split across
    devices); results come back in the same layout.
    """

    def __init__(self, config: ShardedCuckooConfig, mesh: Mesh,
                 local_batch: int):
        if mesh.shape[config.axis_name] != config.num_shards:
            raise ValueError(
                f"mesh axis {config.axis_name} has size "
                f"{mesh.shape[config.axis_name]}, want {config.num_shards}")
        self.config = config
        self.mesh = mesh
        self.local_batch = local_batch
        self._ops = {}  # (op, dedup) -> jitted shard_map — built lazily
        self.state = jax.device_put(
            config.init(),
            NamedSharding(mesh, P(config.axis_name)))

    def _op(self, op: str, dedup: bool = False):
        key = (op, dedup)
        if key not in self._ops:
            ax = self.config.axis_name
            fn = _make_sharded_op(self.config, op, self.local_batch,
                                  dedup_within_batch=dedup)
            n_in = 5 if op == "apply_ops" else 4
            mapped = compat.shard_map(
                fn, mesh=self.mesh,
                in_specs=(P(ax),) * n_in,
                out_specs=(P(ax), P(ax), P(ax), P(ax)),
            )
            self._ops[key] = jax.jit(mapped)
        return self._ops[key]

    def _run(self, op, keys, valid=None, dedup=False, ops=None):
        if valid is None:
            valid = jnp.ones((keys.shape[0],), bool)
        args = (self.state.table, self.state.count, keys, valid)
        if op == "apply_ops":
            args += (ops,)
        table, count, result, routed = self._op(op, dedup)(*args)
        if op != "query":
            self.state = ShardedCuckooState(table, count)
        return result, routed

    def insert(self, keys, bulk: bool = False, *,
               dedup_within_batch: bool = False,
               valid: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """-> (ok, routed): ok[i] requires routed[i]; retry ~routed keys.

        ``bulk=True`` routes through the bucket-sorted bulk-build fast path
        (core.cuckoo_filter.insert_bulk) on every shard. ``valid`` masks
        caller padding (masked keys report ``routed=False``).
        """
        return self._run("insert_bulk" if bulk else "insert", keys,
                         valid, dedup_within_batch)

    def query(self, keys, valid: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self._run("query", keys, valid)

    def delete(self, keys, valid: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self._run("delete", keys, valid)

    def apply_ops(self, keys, ops, valid: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Mixed-batch pass: -> (ok, routed), ok per that slot's op code.

        Op codes travel the same all-to-all as their keys, so every shard
        replays its slice of the interleaved stream in global batch order
        (see _make_sharded_op).
        """
        return self._run("apply_ops", keys, valid,
                         ops=jnp.asarray(ops, jnp.int32))

    @property
    def total_count(self) -> int:
        return int(jnp.sum(self.state.count))
