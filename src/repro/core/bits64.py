"""64-bit unsigned integer arithmetic emulated on uint32 pairs.

TPUs have no native 64-bit integer datapath: int64/uint64 are emulated by XLA
and slow, and Pallas TPU kernels reject them outright. The Cuckoo-GPU paper
hashes keys with xxHash64, so to stay bit-exact we implement the required u64
operations (add, xor, shift, rotate, multiply) on ``(hi, lo)`` uint32 pairs.
Multiplication uses 16-bit limbs so every partial product fits in a uint32
lane — the natural formulation for the TPU VPU.

A ``U64`` value is simply a tuple ``(hi, lo)`` of equal-shaped uint32 arrays.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

U64 = Tuple[jnp.ndarray, jnp.ndarray]  # (hi, lo), both uint32

_U32 = np.uint32
MASK16 = _U32(0xFFFF)


def u64(hi, lo) -> U64:
    """Build a U64 from hi/lo parts (cast to uint32)."""
    return (jnp.asarray(hi, jnp.uint32), jnp.asarray(lo, jnp.uint32))


def from_py(value: int, shape=()) -> U64:
    """Broadcast a Python int constant to a U64 of the given shape."""
    value &= (1 << 64) - 1
    hi = jnp.full(shape, _U32((value >> 32) & 0xFFFFFFFF), jnp.uint32)
    lo = jnp.full(shape, _U32(value & 0xFFFFFFFF), jnp.uint32)
    return (hi, lo)


def to_py(x: U64) -> int:
    """Scalar U64 -> Python int (host only, for tests)."""
    hi, lo = x
    return (int(np.asarray(hi)) << 32) | int(np.asarray(lo))


def xor(a: U64, b: U64) -> U64:
    return (a[0] ^ b[0], a[1] ^ b[1])


def add(a: U64, b: U64) -> U64:
    lo = a[1] + b[1]
    carry = (lo < a[1]).astype(jnp.uint32)
    hi = a[0] + b[0] + carry
    return (hi, lo)


def mul32x32(a: jnp.ndarray, b: jnp.ndarray) -> U64:
    """Full 64-bit product of two uint32 arrays via 16-bit limbs."""
    a0 = a & MASK16
    a1 = a >> 16
    b0 = b & MASK16
    b1 = b >> 16
    p00 = a0 * b0            # <= (2^16-1)^2 < 2^32, exact in uint32
    p01 = a0 * b1
    p10 = a1 * b0
    p11 = a1 * b1
    mid = p01 + p10
    mid_carry = (mid < p01).astype(jnp.uint32)   # overflow of the mid add
    lo = p00 + (mid << 16)
    lo_carry = (lo < p00).astype(jnp.uint32)
    hi = p11 + (mid >> 16) + (mid_carry << 16) + lo_carry
    return (hi, lo)


def mul(a: U64, b: U64) -> U64:
    """Low 64 bits of a 64x64 product."""
    hi, lo = mul32x32(a[1], b[1])
    hi = hi + a[0] * b[1] + a[1] * b[0]
    return (hi, lo)


def shl(a: U64, r: int) -> U64:
    """Logical shift left by a static amount r in [0, 64)."""
    assert 0 <= r < 64
    hi, lo = a
    if r == 0:
        return a
    if r == 32:
        return (lo, jnp.zeros_like(lo))
    if r > 32:
        return (lo << (r - 32), jnp.zeros_like(lo))
    return ((hi << r) | (lo >> (32 - r)), lo << r)


def shr(a: U64, r: int) -> U64:
    """Logical shift right by a static amount r in [0, 64)."""
    assert 0 <= r < 64
    hi, lo = a
    if r == 0:
        return a
    if r == 32:
        return (jnp.zeros_like(hi), hi)
    if r > 32:
        return (jnp.zeros_like(hi), hi >> (r - 32))
    return (hi >> r, (lo >> r) | (hi << (32 - r)))


def rotl(a: U64, r: int) -> U64:
    """Rotate left by a static amount r in (0, 64)."""
    r %= 64
    if r == 0:
        return a
    left = shl(a, r)
    right = shr(a, 64 - r)
    return (left[0] | right[0], left[1] | right[1])


def rotl32(x: jnp.ndarray, r: int) -> jnp.ndarray:
    r %= 32
    if r == 0:
        return x
    return (x << r) | (x >> (32 - r))
