"""Packed-fingerprint bucket layout + SWAR primitives (paper §4.2).

The paper packs 8/16/32-bit fingerprints into 64-bit words. TPU VPU lanes are
32 bits wide, so our machine word is ``uint32`` (hardware-adaptation note in
DESIGN.md §2): a word holds 4×8-bit, 2×16-bit or 1×32-bit fingerprints. The
SWAR zero/match-mask algebra is identical, just on 32-bit constants.

The table is a flat ``uint32[num_buckets * words_per_bucket]`` array; a bucket
is the contiguous word range ``[b * wpb, (b+1) * wpb)`` — bucket-major layout
so one vector load covers a whole bucket (the TPU analogue of the paper's
256-bit vectorized query loads).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

_U32 = np.uint32

# SWAR constants per fingerprint width: (low-7(15,31)-bits pattern, high-bit pattern).
_SWAR_LOW7 = {8: 0x7F7F7F7F, 16: 0x7FFF7FFF, 32: 0x7FFFFFFF}
_SWAR_HIGH = {8: 0x80808080, 16: 0x80008000, 32: 0x80000000}


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static description of the packed bucket layout."""

    num_buckets: int
    bucket_size: int          # b: fingerprints per bucket
    fp_bits: int              # f: bits per stored tag (incl. choice bit if any)

    def __post_init__(self):
        if self.fp_bits not in (8, 16, 32):
            raise ValueError("fp_bits must be 8, 16 or 32 (hardware-friendly widths)")
        if self.bucket_size % self.tags_per_word:
            raise ValueError("bucket_size must be a multiple of tags_per_word")

    @property
    def tags_per_word(self) -> int:
        return 32 // self.fp_bits

    @property
    def words_per_bucket(self) -> int:
        return self.bucket_size // self.tags_per_word

    @property
    def num_words(self) -> int:
        return self.num_buckets * self.words_per_bucket

    @property
    def num_slots(self) -> int:
        return self.num_buckets * self.bucket_size

    @property
    def fp_mask(self) -> int:
        return (1 << self.fp_bits) - 1

    @property
    def table_bytes(self) -> int:
        return self.num_words * 4

    def empty_table(self) -> jnp.ndarray:
        return jnp.zeros((self.num_words,), jnp.uint32)


# ---------------------------------------------------------------------------
# SWAR primitives (paper §4.3 "bitwise SWAR algorithm", §4.4 HasZeroSegment).
# ---------------------------------------------------------------------------

def swar_zero_mask(word: jnp.ndarray, fp_bits: int) -> jnp.ndarray:
    """High bit of each fp lane set iff that lane is zero — *exact* per lane.

    The paper's classic haszero ``(v - 0x01..01) & ~v & 0x80..80`` is only
    exact for the lowest flagged lane (borrows pollute higher lanes); since
    our scans start at a fingerprint-derived circular offset we need the
    carry-free exact variant:

        y = (v & 0x7F..7F) + 0x7F..7F   # high bit <- OR of low bits
        y |= v                           # high bit <- lane nonzero
        mask = ~y & 0x80..80
    """
    low7 = _U32(_SWAR_LOW7[fp_bits])
    high = _U32(_SWAR_HIGH[fp_bits])
    y = ((word & low7) + low7) | word
    return ~y & high


def swar_match_mask(word: jnp.ndarray, tag: jnp.ndarray, fp_bits: int) -> jnp.ndarray:
    """High bit of each fp lane set iff that lane equals ``tag``."""
    return swar_zero_mask(word ^ broadcast_tag(tag, fp_bits), fp_bits)


def broadcast_tag(tag: jnp.ndarray, fp_bits: int) -> jnp.ndarray:
    """Replicate a tag into every lane of a 32-bit word (paper BroadcastTag)."""
    tag = jnp.asarray(tag, jnp.uint32)
    word = tag
    if fp_bits <= 16:
        word = word | (word << 16)
    if fp_bits <= 8:
        word = word | ((word & _U32(0x00FF00FF)) << 8)
    return word


def swar_mask_to_bools(mask: jnp.ndarray, fp_bits: int) -> jnp.ndarray:
    """SWAR high-bit mask (uint32) -> bool[..., tags_per_word] per-lane flags."""
    tpw = 32 // fp_bits
    shifts = (jnp.arange(tpw, dtype=jnp.uint32) * _U32(fp_bits)) + _U32(fp_bits - 1)
    return ((mask[..., None] >> shifts) & _U32(1)).astype(bool)


# ---------------------------------------------------------------------------
# Pack / unpack and slot read-modify-write.
# ---------------------------------------------------------------------------

def unpack_words(words: jnp.ndarray, fp_bits: int) -> jnp.ndarray:
    """uint32[..., W] packed words -> uint32[..., W * tpw] tag values."""
    tpw = 32 // fp_bits
    shifts = jnp.arange(tpw, dtype=jnp.uint32) * _U32(fp_bits)
    tags = (words[..., None] >> shifts) & _U32((1 << fp_bits) - 1)
    return tags.reshape(*words.shape[:-1], words.shape[-1] * tpw)


def pack_tags(tags: jnp.ndarray, fp_bits: int) -> jnp.ndarray:
    """Inverse of unpack_words."""
    tpw = 32 // fp_bits
    t = tags.reshape(*tags.shape[:-1], tags.shape[-1] // tpw, tpw)
    shifts = jnp.arange(tpw, dtype=jnp.uint32) * _U32(fp_bits)
    return jnp.sum(
        (t & _U32((1 << fp_bits) - 1)).astype(jnp.uint32) << shifts, axis=-1,
        dtype=jnp.uint32,
    )


def extract_tag(word: jnp.ndarray, slot_in_word: jnp.ndarray, fp_bits: int) -> jnp.ndarray:
    """ExtractTag (paper Alg. 1 line 17)."""
    shift = (slot_in_word.astype(jnp.uint32) * _U32(fp_bits))
    return (word >> shift) & _U32((1 << fp_bits) - 1)


def replace_tag(
    word: jnp.ndarray, slot_in_word: jnp.ndarray, tag: jnp.ndarray, fp_bits: int
) -> jnp.ndarray:
    """ReplaceTag (paper Alg. 1 line 18) — returns the ``desired`` word."""
    shift = slot_in_word.astype(jnp.uint32) * _U32(fp_bits)
    lane_mask = _U32((1 << fp_bits) - 1) << shift
    return (word & ~lane_mask) | ((tag.astype(jnp.uint32) << shift) & lane_mask)


# ---------------------------------------------------------------------------
# Bucket gather + circular first-empty / first-match scans (paper TryInsert /
# Find start at a fingerprint-derived pseudo-random offset).
# ---------------------------------------------------------------------------

def gather_bucket_words(table: jnp.ndarray, bucket: jnp.ndarray, layout: BucketLayout) -> jnp.ndarray:
    """Gather the packed words of each bucket: -> uint32[..., words_per_bucket]."""
    base = bucket.astype(jnp.uint32) * _U32(layout.words_per_bucket)
    offs = jnp.arange(layout.words_per_bucket, dtype=jnp.uint32)
    return table[(base[..., None] + offs).astype(jnp.int32)]


def bucket_tags(table: jnp.ndarray, bucket: jnp.ndarray, layout: BucketLayout) -> jnp.ndarray:
    """Gather and unpack a bucket: -> uint32[..., bucket_size] tags."""
    return unpack_words(gather_bucket_words(table, bucket, layout), layout.fp_bits)


def scan_start(tag: jnp.ndarray, layout: BucketLayout) -> jnp.ndarray:
    """Pseudo-random slot scan start: ``tag mod bucketSize`` (paper Alg. 1 l.26)."""
    return (tag.astype(jnp.uint32) % _U32(layout.bucket_size)).astype(jnp.int32)


def first_true_circular(flags: jnp.ndarray, start: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """First True position scanning circularly from ``start``.

    flags: bool[..., b]; start: int32[...] in [0, b).
    Returns (found: bool[...], slot: int32[...] absolute index).
    """
    b = flags.shape[-1]
    idx = (start[..., None] + jnp.arange(b, dtype=jnp.int32)) % b
    rot = jnp.take_along_axis(flags, idx, axis=-1)
    found = jnp.any(rot, axis=-1)
    first_rel = jnp.argmax(rot, axis=-1).astype(jnp.int32)
    slot = (start + first_rel) % b
    return found, slot


# ---------------------------------------------------------------------------
# Segmented-scan helpers for the bulk-build insertion path (DESIGN.md §6).
#
# ``unpack_words`` applied to the *flat* table is already the per-slot view in
# global slot order (slot s of bucket b lives at flat index b*bucket_size + s),
# so a bulk placement round is: unpack table -> scatter one tag per free slot
# -> pack. The helpers below compute, for a batch sorted by destination
# bucket, each key's rank within its bucket segment and the bucket's rank-th
# free slot — which together make whole-bucket commits conflict-free by
# construction (every key owns a distinct slot).
# ---------------------------------------------------------------------------

def segment_ranks(sorted_ids: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element within its run of equal values.

    sorted_ids: int32[n] ascending (runs = segments). Returns int32[n] with
    0, 1, 2, ... restarting at every segment boundary.
    """
    n = sorted_ids.shape[0]
    first = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    return jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)


def nth_free_slot(btags: jnp.ndarray, rank: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Position of the ``rank``-th empty slot in each bucket.

    btags: uint32[..., b] unpacked bucket tags; rank: int32[...] >= 0.
    Returns (placed: bool[...], slot: int32[...]). ``placed`` is False when
    the bucket has <= rank free slots (the key spills to the next phase).
    """
    free = btags == 0
    prefix = jnp.cumsum(free, axis=-1, dtype=jnp.int32)      # inclusive count
    target = rank[..., None] + 1
    hit = free & (prefix == target)
    placed = prefix[..., -1] > rank
    slot = jnp.argmax(hit, axis=-1).astype(jnp.int32)
    return placed, slot


def slot_to_word(slot: jnp.ndarray, layout: BucketLayout) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Absolute slot index in bucket -> (word index in bucket, slot within word)."""
    tpw = layout.tags_per_word
    return slot // tpw, slot % tpw


def word_addr(bucket: jnp.ndarray, word_in_bucket: jnp.ndarray, layout: BucketLayout) -> jnp.ndarray:
    """Flat word address of (bucket, word) — the claim/CAS granule."""
    return (bucket.astype(jnp.int32) * layout.words_per_bucket
            + word_in_bucket.astype(jnp.int32))
