"""Bucket placement policies (paper §2.1 and §4.6.2).

* ``XorPolicy``    — standard partial-key cuckoo hashing. Requires num_buckets
  to be a power of two; ``i2 = i1 ^ H(fp)`` is an involution, so an entry's
  alternate bucket is computable from (current bucket, stored tag) alone.

* ``OffsetPolicy`` — the flexible placement of §4.6.2 (after Schmitz et al.):
  any bucket count m; a *choice bit* stored in the tag's top bit records
  whether the entry sits in its primary (0) or alternate (1) bucket:

      choice 0:  i2 = (i1 + offset(fp)) mod m
      choice 1:  i1 = (i2 - offset(fp)) mod m

  Costs one fingerprint bit (higher FPR, Eq. 4 with f-1) and a bit-flip per
  relocation — evaluated in benchmarks/bucket_policy.py (paper Fig. 7).

Both policies expose the same interface over *stored tags* (fingerprint plus
any metadata bits), so the filter core is policy-agnostic.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .hashing import fmix32
from .layout import BucketLayout

_U32 = np.uint32


@dataclasses.dataclass(frozen=True)
class XorPolicy:
    """i2 = i1 XOR H(fp); power-of-two bucket counts only."""

    num_buckets: int
    fp_bits: int

    kind: str = dataclasses.field(default="xor", init=False)

    def __post_init__(self):
        if self.num_buckets & (self.num_buckets - 1):
            raise ValueError(
                "XorPolicy requires a power-of-two number of buckets "
                "(use OffsetPolicy for arbitrary sizes — paper §4.6.2)")

    @property
    def mask(self) -> int:
        return self.num_buckets - 1

    @property
    def effective_fp_bits(self) -> int:
        return self.fp_bits

    def make_tag(self, fp_hash: jnp.ndarray) -> jnp.ndarray:
        """Derive the stored tag from the fingerprint hash word (never 0)."""
        fp = fp_hash & _U32((1 << self.fp_bits) - 1)
        return jnp.where(fp == 0, _U32(1), fp)

    def primary_bucket(self, index_hash: jnp.ndarray) -> jnp.ndarray:
        return index_hash & _U32(self.mask)

    def initial_buckets(self, index_hash, tag):
        i1 = self.primary_bucket(index_hash)
        return i1, self.alt_bucket(i1, tag)

    def alt_bucket(self, bucket: jnp.ndarray, tag: jnp.ndarray) -> jnp.ndarray:
        """Involution: alt(alt(i, t), t) == i."""
        return bucket ^ (fmix32(tag) & _U32(self.mask))

    def place_tag(self, tag: jnp.ndarray, in_alternate: jnp.ndarray) -> jnp.ndarray:
        """Tag as stored when placed in primary/alternate bucket (no-op here)."""
        del in_alternate
        return tag

    def on_relocate(self, stored_tag: jnp.ndarray) -> jnp.ndarray:
        """Stored tag after moving to its other bucket (no-op for XOR)."""
        return stored_tag

    def match_tag(self, stored: jnp.ndarray, query_tag: jnp.ndarray) -> jnp.ndarray:
        return stored == query_tag

    def query_match_tags(self, query_tag: jnp.ndarray):
        """Tags to match in (primary, alternate) buckets for a query."""
        return query_tag, query_tag


@dataclasses.dataclass(frozen=True)
class OffsetPolicy:
    """Asymmetric offset + choice bit; arbitrary bucket counts (§4.6.2)."""

    num_buckets: int
    fp_bits: int

    kind: str = dataclasses.field(default="offset", init=False)

    @property
    def choice_bit(self) -> int:
        return 1 << (self.fp_bits - 1)

    @property
    def effective_fp_bits(self) -> int:
        return self.fp_bits - 1  # one bit of entropy spent on the choice bit

    @property
    def fp_value_mask(self) -> int:
        return (1 << (self.fp_bits - 1)) - 1

    def make_tag(self, fp_hash: jnp.ndarray) -> jnp.ndarray:
        fp = fp_hash & _U32(self.fp_value_mask)
        return jnp.where(fp == 0, _U32(1), fp)

    def _offset(self, tag: jnp.ndarray) -> jnp.ndarray:
        """Fingerprint-derived offset in [1, m) (0 would alias the buckets)."""
        fp = tag & _U32(self.fp_value_mask)
        return (fmix32(fp ^ _U32(0x27D4EB2F)) % _U32(self.num_buckets - 1)) + _U32(1)

    def primary_bucket(self, index_hash: jnp.ndarray) -> jnp.ndarray:
        return index_hash % _U32(self.num_buckets)

    def initial_buckets(self, index_hash, tag):
        i1 = self.primary_bucket(index_hash)
        m = _U32(self.num_buckets)
        i2 = (i1 + self._offset(tag)) % m
        return i1, i2

    def alt_bucket(self, bucket: jnp.ndarray, stored_tag: jnp.ndarray) -> jnp.ndarray:
        """Other bucket of a *stored* entry, using its choice bit."""
        m = _U32(self.num_buckets)
        off = self._offset(stored_tag)
        in_alt = (stored_tag & _U32(self.choice_bit)) != 0
        fwd = (bucket + off) % m          # choice 0: currently primary -> alt
        back = (bucket + m - off) % m     # choice 1: currently alt -> primary
        return jnp.where(in_alt, back, fwd)

    def place_tag(self, tag: jnp.ndarray, in_alternate: jnp.ndarray) -> jnp.ndarray:
        base = tag & _U32(self.fp_value_mask)
        return jnp.where(in_alternate, base | _U32(self.choice_bit), base)

    def on_relocate(self, stored_tag: jnp.ndarray) -> jnp.ndarray:
        """Moving between buckets flips the choice bit (paper §4.6.2)."""
        return stored_tag ^ _U32(self.choice_bit)

    def match_tag(self, stored: jnp.ndarray, query_tag: jnp.ndarray) -> jnp.ndarray:
        """Match ignores the choice bit — but a query knows which bucket it is
        scanning, so the caller matches against the properly-placed tag."""
        return (stored & _U32(self.fp_value_mask)) == (query_tag & _U32(self.fp_value_mask))

    def query_match_tags(self, query_tag: jnp.ndarray):
        """In the primary bucket an entry must carry choice=0; in the
        alternate, choice=1. Matching the full tag (incl. choice bit) keeps the
        effective fingerprint at f-1 bits without extra masking."""
        base = query_tag & _U32(self.fp_value_mask)
        return base, base | _U32(self.choice_bit)


def make_policy(kind: str, num_buckets: int, fp_bits: int):
    if kind == "xor":
        return XorPolicy(num_buckets, fp_bits)
    if kind == "offset":
        return OffsetPolicy(num_buckets, fp_bits)
    raise ValueError(f"unknown placement policy {kind!r}")
