"""Key hashing for the Cuckoo filter (paper §4.3 step 1).

The paper hashes each 64-bit key with xxHash64, then splits the digest:
upper 32 bits derive the fingerprint, lower 32 bits the primary bucket index
("Distinct hash parts are used to avoid fingerprint clustering").

We provide:

* ``xxhash64_u64``  — bit-exact xxHash64 of a single 8-byte key (the paper's
  configuration: keys are uint64), on emulated u64 arithmetic (TPU-native).
* ``fmix32_pair``   — a cheaper TPU-native path: two chained murmur3 finalizers
  over the (hi, lo) words. Used as the beyond-paper default where bit-parity
  with the CUDA library is not required.

Keys everywhere in this library are ``uint32[..., 2]`` arrays laid out as
``[..., 0] = lo, [..., 1] = hi`` (no x64 mode required; TPU friendly).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import bits64 as b64

# xxHash64 primes.
PRIME64_1 = 0x9E3779B185EBCA87
PRIME64_2 = 0xC2B2AE3D4F118CB1
PRIME64_3 = 0x165667B19E3779F9
PRIME64_4 = 0x85EBCA77C2B2AE63
PRIME64_5 = 0x27D4EB2F165667C5

_U32 = np.uint32


def keys_to_u64(keys: jnp.ndarray) -> b64.U64:
    """uint32[..., 2] (lo, hi) -> U64 pair."""
    shape = getattr(keys, "shape", None)
    dtype = getattr(keys, "dtype", None)
    if shape is None or len(shape) < 1 or shape[-1] != 2:
        raise ValueError(
            f"keys must be uint32[..., 2] (lo, hi) pairs, got shape {shape}; "
            "raw uint64[n] keys are accepted at the FilterHandle / OpBatch / "
            "CuckooFilter boundaries (see repro.core.hashing.normalize_keys)")
    if dtype is not None and np.dtype(dtype).itemsize > 4:
        raise ValueError(
            f"keys must be uint32[..., 2] (lo, hi) pairs, got dtype {dtype}: "
            "casting 64-bit lanes to uint32 would silently truncate; split "
            "them with repro.core.hashing.keys_from_numpy/normalize_keys")
    keys = jnp.asarray(keys, jnp.uint32)
    return (keys[..., 1], keys[..., 0])


def keys_from_numpy(arr: np.ndarray) -> np.ndarray:
    """Host helper: uint64 numpy array -> uint32[..., 2] (lo, hi)."""
    arr = np.asarray(arr, np.uint64)
    out = np.empty(arr.shape + (2,), np.uint32)
    out[..., 0] = (arr & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    out[..., 1] = (arr >> np.uint64(32)).astype(np.uint32)
    return out


def keys_to_numpy(keys) -> np.ndarray:
    """Host helper: uint32[..., 2] (lo, hi) -> uint64 numpy array.

    Exact inverse of :func:`keys_from_numpy` — the one key-normalization
    helper shared by every host-side consumer (the Python oracle, the AMQ
    adapters, the service front-end), so the packing convention cannot
    drift between them.
    """
    arr = np.asarray(keys, np.uint32)
    return (arr[..., 0].astype(np.uint64)
            | (arr[..., 1].astype(np.uint64) << np.uint64(32)))


def _is_tracer(x) -> bool:
    """True for abstract jax values (inside jit/vmap) that cannot leave the
    device program — normalize_keys then only checks shapes/dtypes."""
    try:
        import jax

        return isinstance(x, jax.core.Tracer)
    except (ImportError, AttributeError):  # pragma: no cover — old jax
        return False


def normalize_keys(keys, *, arg: str = "keys") -> np.ndarray:
    """Normalize any accepted key batch form to the internal layout.

    The public key-format contract (README "Key format"): filters accept

    * raw ``uint64[n]`` keys (numpy arrays, Python int lists/tuples) — the
      natural input form; split into (lo, hi) pairs host-side;
    * already-packed ``uint32[n, 2]`` (lo, hi) pairs — the internal layout,
      passed through (any 32-bit-or-narrower integer dtype is accepted).

    Returns ``uint32[n, 2]`` (numpy for host inputs, the original array for
    jax inputs so device residency is preserved). Raises ``ValueError``
    naming ``arg`` for genuinely malformed shapes/dtypes instead of letting
    the shape error surface deep inside a jitted eviction loop
    (the former ``layout.py:184`` crash).
    """
    if (getattr(keys, "ndim", None) == 2 and keys.shape[-1] == 2
            and getattr(keys, "dtype", None) == np.uint32):
        return keys  # already the internal layout: no host round-trip
    if _is_tracer(keys):  # device values: validate statically, never convert
        if keys.ndim != 2 or keys.shape[-1] != 2 or keys.dtype.itemsize > 4:
            raise ValueError(
                f"{arg}: traced key batches must already be uint32[n, 2] "
                f"(lo, hi) pairs, got {keys.dtype}{list(keys.shape)}")
        return keys
    if isinstance(keys, (list, tuple)):
        try:
            keys = np.asarray(keys, np.uint64)
        except (OverflowError, TypeError, ValueError) as e:
            raise ValueError(
                f"{arg}: key values must fit uint64 ({e})") from None
    arr = np.asarray(keys)
    if arr.dtype == object or not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"{arg}: expected an integer key batch (uint64[n] or "
            f"uint32[n, 2]), got dtype {arr.dtype}")
    if arr.ndim == 1:
        if arr.dtype.itemsize <= 4:  # widen 32-bit scalars losslessly
            arr = arr.astype(np.uint32).astype(np.uint64)
        return keys_from_numpy(arr)
    if arr.ndim == 2 and arr.shape[-1] == 2:
        if arr.dtype.itemsize > 4:
            if (arr >> 32).any():
                raise ValueError(
                    f"{arg}: [n, 2] key pairs carry 64-bit lane values — "
                    "lanes must be 32-bit (lo, hi) halves "
                    "(see repro.core.hashing.keys_from_numpy)")
            arr = arr.astype(np.uint32)
        return np.ascontiguousarray(arr, np.uint32)
    raise ValueError(
        f"{arg}: expected uint64[n] keys or uint32[n, 2] (lo, hi) pairs, "
        f"got shape {list(arr.shape)} dtype {arr.dtype}")


def xxhash64_u64(key: b64.U64, seed: int = 0) -> b64.U64:
    """xxHash64 of a single 64-bit lane (length-8 input), bit exact.

    Mirrors the reference implementation specialised to len==8:
        h  = seed + PRIME64_5 + 8
        k1 = rotl(key * PRIME64_2, 31) * PRIME64_1
        h ^= k1
        h  = rotl(h, 27) * PRIME64_1 + PRIME64_4
        avalanche(h)
    """
    shape = key[0].shape
    p1 = b64.from_py(PRIME64_1, shape)
    p2 = b64.from_py(PRIME64_2, shape)
    p3 = b64.from_py(PRIME64_3, shape)
    p4 = b64.from_py(PRIME64_4, shape)

    h = b64.from_py((seed + PRIME64_5 + 8) & ((1 << 64) - 1), shape)
    k1 = b64.mul(key, p2)
    k1 = b64.rotl(k1, 31)
    k1 = b64.mul(k1, p1)
    h = b64.xor(h, k1)
    h = b64.add(b64.mul(b64.rotl(h, 27), p1), p4)
    # Avalanche.
    h = b64.xor(h, b64.shr(h, 33))
    h = b64.mul(h, p2)
    h = b64.xor(h, b64.shr(h, 29))
    h = b64.mul(h, p3)
    h = b64.xor(h, b64.shr(h, 32))
    return h


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer — full-avalanche mix on uint32."""
    x = jnp.asarray(x, jnp.uint32)
    x ^= x >> 16
    x = x * _U32(0x85EBCA6B)
    x ^= x >> 13
    x = x * _U32(0xC2B2AE35)
    x ^= x >> 16
    return x


def fmix32_pair(key: b64.U64) -> b64.U64:
    """TPU-native 64-bit-ish mix: two dependent fmix32 passes.

    Produces (hi, lo) with hi/lo each full-avalanche over both input words.
    Cheaper than emulated xxHash64 (no 16-bit-limb multiplies); the empirical
    FPR benchmark (§5.3 analogue) shows it matches xxHash64 quality for the
    filter's purposes.
    """
    hi_in, lo_in = key
    a = fmix32(lo_in ^ fmix32(hi_in ^ _U32(0x9E3779B9)))
    b = fmix32(hi_in ^ fmix32(lo_in + _U32(0x85EBCA6B)) ^ a)
    return (b, a)


def hash_key(keys: jnp.ndarray, kind: str = "xxhash64", seed: int = 0) -> b64.U64:
    """Hash uint32[..., 2] keys -> (hi, lo) digest pair."""
    k = keys_to_u64(keys)
    if kind == "xxhash64":
        return xxhash64_u64(k, seed=seed)
    if kind == "fmix32":
        if seed:
            k = (k[0] ^ _U32(seed & 0xFFFFFFFF), k[1] ^ _U32((seed >> 32) & 0xFFFFFFFF))
        return fmix32_pair(k)
    raise ValueError(f"unknown hash kind: {kind!r}")


# ---------------------------------------------------------------------------
# Pure-Python oracles (used by tests; operate on Python ints).
# ---------------------------------------------------------------------------

def _rotl64_py(x: int, r: int) -> int:
    x &= (1 << 64) - 1
    return ((x << r) | (x >> (64 - r))) & ((1 << 64) - 1)


def xxhash64_py(key: int, seed: int = 0) -> int:
    """Reference xxHash64 for an 8-byte little-endian input (Python ints)."""
    mask = (1 << 64) - 1
    h = (seed + PRIME64_5 + 8) & mask
    k1 = (key * PRIME64_2) & mask
    k1 = _rotl64_py(k1, 31)
    k1 = (k1 * PRIME64_1) & mask
    h ^= k1
    h = (_rotl64_py(h, 27) * PRIME64_1 + PRIME64_4) & mask
    h ^= h >> 33
    h = (h * PRIME64_2) & mask
    h ^= h >> 29
    h = (h * PRIME64_3) & mask
    h ^= h >> 32
    return h


def fmix32_py(x: int) -> int:
    m = 0xFFFFFFFF
    x &= m
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & m
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & m
    x ^= x >> 16
    return x
