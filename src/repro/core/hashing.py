"""Key hashing for the Cuckoo filter (paper §4.3 step 1).

The paper hashes each 64-bit key with xxHash64, then splits the digest:
upper 32 bits derive the fingerprint, lower 32 bits the primary bucket index
("Distinct hash parts are used to avoid fingerprint clustering").

We provide:

* ``xxhash64_u64``  — bit-exact xxHash64 of a single 8-byte key (the paper's
  configuration: keys are uint64), on emulated u64 arithmetic (TPU-native).
* ``fmix32_pair``   — a cheaper TPU-native path: two chained murmur3 finalizers
  over the (hi, lo) words. Used as the beyond-paper default where bit-parity
  with the CUDA library is not required.

Keys everywhere in this library are ``uint32[..., 2]`` arrays laid out as
``[..., 0] = lo, [..., 1] = hi`` (no x64 mode required; TPU friendly).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import bits64 as b64

# xxHash64 primes.
PRIME64_1 = 0x9E3779B185EBCA87
PRIME64_2 = 0xC2B2AE3D4F118CB1
PRIME64_3 = 0x165667B19E3779F9
PRIME64_4 = 0x85EBCA77C2B2AE63
PRIME64_5 = 0x27D4EB2F165667C5

_U32 = np.uint32


def keys_to_u64(keys: jnp.ndarray) -> b64.U64:
    """uint32[..., 2] (lo, hi) -> U64 pair."""
    keys = jnp.asarray(keys, jnp.uint32)
    return (keys[..., 1], keys[..., 0])


def keys_from_numpy(arr: np.ndarray) -> np.ndarray:
    """Host helper: uint64 numpy array -> uint32[..., 2] (lo, hi)."""
    arr = np.asarray(arr, np.uint64)
    out = np.empty(arr.shape + (2,), np.uint32)
    out[..., 0] = (arr & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    out[..., 1] = (arr >> np.uint64(32)).astype(np.uint32)
    return out


def keys_to_numpy(keys) -> np.ndarray:
    """Host helper: uint32[..., 2] (lo, hi) -> uint64 numpy array.

    Exact inverse of :func:`keys_from_numpy` — the one key-normalization
    helper shared by every host-side consumer (the Python oracle, the AMQ
    adapters, the service front-end), so the packing convention cannot
    drift between them.
    """
    arr = np.asarray(keys, np.uint32)
    return (arr[..., 0].astype(np.uint64)
            | (arr[..., 1].astype(np.uint64) << np.uint64(32)))


def xxhash64_u64(key: b64.U64, seed: int = 0) -> b64.U64:
    """xxHash64 of a single 64-bit lane (length-8 input), bit exact.

    Mirrors the reference implementation specialised to len==8:
        h  = seed + PRIME64_5 + 8
        k1 = rotl(key * PRIME64_2, 31) * PRIME64_1
        h ^= k1
        h  = rotl(h, 27) * PRIME64_1 + PRIME64_4
        avalanche(h)
    """
    shape = key[0].shape
    p1 = b64.from_py(PRIME64_1, shape)
    p2 = b64.from_py(PRIME64_2, shape)
    p3 = b64.from_py(PRIME64_3, shape)
    p4 = b64.from_py(PRIME64_4, shape)

    h = b64.from_py((seed + PRIME64_5 + 8) & ((1 << 64) - 1), shape)
    k1 = b64.mul(key, p2)
    k1 = b64.rotl(k1, 31)
    k1 = b64.mul(k1, p1)
    h = b64.xor(h, k1)
    h = b64.add(b64.mul(b64.rotl(h, 27), p1), p4)
    # Avalanche.
    h = b64.xor(h, b64.shr(h, 33))
    h = b64.mul(h, p2)
    h = b64.xor(h, b64.shr(h, 29))
    h = b64.mul(h, p3)
    h = b64.xor(h, b64.shr(h, 32))
    return h


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer — full-avalanche mix on uint32."""
    x = jnp.asarray(x, jnp.uint32)
    x ^= x >> 16
    x = x * _U32(0x85EBCA6B)
    x ^= x >> 13
    x = x * _U32(0xC2B2AE35)
    x ^= x >> 16
    return x


def fmix32_pair(key: b64.U64) -> b64.U64:
    """TPU-native 64-bit-ish mix: two dependent fmix32 passes.

    Produces (hi, lo) with hi/lo each full-avalanche over both input words.
    Cheaper than emulated xxHash64 (no 16-bit-limb multiplies); the empirical
    FPR benchmark (§5.3 analogue) shows it matches xxHash64 quality for the
    filter's purposes.
    """
    hi_in, lo_in = key
    a = fmix32(lo_in ^ fmix32(hi_in ^ _U32(0x9E3779B9)))
    b = fmix32(hi_in ^ fmix32(lo_in + _U32(0x85EBCA6B)) ^ a)
    return (b, a)


def hash_key(keys: jnp.ndarray, kind: str = "xxhash64", seed: int = 0) -> b64.U64:
    """Hash uint32[..., 2] keys -> (hi, lo) digest pair."""
    k = keys_to_u64(keys)
    if kind == "xxhash64":
        return xxhash64_u64(k, seed=seed)
    if kind == "fmix32":
        if seed:
            k = (k[0] ^ _U32(seed & 0xFFFFFFFF), k[1] ^ _U32((seed >> 32) & 0xFFFFFFFF))
        return fmix32_pair(k)
    raise ValueError(f"unknown hash kind: {kind!r}")


# ---------------------------------------------------------------------------
# Pure-Python oracles (used by tests; operate on Python ints).
# ---------------------------------------------------------------------------

def _rotl64_py(x: int, r: int) -> int:
    x &= (1 << 64) - 1
    return ((x << r) | (x >> (64 - r))) & ((1 << 64) - 1)


def xxhash64_py(key: int, seed: int = 0) -> int:
    """Reference xxHash64 for an 8-byte little-endian input (Python ints)."""
    mask = (1 << 64) - 1
    h = (seed + PRIME64_5 + 8) & mask
    k1 = (key * PRIME64_2) & mask
    k1 = _rotl64_py(k1, 31)
    k1 = (k1 * PRIME64_1) & mask
    h ^= k1
    h = (_rotl64_py(h, 27) * PRIME64_1 + PRIME64_4) & mask
    h ^= h >> 33
    h = (h * PRIME64_2) & mask
    h ^= h >> 29
    h = (h * PRIME64_3) & mask
    h ^= h >> 32
    return h


def fmix32_py(x: int) -> int:
    m = 0xFFFFFFFF
    x &= m
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & m
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & m
    x ^= x >> 16
    return x
