"""Batch-parallel Cuckoo filter — the paper's core contribution in JAX.

Faithful mapping of Cuckoo-GPU's Algorithms 1–3 to the TPU execution model
(see DESIGN.md §2 for the full adaptation table):

* The GPU runs one CUDA thread per key and synchronises with word-granular
  atomic CAS. Here one *batch* of keys advances in lock-step rounds inside a
  ``lax.while_loop``; within a round every key proposes a write to a 32-bit
  table word, and conflicts are resolved **per word** by a deterministic
  priority rule (lowest batch index wins — the batch-synchronous analogue of
  a CAS winner). Losers re-scan and retry next round, exactly like the
  paper's reload-on-CAS-failure loops.
* Eviction follows Alg. 1 phase 2: a stuck key picks a pseudo-random victim,
  swaps in, and carries the displaced tag to that tag's alternate bucket.
  With ``eviction="bfs"`` the §4.6.1 heuristic is used instead: inspect up to
  b/2 victims, relocate one whose alternate bucket has a free slot (a
  two-word transaction committed only if both word claims are won).
* Queries are read-only gathers + SWAR-style matching, trivially parallel.

Every operation is a pure function of ``(config, state, keys)`` and is
jit-compatible with ``config`` static; state is a small pytree so filters can
live inside larger jitted programs (data pipelines, serving engines) and be
checkpointed like any other state.

Progress guarantee: claims are resolved by (address, batch-index) priority,
so the lowest-indexed pending key always wins every word it touches; each
round therefore commits at least one action and the round loop terminates.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layout as L
from .hashing import fmix32, hash_key, normalize_keys
from .policies import make_policy

_U32 = np.uint32
_GOLDEN = _U32(0x9E3779B9)


class CuckooState(NamedTuple):
    """Filter state — a pytree of device arrays."""

    table: jnp.ndarray   # uint32[num_words] packed fingerprints
    count: jnp.ndarray   # int32[] stored-fingerprint count


class InsertStats(NamedTuple):
    """Per-key insertion statistics (feeds the Fig. 5/6 benchmarks).

    ``failed``/``load`` are the loud failure report: callers that drop the
    ``ok`` mask still get an explicit count of keys the engine could not
    place (table effectively full — grow or rebuild) plus the post-batch
    load factor that explains *why*. :meth:`CuckooFilter.insert` turns a
    non-zero ``failed`` into a ``RuntimeWarning``.
    """

    evictions: jnp.ndarray  # int32[n] eviction-chain length per key
    rounds: jnp.ndarray     # int32[]  rounds the batch loop ran
    failed: jnp.ndarray     # int32[]  valid keys left unplaced (failures)
    load: jnp.ndarray       # float32[] post-batch load factor


@dataclasses.dataclass(frozen=True)
class CuckooConfig:
    """Static filter configuration (hashable; safe as a jit static arg).

    Defaults follow the paper's GPU configuration: 16-bit fingerprints,
    bucket size 16, XOR placement, xxHash64, BFS eviction.
    """

    num_buckets: int
    fp_bits: int = 16
    bucket_size: int = 16
    policy: str = "xor"          # "xor" | "offset"   (§4.6.2)
    hash_kind: str = "xxhash64"  # "xxhash64" | "fmix32"
    eviction: str = "bfs"        # "bfs" | "dfs"      (§4.6.1)
    max_evictions: int = 64
    max_rounds: Optional[int] = None
    seed: int = 0
    # High-load insertion engine (DESIGN.md §14):
    #   "auto"        — insert_bulk takes the graph-orientation bulk build;
    #                   incremental insert takes the batched BFS frontier
    #                   when eviction == "bfs", else the legacy round loop.
    #   "legacy"      — the original lock-step eviction round loop.
    #   "frontier"    — fixed-depth batched BFS frontier search.
    #   "orientation" — graph-orientation bulk build (+ round-loop residue).
    insert_engine: str = "auto"
    frontier_depth: int = 2      # chain hops per frontier commit (>= 1)
    # Max edge-flip sweeps before committing. Small on purpose: the
    # two-phase commit gives every edge a second chance on its opposite
    # bucket and the residue loop can truly evict, so a handful of sweeps
    # already reaches zero failures at 0.95 load — extra sweeps only
    # oscillate on contended buckets and cost wall-clock.
    orient_sweeps: int = 4

    @property
    def layout(self) -> L.BucketLayout:
        return L.BucketLayout(self.num_buckets, self.bucket_size, self.fp_bits)

    @property
    def placement(self):
        return make_policy(self.policy, self.num_buckets, self.fp_bits)

    @property
    def num_slots(self) -> int:
        return self.layout.num_slots

    @property
    def table_bytes(self) -> int:
        return self.layout.table_bytes

    @property
    def effective_fp_bits(self) -> int:
        return self.placement.effective_fp_bits

    def expected_fpr(self, load_factor: float) -> float:
        """Paper Eq. (4): eps ~= 1 - (1 - 2^-f)^(2 b alpha)."""
        f = self.effective_fp_bits
        return 1.0 - (1.0 - 2.0 ** -f) ** (2 * self.bucket_size * load_factor)

    def init(self) -> CuckooState:
        return CuckooState(self.layout.empty_table(), jnp.zeros((), jnp.int32))

    @staticmethod
    def for_capacity(
        capacity: int,
        load_factor: float = 0.95,
        fp_bits: int = 16,
        bucket_size: int = 16,
        policy: str = "xor",
        **kw,
    ) -> "CuckooConfig":
        """Size a filter for ``capacity`` items at a target load factor.

        With the XOR policy the bucket count is rounded up to a power of two
        (paper's over-provisioning problem); the OFFSET policy sizes exactly
        (§4.6.2's motivation).
        """
        buckets = max(2, int(np.ceil(capacity / (load_factor * bucket_size))))
        if policy == "xor":
            buckets = 1 << int(np.ceil(np.log2(buckets)))
        return CuckooConfig(
            num_buckets=buckets, fp_bits=fp_bits, bucket_size=bucket_size,
            policy=policy, **kw)


# ---------------------------------------------------------------------------
# Key preparation (Alg. 1 lines 2-5).
# ---------------------------------------------------------------------------

def prepare_keys(config: CuckooConfig, keys: jnp.ndarray):
    """keys uint32[n, 2] -> (base_tag, i1, i2), all uint32[n]."""
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    pol = config.placement
    tag = pol.make_tag(hi)           # fingerprint from the upper hash word
    i1, i2 = pol.initial_buckets(lo, tag)  # bucket index from the lower word
    return tag, i1, i2


def _prng(x: jnp.ndarray, salt: jnp.ndarray) -> jnp.ndarray:
    """Deterministic per-key pseudo-randomness (fingerprint-derived, like the
    paper's tag-based starts; salted by the round counter to break livelock)."""
    return fmix32(x ^ (salt.astype(jnp.uint32) * _GOLDEN + _U32(1)))


# ---------------------------------------------------------------------------
# Word-claim resolution: the batch-synchronous CAS.
# ---------------------------------------------------------------------------

def _resolve_claims(addr1: jnp.ndarray, addr2: jnp.ndarray, invalid: int):
    """Per-word winner election.

    addr1/addr2: int32[n] flat word addresses (``invalid`` = no claim).
    Returns (win1, win2): bool[n] — whether this key won each address.
    Winner of an address = lowest (batch index, claim slot) touching it,
    which guarantees the lowest pending key wins all of its claims.
    """
    n = addr1.shape[0]
    flat = jnp.stack([addr1, addr2], axis=1).reshape(-1)        # interleaved
    order = jnp.argsort(flat, stable=True)
    sa = flat[order]
    first = jnp.concatenate([jnp.ones((1,), bool), sa[1:] != sa[:-1]])
    win_sorted = first & (sa != invalid)
    win_flat = jnp.zeros((2 * n,), bool).at[order].set(win_sorted)
    return win_flat[0::2], win_flat[1::2]


def _resolve_claims_multi(addrs: jnp.ndarray, invalid: int) -> jnp.ndarray:
    """K-column generalisation of :func:`_resolve_claims`.

    addrs: int32[n, K] flat word addresses (``invalid`` = no claim).
    Returns win: bool[n, K]. Claims are interleaved so the flat priority of
    key ``i``'s column ``k`` is ``i * K + k`` — the lowest pending key with
    any action still wins *all* of its claims, preserving the round-loop
    progress guarantee for multi-word transactions (frontier chains).
    """
    n, k = addrs.shape
    flat = addrs.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    sa = flat[order]
    first = jnp.concatenate([jnp.ones((1,), bool), sa[1:] != sa[:-1]])
    win_sorted = first & (sa != invalid)
    win = jnp.zeros((n * k,), bool).at[order].set(win_sorted)
    return win.reshape(n, k)


def _masked_write(table, addr, desired, mask, invalid):
    a = jnp.where(mask, addr, invalid)
    return table.at[a].set(desired, mode="drop")


def _batch_dedup(keys: jnp.ndarray, valid: jnp.ndarray):
    """First-occurrence mask + representative index for duplicated batches.

    Returns (first: bool[n], rep: int32[n]): ``first[i]`` marks the earliest
    occurrence of key i's 64-bit value among *valid* entries (``rep[i]`` is
    that occurrence's batch index; ``rep[i] == i`` for firsts). Valid keys
    sort ahead of invalid ones within a value run, so a padding key can never
    become the representative of a live duplicate.
    """
    n = keys.shape[0]
    lo, hi = keys[..., 0], keys[..., 1]
    inv = (~valid).astype(jnp.uint32)
    order = jnp.lexsort((inv, lo, hi))          # by (hi, lo), valid first
    lo_s, hi_s = lo[order], hi[order]
    first_s = jnp.concatenate([
        jnp.ones((1,), bool),
        (lo_s[1:] != lo_s[:-1]) | (hi_s[1:] != hi_s[:-1]),
    ])
    head_pos = jax.lax.cummax(
        jnp.where(first_s, jnp.arange(n, dtype=jnp.int32), 0))
    rep_s = order[head_pos].astype(jnp.int32)
    first = jnp.zeros((n,), bool).at[order].set(first_s)
    rep = jnp.zeros((n,), jnp.int32).at[order].set(rep_s)
    return first, rep


# ---------------------------------------------------------------------------
# Insertion (Alg. 1 + §4.6.1 BFS).
# ---------------------------------------------------------------------------

# Action codes for a round.
_DIRECT, _EVICT, _RELOC = 0, 1, 2


def _insert_rounds(
    config: CuckooConfig, state: CuckooState, keys: jnp.ndarray,
    valid: Optional[jnp.ndarray] = None,
    *, dedup_within_batch: bool = False,
) -> Tuple[CuckooState, jnp.ndarray, InsertStats]:
    """The legacy lock-step eviction round loop (Alg. 1 + §4.6.1 BFS).

    Kept reachable via ``insert_engine="legacy"`` — it is the oracle the
    new engines are differentially tested against, and the benchmark
    baseline the frontier/orientation rows are compared with.
    """
    lay = config.layout
    pol = config.placement
    n = keys.shape[0]
    invalid = lay.num_words  # out-of-range sentinel (dropped by scatter)
    b = config.bucket_size
    wpb = lay.words_per_bucket
    n_cand = max(1, b // 2)  # BFS inspects up to half the bucket (§4.6.1)
    use_bfs = config.eviction == "bfs"
    max_rounds = config.max_rounds or (4 * config.max_evictions + 64)

    base_tag, i1, i2 = prepare_keys(config, keys)
    tag1 = pol.place_tag(base_tag, jnp.zeros((n,), bool))   # stored form @ i1
    tag2 = pol.place_tag(base_tag, jnp.ones((n,), bool))    # stored form @ i2

    def gather_words(table, bucket):
        return L.gather_bucket_words(table, bucket, lay)

    def round_fn(carry):
        (table, count, cur_tag, cur_bucket, evict_mode, pending, success,
         n_evict, rnd) = carry

        # --- expire keys whose eviction budget ran out (Alg. 1 line 24).
        failed = pending & (n_evict >= config.max_evictions) & evict_mode
        pending = pending & ~failed

        # --- scan phase: fresh keys look at (i1, i2); evicting keys look at
        #     their current bucket only (Alg. 1 line 22).
        bucketA = jnp.where(evict_mode, cur_bucket, i1)
        wordsA = gather_words(table, bucketA)                  # [n, wpb]
        wordsB = gather_words(table, i2)                       # [n, wpb]
        tagsA = L.unpack_words(wordsA, lay.fp_bits)            # [n, b]
        tagsB = L.unpack_words(wordsB, lay.fp_bits)

        scan_tag = jnp.where(evict_mode, cur_tag, base_tag)
        start = L.scan_start(scan_tag, lay)
        foundA, slotA = L.first_true_circular(tagsA == 0, start)
        foundB, slotB = L.first_true_circular(tagsB == 0, start)
        foundB = foundB & ~evict_mode

        direct_found = foundA | foundB
        d_bucket = jnp.where(foundA, bucketA, i2)
        d_slot = jnp.where(foundA, slotA, slotB)
        d_tag = jnp.where(
            evict_mode, cur_tag, jnp.where(foundA, tag1, tag2))
        d_widx, d_sw = L.slot_to_word(d_slot, lay)
        d_words = jnp.where(foundA[:, None], wordsA, wordsB)
        d_word = jnp.take_along_axis(d_words, d_widx[:, None], axis=1)[:, 0]
        d_desired = L.replace_tag(d_word, d_sw, d_tag, lay.fp_bits)
        d_addr = L.word_addr(d_bucket, d_widx, lay)

        # --- eviction phase for keys whose candidate bucket(s) are full.
        needs_evict = pending & ~direct_found
        # Fresh keys choose a random bucket to evict from (Alg. 1 line 8).
        coin = (_prng(base_tag, rnd) & _U32(1)).astype(bool)
        e_bucket = jnp.where(evict_mode, cur_bucket,
                             jnp.where(coin, i2, i1))
        e_tag = jnp.where(evict_mode, cur_tag,
                          jnp.where(coin, tag2, tag1))
        e_words = jnp.where(
            evict_mode[:, None] | ~coin[:, None], wordsA, wordsB)
        e_tags = jnp.where(
            evict_mode[:, None] | ~coin[:, None], tagsA, tagsB)

        def eviction_actions(_):
            # DFS victim (also the BFS fallback): pseudo-random occupied slot.
            vic = (_prng(e_tag ^ e_bucket, rnd) % _U32(b)).astype(jnp.int32)

            if use_bfs:
                # §4.6.1: inspect n_cand candidates starting at a prng offset;
                # relocate the first whose alternate bucket has a free slot.
                cstart = (_prng(e_tag, rnd + 1) % _U32(b)).astype(jnp.int32)
                cslots = (cstart[:, None]
                          + jnp.arange(n_cand, dtype=jnp.int32)) % b  # [n,c]
                ctags = jnp.take_along_axis(e_tags, cslots, axis=1)   # [n,c]
                calt = pol.alt_bucket(e_bucket[:, None], ctags)       # [n,c]
                cwords = gather_words(table, calt)                # [n,c,wpb]
                cfree = L.unpack_words(cwords, lay.fp_bits) == 0  # [n,c,b]
                reloc_tag = pol.on_relocate(ctags)
                fstart = L.scan_start(reloc_tag, lay)
                cfound, cslot_dst = L.first_true_circular(cfree, fstart)
                has_viable = jnp.any(cfound, axis=1)
                jstar = jnp.argmax(cfound, axis=1).astype(jnp.int32)

                take = lambda a: jnp.take_along_axis(
                    a, jstar[:, None], axis=1)[:, 0]
                r_src_slot = take(cslots)
                r_tag = take(ctags)
                r_reloc = take(reloc_tag)
                r_dst_bucket = take(calt)
                r_dst_slot = take(cslot_dst)
                r_dst_words = jnp.take_along_axis(
                    cwords, jstar[:, None, None], axis=1)[:, 0]   # [n, wpb]

                dst_widx, dst_sw = L.slot_to_word(r_dst_slot, lay)
                dst_word = jnp.take_along_axis(
                    r_dst_words, dst_widx[:, None], axis=1)[:, 0]
                dst_desired = L.replace_tag(dst_word, dst_sw, r_reloc,
                                            lay.fp_bits)
                dst_addr = L.word_addr(r_dst_bucket, dst_widx, lay)

                src_widx, src_sw = L.slot_to_word(r_src_slot, lay)
                src_word = jnp.take_along_axis(
                    e_words, src_widx[:, None], axis=1)[:, 0]
                src_desired = L.replace_tag(src_word, src_sw, e_tag,
                                            lay.fp_bits)
                src_addr = L.word_addr(e_bucket, src_widx, lay)

                # Same-word transaction: compose both lane updates into one
                # write (the batch analogue of the paper's two-step relocation
                # with CAS-failure compensation — impossible to half-apply).
                same = src_addr == dst_addr
                merged = L.replace_tag(
                    L.replace_tag(src_word, dst_sw, r_reloc, lay.fp_bits),
                    src_sw, e_tag, lay.fp_bits)
                src_desired = jnp.where(same, merged, src_desired)
                dst_addr = jnp.where(same, invalid, dst_addr)

                # Fall back to DFS-evicting the last inspected candidate.
                vic_bfs = (cstart + (n_cand - 1)) % b
                vic = jnp.where(has_viable, vic, vic_bfs)
            else:
                has_viable = jnp.zeros((n,), bool)
                src_addr = jnp.full((n,), invalid, jnp.int32)
                src_desired = jnp.zeros((n,), jnp.uint32)
                dst_addr = jnp.full((n,), invalid, jnp.int32)
                dst_desired = jnp.zeros((n,), jnp.uint32)

            # DFS eviction action (Alg. 1 lines 10-21).
            v_widx, v_sw = L.slot_to_word(vic, lay)
            v_word = jnp.take_along_axis(e_words, v_widx[:, None], axis=1)[:, 0]
            v_desired = L.replace_tag(v_word, v_sw, e_tag, lay.fp_bits)
            v_evicted = L.extract_tag(v_word, v_sw, lay.fp_bits)
            v_addr = L.word_addr(e_bucket, v_widx, lay)

            return (has_viable, src_addr, src_desired, dst_addr, dst_desired,
                    v_addr, v_desired, v_evicted)

        def no_eviction(_):
            z32 = jnp.zeros((n,), jnp.uint32)
            inv = jnp.full((n,), invalid, jnp.int32)
            return (jnp.zeros((n,), bool), inv, z32, inv, z32, inv, z32, z32)

        (has_viable, r_src_addr, r_src_desired, r_dst_addr, r_dst_desired,
         v_addr, v_desired, v_evicted) = jax.lax.cond(
            jnp.any(needs_evict), eviction_actions, no_eviction, None)

        # --- assemble one action per pending key.
        is_reloc = needs_evict & has_viable
        is_evict = needs_evict & ~has_viable
        is_direct = pending & direct_found

        addr1 = jnp.where(is_direct, d_addr,
                          jnp.where(is_reloc, r_src_addr,
                                    jnp.where(is_evict, v_addr, invalid)))
        desired1 = jnp.where(is_direct, d_desired,
                             jnp.where(is_reloc, r_src_desired, v_desired))
        addr2 = jnp.where(is_reloc, r_dst_addr, invalid)
        addr1 = jnp.where(pending, addr1, invalid)
        addr2 = jnp.where(pending, addr2, invalid)

        win1, win2 = _resolve_claims(addr1, addr2, invalid)
        has2 = addr2 != invalid
        commit = pending & win1 & (win2 | ~has2) & (addr1 != invalid)

        # --- apply winning writes.
        table = _masked_write(table, addr1, desired1, commit, invalid)
        table = _masked_write(table, addr2, r_dst_desired, commit & has2,
                              invalid)

        # --- state transitions.
        done = commit & (is_direct | is_reloc)
        success = success | done
        count = count + jnp.sum(done, dtype=jnp.int32)
        pending = pending & ~done

        did_evict = commit & is_evict
        new_cur_tag = pol.on_relocate(v_evicted)
        new_cur_bucket = pol.alt_bucket(e_bucket, v_evicted)
        cur_tag = jnp.where(did_evict, new_cur_tag, cur_tag)
        cur_bucket = jnp.where(did_evict, new_cur_bucket, cur_bucket)
        evict_mode = evict_mode | did_evict
        n_evict = n_evict + did_evict.astype(jnp.int32)

        return (table, count, cur_tag, cur_bucket, evict_mode, pending,
                success, n_evict, rnd + 1)

    def cond_fn(carry):
        pending, rnd = carry[5], carry[8]
        return jnp.any(pending) & (rnd < max_rounds)

    pending0 = jnp.ones((n,), bool) if valid is None else valid.astype(bool)
    valid0 = pending0
    if dedup_within_batch:
        first, rep = _batch_dedup(keys, valid0)
        pending0 = pending0 & first
    carry0 = (
        state.table, state.count,
        base_tag.astype(jnp.uint32),              # cur_tag (evict mode)
        i1.astype(jnp.uint32),                    # cur_bucket (evict mode)
        jnp.zeros((n,), bool),                    # evict_mode
        pending0,                                 # pending
        jnp.zeros((n,), bool),                    # success
        jnp.zeros((n,), jnp.int32),               # n_evict
        jnp.zeros((), jnp.int32),                 # round
    )
    out = jax.lax.while_loop(cond_fn, round_fn, carry0)
    (table, count, _, _, _, pending, success, n_evict, rnd) = out
    # Keys still pending at max_rounds are reported as failures.
    ok = success & ~pending
    if dedup_within_batch:
        ok = jnp.where(first, ok, ok[rep] & valid0)
    failed = jnp.sum(valid0 & ~ok, dtype=jnp.int32)
    load = count.astype(jnp.float32) / lay.num_slots
    return CuckooState(table, count), ok, InsertStats(n_evict, rnd, failed,
                                                      load)


# ---------------------------------------------------------------------------
# Batched BFS frontier insertion (DESIGN.md §14).
# ---------------------------------------------------------------------------

def _insert_frontier(
    config: CuckooConfig, state: CuckooState, keys: jnp.ndarray,
    valid: Optional[jnp.ndarray] = None,
    *, dedup_within_batch: bool = False,
) -> Tuple[CuckooState, jnp.ndarray, InsertStats]:
    """Fixed-depth, width-``bucket_size`` frontier search per round.

    Where the legacy loop advances one eviction hop per *global* round (the
    whole batch waits on the longest chain), a frontier round resolves an
    entire chain in one multi-word transaction: a stuck key picks a root
    bucket, treats each of its ``b`` occupied slots as a branch, expands
    the branch set one gather per depth level (all slots of every frontier
    bucket inspected at once), and commits the shortest free path found —
    up to ``frontier_depth + 1`` word writes, won all-or-nothing through
    the claim election. Chains therefore cost O(depth) data-parallel steps
    instead of O(chain length) rounds.

    A key whose shortest eviction chain exceeds ``frontier_depth`` can
    never commit here no matter how many salted retries it gets, so the
    round loop exits once a few consecutive rounds make no progress and
    the stragglers spill to the legacy round loop, which walks chains up
    to ``max_evictions`` — the frontier engine keeps the oracle's
    placement guarantees without paying its per-hop global rounds on the
    fast path.
    """
    lay = config.layout
    pol = config.placement
    n = keys.shape[0]
    invalid = lay.num_words
    b = config.bucket_size
    wpb = lay.words_per_bucket
    depth = max(1, config.frontier_depth)
    K = depth + 1  # claim columns: root write + one per chain hop
    max_rounds = config.max_rounds or (4 * config.max_evictions + 64)

    base_tag, i1, i2 = prepare_keys(config, keys)
    tag1 = pol.place_tag(base_tag, jnp.zeros((n,), bool))
    tag2 = pol.place_tag(base_tag, jnp.ones((n,), bool))

    def gather_words(table, bucket):
        return L.gather_bucket_words(table, bucket, lay)

    def round_fn(carry):
        table, count, pending, success, n_evict, rnd, stall = carry

        # --- direct phase: identical to the legacy scan of (i1, i2).
        words1 = gather_words(table, i1)                       # [n, wpb]
        words2 = gather_words(table, i2)
        tags_1 = L.unpack_words(words1, lay.fp_bits)           # [n, b]
        tags_2 = L.unpack_words(words2, lay.fp_bits)

        start = L.scan_start(base_tag, lay)
        found1, slot1 = L.first_true_circular(tags_1 == 0, start)
        found2, slot2 = L.first_true_circular(tags_2 == 0, start)
        direct_found = found1 | found2

        d_bucket = jnp.where(found1, i1, i2)
        d_slot = jnp.where(found1, slot1, slot2)
        d_tag = jnp.where(found1, tag1, tag2)
        d_widx, d_sw = L.slot_to_word(d_slot, lay)
        d_words = jnp.where(found1[:, None], words1, words2)
        d_word = jnp.take_along_axis(d_words, d_widx[:, None], axis=1)[:, 0]
        d_desired = L.replace_tag(d_word, d_sw, d_tag, lay.fp_bits)
        d_addr = L.word_addr(d_bucket, d_widx, lay)

        is_direct = pending & direct_found
        needs_chain = pending & ~direct_found

        def frontier_actions(_):
            # Both candidate buckets are full for every chaining key, so the
            # root (picked by a salted coin) is a full bucket: each of its b
            # occupied slots seeds one branch of the frontier.
            coin = (_prng(base_tag, rnd) & _U32(1)).astype(bool)
            e_bucket = jnp.where(coin, i2, i1)
            e_tag = jnp.where(coin, tag2, tag1)
            e_words = jnp.where(coin[:, None], words2, words1)
            e_tags = jnp.where(coin[:, None], tags_2, tags_1)

            branch = jnp.broadcast_to(
                jnp.arange(b, dtype=jnp.int32), (n, b))
            # Lanes the chain displaces so far — the cycle guard kills any
            # branch whose next victim revisits one (a revisit would make
            # two writes race on one lane and silently drop a resident tag).
            pos_b = [jnp.broadcast_to(
                e_bucket.astype(jnp.int32)[:, None], (n, b))]
            pos_s = [branch]
            move = pol.on_relocate(e_tags)          # tag entering level 1
            nxt = pol.alt_bucket(e_bucket[:, None], e_tags)        # [n, b]
            alive = jnp.ones((n, b), bool)
            lv_bucket, lv_words, lv_found, lv_slot, lv_move, lv_vic = (
                [], [], [], [], [], [])
            for d in range(1, depth + 1):
                wds = gather_words(table, nxt)                 # [n, b, wpb]
                tgs = L.unpack_words(wds, lay.fp_bits)         # [n, b, b]
                fnd, fslot = L.first_true_circular(
                    tgs == 0, L.scan_start(move, lay))
                fnd = fnd & alive
                lv_bucket.append(nxt)
                lv_words.append(wds)
                lv_found.append(fnd)
                lv_slot.append(fslot)
                lv_move.append(move)
                if d < depth:
                    vic = (_prng(move ^ nxt.astype(jnp.uint32), rnd + d)
                           % _U32(b)).astype(jnp.int32)        # [n, b]
                    clash = jnp.zeros((n, b), bool)
                    for pb, ps in zip(pos_b, pos_s):
                        clash = clash | ((pb == nxt.astype(jnp.int32))
                                         & (ps == vic))
                    alive = alive & ~clash
                    pos_b.append(nxt.astype(jnp.int32))
                    pos_s.append(vic)
                    lv_vic.append(vic)
                    vtag = jnp.take_along_axis(
                        tgs, vic[:, :, None], axis=2)[:, :, 0]
                    move = pol.on_relocate(vtag)
                    nxt = pol.alt_bucket(nxt, vtag)

            # Shortest free path: first level with any live branch found.
            taken = jnp.zeros((n,), bool)
            use_lv = []
            for fnd in lv_found:
                fa = jnp.any(fnd, axis=1)
                use_lv.append(fa & ~taken)
                taken = taken | fa
            has_chain = needs_chain & taken
            jstar = jnp.zeros((n,), jnp.int32)
            depth_star = jnp.zeros((n,), jnp.int32)
            for d in reversed(range(depth)):
                jd = jnp.argmax(lv_found[d], axis=1).astype(jnp.int32)
                jstar = jnp.where(use_lv[d], jd, jstar)
                depth_star = jnp.where(use_lv[d], d + 1, depth_star)
            depth_star = jnp.where(has_chain, depth_star, 0)

            take1 = lambda a, j: jnp.take_along_axis(
                a, j[:, None], axis=1)[:, 0]
            take2 = lambda a, j: jnp.take_along_axis(
                a, j[:, None, None], axis=1)[:, 0]

            # Column 0: the root slot receives the key's own tag.
            r_widx, r_sw = L.slot_to_word(jstar, lay)
            r_word = jnp.take_along_axis(
                e_words, r_widx[:, None], axis=1)[:, 0]
            r_addr = L.word_addr(e_bucket, r_widx, lay)
            addrs = [jnp.where(has_chain, r_addr, invalid)]
            sws, wtags, cwords = [r_sw], [e_tag], [r_word]

            # Columns 1..depth: hop t shifts the displaced tag one level
            # deeper; the final hop lands it in the free slot found there.
            for t in range(1, depth + 1):
                lvl = t - 1
                bkt = take1(lv_bucket[lvl], jstar)
                wds = take2(lv_words[lvl], jstar)              # [n, wpb]
                mv = take1(lv_move[lvl], jstar)
                lane_free = take1(lv_slot[lvl], jstar)
                lane_vic = (take1(lv_vic[lvl], jstar) if t < depth
                            else jnp.zeros((n,), jnp.int32))
                lane = jnp.where(depth_star == t, lane_free, lane_vic)
                used = has_chain & (depth_star >= t)
                widx, sw = L.slot_to_word(lane, lay)
                word = jnp.take_along_axis(wds, widx[:, None], axis=1)[:, 0]
                addr = L.word_addr(bkt, widx, lay)
                addrs.append(jnp.where(used, addr, invalid))
                sws.append(sw)
                wtags.append(mv)
                cwords.append(word)

            A = jnp.stack(addrs, axis=1)                       # [n, K]
            # Same-word composition: every write of the chain that targets
            # this address folds into one desired word (all lanes distinct
            # by the cycle guard, so the fold order is immaterial).
            desired = []
            for k in range(K):
                w = cwords[k]
                for j in range(K):
                    hit = (A[:, j] == A[:, k]) & (A[:, j] != invalid)
                    w = jnp.where(
                        hit, L.replace_tag(w, sws[j], wtags[j], lay.fp_bits),
                        w)
                desired.append(w)
            # Only the last claim per duplicated address scatters (it holds
            # the fully-composed word); earlier duplicates drop out.
            scat = []
            for k in range(K):
                superseded = jnp.zeros((n,), bool)
                for j in range(k + 1, K):
                    superseded = superseded | (A[:, j] == A[:, k])
                scat.append(jnp.where(superseded, invalid, A[:, k]))
            return (has_chain, jnp.stack(scat, axis=1),
                    jnp.stack(desired, axis=1), depth_star)

        def no_chain(_):
            return (jnp.zeros((n,), bool),
                    jnp.full((n, K), invalid, jnp.int32),
                    jnp.zeros((n, K), jnp.uint32),
                    jnp.zeros((n,), jnp.int32))

        has_chain, c_addrs, c_desired, depth_star = jax.lax.cond(
            jnp.any(needs_chain), frontier_actions, no_chain, None)

        # --- one claim matrix for the whole batch: direct keys use column
        #     0 alone; chain keys use their (deduped) chain columns.
        addr0 = jnp.where(is_direct, d_addr, c_addrs[:, 0])
        des0 = jnp.where(is_direct, d_desired, c_desired[:, 0])
        all_addrs = jnp.concatenate([addr0[:, None], c_addrs[:, 1:]], axis=1)
        all_des = jnp.concatenate([des0[:, None], c_desired[:, 1:]], axis=1)
        all_addrs = jnp.where(pending[:, None], all_addrs, invalid)

        win = _resolve_claims_multi(all_addrs, invalid)
        valid_claim = all_addrs != invalid
        has_action = is_direct | (pending & has_chain)
        commit = has_action & jnp.all(win | ~valid_claim, axis=1)

        for k in range(K):
            table = _masked_write(table, all_addrs[:, k], all_des[:, k],
                                  commit & valid_claim[:, k], invalid)

        success = success | commit
        count = count + jnp.sum(commit, dtype=jnp.int32)
        pending = pending & ~commit
        n_evict = n_evict + jnp.where(commit, depth_star, 0)
        stall = jnp.where(jnp.any(commit), jnp.int32(0), stall + 1)
        return table, count, pending, success, n_evict, rnd + 1, stall

    # Consecutive no-commit rounds before giving up on the frontier: each
    # round re-salts the coin and the victim lanes, so a handful of
    # retries resolves transient claim contention — anything still stuck
    # after that is depth-limited and belongs to the residue loop.
    stall_limit = jnp.int32(8)

    def cond_fn(carry):
        return (jnp.any(carry[2]) & (carry[5] < max_rounds)
                & (carry[6] < stall_limit))

    pending0 = jnp.ones((n,), bool) if valid is None else valid.astype(bool)
    valid0 = pending0
    if dedup_within_batch:
        first, rep = _batch_dedup(keys, valid0)
        pending0 = pending0 & first
    carry0 = (state.table, state.count, pending0,
              jnp.zeros((n,), bool), jnp.zeros((n,), jnp.int32),
              jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    table, count, pending, success, n_evict, rnd, _ = jax.lax.while_loop(
        cond_fn, round_fn, carry0)

    # Residue: chains longer than ``depth`` (or claim-starved stragglers)
    # take the legacy eviction loop — a no-op when nothing is pending.
    state2, ok_res, res_stats = _insert_rounds(
        config, CuckooState(table, count), keys, valid=pending)

    ok = (success & ~pending) | ok_res
    if dedup_within_batch:
        ok = jnp.where(first, ok, ok[rep] & valid0)
    failed = jnp.sum(valid0 & ~ok, dtype=jnp.int32)
    load = state2.count.astype(jnp.float32) / lay.num_slots
    stats = InsertStats(n_evict + res_stats.evictions,
                        rnd + res_stats.rounds, failed, load)
    return state2, ok, stats


# ---------------------------------------------------------------------------
# Graph-orientation bulk build (DESIGN.md §14; SNIPPETS.md Snippet 1).
# ---------------------------------------------------------------------------

def _insert_orient(
    config: CuckooConfig, state: CuckooState, keys: jnp.ndarray,
    valid: Optional[jnp.ndarray] = None,
    *, dedup_within_batch: bool = False,
) -> Tuple[CuckooState, jnp.ndarray, InsertStats]:
    """Orient the batch's bucket-graph edges, then commit conflict-free.

    Each key is a directed edge ``i1 -> i2`` of the bucket graph; its
    orientation picks the bucket it will occupy. Sweeps flip edges incident
    to over-full vertices (vectorized scatter-add indegree against each
    bucket's *actual* free capacity, masked flip selection preferring edges
    whose other endpoint has headroom) until every indegree fits, then a
    single sorted pass commits every tag conflict-free — no eviction loop.
    Existing table entries never move during orientation, so keys that
    would require a true eviction (both candidate buckets already full)
    are excluded from the sweep up front and spill to the round-loop
    residue pass, which can evict. The sweep exits early at feasibility
    *or* at a fixed point (no productive flips left) — both are salt-
    independent, so contended regimes don't burn the full sweep budget.
    """
    lay = config.layout
    pol = config.placement
    n = keys.shape[0]
    b = config.bucket_size
    nb = config.num_buckets
    sweeps = max(1, config.orient_sweeps)

    pending = jnp.ones((n,), bool) if valid is None else valid.astype(bool)
    valid0 = pending
    if dedup_within_batch:
        first, rep = _batch_dedup(keys, valid0)
        pending = pending & first

    base_tag, i1, i2 = prepare_keys(config, keys)
    i1s = i1.astype(jnp.int32)
    i2s = i2.astype(jnp.int32)
    aliased = i1s == i2s  # XOR degenerate: both endpoints coincide

    tags_flat = L.unpack_words(state.table, lay.fp_bits)     # per-slot view
    occ = jnp.sum(tags_flat.reshape(nb, b) != 0, axis=1, dtype=jnp.int32)
    free = jnp.int32(b) - occ                                # [nb]

    # Edges whose candidate buckets are both already full can never be
    # placed by orientation (existing entries never move); dropping them
    # from the sweep keeps the feasibility exit reachable — they go
    # straight to the residue pass. Active edges start pointing at an
    # endpoint that actually has headroom.
    active = pending & ((free[i1s] > 0) | (free[i2s] > 0))
    orient0 = active & (free[i1s] == 0) & ~aliased

    def sweep_body(carry):
        orient, _, s = carry
        dest = jnp.where(orient, i2s, i1s)
        other = jnp.where(orient, i1s, i2s)
        dkey = jnp.where(active, dest, nb)
        indeg = jnp.zeros((nb + 1,), jnp.int32).at[dkey].add(1)[:nb]
        done = ~jnp.any(indeg > free)

        # Flip priority within an over-full bucket: edges whose other
        # endpoint still has headroom net of its own inflow move first
        # (spare, bit 31), then edges whose other endpoint is at least
        # non-full (flippable, bit 30); ties break pseudo-randomly (salted
        # per sweep so repeated sweeps explore new orientations).
        flippable = free[other] > 0
        spare = (free[other] - indeg[other]) > 0
        r = _prng(base_tag, s) >> _U32(2)
        score = (r
                 | jnp.where(spare, _U32(0x80000000), _U32(0))
                 | jnp.where(flippable, _U32(0x40000000), _U32(0)))

        sort_key = jnp.where(active, dest, nb)
        order = jnp.lexsort((score, sort_key))
        sd = sort_key[order]
        rank = L.segment_ranks(sd)
        cap = free[jnp.minimum(sd, nb - 1)]
        flip_s = (rank >= cap) & (sd < nb)
        flip = jnp.zeros((n,), bool).at[order].set(flip_s)
        # A flip into a full bucket is pointless; masking it makes "no
        # flips happened" salt-independent (flippable edges always outrank
        # non-flippable ones), i.e. a true fixed point — the second exit.
        flip = flip & ~aliased & flippable
        return orient ^ flip, done | ~jnp.any(flip), s + 1

    def sweep_cond(carry):
        return (~carry[1]) & (carry[2] < sweeps)

    orient, _, _ = jax.lax.while_loop(
        sweep_cond, sweep_body,
        (orient0, jnp.zeros((), bool), jnp.zeros((), jnp.int32)))

    # Conflict-free commit of the oriented edges, then a second chance on
    # the opposite bucket for the few keys an unconverged sweep left over.
    dest = jnp.where(orient, i2s, i1s)
    stored = pol.place_tag(base_tag, orient)
    tags_flat, placed1 = _bulk_place_phase(
        config, tags_flat, dest, stored, pending)
    pending = pending & ~placed1
    dest2 = jnp.where(orient, i1s, i2s)
    stored2 = pol.place_tag(base_tag, ~orient)
    tags_flat, placed2 = _bulk_place_phase(
        config, tags_flat, dest2, stored2, pending)
    pending = pending & ~placed2

    table = L.pack_tags(tags_flat, lay.fp_bits)
    placed = placed1 | placed2
    count = state.count + jnp.sum(placed, dtype=jnp.int32)

    # Residue: both candidate buckets genuinely full — these keys need a
    # real eviction, which orientation (by construction) never performs.
    # The round loop handles them regardless of the eviction policy: its
    # per-round claim pass is much cheaper at full batch width than the
    # frontier's gather tree, and the residue is a small tail.
    state2, ok_res, res_stats = _insert_rounds(
        config, CuckooState(table, count), keys, valid=pending)

    ok = placed | ok_res
    if dedup_within_batch:
        ok = jnp.where(first, ok, ok[rep] & valid0)
    failed = jnp.sum(valid0 & ~ok, dtype=jnp.int32)
    load = state2.count.astype(jnp.float32) / lay.num_slots
    stats = InsertStats(res_stats.evictions, res_stats.rounds + 2, failed,
                        load)
    return state2, ok, stats


# ---------------------------------------------------------------------------
# Engine routing.
# ---------------------------------------------------------------------------

INSERT_ENGINES = ("auto", "legacy", "frontier", "orientation")


def resolve_engine(config: CuckooConfig, bulk: bool) -> str:
    """The concrete engine a (config, entry point) pair routes to."""
    eng = config.insert_engine
    if eng not in INSERT_ENGINES:
        raise ValueError(f"unknown insert_engine {eng!r} "
                         f"(want one of {INSERT_ENGINES})")
    if eng == "auto":
        if bulk:
            return "orientation"
        return "frontier" if config.eviction == "bfs" else "legacy"
    return eng


_ENGINE_FNS = {"legacy": _insert_rounds, "frontier": _insert_frontier,
               "orientation": _insert_orient}


def insert(
    config: CuckooConfig, state: CuckooState, keys: jnp.ndarray,
    valid: Optional[jnp.ndarray] = None,
    *, dedup_within_batch: bool = False,
) -> Tuple[CuckooState, jnp.ndarray, InsertStats]:
    """Insert a batch of keys. Returns (state', ok[n], stats).

    ``ok[i]`` False means the table was too full for key i (paper Alg. 1
    "Failure — caller will have to rebuild"). The same information is
    surfaced loudly in ``stats.failed`` (count of unplaced valid keys) and
    ``stats.load`` (post-batch load factor) — the round loop gives up after
    ``max_rounds`` (default ``4 * max_evictions + 64``) rounds, which near
    ~0.98 load silently turned into failures callers could ignore by
    dropping the ``ok`` mask. ``valid`` masks padding keys (used by the
    sharded filter's fixed-capacity routing).

    Engine routing (``config.insert_engine``, DESIGN.md §14): ``"auto"``
    runs the batched BFS frontier when ``eviction == "bfs"`` and the legacy
    round loop otherwise; the other values force one engine.

    Duplicate semantics: by default the filter is a *multiset* — two equal
    keys in one batch insert two copies (each needs its own ``delete``),
    exactly like two sequential single-key inserts. With
    ``dedup_within_batch=True`` (a static flag) only the first occurrence of
    each 64-bit key value is inserted; later copies report the first copy's
    ``ok`` (idempotent set semantics within the batch). See DESIGN.md §4.
    """
    fn = _ENGINE_FNS[resolve_engine(config, bulk=False)]
    return fn(config, state, keys, valid,
              dedup_within_batch=dedup_within_batch)


# ---------------------------------------------------------------------------
# Bulk-build insertion (paper §4.6.3 sorted-insertion, made the fast path;
# DESIGN.md §6).
# ---------------------------------------------------------------------------


def _bulk_place_phase(config: CuckooConfig, tags_flat: jnp.ndarray,
                      bucket: jnp.ndarray, stored_tag: jnp.ndarray,
                      pend: jnp.ndarray):
    """One whole-bucket placement round over the unpacked per-slot table.

    Sorts the pending keys by destination bucket, ranks each key within its
    bucket segment, and commits the rank-th free slot of every bucket in a
    single conflict-free scatter (each key owns a distinct slot by
    construction — no word-claim election needed).

    Returns (tags_flat', placed: bool[n] in original batch order).
    """
    lay = config.layout
    n = bucket.shape[0]
    b = config.bucket_size
    nb = config.num_buckets

    # One sort per phase — the whole point: pending keys grouped by bucket,
    # masked-out keys pushed past every real segment via the nb sentinel.
    sort_key = jnp.where(pend, bucket.astype(jnp.int32), nb)
    order = jnp.argsort(sort_key, stable=True)
    sb = sort_key[order]
    rank = L.segment_ranks(sb)

    safe_b = jnp.minimum(sb, nb - 1)
    btags = tags_flat.reshape(nb, b)[safe_b]                  # [n, b]
    placed_s, slot_s = L.nth_free_slot(btags, rank)
    placed_s = placed_s & (sb < nb)
    dest = safe_b * b + slot_s
    tags_flat = tags_flat.at[
        jnp.where(placed_s, dest, lay.num_slots)
    ].set(stored_tag[order], mode="drop")

    placed = jnp.zeros((n,), bool).at[order].set(placed_s)
    return tags_flat, placed


def insert_bulk(
    config: CuckooConfig, state: CuckooState, keys: jnp.ndarray,
    valid: Optional[jnp.ndarray] = None,
    *, dedup_within_batch: bool = False,
) -> Tuple[CuckooState, jnp.ndarray, InsertStats]:
    """Bulk-build insertion fast path. Same contract as :func:`insert`.

    Where :func:`insert` re-elects per-word winners with a full stable sort
    of all claim addresses in *every* round of its while-loop, this entry
    point sorts the batch by primary bucket **once** and commits whole
    buckets per round (paper §4.6.3's sorted insertion, promoted from a
    rejected GPU ablation to the batch-synchronous fast path — DESIGN.md §6):

    1. unpack the table to its per-slot view (a pure bit-shuffle);
    2. phase 1: place up to ``bucket_size`` keys per *primary* bucket —
       each key takes the rank-th free slot of its bucket segment;
    3. phase 2: re-sort the overflow by *alternate* bucket, place again;
    4. spill the residue (both candidate buckets full — rare below ~0.9
       load) into the general eviction round loop;
    5. restore original batch order for ``ok``/stats outputs (the sorted
       view never escapes).

    ``stats.rounds`` counts the two bulk phases plus the residue loop's
    rounds, so it is directly comparable with :func:`insert`'s round count.

    Engine routing (``config.insert_engine``, DESIGN.md §14): ``"auto"``
    and ``"orientation"`` take the graph-orientation bulk build —
    :func:`_insert_orient` replaces the eviction loop entirely for this
    entry point; ``"legacy"``/``"frontier"`` keep the two sorted phases
    here and spill the residue through that engine's round loop.
    """
    eng = resolve_engine(config, bulk=True)
    if eng == "orientation":
        return _insert_orient(config, state, keys, valid,
                              dedup_within_batch=dedup_within_batch)
    residue_fn = _ENGINE_FNS[eng]
    lay = config.layout
    pol = config.placement
    n = keys.shape[0]

    pending = jnp.ones((n,), bool) if valid is None else valid.astype(bool)
    valid0 = pending
    if dedup_within_batch:
        first, rep = _batch_dedup(keys, valid0)
        pending = pending & first

    base_tag, i1, i2 = prepare_keys(config, keys)
    tag1 = pol.place_tag(base_tag, jnp.zeros((n,), bool))
    tag2 = pol.place_tag(base_tag, jnp.ones((n,), bool))

    tags_flat = L.unpack_words(state.table, lay.fp_bits)      # per-slot view

    tags_flat, placed1 = _bulk_place_phase(
        config, tags_flat, i1, tag1, pending)
    pending = pending & ~placed1
    tags_flat, placed2 = _bulk_place_phase(
        config, tags_flat, i2, tag2, pending)
    pending = pending & ~placed2

    table = L.pack_tags(tags_flat, lay.fp_bits)
    placed = placed1 | placed2
    count = state.count + jnp.sum(placed, dtype=jnp.int32)

    # Residue: both candidate buckets full — hand the stragglers to the
    # eviction-capable round loop against the bulk-updated table.
    state2, ok_res, res_stats = residue_fn(
        config, CuckooState(table, count), keys, valid=pending)

    ok = placed | ok_res
    if dedup_within_batch:
        ok = jnp.where(first, ok, ok[rep] & valid0)
    failed = jnp.sum(valid0 & ~ok, dtype=jnp.int32)
    load = state2.count.astype(jnp.float32) / lay.num_slots
    stats = InsertStats(res_stats.evictions, res_stats.rounds + 2, failed,
                        load)
    return state2, ok, stats


# ---------------------------------------------------------------------------
# Query (Alg. 2) — read-only, trivially parallel.
# ---------------------------------------------------------------------------

def query(config: CuckooConfig, state: CuckooState, keys: jnp.ndarray) -> jnp.ndarray:
    """Membership test for a batch of keys -> bool[n]."""
    lay = config.layout
    pol = config.placement
    base_tag, i1, i2 = prepare_keys(config, keys)
    t1, t2 = pol.query_match_tags(base_tag)
    tags1 = L.bucket_tags(state.table, i1, lay)
    tags2 = L.bucket_tags(state.table, i2, lay)
    hit1 = jnp.any(tags1 == t1[:, None], axis=-1)
    hit2 = jnp.any(tags2 == t2[:, None], axis=-1)
    return hit1 | hit2


# ---------------------------------------------------------------------------
# Deletion (Alg. 3).
# ---------------------------------------------------------------------------

def delete(
    config: CuckooConfig, state: CuckooState, keys: jnp.ndarray,
    valid: Optional[jnp.ndarray] = None,
) -> Tuple[CuckooState, jnp.ndarray]:
    """Delete one stored copy per key. Returns (state', ok[n])."""
    lay = config.layout
    pol = config.placement
    n = keys.shape[0]
    invalid = lay.num_words
    max_rounds = 2 * config.bucket_size + 2  # duplicate deleters serialise

    base_tag, i1, i2 = prepare_keys(config, keys)
    t1, t2 = pol.query_match_tags(base_tag)

    def round_fn(carry):
        table, count, pending, success, rnd = carry
        words1 = L.gather_bucket_words(table, i1, lay)
        words2 = L.gather_bucket_words(table, i2, lay)
        tags1 = L.unpack_words(words1, lay.fp_bits)
        tags2 = L.unpack_words(words2, lay.fp_bits)

        start = L.scan_start(base_tag, lay)
        f1, s1 = L.first_true_circular(tags1 == t1[:, None], start)
        f2, s2 = L.first_true_circular(tags2 == t2[:, None], start)

        found = f1 | f2
        bucket = jnp.where(f1, i1, i2)
        slot = jnp.where(f1, s1, s2)
        words = jnp.where(f1[:, None], words1, words2)
        widx, sw = L.slot_to_word(slot, lay)
        word = jnp.take_along_axis(words, widx[:, None], axis=1)[:, 0]
        desired = L.replace_tag(word, sw, jnp.zeros((n,), jnp.uint32),
                                lay.fp_bits)
        addr = L.word_addr(bucket, widx, lay)

        # Keys with no remaining match fail out (Alg. 3 line 21).
        pending = pending & found

        addr = jnp.where(pending, addr, invalid)
        win, _ = _resolve_claims(addr, jnp.full((n,), invalid, jnp.int32),
                                 invalid)
        commit = pending & win & (addr != invalid)
        table = _masked_write(table, addr, desired, commit, invalid)
        success = success | commit
        pending = pending & ~commit
        count = count - jnp.sum(commit, dtype=jnp.int32)
        return table, count, pending, success, rnd + 1

    def cond_fn(carry):
        return jnp.any(carry[2]) & (carry[4] < max_rounds)

    pending0 = jnp.ones((n,), bool) if valid is None else valid.astype(bool)
    carry0 = (state.table, state.count, pending0,
              jnp.zeros((n,), bool), jnp.zeros((), jnp.int32))
    table, count, _, success, _ = jax.lax.while_loop(cond_fn, round_fn, carry0)
    return CuckooState(table, count), success


# ---------------------------------------------------------------------------
# Fused mixed-operation execution (DESIGN.md §9).
# ---------------------------------------------------------------------------

# Op codes shared with the AMQ protocol (repro.amq.protocol is
# dependency-light by contract, so this import cannot cycle).
from ..amq.protocol import OP_DELETE, OP_INSERT, OP_QUERY  # noqa: E402


def _count_matches(config: CuckooConfig, state: CuckooState,
                   keys: jnp.ndarray):
    """Stored copies matching each key across its two candidate buckets.

    Returns int32[n]. When XOR placement degenerates to ``i1 == i2`` (and
    the match tags coincide), the single bucket is counted once — exactly
    the pool of copies a sequential delete chain could consume.
    """
    lay = config.layout
    pol = config.placement
    base_tag, i1, i2 = prepare_keys(config, keys)
    t1, t2 = pol.query_match_tags(base_tag)
    cnt1 = jnp.sum(L.bucket_tags(state.table, i1, lay) == t1[:, None],
                   axis=-1, dtype=jnp.int32)
    cnt2 = jnp.sum(L.bucket_tags(state.table, i2, lay) == t2[:, None],
                   axis=-1, dtype=jnp.int32)
    aliased = (i1 == i2) & (t1 == t2)
    return jnp.where(aliased, cnt1, cnt1 + cnt2)


def apply_ops(
    config: CuckooConfig, state: CuckooState, keys: jnp.ndarray,
    ops: jnp.ndarray, valid: Optional[jnp.ndarray] = None,
) -> Tuple[CuckooState, jnp.ndarray, InsertStats]:
    """Execute an interleaved QUERY/INSERT/DELETE stream in one fused pass.

    ``ops`` is int32[n] of op codes; returns ``(state', ok[n], stats)``
    where ``ok[i]`` is that slot's outcome under its op code (query → hit,
    insert → landed, delete → removed a stored copy).

    Intra-batch semantics (validated against the per-op sequential oracle
    in tests/test_mixed_ops.py): **operations on the same 64-bit key
    resolve in batch order** — a query at index i observes exactly that
    key's inserts and deletes at indices j < i, and a delete consumes the
    oldest available copy. Rather than serialising per-key chains, the
    pass materialises them algebraically:

    1. one gather over the table counts each key's stored copies ``c0``
       (the SWAR-unpacked match count over both candidate buckets);
    2. a segmented associative scan over the batch (grouped by key value,
       batch order within groups) runs the saturating counter
       ``c_t = max(c_{t-1} + a_t, 0)`` (+1 insert, −1 delete, 0 query)
       from ``c0``, which answers every query (``c > 0``) and delete
       (``c_before > 0``) in its correct intra-batch position;
    3. only each key's *net* effect touches the table: ``d = c_last − c0``
       surplus copies are inserted (the last ``d`` insert slots) or
       ``−d`` copies deleted (the first ``−d`` delete slots) through the
       existing claim machinery — insert/delete pairs that cancel within
       the batch never generate memory traffic.

    Documented deviations from the sequential oracle (DESIGN.md §9): a
    cancelled insert reports ``ok=True`` even when a sequential execution
    would have failed it against a full table, and *cross-key* fingerprint
    aliasing within one batch is observed as-if-reordered (net effects are
    applied deletes-then-inserts). Neither can produce a false negative
    for a key's own inserts, and both vanish below the design load.
    """
    n = keys.shape[0]
    if n == 0:  # static: the segmented scans assume at least one slot
        return state, jnp.zeros((0,), bool), InsertStats(
            jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32),
            state.count.astype(jnp.float32) / config.num_slots)
    v = (jnp.ones((n,), bool) if valid is None else valid.astype(bool))
    ops = ops.astype(jnp.int32)
    is_ins = v & (ops == OP_INSERT)
    is_del = v & (ops == OP_DELETE)
    is_qry = v & (ops == OP_QUERY)

    c0 = _count_matches(config, state, keys)

    # --- group by 64-bit key value; batch order within groups (stable).
    lo, hi = keys[..., 0], keys[..., 1]
    order = jnp.lexsort((lo, hi))
    lo_s, hi_s = lo[order], hi[order]
    seg_start = jnp.concatenate([
        jnp.ones((1,), bool),
        (lo_s[1:] != lo_s[:-1]) | (hi_s[1:] != hi_s[:-1]),
    ])
    seg_id = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    idx = jnp.arange(n, dtype=jnp.int32)
    head_pos = jax.lax.cummax(jnp.where(seg_start, idx, 0))

    def seg_cumsum(x_s):
        c = jnp.cumsum(x_s)
        return c - (c[head_pos] - x_s[head_pos])

    a = (is_ins.astype(jnp.int32) - is_del.astype(jnp.int32))[order]
    c0_s = c0[order]

    # --- segmented saturating-counter scan. Each op is the map
    #     c -> max(c + a, 0); maps compose as (A, M): c -> max(c + A, M)
    #     with A = A1 + A2, M = max(M1 + A2, M2) — associative, and the
    #     segment-start flag resets composition at key-group boundaries.
    def combine(left, right):
        A1, M1, r1 = left
        A2, M2, r2 = right
        A = jnp.where(r2, A2, A1 + A2)
        M = jnp.where(r2, M2, jnp.maximum(M1 + A2, M2))
        return A, M, r1 | r2

    A, M, _ = jax.lax.associative_scan(
        combine, (a, jnp.zeros((n,), jnp.int32), seg_start))
    c_incl = jnp.maximum(c0_s + A, M)
    c_before = jnp.where(seg_start, c0_s, jnp.roll(c_incl, 1))

    # --- net effect per key group: surplus inserts / deficit deletes.
    last_pos = jnp.clip(
        jax.ops.segment_max(idx, seg_id, num_segments=n), 0, n - 1)
    c_last = c_incl[last_pos][seg_id]
    d = c_last - c0_s                       # net copies to add (+) / drop (−)
    ins_rank = seg_cumsum(is_ins[order].astype(jnp.int32))    # 1-based
    del_rank = seg_cumsum(is_del[order].astype(jnp.int32))
    ins_total = ins_rank[last_pos][seg_id]
    net_ins_s = is_ins[order] & (ins_rank > ins_total - jnp.maximum(d, 0))
    net_del_s = is_del[order] & (del_rank <= jnp.maximum(-d, 0))

    unsort = lambda x_s, fill: jnp.full((n,), fill, x_s.dtype).at[order].set(x_s)
    net_ins = unsort(net_ins_s, False)
    net_del = unsort(net_del_s, False)
    q_ok = unsort(c_incl > 0, False)
    d_ok_prov = unsort(c_before > 0, False)

    # --- apply net mutations through the existing claim machinery
    #     (deletes first: they free slots the surplus inserts may claim).
    #     The claim loops pay full-batch-width sorts per round, so sparse
    #     net slices (the common case for read-heavy traffic) are first
    #     *compacted* into a narrow static sub-batch with one cumsum
    #     scatter — no sort — and only dense slices run full width, where
    #     net inserts take the bulk-build fast path (DESIGN.md §6; the
    #     fused pass already paid for the batch analysis). lax.cond picks
    #     the branch at runtime; shapes stay static either way.
    sub = max(8, n // 8)

    def _compact(mask, width):
        pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
        slot = jnp.where(mask, pos, width)
        sub_keys = jnp.zeros((width, 2), jnp.uint32).at[slot].set(
            keys, mode="drop")
        sub_valid = jnp.zeros((width,), bool).at[slot].set(mask, mode="drop")
        return jnp.clip(pos, 0, width - 1), sub_keys, sub_valid

    def sparse_delete(st):
        pos, skeys, svalid = _compact(net_del, sub)
        st, ok_sub = delete(config, st, skeys, valid=svalid)
        return st, net_del & ok_sub[pos]

    def dense_delete(st):
        return delete(config, st, keys, valid=net_del)

    state, del_ok = jax.lax.cond(
        jnp.sum(net_del, dtype=jnp.int32) <= sub,
        sparse_delete, dense_delete, state)

    def sparse_insert(st):
        pos, skeys, svalid = _compact(net_ins, sub)
        st, ok_sub, st_stats = insert(config, st, skeys, valid=svalid)
        ev = jnp.where(net_ins, st_stats.evictions[pos], 0)
        return st, net_ins & ok_sub[pos], ev, st_stats.rounds

    def dense_insert(st):
        st, ok_f, st_stats = insert_bulk(config, st, keys, valid=net_ins)
        return st, ok_f, st_stats.evictions, st_stats.rounds

    state, ins_ok, evictions, rounds = jax.lax.cond(
        jnp.sum(net_ins, dtype=jnp.int32) <= sub,
        sparse_insert, dense_insert, state)

    ok = jnp.where(
        is_qry, q_ok,
        jnp.where(is_ins, jnp.where(net_ins, ins_ok, True),
                  jnp.where(is_del,
                            d_ok_prov & jnp.where(net_del, del_ok, True),
                            False)))
    failed = jnp.sum(net_ins & ~ins_ok, dtype=jnp.int32)
    load = state.count.astype(jnp.float32) / config.num_slots
    return state, ok, InsertStats(evictions, rounds, failed, load)


# ---------------------------------------------------------------------------
# Convenience object API (functional; methods return new state).
# ---------------------------------------------------------------------------

class CuckooFilter:
    """Thin OO wrapper with per-config cached jitted entry points.

    New code should prefer :func:`repro.amq.make`\\ ("cuckoo", ...) — this
    class is kept as a stable shim and mirrors the unified keyword surface:
    ``insert(keys, bulk=..., dedup_within_batch=...)`` (matching
    ``ShardedCuckooFilter.insert``).
    """

    def __init__(self, config: CuckooConfig, state: Optional[CuckooState] = None,
                 dedup_within_batch: bool = False):
        self.config = config
        self.state = config.init() if state is None else state
        self._default_dedup = dedup_within_batch
        self._jits = {}

    def _op(self, fn, **static):
        key = (fn.__name__, tuple(sorted(static.items())))
        if key not in self._jits:
            self._jits[key] = jax.jit(
                functools.partial(fn, self.config, **static))
        return self._jits[key]

    def insert(self, keys, *, bulk: bool = False,
               dedup_within_batch: Optional[bool] = None
               ) -> Tuple[jnp.ndarray, InsertStats]:
        """Insert a batch; ``bulk=True`` takes the bucket-sorted fast path.

        A batch the engine could not fully place raises a loud
        ``RuntimeWarning`` carrying the failure count and the load factor
        (``stats.failed`` / ``stats.load``) — the round loop's
        ``max_rounds`` budget (default ``4 * max_evictions + 64``) means
        near-full tables fail keys rather than spin, and that must never
        pass silently just because the caller dropped the ``ok`` mask.
        """
        import warnings

        dd = (self._default_dedup if dedup_within_batch is None
              else dedup_within_batch)
        fn = self._op(insert_bulk if bulk else insert, dedup_within_batch=dd)
        self.state, ok, stats = fn(self.state, normalize_keys(keys))
        failed = int(stats.failed)
        if failed:
            warnings.warn(
                f"cuckoo insert left {failed} of {ok.shape[0]} keys "
                f"unplaced at load factor {float(stats.load):.3f} — the "
                f"filter is effectively full; grow it "
                f"(CuckooConfig.for_capacity) or rebuild",
                RuntimeWarning, stacklevel=2)
        return ok, stats

    def insert_bulk(self, keys) -> Tuple[jnp.ndarray, InsertStats]:
        """Deprecated alias for ``insert(keys, bulk=True)``."""
        import warnings

        warnings.warn("CuckooFilter.insert_bulk is deprecated; use "
                      "insert(keys, bulk=True)", DeprecationWarning,
                      stacklevel=2)
        return self.insert(keys, bulk=True)

    def query(self, keys) -> jnp.ndarray:
        return self._op(query)(self.state, normalize_keys(keys))

    def delete(self, keys) -> jnp.ndarray:
        self.state, ok = self._op(delete)(self.state, normalize_keys(keys))
        return ok

    def apply_ops(self, keys, ops, valid=None
                  ) -> Tuple[jnp.ndarray, InsertStats]:
        """Run an interleaved query/insert/delete stream in one fused pass."""
        self.state, ok, stats = self._op(apply_ops)(
            self.state, normalize_keys(keys), ops, valid)
        return ok, stats

    @property
    def load_factor(self) -> float:
        return float(self.state.count) / self.config.num_slots
