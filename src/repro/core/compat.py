"""Version-compat shims for jax APIs that moved between releases.

The repo targets the current jax API (``jax.shard_map``, ``AxisType`` mesh
axis types) but must also run on the 0.4.x line installed in the CI/CPU
container, where ``shard_map`` still lives in ``jax.experimental`` (with
``check_rep`` instead of ``check_vma``) and ``jax.make_mesh`` has no
``axis_types`` parameter. Everything here resolves at import/call time so
callers stay version-agnostic.
"""

from __future__ import annotations

import inspect

import jax

# AxisType (explicit-sharding mesh axis annotations) — absent before jax 0.6.
AxisType = getattr(jax.sharding, "AxisType", None)


def auto_axis_types_kw(num_axes: int) -> dict:
    """``axis_types=(AxisType.Auto,) * n`` where supported, ``{}`` before."""
    if AxisType is None:
        return {}
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        return {}
    return {"axis_types": (AxisType.Auto,) * num_axes}


def shard_map(fn, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` / ``jax.experimental.shard_map`` dispatch.

    ``check`` maps to ``check_vma`` (new API) or ``check_rep`` (old API) —
    both gate the same replication/varying-manual-axes verification.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)
