"""Tiered storage: a GPU-hot / host-cold cascade for beyond-HBM capacity.

Device memory caps the keyspace of every handle in this package — a
cascade grows until HBM runs out, then nothing helps. The classic escape
is the cascade filter of Bender et al. ("Don't Thrash: How to Cache Your
Hash on Flash", §3): a small fast filter absorbing writes in front of
exponentially larger cold levels, with the cold levels living on cheaper,
bigger storage. :class:`TieredHandle` implements that recipe over the
PR 3 cascade and the PR 5 snapshot machinery (DESIGN.md §12):

* **Hot tier** — a live :class:`~repro.amq.cascade.CascadeHandle` holding
  the newest (write-absorbing) levels on device. Inserts land *only*
  here; queries over it run as the cascade's one fused jit.
* **Cold tier** — frozen older levels demoted through the snapshot path
  into packed host-RAM numpy arrays (:class:`ColdLevel`). They are probed
  with the adapter's vectorized ``host_query`` — table gathers run in
  numpy against host memory; only tiny per-key hash scalars ever touch
  the device, so hashing stays bit-identical to the device kernels.
* **Hot-hit short-circuit** — a query batch first runs the fused device
  pass; only the slots that *missed* every hot level are probed cold, in
  one batched host pass per cold level. The common case (recent keys)
  never leaves the device.
* **Budget** — ``device_budget_bytes`` bounds the hot tier's footprint.
  Inserts that grow the cascade past it trigger demotion of the oldest
  hot level; :meth:`TieredHandle.maintain` performs one bounded
  demote-or-promote step (background-callable), and
  :meth:`TieredHandle.promote` pulls the newest cold level back on device
  when the budget allows.
* **Deletes** route newest-first across *both* tiers: the hot cascade's
  query-then-delete pass first, then a host-side slot clear
  (``host_delete``) on the packed cold arrays.

Levels keep their FPR shares and allocation indices across tier moves, so
the aggregate false-positive budget and the snapshot-reconstruction order
are preserved no matter how levels shuffle between device and host.

Example::

    from repro import amq

    h = amq.make("cuckoo", capacity=4096, tiered=True,
                 device_budget_bytes=256 * 1024)
    h.insert(keys_1m)                  # hot tier spills old levels to host
    assert bool(h.query(keys_1m).hits.all())
    print(h.report().hot_levels, h.report().cold_levels)
"""

from __future__ import annotations

from typing import Any, Optional

import numpy as np

from ..core.hashing import normalize_keys
from .adapters import AMQAdapter, config_fingerprint
from .cascade import CascadeHandle, _mask
from .handle import FilterHandle
from .protocol import (
    OP_DELETE,
    OP_QUERY,
    DeleteReport,
    InsertReport,
    MixedReport,
    OpBatch,
    QueryResult,
    Snapshot,
    SnapshotMismatchError,
    TieredReport,
    TierStats,
)

# Demotion loop backstop: one demotion per excess level, and a cascade
# cannot hold more levels than this in any realistic configuration.
_MAX_DEMOTE_ROUNDS = 256


def _max_capacity_under(adapter: AMQAdapter, budget: int, floor: int,
                        base_kwargs: dict) -> int:
    """Largest level capacity whose sized config fits ``budget`` bytes.

    Sized against the adapter's *tightest* growth sizing (the ladder's
    last overlay — deep levels tighten fingerprints to hold their FPR
    share, which grows bytes-per-slot), so a level at the clamp fits the
    budget whatever overlay the cascade picks for it. Binary search over
    the adapter's own ``make_config`` (sizing is monotone but not
    linear — cuckoo configs round buckets to powers of two), floored at
    the base capacity, which the caller has verified fits loosely sized.
    """
    kw = {**base_kwargs, **(adapter.growth_sizings[-1]
                            if adapter.growth_sizings else {})}

    def _fits(capacity: int) -> bool:
        return adapter.make_config(capacity, **kw).table_bytes <= budget

    lo = hi = max(1, int(floor))
    if not _fits(lo):
        return lo  # tightest sizing of even the base level overflows:
        # keep levels at base capacity — smaller would break the cascade's
        # base-capacity floor; the transient overshoot is visible in
        # report() and bounded by one level's tightest-vs-base ratio.
    while _fits(hi * 2):
        hi *= 2
    hi *= 2  # first known-too-big capacity
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if _fits(mid):
            lo = mid
        else:
            hi = mid
    return lo


class ColdLevel:
    """One frozen cascade level resident in host RAM (DESIGN.md §12).

    Holds the level's static config plus *writable* numpy copies of its
    packed state arrays (the snapshot payload). Queries go through the
    adapter's vectorized ``host_query``; deletes clear slots in place via
    ``host_delete``. The FPR ``share`` and ``alloc_id`` ride along so the
    level can be promoted back (or snapshotted) with the cascade's budget
    accounting intact.
    """

    __slots__ = ("config", "arrays", "share", "alloc_id")

    def __init__(self, config, arrays: dict, share: float, alloc_id: int):
        """Wrap packed state arrays; copies anything not writable numpy."""
        self.config = config
        self.arrays = {
            k: (v if isinstance(v, np.ndarray) and v.flags.writeable
                else np.array(v))
            for k, v in arrays.items()}
        self.share = float(share)
        self.alloc_id = int(alloc_id)

    @property
    def count(self) -> int:
        """Stored-key count, read off the packed ``count`` array."""
        return int(np.asarray(self.arrays["count"]).sum())

    @property
    def table_bytes(self) -> int:
        """Host-RAM footprint of the packed table."""
        return self.config.table_bytes

    @property
    def num_slots(self) -> int:
        """Nominal slot capacity of the frozen level."""
        return self.config.num_slots

    @property
    def load_factor(self) -> float:
        """Occupancy of the frozen level."""
        return self.count / self.num_slots

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        """Summarize allocation index, occupancy, and footprint."""
        return (f"ColdLevel(alloc={self.alloc_id}, count={self.count}, "
                f"bytes={self.table_bytes})")


class TieredHandle:
    """GPU-hot / host-cold tiered filter under a device-memory budget.

    Obtain via ``amq.make(name, capacity=..., tiered=True,
    device_budget_bytes=...)``. The surface mirrors
    :class:`~repro.amq.cascade.CascadeHandle` (``insert`` / ``query`` /
    ``delete`` / ``apply_ops`` / ``snapshot`` / ``restore`` / ...), so
    consumers — including :class:`~repro.amq.service.FilterService` —
    swap cascades for tiered handles without code changes.

    Example::

        >>> h = amq.make("cuckoo", capacity=1024, tiered=True,
        ...              device_budget_bytes=64 * 1024)
        >>> _ = h.insert(keys)          # spills past the budget to host RAM
        >>> bool(h.query(keys).hits.all())
        True
    """

    def __init__(self, adapter: AMQAdapter, capacity: int, *,
                 device_budget_bytes: int,
                 growth: float = 2.0, watermark: float = 0.85,
                 fpr_budget: Optional[float] = None,
                 split_ratio: float = 0.5,
                 max_levels: Optional[int] = None,
                 **base_kwargs: Any):
        """Build a one-level hot cascade under ``device_budget_bytes``."""
        caps = adapter.capabilities
        if not caps.supports_tiering or adapter.host_query is None:
            raise NotImplementedError(
                f"{adapter.name}: backend cannot tier "
                "(capabilities.supports_tiering is False / no host_query)")
        if not caps.supports_snapshot:
            raise NotImplementedError(
                f"{adapter.name}: tiering demotes levels through snapshots "
                "(capabilities.supports_snapshot is False)")
        budget = int(device_budget_bytes)
        if budget <= 0:
            raise ValueError(
                f"device_budget_bytes must be positive, got {budget}")
        self.adapter = adapter
        self.device_budget_bytes = budget
        base_bytes = adapter.make_config(int(capacity),
                                         **base_kwargs).table_bytes
        if base_bytes > budget:
            raise ValueError(
                f"device_budget_bytes={budget} cannot hold even the base "
                f"level ({base_bytes} bytes) — the active level never "
                "demotes; raise the budget or shrink capacity")
        # Clamp the geometric ladder so the *active* level always fits the
        # budget on its own: without the clamp the newest level doubles
        # without bound and the budget is structurally unenforceable.
        clamp = _max_capacity_under(adapter, budget, int(capacity),
                                    base_kwargs)
        self.hot = CascadeHandle(
            adapter, capacity, growth=growth, watermark=watermark,
            fpr_budget=fpr_budget, split_ratio=split_ratio,
            max_levels=max_levels, max_level_capacity=clamp,
            **base_kwargs)
        self.cold: list[ColdLevel] = []
        self._counters = {"demotions": 0, "promotions": 0,
                          "cold_probes": 0, "cold_probe_keys": 0,
                          "cold_hits": 0}

    # -- introspection -------------------------------------------------------

    @property
    def name(self) -> str:
        """Registry name of the wrapped backend."""
        return self.adapter.name

    @property
    def capabilities(self):
        """The wrapped backend's capability flags."""
        return self.adapter.capabilities

    @property
    def config(self):
        """The hot tier's active (newest) level config."""
        return self.hot.config

    @property
    def state(self):
        """The hot tier's active (newest) level state pytree."""
        return self.hot.state

    @property
    def levels(self) -> list:
        """The *device-resident* level handles (hot cascade's levels).

        Exposed under the cascade's attribute name so device-sync code
        (``FilterService.hot_swap``) treats tiered handles uniformly; the
        cold tier is host memory and needs no device sync.
        """
        return self.hot.levels

    @property
    def fpr_budget(self) -> float:
        """Aggregate FPR budget shared across both tiers."""
        return self.hot.fpr_budget

    @property
    def base_capacity(self) -> int:
        """Level-0 design capacity (the geometric ladder's base)."""
        return self.hot.base_capacity

    @property
    def device_bytes(self) -> int:
        """Current device (hot-tier) footprint."""
        return self.hot.table_bytes

    @property
    def host_bytes(self) -> int:
        """Current host-RAM (cold-tier) footprint."""
        return sum(c.table_bytes for c in self.cold)

    @property
    def table_bytes(self) -> int:
        """Total footprint across both tiers."""
        return self.device_bytes + self.host_bytes

    @property
    def num_slots(self) -> int:
        """Aggregate nominal capacity across both tiers."""
        return self.hot.num_slots + sum(c.num_slots for c in self.cold)

    @property
    def load_factor(self) -> float:
        """Aggregate occupancy across both tiers."""
        return self.count() / self.num_slots

    def count(self) -> int:
        """Total stored-key count across both tiers."""
        return self.hot.count() + sum(c.count for c in self.cold)

    def expected_fpr(self, load_factor: Optional[float] = None) -> float:
        """Aggregate analytic FPR ``1 - prod(1 - eps_i)`` over both tiers."""
        miss = 1.0 - self.hot.expected_fpr(load_factor)
        for c in self.cold:
            lf = c.load_factor if load_factor is None else load_factor
            miss *= 1.0 - c.config.expected_fpr(lf)
        return 1.0 - miss

    def report(self) -> TieredReport:
        """Per-level residency-annotated stats (a :class:`TieredReport`)."""
        stats = []
        for c in self.cold:
            lf = c.load_factor
            stats.append(TierStats("cold", c.alloc_id, c.num_slots, c.count,
                                   lf, c.table_bytes,
                                   c.config.expected_fpr(lf), c.share))
        for lvl, share, aid in zip(self.hot.levels, self.hot.level_shares,
                                   self.hot.level_alloc_ids):
            cnt, lf = lvl.count(), lvl.load_factor
            stats.append(TierStats("hot", aid, lvl.config.num_slots, cnt,
                                   lf, lvl.config.table_bytes,
                                   lvl.config.expected_fpr(lf), share))
        c = self._counters
        return TieredReport(tuple(stats), self.device_budget_bytes,
                            self.device_bytes, self.host_bytes,
                            self.count(), self.expected_fpr(),
                            self.fpr_budget, c["demotions"],
                            c["promotions"], c["cold_probes"],
                            c["cold_hits"])

    def tier_stats(self) -> dict:
        """JSON-able tier summary (surfaced by ``FilterService.stats``)."""
        return {"device_budget_bytes": self.device_budget_bytes,
                "device_bytes": self.device_bytes,
                "host_bytes": self.host_bytes,
                "hot_levels": len(self.hot.levels),
                "cold_levels": len(self.cold),
                **self._counters}

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        """Summarize backend, tier shape, and budget utilisation."""
        return (f"TieredHandle({self.adapter.name!r}, "
                f"hot={len(self.hot.levels)}, cold={len(self.cold)}, "
                f"device={self.device_bytes}/{self.device_budget_bytes}B, "
                f"host={self.host_bytes}B)")

    # -- tier movement -------------------------------------------------------

    def demote(self) -> Optional[ColdLevel]:
        """Freeze the oldest hot level into host RAM; None if impossible.

        The level's state is pulled through the snapshot path into
        writable numpy arrays and detached from the cascade (its FPR share
        and allocation index travel with it). The active level never
        demotes — a cascade needs a device-resident write target.
        """
        if len(self.hot.levels) <= 1:
            return None
        lvl, share, aid = self.hot.detach_oldest()
        arrays = {k: np.array(v) for k, v
                  in self.adapter.snapshot(lvl.config, lvl.state).items()}
        cold = ColdLevel(lvl.config, arrays, share, aid)
        self.cold.append(cold)
        self._counters["demotions"] += 1
        return cold

    def promote(self, *, force: bool = False) -> bool:
        """Move the newest cold level back on device; False if refused.

        Refuses (without ``force``) when the promoted level would push the
        hot tier past ``device_budget_bytes`` — by construction that is
        exactly when :meth:`maintain` would immediately demote it again,
        so the budget check doubles as ping-pong protection.
        """
        if not self.cold:
            return False
        lvl = self.cold[-1]
        if (not force and self.hot.table_bytes + lvl.table_bytes
                > self.device_budget_bytes):
            return False
        state = self.adapter.restore(lvl.config, lvl.arrays)
        self.hot.attach_oldest(FilterHandle(self.adapter, lvl.config, state),
                               lvl.share, lvl.alloc_id)
        self.cold.pop()
        self._counters["promotions"] += 1
        return True

    def maintain(self) -> dict:
        """One bounded rebalance step — safe to call from a background loop.

        Demotes the oldest hot level when the hot tier exceeds the budget;
        otherwise promotes the newest cold level if it fits. Returns an
        action record (``{"action": "demote" | "promote" | "none", ...}``)
        so callers can log or stop iterating once the tier is balanced.
        """
        if (self.hot.table_bytes > self.device_budget_bytes
                and len(self.hot.levels) > 1):
            cold = self.demote()
            return {"action": "demote", "alloc_index": cold.alloc_id,
                    "bytes": cold.table_bytes}
        if self.cold and (self.hot.table_bytes + self.cold[-1].table_bytes
                          <= self.device_budget_bytes):
            aid = self.cold[-1].alloc_id
            nbytes = self.cold[-1].table_bytes
            self.promote()
            return {"action": "promote", "alloc_index": aid, "bytes": nbytes}
        return {"action": "none"}

    def compact(self) -> TieredReport:
        """Reclaim drained levels in both tiers; returns the tier report.

        Cold levels whose count reached zero are dropped (host RAM freed);
        the hot cascade compacts in non-resetting mode while cold levels
        remain — resetting its allocation counter would break the
        cross-tier allocation ordering that snapshots rely on.
        """
        self.cold = [c for c in self.cold if c.count > 0]
        self.hot.compact(reset_when_empty=not self.cold)
        return self.report()

    def _enforce_budget(self) -> None:
        """Demote oldest hot levels until the budget holds (or one left)."""
        for _ in range(_MAX_DEMOTE_ROUNDS):
            if (self.hot.table_bytes <= self.device_budget_bytes
                    or len(self.hot.levels) <= 1):
                return
            self.demote()

    # -- cold-tier probes ----------------------------------------------------

    def _cold_query(self, keys_np: np.ndarray) -> np.ndarray:
        """One vectorized host probe per cold level, OR-reduced."""
        hits = np.zeros((keys_np.shape[0],), bool)
        for c in reversed(self.cold):
            hits |= np.asarray(
                self.adapter.host_query(c.config, c.arrays, keys_np))
        self._counters["cold_probes"] += 1
        self._counters["cold_probe_keys"] += int(keys_np.shape[0])
        self._counters["cold_hits"] += int(hits.sum())
        return hits

    def _cold_delete(self, keys_np: np.ndarray,
                     pending: np.ndarray) -> np.ndarray:
        """Newest-first host-side slot clear across cold levels."""
        ok = np.zeros((keys_np.shape[0],), bool)
        for c in reversed(self.cold):
            if not pending.any():
                break
            done = np.asarray(self.adapter.host_delete(
                c.config, c.arrays, keys_np, pending))
            ok |= pending & done
            pending = pending & ~done
        return ok

    # -- ops -----------------------------------------------------------------

    def insert(self, keys, *, bulk: bool = False,
               dedup_within_batch: bool = False,
               valid=None) -> InsertReport:
        """Insert into the hot tier, demoting old levels past the budget.

        Writes never touch the cold tier: the hot cascade grows under the
        watermark as usual, and any growth that pushes the device
        footprint past ``device_budget_bytes`` immediately demotes the
        oldest hot level(s) to host RAM.
        """
        report = self.hot.insert(keys, bulk=bulk,
                                 dedup_within_batch=dedup_within_batch,
                                 valid=valid)
        self._enforce_budget()
        return report

    def query(self, keys, *, valid=None) -> QueryResult:
        """Membership across both tiers with hot-hit short-circuit.

        One fused device pass over all hot levels first; only the slots
        that missed every hot level are gathered into a (usually much
        smaller) host batch and probed against the cold levels in one
        vectorized pass each. The common case — recently inserted keys —
        never leaves the device.
        """
        keys = normalize_keys(keys)
        qr = self.hot.query(keys, valid=valid)
        if not self.cold:
            return qr
        hits = np.array(np.asarray(qr.hits), bool)
        pend = _mask(keys, valid) & ~hits
        if pend.any():
            sub = np.asarray(keys, np.uint32)[pend]
            hits[pend] = self._cold_query(sub)
        return QueryResult(hits, np.asarray(qr.routed))

    def delete(self, keys, *, valid=None) -> DeleteReport:
        """Delete one stored copy per key, newest tier first.

        The hot cascade's query-then-delete pass runs first (newest level
        first); keys it could not find are cleared host-side from the
        packed cold arrays, again newest level first, so duplicate keys
        spanning tiers resolve in recency order exactly like a flat
        cascade would.
        """
        if not self.adapter.capabilities.supports_delete:
            raise NotImplementedError(
                f"{self.name}: append-only structure "
                "(capabilities.supports_delete is False)")
        keys = normalize_keys(keys)
        dr = self.hot.delete(keys, valid=valid)
        if not self.cold:
            return dr
        ok = np.array(np.asarray(dr.ok), bool)
        pend = _mask(keys, valid) & ~ok
        if pend.any():
            ok |= self._cold_delete(np.asarray(keys, np.uint32), pend)
        return DeleteReport(ok, np.asarray(dr.routed))

    def apply_ops(self, batch: OpBatch) -> MixedReport:
        """Execute a mixed op stream across both tiers (DESIGN.md §9/§12).

        The hot cascade runs the whole batch on its fused padded path
        first (inserts always resolve there). Query/delete slots the hot
        tier missed fall through to the cold tier: with no cold-routed
        deletes in the batch, all missed queries run as a single batched
        host probe; when a missed delete is present, the missed slots are
        replayed host-side in batch order so same-key query/delete
        interleavings keep exact positional semantics.
        """
        report = self.hot.apply_ops(batch)
        self._enforce_budget()
        if not self.cold:
            return report
        ok = np.array(np.asarray(report.ok), bool)
        valid = np.asarray(batch.valid, bool)
        ops = np.asarray(batch.ops)
        miss = valid & ~ok & ((ops == OP_QUERY) | (ops == OP_DELETE))
        if not miss.any():
            return report
        keys_np = np.asarray(batch.keys, np.uint32)
        deletes = miss & (ops == OP_DELETE)
        if deletes.any():
            ok |= self._cold_replay(keys_np, ops, miss)
        else:
            ok[miss] = self._cold_query(keys_np[miss])
        return MixedReport(ok, np.asarray(report.routed),
                           np.asarray(report.evictions),
                           np.asarray(report.rounds))

    def _cold_replay(self, keys_np: np.ndarray, ops: np.ndarray,
                     miss: np.ndarray) -> np.ndarray:
        """Sequential host replay of hot-missed slots, in batch order.

        Only taken when a batch routes a delete to the cold tier — a
        later query of the same key must observe that delete, so the
        missed slots cannot be batched into one probe. Exactness over
        throughput on this rare path.
        """
        ok = np.zeros((keys_np.shape[0],), bool)
        one = np.ones((1,), bool)
        for i in np.flatnonzero(miss):
            key = keys_np[i:i + 1]
            if ops[i] == OP_DELETE:
                ok[i] = bool(self._cold_delete(key, one.copy())[0])
            else:
                ok[i] = bool(self._cold_query(key)[0])
        return ok

    # -- lifecycle (DESIGN.md §10/§12) ---------------------------------------

    def snapshot(self) -> Snapshot:
        """Snapshot the full tier layout as one versioned host payload.

        Hot level ``i``'s arrays live under ``hot/level<i>/``, cold level
        ``i``'s under ``cold/level<i>/``; ``meta`` records each level's
        fingerprint, share, allocation index, and residency plus the
        cascade knobs and the device budget — enough for :meth:`restore`
        to rebuild both tiers exactly (and fail loudly on drift).
        """
        arrays, cold_meta, hot_meta = {}, [], []
        for i, c in enumerate(self.cold):
            for k, v in c.arrays.items():
                arrays[f"cold/level{i}/{k}"] = v
            cold_meta.append(self._level_meta(
                c.config, c.share, c.alloc_id, c.count, "cold"))
        for i, lvl in enumerate(self.hot.levels):
            for k, v in self.adapter.snapshot(lvl.config, lvl.state).items():
                arrays[f"hot/level{i}/{k}"] = v
            hot_meta.append(self._level_meta(
                lvl.config, self.hot.level_shares[i],
                self.hot.level_alloc_ids[i], lvl.count(), "hot"))
        hot = self.hot
        meta = {"hot_levels": hot_meta, "cold_levels": cold_meta,
                "device_budget_bytes": self.device_budget_bytes,
                "allocated": hot._allocated,
                "base_capacity": hot.base_capacity, "growth": hot.growth,
                "watermark": hot.watermark, "fpr_budget": hot.fpr_budget,
                "split_ratio": hot.split_ratio, "count": self.count()}
        configs = tuple(c.config for c in self.cold) + tuple(
            lvl.config for lvl in hot.levels)
        return Snapshot(backend=self.name, kind="tiered", fingerprint="",
                        arrays=arrays, meta=meta, configs=configs)

    def _level_meta(self, config, share: float, alloc_id: int,
                    count: int, residency: str) -> dict:
        """One level's snapshot/CLI metadata record."""
        return {"fingerprint": config_fingerprint(self.adapter, config),
                "share": share, "alloc_index": alloc_id, "count": count,
                "num_slots": config.num_slots,
                "table_bytes": config.table_bytes, "residency": residency}

    def restore(self, snap: Snapshot) -> "TieredHandle":
        """Rebuild both tiers from a tiered snapshot — validated.

        Level configs come from the snapshot when taken in-process;
        file-loaded snapshots replay the cascade's deterministic sizing
        over the *combined* allocation chain (cold then hot — allocation
        order by construction) and verify every config against its
        recorded fingerprint, raising
        :class:`~repro.amq.protocol.SnapshotMismatchError` on any drift.
        Returns ``self``.
        """
        if snap.kind != "tiered":
            raise SnapshotMismatchError(
                f"cannot restore a {snap.kind!r} snapshot onto a tiered "
                "handle (use auto_expand/static handles for those kinds)")
        if snap.backend != self.name:
            raise SnapshotMismatchError(
                f"snapshot is from backend {snap.backend!r}, "
                f"this handle is {self.name!r}")
        meta = snap.meta
        if meta["device_budget_bytes"] != self.device_budget_bytes:
            raise SnapshotMismatchError(
                f"device_budget_bytes mismatch: snapshot has "
                f"{meta['device_budget_bytes']}, this handle was built "
                f"with {self.device_budget_bytes}")
        hot = self.hot
        for knob in ("base_capacity", "growth", "split_ratio",
                     "watermark", "fpr_budget"):
            if getattr(hot, knob) != meta[knob]:
                raise SnapshotMismatchError(
                    f"cascade {knob} mismatch: snapshot has {meta[knob]}, "
                    f"this handle was built with {getattr(hot, knob)}")
        cold_meta, hot_meta = meta["cold_levels"], meta["hot_levels"]
        chain = list(cold_meta) + list(hot_meta)
        configs = snap.configs
        if not configs:  # file-loaded: replay the deterministic sizing
            configs, prev = [], None
            for lm in chain:
                cfg = hot._config_for(hot._level_capacity(lm["alloc_index"]),
                                      lm["share"], prev)
                configs.append(cfg)
                prev = cfg
        if len(configs) != len(chain):
            raise SnapshotMismatchError(
                f"snapshot carries {len(configs)} level configs for "
                f"{len(chain)} recorded levels")
        for i, (cfg, lm) in enumerate(zip(configs, chain)):
            got = config_fingerprint(self.adapter, cfg)
            if got != lm["fingerprint"]:
                raise SnapshotMismatchError(
                    f"tier level {i} config fingerprint mismatch:\n"
                    f"  snapshot: {lm['fingerprint']}\n  rebuilt:  {got}")
        n_cold = len(cold_meta)
        cold = []
        for i, (cfg, lm) in enumerate(zip(configs[:n_cold], cold_meta)):
            prefix = f"cold/level{i}/"
            arrays = {k[len(prefix):]: v for k, v in snap.arrays.items()
                      if k.startswith(prefix)}
            cold.append(ColdLevel(cfg, arrays, lm["share"],
                                  lm["alloc_index"]))
        levels = []
        for i, cfg in enumerate(configs[n_cold:]):
            prefix = f"hot/level{i}/"
            arrays = {k[len(prefix):]: v for k, v in snap.arrays.items()
                      if k.startswith(prefix)}
            state = self.adapter.restore(cfg, arrays)
            levels.append(FilterHandle(self.adapter, cfg, state))
        self.cold = cold
        hot.levels = levels
        hot._shares = [lm["share"] for lm in hot_meta]
        hot._alloc_ids = [lm["alloc_index"] for lm in hot_meta]
        hot._allocated = meta["allocated"]
        hot._query_fn = None
        return self
