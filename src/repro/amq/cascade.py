"""Auto-expanding AMQ cascades: unbounded inserts over any registry backend.

Every static filter in the registry is frozen at its ``make(capacity=...)``
size — an insert burst past capacity simply fails. The source paper's
partial-key Cuckoo filter cannot rehash its way out: stored tags are
fingerprints, not keys, so a bigger table cannot be rebuilt from a full one
(the bucket index needs hash bits the table never stored). The classic
escape is the *cascade filter* of Bender et al. ("Don't Thrash: How to
Cache Your Hash on Flash", §3) and the expandable AMQs of Maier et al.
(arXiv:1911.08374): keep a geometric sequence of levels, insert into the
newest, query them all, and split the false-positive budget across levels
so the aggregate FPR stays bounded however far the structure grows.

:class:`CascadeHandle` implements that scheme over *any* backend whose
adapter advertises ``supports_expand`` (DESIGN.md §8):

* **Levels** grow geometrically (``growth`` factor g, default 2): level
  ``i`` holds ``capacity * g**i`` keys. A new level is allocated when the
  active one reaches the ``watermark`` load factor or rejects keys.
* **Inserts** land in the active (newest) level, throttled to the level's
  remaining watermark headroom so no level is ever driven past its design
  load (which would blow its FPR share and, for cuckoo structures, its
  insert success guarantee).
* **Queries** fan across all levels in one batched pass — a single jitted
  program per level-set, so XLA shares the key hashing between levels and
  fuses the per-level probes.
* **Deletes** are routed to the level that holds the key (newest first), a
  query-then-delete pass per level, capability-gated like static handles.
* **``compact()``** reclaims drained levels. Stored tags cannot migrate
  between levels (the same partial-key constraint that forces the cascade
  in the first place), so compaction frees empty levels rather than
  merging live ones; a fully drained cascade resets to one fresh
  base-capacity level.

Example::

    from repro import amq

    h = amq.make("cuckoo", capacity=100_000, auto_expand=True)
    h.insert(keys_1m)                 # grows to ~4 levels, never refuses
    assert bool(h.query(keys_1m).hits.all())
    print(len(h.levels), h.load_factor, h.report().expected_fpr)
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hashing import normalize_keys
from .adapters import AMQAdapter, config_fingerprint, segmented_apply_ops
from .handle import FilterHandle
from .protocol import (
    OP_INSERT,
    CascadeReport,
    DeleteReport,
    InsertReport,
    LevelStats,
    MixedReport,
    OpBatch,
    QueryResult,
    Snapshot,
    SnapshotMismatchError,
    fpr_share,
)

# Per-level FPR shares are enforced at the structure's design load: a level
# is never filled past ``watermark``, so its analytic FPR at full load upper
# bounds anything it will exhibit in service.
_REF_LOAD = 1.0

# An insert batch provokes at most ~log_g(batch / capacity) growths; this
# backstop only trips if a backend keeps rejecting keys into fresh levels.
_MAX_GROW_ROUNDS = 64


def _mask(keys, valid) -> np.ndarray:
    """Normalize an optional validity mask to a host-side bool[n] copy."""
    n = int(keys.shape[0])
    if valid is None:
        return np.ones((n,), bool)
    return np.array(np.asarray(valid), bool)


class CascadeHandle:
    """Auto-expanding filter handle: a geometric cascade of level handles.

    Obtain via ``amq.make(name, capacity=..., auto_expand=True)``. The
    surface mirrors :class:`repro.amq.handle.FilterHandle` (``insert`` /
    ``query`` / ``delete`` / ``count`` / ``load_factor`` / ...) so
    consumers swap static handles for cascades without code changes.

    Example::

        >>> h = amq.make("cuckoo", capacity=1000, auto_expand=True)
        >>> _ = h.insert(keys)            # any number of keys
        >>> len(h.levels) >= 1            # doctest: +SKIP
        True

    Extra keyword arguments are the backend's sizing kwargs (forwarded to
    every level's ``make_config``); per-level FPR tightening overlays them
    with the adapter's ``growth_sizings`` ladder (DESIGN.md §8).
    """

    def __init__(self, adapter: AMQAdapter, capacity: int, *,
                 growth: float = 2.0, watermark: float = 0.85,
                 fpr_budget: Optional[float] = None,
                 split_ratio: float = 0.5,
                 max_levels: Optional[int] = None,
                 max_level_capacity: Optional[int] = None,
                 **base_kwargs: Any):
        """Build the cascade with a single fresh base-capacity level.

        ``max_level_capacity`` clamps the geometric ladder: level sizes
        stop growing once they reach it (the tiered wrapper derives it
        from ``device_budget_bytes`` so the active level always fits on
        device). Shares keep decaying past the clamp, so clamped levels
        may exceed their FPR share once the adapter's sizing ladder tops
        out — visible in ``report()``, never silent.
        """
        if not adapter.capabilities.supports_expand:
            raise NotImplementedError(
                f"{adapter.name}: backend cannot auto-expand "
                "(capabilities.supports_expand is False)")
        if not adapter.growth_sizings:
            raise ValueError(f"{adapter.name}: no growth_sizings hook")
        if growth <= 1.0:
            raise ValueError(f"growth factor must be > 1, got {growth}")
        if not 0.0 < watermark <= 1.0:
            raise ValueError(f"watermark must be in (0, 1], got {watermark}")
        if not 0.0 < split_ratio < 1.0:
            raise ValueError(
                f"split_ratio must be in (0, 1), got {split_ratio}")
        self.adapter = adapter
        self.base_capacity = int(capacity)
        self.growth = float(growth)
        self.watermark = float(watermark)
        self.split_ratio = float(split_ratio)
        self.max_levels = max_levels
        self.max_level_capacity = (None if max_level_capacity is None
                                   else int(max_level_capacity))
        if (self.max_level_capacity is not None
                and self.max_level_capacity < int(capacity)):
            raise ValueError(
                f"max_level_capacity ({self.max_level_capacity}) is below "
                f"the base capacity ({int(capacity)})")
        self.base_kwargs = dict(base_kwargs)
        if fpr_budget is None:
            # Declared budget: twice the base config's design FPR for level
            # 0, decaying geometrically — the level-0 share then admits the
            # backend's default sizing and the infinite-sum stays bounded.
            probe = adapter.make_config(self.base_capacity,
                                        **self.base_kwargs)
            fpr_budget = (2.0 * probe.expected_fpr(_REF_LOAD)
                          / (1.0 - self.split_ratio))
        self.fpr_budget = float(fpr_budget)
        self.levels: list = []
        self._shares: list = []
        self._alloc_ids: list = []  # allocation index per live level
        self._allocated = 0     # monotonic: shares keep decaying past churn
        self._query_fn = None   # (configs tuple, jitted fan) for the live set
        self._grow()

    # -- introspection -------------------------------------------------------

    @property
    def name(self) -> str:
        """Registry name of the wrapped backend."""
        return self.adapter.name

    @property
    def capabilities(self):
        """The wrapped backend's capability flags."""
        return self.adapter.capabilities

    @property
    def config(self):
        """The *active* (newest) level's static config."""
        return self.levels[-1].config

    @property
    def state(self):
        """The *active* (newest) level's state pytree."""
        return self.levels[-1].state

    @property
    def level_shares(self) -> tuple:
        """Per-live-level FPR shares (oldest first) — tier accounting."""
        return tuple(self._shares)

    @property
    def level_alloc_ids(self) -> tuple:
        """Per-live-level allocation indices (oldest first, monotonic)."""
        return tuple(self._alloc_ids)

    @property
    def num_slots(self) -> int:
        """Aggregate nominal capacity across live levels."""
        return sum(lvl.config.num_slots for lvl in self.levels)

    @property
    def table_bytes(self) -> int:
        """Aggregate device memory footprint across live levels."""
        return sum(lvl.config.table_bytes for lvl in self.levels)

    @property
    def load_factor(self) -> float:
        """Aggregate occupancy: total stored keys / total slots."""
        return self.count() / self.num_slots

    def count(self) -> int:
        """Total stored-key count across all levels."""
        return sum(lvl.count() for lvl in self.levels)

    def expected_fpr(self, load_factor: Optional[float] = None) -> float:
        """Aggregate analytic FPR: ``1 - prod(1 - eps_i)`` over levels.

        ``load_factor=None`` evaluates each level at its current occupancy;
        a float evaluates every level at that load (an upper bound).
        """
        miss = 1.0
        for lvl in self.levels:
            lf = lvl.load_factor if load_factor is None else load_factor
            miss *= 1.0 - lvl.config.expected_fpr(lf)
        return 1.0 - miss

    def report(self) -> CascadeReport:
        """Per-level and aggregate statistics (a :class:`CascadeReport`)."""
        stats, miss = [], 1.0
        slots = bytes_ = total = 0
        for i, (lvl, share) in enumerate(zip(self.levels, self._shares)):
            c, lf = lvl.count(), lvl.load_factor
            eps = lvl.config.expected_fpr(lf)
            stats.append(LevelStats(i, lvl.config.num_slots, c, lf,
                                    lvl.config.table_bytes, eps, share))
            slots += lvl.config.num_slots
            bytes_ += lvl.config.table_bytes
            total += c
            miss *= 1.0 - eps
        return CascadeReport(tuple(stats), slots, bytes_, total,
                             total / slots if slots else 0.0,
                             1.0 - miss, self.fpr_budget)

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        """Summarize backend, level count, and aggregate size."""
        return (f"CascadeHandle({self.adapter.name!r}, "
                f"levels={len(self.levels)}, slots={self.num_slots}, "
                f"bytes={self.table_bytes}, budget={self.fpr_budget:.2e})")

    # -- growth --------------------------------------------------------------

    def _config_for(self, capacity: int, share: float, prev=None):
        """Cheapest sizing on the adapter's ladder meeting ``share``.

        Falls back to the tightest available sizing when the ladder tops
        out (visible in ``report()``: that level's ``expected_fpr`` exceeds
        its ``fpr_share``). When the adapter has a ``grow_config`` hook and
        a previous level exists, the level is derived from it — backends
        use this to pin placement state (the sharded backend's mesh)
        across the whole cascade.
        """
        cfg = None
        for overlay in self.adapter.growth_sizings:
            if prev is not None and self.adapter.grow_config is not None:
                cfg = self.adapter.grow_config(prev, self.growth, **overlay)
            else:
                cfg = self.adapter.make_config(
                    capacity, **{**self.base_kwargs, **overlay})
            if cfg.expected_fpr(_REF_LOAD) <= share:
                break
        return cfg

    def _level_capacity(self, alloc_index: int) -> int:
        """Deterministic level sizing: geometric ladder, then the clamp."""
        capacity = max(1, int(round(
            self.base_capacity * self.growth ** alloc_index)))
        if self.max_level_capacity is not None:
            capacity = min(capacity, self.max_level_capacity)
        return capacity

    def _grow(self) -> bool:
        """Allocate the next level; False if ``max_levels`` forbids it."""
        if self.max_levels is not None and len(self.levels) >= self.max_levels:
            return False
        i = self._allocated
        capacity = self._level_capacity(i)
        share = fpr_share(self.fpr_budget, i, self.split_ratio)
        prev = self.levels[-1].config if self.levels else None
        handle = FilterHandle(self.adapter,
                              self._config_for(capacity, share, prev))
        self.levels.append(handle)
        self._shares.append(share)
        self._alloc_ids.append(i)
        self._allocated += 1
        return True

    # -- tier surgery (DESIGN.md §12) ----------------------------------------

    def detach_oldest(self):
        """Remove and return the oldest level: ``(handle, share, alloc_id)``.

        The tiered wrapper's demotion primitive: the detached level keeps
        its FPR share and allocation index so it can be re-attached (or
        probed cold) with the cascade's budget accounting intact. The
        active (newest) level can never be detached — the cascade must
        always have a write target.
        """
        if len(self.levels) <= 1:
            raise ValueError(
                "cannot detach the active level: a cascade needs at least "
                "one device-resident write target")
        self._query_fn = None
        return (self.levels.pop(0), self._shares.pop(0),
                self._alloc_ids.pop(0))

    def attach_oldest(self, handle: FilterHandle, share: float,
                      alloc_id: int) -> None:
        """Re-attach a previously detached level as the oldest (promotion).

        ``alloc_id`` must predate every live level's — levels are probed
        newest-first for deletes and the allocation order is what makes
        tier snapshots reconstructible, so out-of-order attachment fails
        loudly.
        """
        if self._alloc_ids and alloc_id >= self._alloc_ids[0]:
            raise ValueError(
                f"attach_oldest: alloc_id {alloc_id} does not predate the "
                f"oldest live level's ({self._alloc_ids[0]})")
        self._query_fn = None
        self.levels.insert(0, handle)
        self._shares.insert(0, share)
        self._alloc_ids.insert(0, alloc_id)

    # -- lifecycle (DESIGN.md §10) -------------------------------------------

    def snapshot(self) -> Snapshot:
        """Snapshot *all live levels* as one versioned host-side payload.

        Level ``i``'s state arrays are stored under ``level<i>/`` names;
        ``meta["levels"]`` records each level's config fingerprint, FPR
        share, and allocation index, so :meth:`restore` can rebuild the
        exact level stack (and fail loudly on any drift).

        Example::

            >>> snap = cascade.snapshot()
            >>> twin = amq.make(cascade.name, capacity=cascade.base_capacity,
            ...                 auto_expand=True, snapshot=snap)
        """
        if not self.adapter.capabilities.supports_snapshot:
            raise NotImplementedError(
                f"{self.name}: state cannot be snapshotted "
                "(capabilities.supports_snapshot is False)")
        arrays, levels = {}, []
        for i, lvl in enumerate(self.levels):
            for k, v in self.adapter.snapshot(lvl.config, lvl.state).items():
                arrays[f"level{i}/{k}"] = v
            levels.append({
                "fingerprint": config_fingerprint(self.adapter, lvl.config),
                "share": self._shares[i],
                "alloc_index": self._alloc_ids[i],
                "count": lvl.count(),
            })
        meta = {"levels": levels, "allocated": self._allocated,
                "base_capacity": self.base_capacity, "growth": self.growth,
                "watermark": self.watermark, "fpr_budget": self.fpr_budget,
                "split_ratio": self.split_ratio,
                "max_level_capacity": self.max_level_capacity,
                "count": self.count()}
        return Snapshot(backend=self.name, kind="cascade", fingerprint="",
                        arrays=arrays, meta=meta,
                        configs=tuple(lvl.config for lvl in self.levels))

    def restore(self, snap: Snapshot) -> "CascadeHandle":
        """Rebuild every live level from a cascade snapshot — validated.

        Level configs come from the snapshot itself when it was taken in
        this process (``snap.configs``); file-loaded snapshots re-derive
        them by replaying the cascade's deterministic level sizing (same
        ``capacity``/``growth``/sizing kwargs as at save time) and verify
        each against the recorded fingerprint — any disagreement (different
        ctor args, a ``grow_config`` chain broken by compaction) raises
        :class:`~repro.amq.protocol.SnapshotMismatchError` instead of
        restoring a mismatched table. Returns ``self``.
        """
        if snap.kind != "cascade":
            raise SnapshotMismatchError(
                f"cannot restore a {snap.kind!r} snapshot onto a cascade "
                "(static-filter snapshots restore onto FilterHandles)")
        if snap.backend != self.name:
            raise SnapshotMismatchError(
                f"snapshot is from backend {snap.backend!r}, "
                f"this cascade is {self.name!r}")
        meta = snap.meta
        for knob in ("base_capacity", "growth", "split_ratio",
                     "watermark", "fpr_budget", "max_level_capacity"):
            if getattr(self, knob) != meta.get(knob):
                raise SnapshotMismatchError(
                    f"cascade {knob} mismatch: snapshot has "
                    f"{meta.get(knob)}, this handle was built with "
                    f"{getattr(self, knob)}")
        levels_meta = meta["levels"]
        configs = snap.configs
        if not configs:  # file-loaded: replay the deterministic sizing
            configs, prev = [], None
            for lm in levels_meta:
                cfg = self._config_for(self._level_capacity(lm["alloc_index"]),
                                       lm["share"], prev)
                configs.append(cfg)
                prev = cfg
        if len(configs) != len(levels_meta):
            raise SnapshotMismatchError(
                f"snapshot carries {len(configs)} level configs for "
                f"{len(levels_meta)} recorded levels")
        levels = []
        for i, (cfg, lm) in enumerate(zip(configs, levels_meta)):
            got = config_fingerprint(self.adapter, cfg)
            if got != lm["fingerprint"]:
                raise SnapshotMismatchError(
                    f"level {i} config fingerprint mismatch:\n"
                    f"  snapshot: {lm['fingerprint']}\n  rebuilt:  {got}")
            prefix = f"level{i}/"
            arrays = {k[len(prefix):]: v for k, v in snap.arrays.items()
                      if k.startswith(prefix)}
            state = self.adapter.restore(cfg, arrays)
            levels.append(FilterHandle(self.adapter, cfg, state))
        self.levels = levels
        self._shares = [lm["share"] for lm in levels_meta]
        self._alloc_ids = [lm["alloc_index"] for lm in levels_meta]
        self._allocated = meta["allocated"]
        self._query_fn = None
        return self

    # -- ops -----------------------------------------------------------------

    def insert(self, keys, *, bulk: bool = False,
               dedup_within_batch: bool = False,
               valid=None) -> InsertReport:
        """Insert a batch, growing the cascade as needed.

        Keys land in the active level, throttled to its watermark headroom;
        rejected or overflowing keys trigger allocation of the next
        (``growth``-times larger) level and are retried there. ``ok`` is
        False only when growth is exhausted — ``max_levels`` reached, or a
        pathological backend kept rejecting keys into fresh levels until
        the internal round backstop tripped. ``routed`` is all-True:
        unrouted keys of sharded levels are retried internally.

        Example::

            >>> report = h.insert(keys, bulk=True)
            >>> bool(report.ok.all())      # doctest: +SKIP
            True
        """
        keys = normalize_keys(keys)
        n = int(keys.shape[0])
        pending = _mask(keys, valid)
        ok = np.zeros((n,), bool)
        evictions = np.zeros((n,), np.int32)
        rounds = 0
        for _ in range(_MAX_GROW_ROUNDS):
            if not pending.any():
                break
            level = self.levels[-1]
            headroom = (int(self.watermark * level.config.num_slots)
                        - level.count())
            if headroom <= 0:
                if not self._grow():
                    break
                continue
            # Throttle to headroom so the level never exceeds its
            # watermark (keeps every level's FPR share honest even for
            # backends like Bloom whose inserts never fail).
            take = pending & (np.cumsum(pending) <= headroom)
            rep = level.insert(keys, bulk=bulk,
                               dedup_within_batch=dedup_within_batch,
                               valid=take)
            landed = take & np.asarray(rep.ok) & np.asarray(rep.routed)
            ok |= landed
            evictions = np.where(landed, np.asarray(rep.evictions),
                                 evictions)
            rounds += int(np.asarray(rep.rounds))
            pending &= ~landed
            if (take & ~landed).any():
                # The level rejected routed keys (or could not route them):
                # it is effectively full for this workload — move on.
                if not self._grow():
                    break
        return InsertReport(ok, evictions, np.int32(rounds),
                            np.ones((n,), bool))

    def query(self, keys, *, valid=None) -> QueryResult:
        """Membership across all levels in one batched pass.

        For jit-able backends the whole fan is a single jitted program per
        level-set, so key hashing is shared between levels and the
        per-level probes fuse.

        Example::

            >>> hits = h.query(keys).hits
        """
        keys = normalize_keys(keys)
        if self.adapter.jit:
            configs = tuple(lvl.config for lvl in self.levels)
            states = tuple(lvl.state for lvl in self.levels)
            vm = (jnp.ones((keys.shape[0],), bool) if valid is None
                  else jnp.asarray(valid, bool))
            return self._fused_query(configs)(states, keys, vm)
        hits = np.zeros((int(keys.shape[0]),), bool)
        routed = np.ones_like(hits)
        for lvl in self.levels:
            qr = lvl.query(keys, valid=valid)
            hits |= np.asarray(qr.hits) & np.asarray(qr.routed)
            routed &= np.asarray(qr.routed)
        return QueryResult(hits, routed)

    def _fused_query(self, configs: tuple):
        """Build the one-pass multi-level query jit for a level-set.

        Only the *live* level-set's program is cached (growth/compaction
        churn would otherwise pin one dead XLA executable per historical
        level-set for the handle's lifetime).
        """
        if self._query_fn is None or self._query_fn[0] != configs:
            adapter = self.adapter

            def fan(states, keys, vm):
                """OR per-level hits; one trace so XLA shares the hashing."""
                hits = jnp.zeros((keys.shape[0],), bool)
                routed = jnp.ones((keys.shape[0],), bool)
                for cfg, st in zip(configs, states):
                    _, qr = adapter.query(cfg, st, keys, valid=vm)
                    hits = hits | (qr.hits & qr.routed)
                    routed = routed & qr.routed
                return QueryResult(hits, routed)

            self._query_fn = (configs, jax.jit(fan))
        return self._query_fn[1]

    def delete(self, keys, *, valid=None) -> DeleteReport:
        """Delete one stored copy per key, routed to the level holding it.

        Levels are probed newest-first with a query; the delete is applied
        only where that level reports a hit, so aliasing false-deletes are
        bounded by the per-level FPR shares. Capability-gated exactly like
        static handles.

        Example::

            >>> report = h.delete(keys)    # raises on append-only backends
        """
        if not self.adapter.capabilities.supports_delete:
            raise NotImplementedError(
                f"{self.name}: append-only structure "
                "(capabilities.supports_delete is False)")
        keys = normalize_keys(keys)
        n = int(keys.shape[0])
        pending = _mask(keys, valid)
        ok = np.zeros((n,), bool)
        for lvl in reversed(self.levels):
            if not pending.any():
                break
            qr = lvl.query(keys, valid=pending)
            target = pending & np.asarray(qr.hits) & np.asarray(qr.routed)
            if not target.any():
                continue
            dr = lvl.delete(keys, valid=target)
            done = target & np.asarray(dr.ok) & np.asarray(dr.routed)
            ok |= done
            pending &= ~done
        return DeleteReport(ok, np.ones((n,), bool))

    def apply_ops(self, batch: OpBatch) -> MixedReport:
        """Execute a mixed op stream against the cascade (DESIGN.md §9).

        Fast path: while the cascade is a *single* level with enough
        watermark headroom for every insert slot in the batch (the common
        steady state), the whole batch runs as that level's one fused
        program; inserts the level still rejected are retried through the
        growing :meth:`insert` path. Otherwise the batch falls back to
        maximal same-op runs replayed against the cascade ops, which
        preserve per-level routing (queries fan all levels, deletes route
        newest-first).

        Example::

            >>> report = h.apply_ops(batch)   # never refuses inserts
        """
        if len(self.levels) == 1 and self.adapter.apply_ops is not None:
            # Host sync on the op codes only on this branch — the
            # multi-level fallback never needs the insert count.
            n_ins = int(np.asarray(batch.valid
                                   & (batch.ops == OP_INSERT)).sum())
            level = self.levels[0]
            headroom = (int(self.watermark * level.config.num_slots)
                        - level.count())
            if n_ins <= headroom:
                report = level.apply_ops(batch)
                failed = (np.asarray(batch.valid)
                          & np.asarray(batch.ops == OP_INSERT)
                          & ~(np.asarray(report.ok)
                              & np.asarray(report.routed)))
                if not failed.any():
                    return report
                retry = self.insert(batch.keys, valid=jnp.asarray(failed))
                ok = np.asarray(report.ok) | (failed & np.asarray(retry.ok))
                # Only the retried insert slots become routed (the growing
                # insert path handles routing internally); unrouted query/
                # delete slots keep their level report's routed=False so
                # callers still see them as unanswered, never as misses.
                routed = np.asarray(report.routed) | failed
                return MixedReport(ok, routed,
                                   np.asarray(report.evictions),
                                   np.asarray(report.rounds))
        return segmented_apply_ops(self, batch)

    def compact(self, *, reset_when_empty: bool = True) -> CascadeReport:
        """Reclaim drained levels; returns the post-compaction report.

        Stored tags cannot be rehashed into another level (partial-key
        constraint — the reason the cascade exists), so compaction frees
        levels whose count reached zero instead of merging live ones. A
        fully drained cascade resets to a single fresh base-capacity level
        and reclaims its whole FPR budget — unless
        ``reset_when_empty=False`` (the tiered wrapper's mode: resetting
        the allocation counter while demoted cold levels still exist would
        break the cross-tier allocation ordering, so the drained active
        level is kept as the write target instead).

        Example::

            >>> h.delete(keys)             # drain a level ...
            >>> report = h.compact()       # ... and free it
        """
        live = [(lvl, share, aid) for lvl, share, aid
                in zip(self.levels, self._shares, self._alloc_ids)
                if lvl.count() > 0]
        if live:
            if len(live) != len(self.levels):
                self._query_fn = None
            self.levels = [lvl for lvl, _, _ in live]
            self._shares = [share for _, share, _ in live]
            self._alloc_ids = [aid for _, _, aid in live]
        elif reset_when_empty:
            self.levels, self._shares, self._alloc_ids = [], [], []
            self._allocated = 0
            self._query_fn = None
            self._grow()
        else:
            if len(self.levels) > 1:
                self._query_fn = None
            self.levels = self.levels[-1:]
            self._shares = self._shares[-1:]
            self._alloc_ids = self._alloc_ids[-1:]
        return self.report()
