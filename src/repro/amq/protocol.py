"""The unified AMQ protocol: result types, capability model, config contract.

Every filter family in this repo (the paper's Cuckoo filter, its mesh-sharded
variant, the four baselines, and the pure-Python oracle) is exposed through
one functional contract so that consumers — benchmarks, the training-data
deduper, the serving prefix cache — program against *capabilities* instead of
concrete classes (DESIGN.md §7):

    insert / insert_bulk :: (config, state, keys, *, opts) -> (state', InsertReport)
    query                :: (config, state, keys, *, opts) -> (state,  QueryResult)
    delete               :: (config, state, keys, *, opts) -> (state', DeleteReport)

``keys`` are always ``uint32[n, 2]`` little-endian (lo, hi) pairs of 64-bit
keys (see :func:`repro.core.hashing.keys_from_numpy`). Results are pytrees of
arrays so the ops stay jit-compatible with ``config`` static.

This module is dependency-light on purpose (jax/numpy only): both
``repro.core`` and ``repro.filters`` re-export these types, so it must not
import either.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Capability model.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend can do — consumers branch on these, never on names.

    * ``supports_delete`` — keys can be removed (the paper's headline
      capability vs append-only Bloom filters).
    * ``supports_bulk`` — has a dedicated bulk-build insertion fast path
      (``insert(..., bulk=True)`` routes to it).
    * ``supports_sharding`` — state lives sharded across a device mesh; ops
      run under ``shard_map`` and report a ``routed`` mask (keys that
      overflowed their routing bin and must be retried).
    * ``counting`` — multiset semantics: inserting a key twice stores two
      copies and each needs its own delete.
    * ``exact`` — zero false positives (stores full keys, not fingerprints).
    * ``serial_insert`` — insertion is inherently sequential per key (the
      GQF's Robin-Hood shifting); benchmark consumers cap its prefill sizes.
    * ``supports_expand`` — the backend can be stacked into an auto-expanding
      cascade (:mod:`repro.amq.cascade`): its sizing knobs can tighten the
      per-level FPR geometrically (DESIGN.md §8). False for structures whose
      packing caps the fingerprint width (the TCF's uint32 stash words).
    * ``supports_mixed`` — has a *native fused* mixed-operation path
      (``apply_ops`` over an :class:`OpBatch`): one compiled program executes
      an interleaved query/insert/delete stream (DESIGN.md §9). Backends
      without it still accept ``OpBatch``\\ es through the handle — the
      generic fallback segments the batch into maximal same-op runs and
      replays the per-op entry points, at one dispatch per run.
    * ``supports_snapshot`` — filter state round-trips through a versioned
      host-side :class:`Snapshot` (config fingerprint + packed table
      arrays): ``handle.snapshot()`` / ``handle.restore(snap)`` survive
      process restarts, move between meshes, and feed the serving layer's
      zero-downtime ``hot_swap`` (DESIGN.md §10). Restoring onto a
      mismatched config raises :class:`SnapshotMismatchError` — loudly,
      never a silently-corrupt table.
    * ``supports_tiering`` — frozen levels of this backend can live in host
      RAM as packed snapshot arrays and still answer queries: the adapter
      provides a vectorized numpy ``host_query`` (and, when
      ``supports_delete``, a ``host_delete`` slot-clear) over the arrays
      its ``snapshot`` hook produces. This is what lets a
      :class:`~repro.amq.tiering.TieredHandle` demote cold cascade levels
      off-device for beyond-HBM capacity (DESIGN.md §12).
    """

    supports_delete: bool = True
    supports_bulk: bool = False
    supports_sharding: bool = False
    counting: bool = True
    exact: bool = False
    serial_insert: bool = False
    supports_expand: bool = False
    supports_mixed: bool = False
    supports_snapshot: bool = False
    supports_tiering: bool = False


# ---------------------------------------------------------------------------
# Mixed-operation batches (DESIGN.md §9): one unit of execution carrying an
# interleaved stream of queries, inserts, and deletes.
# ---------------------------------------------------------------------------

# Per-key op codes. int32 so op arrays live happily inside jitted programs.
OP_QUERY = 0
OP_INSERT = 1
OP_DELETE = 2

OP_NAMES = {OP_QUERY: "query", OP_INSERT: "insert", OP_DELETE: "delete"}


def normalize_ops(ops, n: int, *, arg: str = "ops"):
    """Validate an op-code channel against its ``n``-key batch.

    The one ops-boundary check shared by :meth:`OpBatch.make` and
    ``FilterService.submit`` (so the two cannot drift): integer dtype,
    length ``n``, codes in ``{OP_QUERY, OP_INSERT, OP_DELETE}`` — value
    checks run whenever the array is concrete (host-side; inside jit only
    shape/dtype apply). Returns int32[n] (numpy for host inputs, the
    traced array inside jit). Raises ``ValueError`` naming ``arg``.
    """
    from ..core.hashing import _is_tracer

    if _is_tracer(ops):
        ops = jnp.asarray(ops, jnp.int32)
        if ops.shape != (n,):
            raise ValueError(
                f"{arg}: shape {tuple(ops.shape)} — expected ({n},) to "
                f"match {n} keys")
        return ops
    arr = np.asarray(ops)
    # Bool arrays are rejected on purpose: a hits/valid mask passed as
    # ops would otherwise silently become QUERY/INSERT codes.
    if arr.dtype == object or not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"{arg}: expected integer op codes, got dtype {arr.dtype}")
    if arr.shape != (n,):
        raise ValueError(
            f"{arg}: shape {tuple(arr.shape)} — expected ({n},), one op "
            f"code per key")
    # Range-check in the ORIGINAL dtype: casting first would wrap
    # out-of-int32-range garbage (e.g. 2**32) onto valid codes.
    if arr.size and ((arr < OP_QUERY) | (arr > OP_DELETE)).any():
        bad = arr[(arr < OP_QUERY) | (arr > OP_DELETE)][0]
        raise ValueError(
            f"{arg}: unknown op code {int(bad)} (valid codes: "
            f"{OP_QUERY}=query, {OP_INSERT}=insert, {OP_DELETE}=delete)")
    return arr.astype(np.int32)


class OpBatch(NamedTuple):
    """A mixed stream of filter operations — the unit of fused execution.

    * ``keys``  — uint32[n, 2] (lo, hi) key pairs, like every other op.
    * ``ops``   — int32[n] op codes (:data:`OP_QUERY` / :data:`OP_INSERT` /
      :data:`OP_DELETE`).
    * ``valid`` — bool[n]; False marks padding slots (micro-batching
      front-ends pad to a fixed batch size so one compiled program serves
      every traffic shape).

    Semantics are positional: operations on the *same 64-bit key* resolve
    in batch order (a query at index i observes exactly the inserts and
    deletes of that key at indices j < i — DESIGN.md §9). A plain pytree,
    safe to pass through jit.
    """

    keys: jnp.ndarray
    ops: jnp.ndarray
    valid: jnp.ndarray

    @staticmethod
    def make(keys, ops, valid=None) -> "OpBatch":
        """Normalize (keys, ops[, valid]) into a well-typed batch.

        ``keys`` may be raw ``uint64[n]`` or packed ``uint32[n, 2]`` pairs
        (the key-format contract — see ``repro.core.hashing.
        normalize_keys``); ``ops`` must be integer op codes in
        ``{OP_QUERY, OP_INSERT, OP_DELETE}`` and ``valid`` a bool-like
        ``[n]`` mask. Malformed arguments raise ``ValueError`` naming the
        offending argument; op-code *values* are checked whenever the array
        is concrete (host-side callers — inside jit the check is skipped,
        shapes/dtypes still apply).
        """
        from ..core.hashing import normalize_keys

        keys = jnp.asarray(normalize_keys(keys, arg="keys"), jnp.uint32)
        ops = jnp.asarray(normalize_ops(ops, keys.shape[0]), jnp.int32)
        if valid is not None:
            vshape = tuple(getattr(valid, "shape", np.shape(valid)))
            if vshape != (keys.shape[0],):
                raise ValueError(
                    f"valid: shape {vshape} does not match "
                    f"{keys.shape[0]} keys (want a bool[n] mask)")
        return OpBatch(keys, ops, ensure_valid(keys, valid))

    @staticmethod
    def make_padded(keys, ops, n: int) -> "OpBatch":
        """Build an ``n``-slot batch with the padding done host-side.

        The steady-state dispatch path (``FilterService._dispatch``) holds
        host numpy arrays and needs a ladder-shaped batch on device.
        ``make(...).pad_to(n)`` would transfer the ragged arrays and then
        run three device-side concatenates per dispatch; this constructor
        pads in numpy instead, so each channel crosses the host→device
        boundary exactly once, already at its final shape — zero extra
        device copies on the hot path. Semantically identical to
        ``make(keys, ops).pad_to(n)``.
        """
        from ..core.hashing import normalize_keys

        keys = np.asarray(normalize_keys(keys, arg="keys"), np.uint32)
        ops = np.asarray(normalize_ops(ops, keys.shape[0]), np.int32)
        m = keys.shape[0]
        pad = n - m
        if pad < 0:
            raise ValueError(f"batch of {m} cannot pad to {n}")
        if pad:
            keys = np.concatenate([keys, np.zeros((pad, 2), np.uint32)])
            ops = np.concatenate([ops, np.full((pad,), OP_QUERY, np.int32)])
        valid = np.zeros((n,), bool)
        valid[:m] = True
        return OpBatch(jnp.asarray(keys), jnp.asarray(ops),
                       jnp.asarray(valid))

    @property
    def size(self) -> int:
        """Number of slots in the batch (including padding)."""
        return self.keys.shape[0]

    def pad_to(self, n: int) -> "OpBatch":
        """Pad with invalid query slots up to ``n`` (static-shape batching)."""
        pad = n - self.size
        if pad < 0:
            raise ValueError(f"batch of {self.size} cannot pad to {n}")
        if pad == 0:
            return self
        return OpBatch(
            jnp.concatenate([self.keys, jnp.zeros((pad, 2), jnp.uint32)]),
            jnp.concatenate([self.ops,
                             jnp.full((pad,), OP_QUERY, jnp.int32)]),
            jnp.concatenate([self.valid, jnp.zeros((pad,), bool)]))


# ---------------------------------------------------------------------------
# Standardized result types (pytrees — safe jit return values).
# ---------------------------------------------------------------------------

class InsertReport(NamedTuple):
    """Uniform insertion result.

    * ``ok`` — bool[n]; False means the structure was too full for that key.
    * ``evictions`` — int32[n] eviction-chain length (zeros for filters with
      no eviction machinery).
    * ``rounds`` — int32[] rounds the batch loop ran (0 for single-pass
      structures).
    * ``routed`` — bool[n]; False means the key never reached its owner shard
      (sharded backends' fixed-capacity bins) and should be retried.
      All-True for unsharded backends; ``ok`` is only meaningful where
      ``routed``.
    """

    ok: jnp.ndarray
    evictions: jnp.ndarray
    rounds: jnp.ndarray
    routed: jnp.ndarray


class QueryResult(NamedTuple):
    """Uniform membership-query result (``hits`` valid where ``routed``)."""

    hits: jnp.ndarray
    routed: jnp.ndarray


class DeleteReport(NamedTuple):
    """Uniform deletion result (``ok`` = a stored copy was removed)."""

    ok: jnp.ndarray
    routed: jnp.ndarray


class MixedReport(NamedTuple):
    """Result of executing an :class:`OpBatch` (one slot per operation).

    * ``ok`` — bool[n], interpreted by that slot's op code: query → hit,
      insert → landed, delete → a stored copy was removed. False on padding
      (invalid) slots.
    * ``routed`` — bool[n]; as in the per-op reports, ``ok`` is only
      meaningful where ``routed`` (sharded backends' bin overflow).
    * ``evictions`` — int32[n] eviction-chain lengths (insert slots only).
    * ``rounds`` — int32[] total rounds across the fused program.

    The per-op views below slice this into the standard report types with
    op-masked ``routed`` — a slot outside the view's op reports
    ``routed=False`` there, so consumers can reuse per-op code unchanged.
    """

    ok: jnp.ndarray
    routed: jnp.ndarray
    evictions: jnp.ndarray
    rounds: jnp.ndarray

    def _view(self, batch: "OpBatch", code: int):
        mask = batch.valid & (batch.ops == code)
        return self.ok & mask, self.routed & mask

    def insert_report(self, batch: "OpBatch") -> InsertReport:
        """Sub-report for the batch's insert slots (routed-masked)."""
        ok, routed = self._view(batch, OP_INSERT)
        return InsertReport(ok, self.evictions, self.rounds, routed)

    def query_result(self, batch: "OpBatch") -> QueryResult:
        """Sub-report for the batch's query slots (routed-masked)."""
        hits, routed = self._view(batch, OP_QUERY)
        return QueryResult(hits, routed)

    def delete_report(self, batch: "OpBatch") -> DeleteReport:
        """Sub-report for the batch's delete slots (routed-masked)."""
        ok, routed = self._view(batch, OP_DELETE)
        return DeleteReport(ok, routed)


# ---------------------------------------------------------------------------
# Cascade (auto-expansion) reporting — host-side introspection types.
# ---------------------------------------------------------------------------

class LevelStats(NamedTuple):
    """Snapshot of one cascade level (host-side plain Python values).

    Example::

        >>> report = handle.report()          # handle: a CascadeHandle
        >>> report.levels[0].load_factor      # doctest: +SKIP
        0.85

    ``fpr_share`` is the slice of the cascade's FPR budget this level was
    sized against (DESIGN.md §8); ``expected_fpr`` is the level's analytic
    FPR at its *current* load, so ``expected_fpr <= fpr_share`` holds for
    every level whose backend could meet its share.
    """

    level: int
    num_slots: int
    count: int
    load_factor: float
    table_bytes: int
    expected_fpr: float
    fpr_share: float


class CascadeReport(NamedTuple):
    """Aggregate view of an auto-expanding cascade (DESIGN.md §8).

    Example::

        >>> h = amq.make("cuckoo", capacity=1000, auto_expand=True)
        >>> h.report().num_levels             # doctest: +SKIP
        1

    ``expected_fpr`` is the aggregate analytic false-positive rate
    ``1 - prod(1 - eps_i)`` over live levels; the cascade keeps it under
    ``fpr_budget`` whenever every level met its share.
    """

    levels: tuple
    num_slots: int
    table_bytes: int
    count: int
    load_factor: float
    expected_fpr: float
    fpr_budget: float

    @property
    def num_levels(self) -> int:
        """Number of live levels in the cascade."""
        return len(self.levels)


class TierStats(NamedTuple):
    """One level of a tiered handle, annotated with its residency.

    ``residency`` is ``"hot"`` (device-resident, write-absorbing) or
    ``"cold"`` (frozen in host RAM as packed snapshot arrays — DESIGN.md
    §12). ``alloc_index`` is the cascade allocation index the level was
    born with: cold levels always carry strictly smaller indices than hot
    ones (demotion is oldest-first), so sorting by it recovers the full
    newest-to-oldest delete routing order across tiers.
    """

    residency: str
    alloc_index: int
    num_slots: int
    count: int
    load_factor: float
    table_bytes: int
    expected_fpr: float
    fpr_share: float


class TieredReport(NamedTuple):
    """Aggregate view of a GPU-hot / host-cold tiered handle (DESIGN.md §12).

    ``device_bytes`` counts only hot (device-resident) levels and is what
    the handle keeps under ``device_budget_bytes``; ``host_bytes`` is the
    cold tier's RAM footprint. ``expected_fpr`` aggregates *all* levels —
    a query consults both tiers, so the cascade FPR-budget accounting is
    unchanged by demotion.
    """

    levels: tuple
    device_budget_bytes: int
    device_bytes: int
    host_bytes: int
    count: int
    expected_fpr: float
    fpr_budget: float
    demotions: int
    promotions: int
    cold_probes: int
    cold_hits: int

    @property
    def hot_levels(self) -> tuple:
        """The device-resident subset of ``levels``."""
        return tuple(s for s in self.levels if s.residency == "hot")

    @property
    def cold_levels(self) -> tuple:
        """The host-RAM subset of ``levels``."""
        return tuple(s for s in self.levels if s.residency == "cold")


def fpr_share(budget: float, level: int, ratio: float = 0.5) -> float:
    """Geometric FPR-budget split: level ``i`` gets ``budget*(1-r)*r^i``.

    The shares of an infinite cascade sum to exactly ``budget`` (classic
    cascade-filter accounting, Bender et al. §3), so however many levels an
    insert stream provokes, the aggregate analytic FPR stays under target::

        >>> sum(fpr_share(0.01, i) for i in range(50))  # -> ~0.01
        0.00999...

    ``ratio`` is the per-level decay (0.5 halves each level's share, which
    for fingerprint filters costs ~1 extra tag bit per level).
    """
    if not 0.0 < ratio < 1.0:
        raise ValueError(f"fpr split ratio must be in (0, 1), got {ratio}")
    return budget * (1.0 - ratio) * ratio ** level


# ---------------------------------------------------------------------------
# Filter-state lifecycle: versioned host-side snapshots (DESIGN.md §10).
# ---------------------------------------------------------------------------

SNAPSHOT_VERSION = 1
"""Format version stamped into every :class:`Snapshot` (and snapshot file).

Bump when the payload layout changes; ``restore`` refuses newer versions
loudly instead of misreading them.
"""


class SnapshotMismatchError(ValueError):
    """A snapshot does not fit its restore target.

    Raised when backend names, config fingerprints, format versions, or
    array shapes/dtypes disagree — a partial-key filter state is only
    meaningful under the exact config (hashes, layout, placement) that
    built it, so a mismatched restore must fail loudly rather than produce
    a silently-corrupt table.
    """


class Snapshot(NamedTuple):
    """Versioned host-side filter-state payload (DESIGN.md §10).

    * ``backend`` — registry name of the producing backend.
    * ``kind`` — ``"filter"`` (one static handle), ``"cascade"`` (all
      live levels of a :class:`~repro.amq.cascade.CascadeHandle`), or
      ``"tiered"`` (both tiers of a
      :class:`~repro.amq.tiering.TieredHandle`, hot and cold).
    * ``fingerprint`` — the producing config's identity string (see
      ``repro.amq.adapters.config_fingerprint``); restore targets must
      match it exactly. Cascade snapshots keep per-level fingerprints in
      ``meta["levels"]`` instead.
    * ``arrays`` — ``name -> numpy array``: the packed state, pulled to
      host (cascade levels prefix names with ``level<i>/``).
    * ``meta`` — JSON-able descriptive payload (counts, level shares, ...).
    * ``configs`` — the in-memory config objects the snapshot was taken
      under (one per level; empty for file-loaded snapshots, which restore
      onto a caller-built config after fingerprint validation).
    * ``version`` — :data:`SNAPSHOT_VERSION` at creation time.
    """

    backend: str
    kind: str
    fingerprint: str
    arrays: dict
    meta: dict
    configs: tuple = ()
    version: int = SNAPSHOT_VERSION

    @property
    def nbytes(self) -> int:
        """Total host-side payload size in bytes."""
        return int(sum(a.nbytes for a in self.arrays.values()))


def save_snapshot(path, snap: Snapshot) -> None:
    """Persist a snapshot as an ``.npz`` (arrays + JSON header).

    The in-memory ``configs`` tuple is deliberately *not* serialized:
    a file restore rebuilds the config from code (the same ``amq.make``
    call that created the filter) and the fingerprint check proves it
    matches — so snapshot files contain only arrays and JSON, no pickled
    code objects.
    """
    import json

    header = {"version": snap.version, "backend": snap.backend,
              "kind": snap.kind, "fingerprint": snap.fingerprint,
              "meta": snap.meta}
    np.savez(path, __header__=np.frombuffer(
        json.dumps(header).encode(), np.uint8),
        **{k: np.asarray(v) for k, v in snap.arrays.items()})


def load_snapshot(path) -> Snapshot:
    """Load a snapshot written by :func:`save_snapshot`.

    The returned snapshot carries no ``configs``; restore it through a
    handle built with the matching config (``amq.make(..., snapshot=...)``
    or ``handle.restore``), which validates the fingerprint.
    """
    import json

    with np.load(path) as z:
        header = json.loads(bytes(z["__header__"]).decode())
        arrays = {k: z[k] for k in z.files if k != "__header__"}
    if header["version"] > SNAPSHOT_VERSION:
        raise SnapshotMismatchError(
            f"snapshot format v{header['version']} is newer than this "
            f"library's v{SNAPSHOT_VERSION}; refusing to guess its layout")
    return Snapshot(header["backend"], header["kind"],
                    header["fingerprint"], arrays, header["meta"],
                    (), header["version"])


# ---------------------------------------------------------------------------
# Config/state contract.
# ---------------------------------------------------------------------------

@runtime_checkable
class AMQConfig(Protocol):
    """Static, hashable configuration every backend config satisfies.

    Concrete configs are frozen dataclasses usable as jit static arguments;
    each also provides a ``for_capacity(capacity, **kw)`` constructor
    (classmethod/staticmethod — not expressible in a Protocol method here).
    """

    @property
    def num_slots(self) -> int:
        """Nominal key capacity of the structure."""
        ...

    @property
    def table_bytes(self) -> int:
        """Device memory footprint of the state."""
        ...

    def expected_fpr(self, load_factor: float) -> float:
        """Analytic false-positive rate at a given load (0.0 if exact)."""
        ...

    def init(self):
        """Fresh empty state (a pytree of arrays, or a host-side oracle)."""
        ...


def load_factor(config: AMQConfig, state) -> float:
    """Uniform occupancy: stored keys / nominal capacity.

    Works for any backend whose state carries a ``count`` field (all of
    ours, including the Python oracle's ``count`` attribute).
    """
    count = getattr(state, "count")
    total = float(jnp.sum(count)) if hasattr(count, "ndim") else float(count)
    return total / config.num_slots


def all_routed(keys: jnp.ndarray) -> jnp.ndarray:
    """The trivial ``routed`` mask for unsharded backends."""
    return jnp.ones((keys.shape[0],), bool)


def ensure_valid(keys: jnp.ndarray,
                 valid: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Normalize an optional validity mask to a concrete bool[n]."""
    if valid is None:
        return jnp.ones((keys.shape[0],), bool)
    return valid.astype(bool)


def fpr_tolerance(expected: float, n_probes: int,
                  factor: float = 5.0) -> tuple:
    """Acceptance band ``(lo, hi)`` for an empirically measured FPR.

    Example::

        >>> lo, hi = fpr_tolerance(expected=1e-3, n_probes=1 << 14)
        >>> lo <= 1e-3 <= hi
        True

    The analytic formulas are asymptotic (blocked-Bloom skew, partial
    buckets), hence the multiplicative ``factor``; the additive slack keeps
    a few stray hits from failing low-FPR structures, and the lower bound
    only applies when the model predicts enough hits to rise above counting
    noise. Shared by benchmarks/fpr.py and the conformance suite so the
    band cannot drift between them. Exact structures get (0, 0).
    """
    if expected == 0.0:
        return 0.0, 0.0
    hi = factor * expected + 8.0 / n_probes
    lo = expected / factor if expected * n_probes >= 30 else 0.0
    return lo, hi
