"""Unified AMQ API: one protocol, one registry, every filter family.

    from repro import amq

    amq.names()                          # registered backends
    h = amq.make("cuckoo", capacity=1_000_000)
    h.insert(keys, bulk=True)            # -> InsertReport(ok, evictions, ...)
    h.query(keys).hits                   # -> bool[n]
    h.delete(keys)                       # capability-gated

See DESIGN.md §7 for the protocol, capability flags, and result types.

Only :mod:`repro.amq.protocol` is imported eagerly (it is dependency-light
and re-exported by ``repro.core``/``repro.filters``); the registry and its
adapters — which import the whole filter zoo — load lazily on first use, so
``import repro.core`` never cycles through this package.
"""

from .protocol import (  # noqa: F401
    OP_DELETE,
    OP_INSERT,
    OP_QUERY,
    SNAPSHOT_VERSION,
    AMQConfig,
    Capabilities,
    CascadeReport,
    DeleteReport,
    InsertReport,
    LevelStats,
    MixedReport,
    OpBatch,
    QueryResult,
    Snapshot,
    SnapshotMismatchError,
    TieredReport,
    TierStats,
    fpr_share,
    fpr_tolerance,
    load_factor,
    load_snapshot,
    save_snapshot,
)

_LAZY = ("make", "get", "names", "register", "FilterHandle", "AMQAdapter",
         "CascadeHandle", "TieredHandle", "ColdLevel", "FilterService",
         "Ticket", "ServiceMetrics", "QueueFullError")

__all__ = list(_LAZY) + [
    "AMQConfig", "Capabilities", "CascadeReport", "DeleteReport",
    "InsertReport", "LevelStats", "MixedReport", "OpBatch", "OP_QUERY",
    "OP_INSERT", "OP_DELETE", "QueryResult", "Snapshot",
    "SnapshotMismatchError", "SNAPSHOT_VERSION", "TieredReport",
    "TierStats", "fpr_share", "fpr_tolerance", "load_factor",
    "load_snapshot", "save_snapshot",
]


def __getattr__(name):
    """Resolve the registry/handle surface lazily (see module docstring)."""
    if name in ("make", "get", "names", "register"):
        from . import registry

        return getattr(registry, name)
    if name == "FilterHandle":
        from .handle import FilterHandle

        return FilterHandle
    if name == "CascadeHandle":
        from .cascade import CascadeHandle

        return CascadeHandle
    if name in ("TieredHandle", "ColdLevel"):
        from . import tiering

        return getattr(tiering, name)
    if name in ("FilterService", "Ticket"):
        from . import service

        return getattr(service, name)
    if name in ("ServiceMetrics", "QueueFullError"):
        from . import dispatch

        return getattr(dispatch, name)
    if name == "AMQAdapter":
        from .adapters import AMQAdapter

        return AMQAdapter
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
