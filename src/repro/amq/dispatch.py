"""Dispatch engine internals for :class:`repro.amq.service.FilterService`.

The service front door (submission API, tickets, hot swap) lives in
``service.py``; this module owns the machinery underneath (DESIGN.md §11):

* **Shape ladder** (:func:`shape_ladder` / :func:`rung_for`): a forced
  (deadline/flush/backpressure) dispatch no longer pads a 3-op tail to the
  full ``batch_size`` — it pads to the smallest ladder rung that fits.
  Rungs double from a small base up to ``batch_size``, so the set of
  compiled shapes stays logarithmic (one cached jit per rung, cached
  inside the handle's per-op jit by XLA's shape-keyed trace cache) while
  padding waste on short dispatches drops from ``batch_size - m`` to at
  most ``m``. Every rung is a multiple of the backend's ``batch_align``
  (the sharded backend's shard count — its all-to-all splits the batch
  across devices), so ladder dispatches stay legal on every backend.
* **Pending stream** (:class:`PendingStream`): the bounded admission queue
  — arrival-ordered keys/ops plus per-op enqueue timestamps and per-client
  occupancy (the fairness ledger admission control reads).
* **In-flight tracking** (:class:`Dispatch`): each dispatched batch keeps
  its report lazy (double buffering: the host packs batch *k+1* while the
  device runs batch *k*) until a ticket demands results or the engine's
  ``max_in_flight`` window slides past it; first concretisation stamps the
  batch's enqueue→ready latencies into the metrics.
* **SLO observability** (:class:`ServiceMetrics`): histogram-bucketed
  enqueue→dispatch and enqueue→ready latency (p50/p99 without retaining
  per-op samples), queue-depth high-water mark, padding waste,
  dispatch-size/trigger distributions, admission outcomes per client, and
  hot-swap pauses — exported by ``FilterService.stats()`` and emitted into
  ``BENCH_serving_slo.json`` by the traffic harness.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .protocol import MixedReport


class QueueFullError(RuntimeError):
    """Admission refused: the pending queue is at its configured bound.

    Raised by ``FilterService.submit`` under the ``"error"`` admission
    policy (and only then — ``"block"`` makes room by dispatching early,
    ``"shed"`` drops the submission and marks its ticket). The message
    names the bound that was hit (global ``max_pending`` or a client's
    fair share).
    """


def batch_align(handle) -> int:
    """The dispatch-width divisor ``handle`` requires (1 = unconstrained).

    Sharded backends split every batch across ``num_shards`` devices, so
    dispatch shapes must be multiples of the shard count; everything else
    accepts any width. Backends advertise the constraint via a
    ``batch_align`` property on their config (or on the handle itself, for
    cascades tracking their current level).
    """
    align = getattr(handle, "batch_align", None)
    if align is None:
        align = getattr(getattr(handle, "config", None), "batch_align", 1)
    return max(1, int(align))


def shape_ladder(batch_size: int, align: int = 1) -> Tuple[int, ...]:
    """Ascending dispatch shapes: ``align``-multiples doubling to the top.

    The base rung is the smallest multiple of ``align`` that is >= 8 (no
    point compiling 1/2/4-wide programs); each rung doubles; ``batch_size``
    is always the top rung. ``batch_size`` itself must be a multiple of
    ``align`` (validated loudly by the service constructor).

    Example::

        >>> shape_ladder(1024)
        (8, 16, 32, 64, 128, 256, 512, 1024)
        >>> shape_ladder(96, align=3)
        (12, 24, 48, 96)
    """
    if batch_size % align:
        raise ValueError(
            f"batch_size={batch_size} is not a multiple of the backend's "
            f"batch_align={align} (sharded dispatch splits the batch "
            "across that many devices)")
    base = align * max(1, math.ceil(8 / align))
    rungs: List[int] = []
    r = base
    while r < batch_size:
        rungs.append(r)
        r *= 2
    rungs.append(batch_size)
    return tuple(rungs)


def rung_for(m: int, ladder: Tuple[int, ...]) -> int:
    """The smallest ladder shape that fits ``m`` live ops."""
    for r in ladder:
        if m <= r:
            return r
    return ladder[-1]


# ---------------------------------------------------------------------------
# Latency accounting: fixed log-spaced histograms (no per-op retention).
# ---------------------------------------------------------------------------

# Bucket upper bounds in seconds: 1us .. ~68s doubling, +inf overflow.
_BUCKET_BOUNDS = tuple(1e-6 * 2.0 ** i for i in range(27)) + (float("inf"),)


class LatencyHistogram:
    """Log2-bucketed latency histogram with percentile readout.

    Observations land in doubling buckets from 1us to ~68s (overflow bucket
    above); percentiles report the bucket upper bound — a <=2x-granular,
    O(1)-memory estimate, which is the right fidelity for SLO dashboards
    (the alternative, retaining every sample, scales with traffic).
    """

    __slots__ = ("counts", "total")

    def __init__(self):
        self.counts = np.zeros((len(_BUCKET_BOUNDS),), np.int64)
        self.total = 0

    def observe(self, seconds) -> None:
        """Record one latency or an array of latencies (seconds)."""
        arr = np.atleast_1d(np.asarray(seconds, np.float64))
        if not arr.size:
            return
        idx = np.searchsorted(_BUCKET_BOUNDS, arr, side="left")
        np.add.at(self.counts, np.minimum(idx, len(_BUCKET_BOUNDS) - 1), 1)
        self.total += int(arr.size)

    def percentile(self, q: float) -> float:
        """Upper bound of the bucket containing quantile ``q`` in [0, 1]."""
        if not self.total:
            return 0.0
        rank = q * self.total
        cum = np.cumsum(self.counts)
        idx = int(np.searchsorted(cum, rank, side="left"))
        idx = min(idx, len(_BUCKET_BOUNDS) - 1)
        if math.isinf(_BUCKET_BOUNDS[idx]):  # overflow bucket: report last edge
            return _BUCKET_BOUNDS[-2]
        return _BUCKET_BOUNDS[idx]

    def summary(self) -> dict:
        """JSON-able snapshot: count + p50/p90/p99 (seconds)."""
        return {"count": self.total,
                "p50_s": self.percentile(0.50),
                "p90_s": self.percentile(0.90),
                "p99_s": self.percentile(0.99)}


class ServiceMetrics:
    """The service's SLO ledger (DESIGN.md §11) — all host-side, O(1) size.

    * ``queue_wait`` — enqueue→dispatch latency histogram (time an op sat
      in the pending queue).
    * ``ready`` — enqueue→ready latency histogram (until its batch's
      results were concretised — the client-visible latency).
    * ``dispatch_sizes`` — ladder-rung → dispatch count (the shape mix).
    * ``dispatch_kinds`` — trigger → count (``full`` batch, ``deadline``,
      ``flush``, ``backpressure``).
    * ``clients`` — per-client accepted/shed op counts (the fairness
      ledger; clients are whatever hashable ids submitters pass).
    * ``swaps`` — hot-swap pause records.
    """

    def __init__(self):
        self.queue_wait = LatencyHistogram()
        self.ready = LatencyHistogram()
        self.accepted_ops = 0
        self.shed_ops = 0
        self.shed_submissions = 0
        self.dispatched_ops = 0
        self.padded_slots = 0
        self.dispatches = 0
        self.queue_depth_max = 0
        self.dispatch_sizes: Dict[int, int] = {}
        self.dispatch_kinds: Dict[str, int] = {}
        self.clients: Dict[object, Dict[str, int]] = {}
        self.swaps: List[dict] = []

    # -- observation hooks ---------------------------------------------------

    def _client(self, client) -> Dict[str, int]:
        return self.clients.setdefault(client, {"accepted": 0, "shed": 0})

    def observe_enqueue(self, n: int, client, depth: int) -> None:
        """An accepted submission: ``n`` ops now pending, queue at ``depth``."""
        self.accepted_ops += n
        self._client(client)["accepted"] += n
        self.queue_depth_max = max(self.queue_depth_max, depth)

    def observe_shed(self, n: int, client) -> None:
        """A shed submission (``n`` ops refused under the shed policy)."""
        self.shed_ops += n
        self.shed_submissions += 1
        self._client(client)["shed"] += n

    def observe_dispatch(self, live: int, shape: int, kind: str,
                         waits: np.ndarray) -> None:
        """One batch left the queue: ``live`` real ops padded to ``shape``."""
        self.dispatches += 1
        self.dispatched_ops += live
        self.padded_slots += shape - live
        self.dispatch_sizes[shape] = self.dispatch_sizes.get(shape, 0) + 1
        self.dispatch_kinds[kind] = self.dispatch_kinds.get(kind, 0) + 1
        self.queue_wait.observe(waits)

    def observe_ready(self, latencies: np.ndarray) -> None:
        """A batch's results were concretised; per-op enqueue→ready."""
        self.ready.observe(latencies)

    def observe_swap(self, record: dict) -> None:
        """A hot swap completed (the record from ``hot_swap``)."""
        self.swaps.append(dict(record))

    # -- readout -------------------------------------------------------------

    @property
    def padding_waste(self) -> float:
        """Padded slots / dispatched slots (0.0 before any dispatch)."""
        total = self.dispatched_ops + self.padded_slots
        return self.padded_slots / total if total else 0.0

    def stats(self) -> dict:
        """JSON-able snapshot of every series (the ``BENCH_*`` payload)."""
        return {
            "accepted_ops": self.accepted_ops,
            "shed_ops": self.shed_ops,
            "shed_submissions": self.shed_submissions,
            "dispatched_ops": self.dispatched_ops,
            "dispatches": self.dispatches,
            "padded_slots": self.padded_slots,
            "padding_waste": self.padding_waste,
            "queue_depth_max": self.queue_depth_max,
            "dispatch_sizes": {str(k): v for k, v
                               in sorted(self.dispatch_sizes.items())},
            "dispatch_kinds": dict(sorted(self.dispatch_kinds.items())),
            "queue_wait": self.queue_wait.summary(),
            "ready": self.ready.summary(),
            "clients": {str(k): dict(v) for k, v in self.clients.items()},
            "swaps": [dict(s) for s in self.swaps],
        }


# ---------------------------------------------------------------------------
# In-flight dispatches.
# ---------------------------------------------------------------------------

class Dispatch:
    """One executed micro-batch: its (lazy) report and concretised cache.

    The report's arrays stay un-concretised device values until first
    touch (double buffering — the host keeps packing while the device
    churns); the first touch blocks, caches the host arrays, and stamps
    this batch's enqueue→ready latencies into the metrics.
    """

    __slots__ = ("report", "_ok", "_routed", "_metrics", "_clock",
                 "_enqueued_at", "done")

    def __init__(self, report: MixedReport, metrics: ServiceMetrics,
                 clock: Callable[[], float], enqueued_at: np.ndarray):
        self.report = report
        self._ok: Optional[np.ndarray] = None
        self._routed: Optional[np.ndarray] = None
        self._metrics = metrics
        self._clock = clock
        self._enqueued_at = enqueued_at
        self.done = False

    def _observe_ready(self) -> None:
        if not self.done:
            self.done = True
            self._metrics.observe_ready(self._clock() - self._enqueued_at)
            self._enqueued_at = None  # release; latencies are binned now

    def ok(self) -> np.ndarray:
        if self._ok is None:  # first touch blocks on the device result
            self._ok = np.asarray(self.report.ok, bool)
            self._observe_ready()
        return self._ok

    def routed(self) -> np.ndarray:
        if self._routed is None:
            self._routed = np.asarray(self.report.routed, bool)
            self._observe_ready()
        return self._routed


# ---------------------------------------------------------------------------
# The pending (admission) queue.
# ---------------------------------------------------------------------------

class PendingStream:
    """Arrival-ordered op queue with per-client occupancy accounting.

    Submissions append (keys, ops, enqueue-time, claim) column-wise;
    ``take(m)`` pops the stream head, splitting a submission that
    straddles the boundary. Claims are (ticket, start, count) ranges, so
    bookkeeping is O(#submissions), never O(#ops). ``client_pending``
    tracks each client's share of the queue — the ledger the admission
    policies consult (DESIGN.md §11).
    """

    def __init__(self):
        self._keys: List[np.ndarray] = []      # pending key rows [m, 2]
        self._ops: List[np.ndarray] = []       # pending op codes [m]
        self._tenq: List[np.ndarray] = []      # enqueue stamps float64[m]
        self._claims: List[Tuple[object, int, int]] = []
        self._clients: List[object] = []       # claim -> client id
        self.pending = 0
        self.client_pending: Dict[object, int] = {}

    def append(self, keys: np.ndarray, ops: np.ndarray, t: float,
               ticket, client) -> None:
        """Enqueue one submission (all ops share enqueue stamp ``t``)."""
        n = keys.shape[0]
        self._keys.append(keys)
        self._ops.append(ops)
        self._tenq.append(np.full((n,), t, np.float64))
        self._claims.append((ticket, 0, n))
        self._clients.append(client)
        self.pending += n
        self.client_pending[client] = self.client_pending.get(client, 0) + n

    def oldest_enqueue(self) -> Optional[float]:
        """Enqueue stamp of the head op (None when empty)."""
        return float(self._tenq[0][0]) if self._tenq else None

    def take(self, m: int):
        """Pop the first ``m`` pending ops off the stream.

        Returns (keys[m, 2], ops[m], enqueued_at[m], claims) where claims
        are (ticket, start-pos-in-submission, count) ranges in stream
        order.
        """
        keys_out, ops_out, t_out, claims = [], [], [], []
        need = m
        while need:
            k, o, t = self._keys[0], self._ops[0], self._tenq[0]
            ticket, start, cnt = self._claims[0]
            client = self._clients[0]
            take = min(cnt, need)
            keys_out.append(k[:take])
            ops_out.append(o[:take])
            t_out.append(t[:take])
            claims.append((ticket, start, take))
            self.client_pending[client] -= take
            if not self.client_pending[client]:
                del self.client_pending[client]
            if take == cnt:
                self._keys.pop(0)
                self._ops.pop(0)
                self._tenq.pop(0)
                self._claims.pop(0)
                self._clients.pop(0)
            else:
                self._keys[0] = k[take:]
                self._ops[0] = o[take:]
                self._tenq[0] = t[take:]
                self._claims[0] = (ticket, start + take, cnt - take)
            need -= take
        self.pending -= m
        return (np.concatenate(keys_out), np.concatenate(ops_out),
                np.concatenate(t_out), claims)
