"""FilterHandle: the one stateful object every consumer programs against.

Wraps (adapter, config, state) with per-op cached jits. State buffers are
donated to mutating ops on accelerator backends (the handle immediately
replaces its state, so the old buffers are dead — donation lets XLA update
the table in place, the batch analogue of the paper's in-place CAS writes);
on CPU, where XLA does not support donation, the jits are built without it
to avoid per-compile warnings.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import numpy as np

from .adapters import AMQAdapter, segmented_apply_ops
from .protocol import (
    Capabilities,
    DeleteReport,
    InsertReport,
    MixedReport,
    OpBatch,
    QueryResult,
    load_factor as _load_factor,
)


class FilterHandle:
    """Stateful AMQ handle with capability-driven, uniform ops.

    Obtain via :func:`repro.amq.make`. All ops take ``uint32[n, 2]`` key
    batches and return the protocol's standardized reports; ``insert`` takes
    the unified keyword options (``bulk``, ``dedup_within_batch``,
    ``valid``) and raises on capability violations instead of silently
    degrading.
    """

    def __init__(self, adapter: AMQAdapter, config: Any, state: Any = None):
        """Wrap (adapter, config, state); a fresh state is built if None."""
        self.adapter = adapter
        self.config = config
        self.state = adapter.init(config) if state is None else state
        self._jits = {}

    # -- introspection -------------------------------------------------------

    @property
    def name(self) -> str:
        """Registry name of the wrapped backend (e.g. ``"cuckoo"``)."""
        return self.adapter.name

    @property
    def capabilities(self) -> Capabilities:
        """The backend's capability flags — branch on these, not on names.

        Example::

            >>> if handle.capabilities.supports_delete:
            ...     handle.delete(expired_keys)
        """
        return self.adapter.capabilities

    @property
    def load_factor(self) -> float:
        """Current occupancy: stored keys / nominal capacity."""
        return _load_factor(self.config, self.state)

    @property
    def table_bytes(self) -> int:
        """Device memory footprint of the filter state."""
        return self.config.table_bytes

    def expected_fpr(self, load_factor: Optional[float] = None) -> float:
        """Analytic FPR at ``load_factor`` (default: current occupancy).

        Example::

            >>> amq.make("cuckoo", capacity=1000).expected_fpr(0.95)
            0.000463...
        """
        lf = self.load_factor if load_factor is None else load_factor
        return self.config.expected_fpr(lf)

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        """Summarize backend, size, and capabilities."""
        return (f"FilterHandle({self.adapter.name!r}, "
                f"slots={self.config.num_slots}, "
                f"bytes={self.config.table_bytes}, "
                f"caps={self.adapter.capabilities})")

    # -- ops -----------------------------------------------------------------

    def _fn(self, op: str, **static):
        key = (op, tuple(sorted(static.items())))
        if key not in self._jits:
            raw = functools.partial(getattr(self.adapter, op), self.config,
                                    **static)
            if self.adapter.jit:
                donate = ((0,) if op != "query"
                          and jax.default_backend() != "cpu" else ())
                raw = jax.jit(raw, donate_argnums=donate)
            self._jits[key] = raw
        return self._jits[key]

    def insert(self, keys, *, bulk: bool = False,
               dedup_within_batch: bool = False,
               valid=None) -> InsertReport:
        """Insert a batch of ``uint32[n, 2]`` keys.

        ``bulk=True`` takes the bucket-sorted bulk-build fast path
        (requires ``supports_bulk``); ``dedup_within_batch`` degrades the
        batch to set semantics; ``valid`` masks caller padding.

        Example::

            >>> report = handle.insert(keys, bulk=True)
            >>> bool(report.ok.all())          # everything landed
            True
        """
        op = "insert"
        if bulk:
            if not self.adapter.capabilities.supports_bulk:
                raise NotImplementedError(
                    f"{self.name}: no bulk-build path "
                    "(capabilities.supports_bulk is False)")
            op = "insert_bulk"
        fn = self._fn(op, dedup_within_batch=dedup_within_batch)
        self.state, report = fn(self.state, keys, valid=valid)
        return report

    def query(self, keys, *, valid=None) -> QueryResult:
        """Batch membership: no false negatives, FPR-bounded positives.

        Example::

            >>> hits = handle.query(keys).hits  # bool[n]
        """
        _, result = self._fn("query")(self.state, keys, valid=valid)
        return result

    def delete(self, keys, *, valid=None) -> DeleteReport:
        """Remove one stored copy per key (requires ``supports_delete``).

        Example::

            >>> report = handle.delete(keys)    # raises on e.g. "bloom"
            >>> bool(report.ok.all())
            True
        """
        if not self.adapter.capabilities.supports_delete:
            raise NotImplementedError(
                f"{self.name}: append-only structure "
                "(capabilities.supports_delete is False)")
        self.state, report = self._fn("delete")(self.state, keys, valid=valid)
        return report

    def apply_ops(self, batch: OpBatch) -> MixedReport:
        """Execute an interleaved query/insert/delete stream (one OpBatch).

        Backends with ``capabilities.supports_mixed`` run the batch as one
        fused program (one dispatch, one pass over the table); every other
        backend is served by :func:`repro.amq.adapters.segmented_apply_ops`
        (one dispatch per maximal same-op run). Same-key operations resolve
        in batch order either way (DESIGN.md §9).

        Example::

            >>> from repro.amq import OpBatch, OP_INSERT, OP_QUERY
            >>> batch = OpBatch.make(keys, [OP_INSERT, OP_QUERY])
            >>> bool(handle.apply_ops(batch).ok.all())   # doctest: +SKIP
            True
        """
        if self.adapter.apply_ops is None:
            return segmented_apply_ops(self, batch)
        fn = self._fn("apply_ops")
        self.state, report = fn(self.state, batch.keys, batch.ops,
                                valid=batch.valid)
        return report

    def count(self) -> int:
        """Stored-key count (summed across shards where applicable)."""
        c = getattr(self.state, "count")
        return int(np.sum(np.asarray(c)))
