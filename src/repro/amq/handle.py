"""FilterHandle: the one stateful object every consumer programs against.

Wraps (adapter, config, state) with per-op cached jits. State buffers are
donated to mutating ops on accelerator backends (the handle immediately
replaces its state, so the old buffers are dead — donation lets XLA update
the table in place, the batch analogue of the paper's in-place CAS writes);
on CPU, where XLA does not support donation, the jits are built without it
to avoid per-compile warnings.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import numpy as np

from ..core.hashing import normalize_keys
from .adapters import (
    AMQAdapter,
    config_fingerprint,
    segmented_apply_ops,
)
from .protocol import (
    Capabilities,
    DeleteReport,
    InsertReport,
    MixedReport,
    OpBatch,
    QueryResult,
    Snapshot,
    SnapshotMismatchError,
    load_factor as _load_factor,
)


def _check_snapshot_target(adapter: AMQAdapter, config: Any,
                           snap: Snapshot) -> None:
    """Validate that ``snap`` may restore onto (adapter, config) — loudly."""
    if snap.kind != "filter":
        raise SnapshotMismatchError(
            f"cannot restore a {snap.kind!r} snapshot onto a static "
            "FilterHandle (cascade snapshots restore onto cascades)")
    if snap.backend != adapter.name:
        raise SnapshotMismatchError(
            f"snapshot is from backend {snap.backend!r}, "
            f"this handle is {adapter.name!r}")
    fp = config_fingerprint(adapter, config)
    if snap.fingerprint != fp:
        raise SnapshotMismatchError(
            f"config fingerprint mismatch:\n  snapshot: "
            f"{snap.fingerprint}\n  target:   {fp}")


class FilterHandle:
    """Stateful AMQ handle with capability-driven, uniform ops.

    Obtain via :func:`repro.amq.make`. All ops take ``uint32[n, 2]`` key
    batches and return the protocol's standardized reports; ``insert`` takes
    the unified keyword options (``bulk``, ``dedup_within_batch``,
    ``valid``) and raises on capability violations instead of silently
    degrading.
    """

    def __init__(self, adapter: AMQAdapter, config: Any, state: Any = None):
        """Wrap (adapter, config, state); a fresh state is built if None."""
        self.adapter = adapter
        self.config = config
        self.state = adapter.init(config) if state is None else state
        self._jits = {}

    # -- introspection -------------------------------------------------------

    @property
    def name(self) -> str:
        """Registry name of the wrapped backend (e.g. ``"cuckoo"``)."""
        return self.adapter.name

    @property
    def capabilities(self) -> Capabilities:
        """The backend's capability flags — branch on these, not on names.

        Example::

            >>> if handle.capabilities.supports_delete:
            ...     handle.delete(expired_keys)
        """
        return self.adapter.capabilities

    @property
    def load_factor(self) -> float:
        """Current occupancy: stored keys / nominal capacity."""
        return _load_factor(self.config, self.state)

    @property
    def table_bytes(self) -> int:
        """Device memory footprint of the filter state."""
        return self.config.table_bytes

    def expected_fpr(self, load_factor: Optional[float] = None) -> float:
        """Analytic FPR at ``load_factor`` (default: current occupancy).

        Example::

            >>> amq.make("cuckoo", capacity=1000).expected_fpr(0.95)
            0.000463...
        """
        lf = self.load_factor if load_factor is None else load_factor
        return self.config.expected_fpr(lf)

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        """Summarize backend, size, and capabilities."""
        return (f"FilterHandle({self.adapter.name!r}, "
                f"slots={self.config.num_slots}, "
                f"bytes={self.config.table_bytes}, "
                f"caps={self.adapter.capabilities})")

    # -- ops -----------------------------------------------------------------

    def _fn(self, op: str, **static):
        key = (op, tuple(sorted(static.items())))
        if key not in self._jits:
            raw = functools.partial(getattr(self.adapter, op), self.config,
                                    **static)
            if self.adapter.jit:
                donate = ((0,) if op != "query"
                          and jax.default_backend() != "cpu" else ())
                raw = jax.jit(raw, donate_argnums=donate)
            self._jits[key] = raw
        return self._jits[key]

    def insert(self, keys, *, bulk: bool = False,
               dedup_within_batch: bool = False,
               valid=None) -> InsertReport:
        """Insert a batch of ``uint32[n, 2]`` keys.

        ``bulk=True`` takes the bucket-sorted bulk-build fast path
        (requires ``supports_bulk``); ``dedup_within_batch`` degrades the
        batch to set semantics; ``valid`` masks caller padding.

        Example::

            >>> report = handle.insert(keys, bulk=True)
            >>> bool(report.ok.all())          # everything landed
            True
        """
        op = "insert"
        if bulk:
            if not self.adapter.capabilities.supports_bulk:
                raise NotImplementedError(
                    f"{self.name}: no bulk-build path "
                    "(capabilities.supports_bulk is False)")
            op = "insert_bulk"
        fn = self._fn(op, dedup_within_batch=dedup_within_batch)
        self.state, report = fn(self.state, normalize_keys(keys),
                                valid=valid)
        return report

    def query(self, keys, *, valid=None) -> QueryResult:
        """Batch membership: no false negatives, FPR-bounded positives.

        Example::

            >>> hits = handle.query(keys).hits  # bool[n]
        """
        _, result = self._fn("query")(self.state, normalize_keys(keys),
                                      valid=valid)
        return result

    def delete(self, keys, *, valid=None) -> DeleteReport:
        """Remove one stored copy per key (requires ``supports_delete``).

        Example::

            >>> report = handle.delete(keys)    # raises on e.g. "bloom"
            >>> bool(report.ok.all())
            True
        """
        if not self.adapter.capabilities.supports_delete:
            raise NotImplementedError(
                f"{self.name}: append-only structure "
                "(capabilities.supports_delete is False)")
        self.state, report = self._fn("delete")(
            self.state, normalize_keys(keys), valid=valid)
        return report

    def apply_ops(self, batch: OpBatch) -> MixedReport:
        """Execute an interleaved query/insert/delete stream (one OpBatch).

        Backends with ``capabilities.supports_mixed`` run the batch as one
        fused program (one dispatch, one pass over the table); every other
        backend is served by :func:`repro.amq.adapters.segmented_apply_ops`
        (one dispatch per maximal same-op run). Same-key operations resolve
        in batch order either way (DESIGN.md §9).

        Example::

            >>> from repro.amq import OpBatch, OP_INSERT, OP_QUERY
            >>> batch = OpBatch.make(keys, [OP_INSERT, OP_QUERY])
            >>> bool(handle.apply_ops(batch).ok.all())   # doctest: +SKIP
            True
        """
        if self.adapter.apply_ops is None:
            return segmented_apply_ops(self, batch)
        fn = self._fn("apply_ops")
        self.state, report = fn(self.state, batch.keys, batch.ops,
                                valid=batch.valid)
        return report

    def count(self) -> int:
        """Stored-key count (summed across shards where applicable)."""
        c = getattr(self.state, "count")
        return int(np.sum(np.asarray(c)))

    # -- lifecycle (DESIGN.md §10) -------------------------------------------

    @property
    def fingerprint(self) -> str:
        """This handle's config-identity string (snapshot compatibility)."""
        return config_fingerprint(self.adapter, self.config)

    def snapshot(self) -> Snapshot:
        """Pull the filter state to host as a versioned :class:`Snapshot`.

        The payload (config fingerprint + packed table arrays) survives
        process restarts (:func:`repro.amq.save_snapshot`), restores onto
        any handle whose config fingerprint matches — including, for the
        sharded backend, a different mesh or shard count — and feeds
        :meth:`repro.amq.FilterService.hot_swap`.

        Example::

            >>> snap = handle.snapshot()
            >>> twin = amq.make(handle.name, config=handle.config,
            ...                 snapshot=snap)      # bit-exact replica
        """
        if not self.adapter.capabilities.supports_snapshot:
            raise NotImplementedError(
                f"{self.name}: state cannot be snapshotted "
                "(capabilities.supports_snapshot is False)")
        arrays = self.adapter.snapshot(self.config, self.state)
        return Snapshot(
            backend=self.name, kind="filter", fingerprint=self.fingerprint,
            arrays=arrays,
            meta={"count": self.count(),
                  "num_slots": int(self.config.num_slots),
                  "table_bytes": int(self.config.table_bytes)},
            configs=(self.config,))

    def restore(self, snap: Snapshot) -> "FilterHandle":
        """Replace this handle's state with a snapshot's — validated.

        The snapshot must come from the same backend and a config with an
        identical fingerprint; anything else raises
        :class:`~repro.amq.protocol.SnapshotMismatchError` (a partial-key
        table is meaningless under different hashes/layout). Returns
        ``self`` for chaining.
        """
        _check_snapshot_target(self.adapter, self.config, snap)
        self.state = self.adapter.restore(self.config, snap.arrays)
        return self

    @classmethod
    def from_snapshot(cls, adapter: AMQAdapter, config: Any,
                      snap: Snapshot) -> "FilterHandle":
        """Build a handle whose initial state *is* the snapshot's.

        Equivalent to ``FilterHandle(adapter, config).restore(snap)`` but
        without allocating (and immediately discarding) a fresh zero
        table first — restore latency is a tracked serving metric.
        """
        _check_snapshot_target(adapter, config, snap)
        return cls(adapter, config, adapter.restore(config, snap.arrays))

    def resharded(self, num_shards: Optional[int] = None,
                  **kw) -> "FilterHandle":
        """Exact reshard: the same filter on a different device layout.

        Only meaningful for backends whose config exposes a ``resharded``
        hook (the mesh-sharded cuckoo filter): returns a *new* handle
        whose state holds the same partitions re-placed over ``num_shards``
        devices (or an explicit ``mesh=``), with zero membership change —
        the config fingerprint deliberately excludes placement, so the
        snapshot round-trip is legal by construction (DESIGN.md §10).

        Example::

            >>> h2 = h.resharded(num_shards=2)     # K -> K' migration
            >>> svc.hot_swap(h2)                   # and into service
        """
        hook = getattr(self.config, "resharded", None)
        if hook is None:
            raise NotImplementedError(
                f"{self.name}: backend config has no resharding surface "
                "(only mesh-sharded backends relocate partitions)")
        return FilterHandle.from_snapshot(
            self.adapter, hook(num_shards, **kw), self.snapshot())
