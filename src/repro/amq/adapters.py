"""Adapters: every filter family of the repo behind the unified AMQ protocol.

One :class:`AMQAdapter` per backend normalizes the family's native surface
(``CuckooFilter.insert`` returning ``(ok, InsertStats)``, baselines returning
bare masks, the sharded filter's ``(ok, routed)`` pairs, the Python oracle's
host-side batches) to the protocol of :mod:`repro.amq.protocol`:

    insert/insert_bulk(config, state, keys, *, valid, dedup_within_batch)
        -> (state', InsertReport)
    query(config, state, keys, *, valid) -> (state, QueryResult)
    delete(config, state, keys, *, valid) -> (state', DeleteReport)

Adapters are *static* objects: all jit-compilation lives in the
:class:`repro.amq.handle.FilterHandle` (or, for the sharded backend, in a
shard_map builder cache below), so the functional ops stay composable inside
larger jitted programs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import cuckoo_filter as CF
from ..core import sharded_filter as SF
from ..core.compat import shard_map as _shard_map
from ..core.hashing import keys_to_numpy
from ..filters import bcht as HT
from ..filters import blocked_bloom as BB
from ..filters import cpu_reference as PYREF
from ..filters import quotient as QF
from ..filters import two_choice as TC
from .protocol import (
    OP_DELETE,
    OP_INSERT,
    OP_QUERY,
    Capabilities,
    DeleteReport,
    InsertReport,
    MixedReport,
    OpBatch,
    QueryResult,
    all_routed,
    ensure_valid,
)


@dataclasses.dataclass(frozen=True)
class AMQAdapter:
    """One backend behind the unified AMQ protocol.

    Fields are plain callables (not bound methods), so
    ``adapter.insert(config, state, keys)`` works directly and composes
    with ``functools.partial`` + ``jax.jit``.

    ``jit=False`` marks backends whose ops must not be re-jitted by the
    handle (the host-side oracle; the sharded backend, which jits its own
    shard_map'd programs per batch shape).

    ``growth_sizings`` is the backend's growth hook for the auto-expanding
    cascade (DESIGN.md §8): an ordered tuple of sizing-kwarg overlays, from
    loosest/cheapest to tightest. When the cascade allocates a level it
    merges each overlay over the caller's base kwargs in turn and picks the
    first whose config meets the level's FPR share; ``({},)`` means the
    backend needs no per-level tightening (exact structures). Required when
    ``capabilities.supports_expand`` is True.

    ``grow_config`` optionally derives level ``i+1``'s config from level
    ``i``'s — ``(prev_config, factor, **overlay) -> config`` — instead of
    re-running ``make_config`` from scratch. Backends whose configs carry
    placement state use it to pin that state across levels (the sharded
    backend keeps one mesh for the whole cascade).

    ``apply_ops`` is the native fused mixed-batch path (DESIGN.md §9):
    ``(config, state, keys, ops, *, valid) -> (state', MixedReport)``
    executing an interleaved query/insert/delete stream in one program.
    Required when ``capabilities.supports_mixed`` is True; backends
    without it are served by :func:`segmented_apply_ops`.

    ``snapshot``/``restore`` are the lifecycle hooks (DESIGN.md §10):
    ``snapshot(config, state) -> dict[str, np.ndarray]`` pulls the packed
    state to host; ``restore(config, arrays) -> state`` places it back
    under the *same* config (the handle validates the config fingerprint
    before calling it). Both required when
    ``capabilities.supports_snapshot`` is True. ``fingerprint`` overrides
    the default config-identity string (:func:`config_fingerprint`) —
    the sharded backend uses it to exclude placement (mesh, shard count)
    from identity, which is what makes restore-onto-a-new-mesh and exact
    resharding legal.

    ``host_query``/``host_delete`` are the cold-tier hooks (DESIGN.md §12):
    ``host_query(config, arrays, keys) -> bool[n]`` probes the packed
    snapshot arrays *in host RAM* with vectorized numpy (per-key hash
    scalars may go through the backend's jax hashing — they are tiny; the
    table gather must not touch the device), and
    ``host_delete(config, arrays, keys, valid) -> ok bool[n]`` clears one
    matching slot per key in the arrays in place (updating ``count``).
    ``host_query`` is required when ``capabilities.supports_tiering`` is
    True; ``host_delete`` additionally when the backend supports deletes.
    """

    name: str
    capabilities: Capabilities
    make_config: Callable[..., Any]      # (capacity, **kw) -> config
    init: Callable[[Any], Any]           # config -> fresh state
    insert: Callable[..., Any]
    query: Callable[..., Any]
    delete: Optional[Callable[..., Any]] = None
    insert_bulk: Optional[Callable[..., Any]] = None
    apply_ops: Optional[Callable[..., Any]] = None
    jit: bool = True
    growth_sizings: Optional[tuple] = None
    grow_config: Optional[Callable[..., Any]] = None
    snapshot: Optional[Callable[..., Any]] = None
    restore: Optional[Callable[..., Any]] = None
    fingerprint: Optional[Callable[[Any], str]] = None
    host_query: Optional[Callable[..., Any]] = None
    host_delete: Optional[Callable[..., Any]] = None


def _zero_stats(n):
    return jnp.zeros((n,), jnp.int32), jnp.zeros((), jnp.int32)


# ---------------------------------------------------------------------------
# Lifecycle hooks (DESIGN.md §10): snapshot / restore / config fingerprints.
# ---------------------------------------------------------------------------

def default_fingerprint(config) -> str:
    """Config identity for snapshot validation: the frozen-dataclass repr.

    Every backend config is a frozen dataclass of primitives, so its repr
    is deterministic and covers exactly the knobs that shape the packed
    state (layout, hashes, seeds). Backends whose configs carry placement
    state override this (see ``ShardedAMQConfig``).
    """
    return repr(config)


def config_fingerprint(adapter: AMQAdapter, config) -> str:
    """The adapter's fingerprint for ``config`` (custom hook or default)."""
    fn = adapter.fingerprint or default_fingerprint
    return fn(config)


def state_snapshot(config, state) -> Dict[str, Any]:
    """Generic snapshot: pull every field of a NamedTuple state to host."""
    del config
    return {f: np.asarray(getattr(state, f)) for f in state._fields}


def _validated_state_arrays(config, arrays):
    """Check snapshot arrays against the config's abstract state template.

    The template comes from ``jax.eval_shape(config.init)`` — authoritative
    shapes and dtypes with **no device allocation** (restore latency is a
    tracked metric; materializing a zero table just to read its shapes
    would double it). Returns ``(state_cls, host_arrays_in_field_order)``;
    any disagreement raises
    :class:`~repro.amq.protocol.SnapshotMismatchError`.
    """
    from .protocol import SnapshotMismatchError

    template = jax.eval_shape(config.init)
    missing = set(template._fields) - set(arrays)
    if missing:
        raise SnapshotMismatchError(
            f"snapshot is missing state arrays {sorted(missing)} "
            f"(has {sorted(arrays)})")
    values = []
    for f in template._fields:
        t = getattr(template, f)
        a = np.asarray(arrays[f])
        if tuple(a.shape) != tuple(t.shape) or a.dtype != np.dtype(t.dtype):
            raise SnapshotMismatchError(
                f"state array {f!r}: snapshot has {a.dtype}"
                f"{list(a.shape)}, config expects {np.dtype(t.dtype)}"
                f"{list(t.shape)}")
        values.append(a)
    return type(template), values


def state_restore(config, arrays):
    """Generic restore: validate against the abstract template, place on
    the default device(s). Backends whose state is mesh-placed provide a
    custom hook (``_sharded_restore``)."""
    state_cls, values = _validated_state_arrays(config, arrays)
    return state_cls(*(jnp.asarray(a) for a in values))


# ---------------------------------------------------------------------------
# Cold-tier host probes (DESIGN.md §12): vectorized numpy queries (and
# slot-clear deletes) over the packed snapshot arrays a demoted level left
# in host RAM. Per-key hash scalars reuse the backend's own jax hashing
# (bit-exactness is non-negotiable and the [n]-sized outputs are tiny);
# only the table-sized gathers must stay host-side.
# ---------------------------------------------------------------------------

def _np_bucket_tags(table: np.ndarray, buckets: np.ndarray, lay) -> np.ndarray:
    """Numpy mirror of ``layout.bucket_tags``: -> uint32[n, bucket_size]."""
    wpb = lay.words_per_bucket
    base = buckets.astype(np.int64) * wpb
    words = table[base[:, None] + np.arange(wpb, dtype=np.int64)]  # [n, wpb]
    shifts = np.arange(lay.tags_per_word, dtype=np.uint32) * np.uint32(
        lay.fp_bits)
    tags = (words[:, :, None] >> shifts) & np.uint32(lay.fp_mask)
    return tags.reshape(words.shape[0], lay.bucket_size)


def _cuckoo_host_prepare(config, keys):
    """Per-key probe scalars (match tags + candidate buckets), as numpy."""
    tag, i1, i2 = CF.prepare_keys(config, jnp.asarray(keys, jnp.uint32))
    t1, t2 = config.placement.query_match_tags(tag)
    return (np.asarray(t1), np.asarray(t2),
            np.asarray(i1), np.asarray(i2))


def _cuckoo_host_query(config, arrays, keys) -> np.ndarray:
    """Vectorized numpy membership probe over packed snapshot arrays."""
    lay = config.layout
    table = np.asarray(arrays["table"])
    t1, t2, i1, i2 = _cuckoo_host_prepare(config, keys)
    hit1 = (_np_bucket_tags(table, i1, lay) == t1[:, None]).any(axis=-1)
    hit2 = (_np_bucket_tags(table, i2, lay) == t2[:, None]).any(axis=-1)
    return hit1 | hit2


def _cuckoo_host_delete(config, arrays, keys, valid=None) -> np.ndarray:
    """Clear one matching slot per key in the host-RAM table, in place.

    Candidate slots are located with the same vectorized probe as
    ``host_query``; the actual clears run serially per key so duplicate
    deletes of one key in a batch consume distinct stored copies, exactly
    like the device path's per-round claim resolution. Cold-tier deletes
    are the rare path (DESIGN.md §12) — the loop runs only over keys whose
    candidate buckets matched at all.
    """
    lay = config.layout
    table = arrays["table"]
    if not (isinstance(table, np.ndarray) and table.flags.writeable):
        table = arrays["table"] = np.array(table, np.uint32)
    n = int(np.asarray(keys).shape[0])
    v = (np.ones((n,), bool) if valid is None
         else np.asarray(valid, bool))
    ok = np.zeros((n,), bool)
    if not v.any():
        return ok
    t1, t2, i1, i2 = _cuckoo_host_prepare(config, keys)
    cand1 = (_np_bucket_tags(table, i1, lay) == t1[:, None]).any(axis=-1)
    cand2 = (_np_bucket_tags(table, i2, lay) == t2[:, None]).any(axis=-1)
    wpb, tpw = lay.words_per_bucket, lay.tags_per_word
    fp_mask, fp_bits = np.uint32(lay.fp_mask), lay.fp_bits
    removed = 0
    for i in np.flatnonzero(v & (cand1 | cand2)):
        for bucket, t in ((int(i1[i]), int(t1[i])),
                          (int(i2[i]), int(t2[i]))):
            done = False
            for s in range(lay.bucket_size):
                widx = bucket * wpb + s // tpw
                shift = np.uint32((s % tpw) * fp_bits)
                if int((table[widx] >> shift) & fp_mask) == t:
                    table[widx] &= ~np.uint32(fp_mask << shift)
                    done = True
                    break
            if done:
                ok[i] = True
                removed += 1
                break
    if removed:
        count = arrays["count"]
        arrays["count"] = np.asarray(int(count) - removed,
                                     np.asarray(count).dtype)
    return ok


def _bloom_host_query(config, arrays, keys) -> np.ndarray:
    """Vectorized numpy probe of a blocked-Bloom snapshot (k bits all set)."""
    table = np.asarray(arrays["table"])
    block, word, mask = BB._bit_positions(config, jnp.asarray(keys,
                                                              jnp.uint32))
    block, word, mask = (np.asarray(block), np.asarray(word),
                         np.asarray(mask))
    addr = block[:, None].astype(np.int64) * config.words_per_block + word
    words = table[addr]                                  # [n, k]
    return ((words & mask) == mask).all(axis=-1)


# ---------------------------------------------------------------------------
# Mixed-batch execution: the generic segmented fallback (DESIGN.md §9).
# ---------------------------------------------------------------------------

def segmented_apply_ops(target, batch: OpBatch) -> MixedReport:
    """Execute an :class:`OpBatch` on any handle by segmenting it.

    The universal fallback behind ``FilterHandle.apply_ops`` for backends
    without a native fused path: the batch is split into **maximal
    same-op runs** (host-side — run boundaries are data-dependent) and
    each run replays the existing per-op entry point as one full-width,
    ``valid``-masked call. Shapes never vary, so each op compiles once;
    the cost is one dispatch per run — which is exactly the per-op
    round-trip tax the fused paths erase (benchmarks/mixed_workload.py).

    Correctness is inherited: runs execute in batch order, duplicates
    within a same-op run already serialise inside the batch ops, so
    same-key operations resolve in batch order exactly like the native
    paths. ``target`` is anything with the handle op surface
    (:class:`~repro.amq.handle.FilterHandle`, a cascade, ...).
    """
    ops = np.asarray(batch.ops)
    v = np.asarray(batch.valid, bool)
    n = ops.shape[0]
    ok = np.zeros((n,), bool)
    routed = np.ones((n,), bool)
    evictions = np.zeros((n,), np.int32)
    rounds = 0

    live = np.flatnonzero(v)
    if live.size == 0:  # all-padding batch (e.g. a forced flush): no-op
        return MixedReport(ok, routed, evictions, np.int32(rounds))
    if ((ops[live] == OP_DELETE).any()
            and not target.capabilities.supports_delete):
        raise NotImplementedError(
            f"{target.name}: mixed batch contains deletes but the backend "
            "is append-only (capabilities.supports_delete is False)")

    o = ops[live]
    bounds = np.flatnonzero(np.diff(o) != 0) + 1
    starts = np.concatenate([[0], bounds])
    ends = np.concatenate([bounds, [o.size]])
    for s, e in zip(starts, ends):
        mask = np.zeros((n,), bool)
        mask[live[s:e]] = True
        vmask = jnp.asarray(mask)
        code = o[s]
        if code == OP_QUERY:
            r = target.query(batch.keys, valid=vmask)
            r_ok, r_routed = r.hits, r.routed
        elif code == OP_INSERT:
            r = target.insert(batch.keys, valid=vmask)
            r_ok, r_routed = r.ok, r.routed
            evictions = np.where(mask, np.asarray(r.evictions), evictions)
            rounds += int(np.asarray(r.rounds))
        else:
            r = target.delete(batch.keys, valid=vmask)
            r_ok, r_routed = r.ok, r.routed
        ok = np.where(mask, np.asarray(r_ok, bool), ok)
        routed = np.where(mask, np.asarray(r_routed, bool), routed)
    return MixedReport(ok, routed, evictions, np.int32(rounds))


# ---------------------------------------------------------------------------
# Growth hooks (cascade level sizing, DESIGN.md §8): ordered loosest->tightest
# sizing overlays; the cascade picks the first that meets a level's FPR share.
# ---------------------------------------------------------------------------

# The packed bucket layout quantizes tag widths to 32-bit-word fractions
# (core.layout), so the cuckoo ladder is the three hardware-friendly widths.
_CUCKOO_SIZINGS = tuple({"fp_bits": f} for f in (8, 16, 32))

# Blocked Bloom tightens by raising the per-key bit budget with the
# matching near-optimal hash count k ~= bits_per_key * ln 2.
_BLOOM_SIZINGS = tuple(
    {"bits_per_key": b, "k": max(1, round(b * 0.693))}
    for b in (8, 12, 16, 20, 24, 32, 40))

# The GQF's remainder is an arbitrary bit slice of a uint32 slot word.
_GQF_SIZINGS = tuple({"remainder_bits": r} for r in (8, 12, 16, 20, 24, 28))


# ---------------------------------------------------------------------------
# Core cuckoo filter (the paper's contribution).
# ---------------------------------------------------------------------------

def _cuckoo_insert(config, state, keys, *, valid=None,
                   dedup_within_batch=False, _fn=CF.insert):
    state, ok, stats = _fn(config, state, keys, valid,
                           dedup_within_batch=dedup_within_batch)
    return state, InsertReport(ok, stats.evictions, stats.rounds,
                               all_routed(keys))


def _cuckoo_query(config, state, keys, *, valid=None):
    hits = CF.query(config, state, keys) & ensure_valid(keys, valid)
    return state, QueryResult(hits, all_routed(keys))


def _cuckoo_delete(config, state, keys, *, valid=None):
    state, ok = CF.delete(config, state, keys, valid)
    return state, DeleteReport(ok, all_routed(keys))


def _cuckoo_apply_ops(config, state, keys, ops, *, valid=None):
    state, ok, stats = CF.apply_ops(config, state, keys, ops, valid)
    return state, MixedReport(ok, all_routed(keys), stats.evictions,
                              stats.rounds)


def _cuckoo_make_config(capacity, **kw):
    # Registry default: the vectorized fmix32 pair-hash (the paper's
    # xxhash64 stays available via hash_kind="xxhash64").
    kw.setdefault("hash_kind", "fmix32")
    return CF.CuckooConfig.for_capacity(capacity, **kw)


CUCKOO = AMQAdapter(
    name="cuckoo",
    capabilities=Capabilities(supports_delete=True, supports_bulk=True,
                              counting=True, supports_expand=True,
                              supports_mixed=True, supports_snapshot=True,
                              supports_tiering=True),
    make_config=_cuckoo_make_config,
    init=lambda cfg: cfg.init(),
    insert=_cuckoo_insert,
    insert_bulk=functools.partial(_cuckoo_insert, _fn=CF.insert_bulk),
    query=_cuckoo_query,
    delete=_cuckoo_delete,
    apply_ops=_cuckoo_apply_ops,
    growth_sizings=_CUCKOO_SIZINGS,
    snapshot=state_snapshot,
    restore=state_restore,
    host_query=_cuckoo_host_query,
    host_delete=_cuckoo_host_delete,
)


# ---------------------------------------------------------------------------
# Blocked Bloom (append-only baseline).
# ---------------------------------------------------------------------------

def _bloom_insert(config, state, keys, *, valid=None,
                  dedup_within_batch=False):
    del dedup_within_batch  # idempotent by construction
    state, ok = BB.insert(config, state, keys, valid)
    return state, InsertReport(ok, *_zero_stats(keys.shape[0]),
                               all_routed(keys))


def _bloom_query(config, state, keys, *, valid=None):
    hits = BB.query(config, state, keys) & ensure_valid(keys, valid)
    return state, QueryResult(hits, all_routed(keys))


BLOOM = AMQAdapter(
    name="bloom",
    capabilities=Capabilities(supports_delete=False, counting=False,
                              supports_expand=True, supports_snapshot=True,
                              supports_tiering=True),
    make_config=lambda capacity, **kw: BB.BloomConfig.for_capacity(
        capacity, **kw),
    init=lambda cfg: cfg.init(),
    insert=_bloom_insert,
    query=_bloom_query,
    growth_sizings=_BLOOM_SIZINGS,
    snapshot=state_snapshot,
    restore=state_restore,
    host_query=_bloom_host_query,
)


# ---------------------------------------------------------------------------
# Two-Choice Filter.
# ---------------------------------------------------------------------------

def _tcf_insert(config, state, keys, *, valid=None, dedup_within_batch=False):
    if dedup_within_batch:
        raise NotImplementedError("tcf: dedup_within_batch not supported")
    state, ok = TC.insert(config, state, keys, valid)
    return state, InsertReport(ok, *_zero_stats(keys.shape[0]),
                               all_routed(keys))


def _tcf_query(config, state, keys, *, valid=None):
    hits = TC.query(config, state, keys) & ensure_valid(keys, valid)
    return state, QueryResult(hits, all_routed(keys))


def _tcf_delete(config, state, keys, *, valid=None):
    state, ok = TC.delete(config, state, keys, valid)
    return state, DeleteReport(ok, all_routed(keys))


TCF = AMQAdapter(
    name="tcf",
    capabilities=Capabilities(supports_delete=True, counting=True,
                              supports_snapshot=True),
    make_config=lambda capacity, **kw: TC.TCFConfig.for_capacity(
        capacity, **kw),
    init=lambda cfg: cfg.init(),
    insert=_tcf_insert,
    query=_tcf_query,
    delete=_tcf_delete,
    snapshot=state_snapshot,
    restore=state_restore,
)


# ---------------------------------------------------------------------------
# GPU Quotient Filter analogue (serial Robin Hood).
# ---------------------------------------------------------------------------

def _gqf_insert(config, state, keys, *, valid=None, dedup_within_batch=False):
    if dedup_within_batch:
        raise NotImplementedError("gqf: dedup_within_batch not supported")
    state, ok = QF.insert(config, state, keys, valid)
    return state, InsertReport(ok, *_zero_stats(keys.shape[0]),
                               all_routed(keys))


def _gqf_query(config, state, keys, *, valid=None):
    hits = QF.query(config, state, keys) & ensure_valid(keys, valid)
    return state, QueryResult(hits, all_routed(keys))


def _gqf_delete(config, state, keys, *, valid=None):
    state, ok = QF.delete(config, state, keys, valid)
    return state, DeleteReport(ok, all_routed(keys))


GQF = AMQAdapter(
    name="gqf",
    capabilities=Capabilities(supports_delete=True, counting=True,
                              serial_insert=True, supports_expand=True,
                              supports_snapshot=True),
    make_config=lambda capacity, **kw: QF.GQFConfig.for_capacity(
        capacity, **kw),
    init=lambda cfg: cfg.init(),
    insert=_gqf_insert,
    query=_gqf_query,
    delete=_gqf_delete,
    growth_sizings=_GQF_SIZINGS,
    snapshot=state_snapshot,
    restore=state_restore,
)


# ---------------------------------------------------------------------------
# BCHT (exact membership).
# ---------------------------------------------------------------------------

def _bcht_insert(config, state, keys, *, valid=None, dedup_within_batch=False):
    if dedup_within_batch:
        raise NotImplementedError("bcht: dedup_within_batch not supported")
    state, ok = HT.insert(config, state, keys, valid)
    return state, InsertReport(ok, *_zero_stats(keys.shape[0]),
                               all_routed(keys))


def _bcht_query(config, state, keys, *, valid=None):
    hits = HT.query(config, state, keys) & ensure_valid(keys, valid)
    return state, QueryResult(hits, all_routed(keys))


def _bcht_delete(config, state, keys, *, valid=None):
    state, ok = HT.delete(config, state, keys, valid)
    return state, DeleteReport(ok, all_routed(keys))


BCHT = AMQAdapter(
    name="bcht",
    capabilities=Capabilities(supports_delete=True, counting=True,
                              exact=True, supports_expand=True,
                              supports_snapshot=True),
    make_config=lambda capacity, **kw: HT.BCHTConfig.for_capacity(
        capacity, **kw),
    init=lambda cfg: cfg.init(),
    insert=_bcht_insert,
    query=_bcht_query,
    delete=_bcht_delete,
    growth_sizings=({},),  # exact: any level trivially meets its FPR share
    snapshot=state_snapshot,
    restore=state_restore,
)


# ---------------------------------------------------------------------------
# Mesh-sharded cuckoo filter.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedAMQConfig:
    """Protocol config for the sharded backend: inner config + its mesh.

    Hashable (``jax.sharding.Mesh`` is) so it stays a valid static arg for
    the shard_map builder cache below.
    """

    inner: SF.ShardedCuckooConfig
    mesh: Any  # jax.sharding.Mesh

    @property
    def num_slots(self) -> int:
        """Aggregate nominal capacity across all shards."""
        return self.inner.num_slots

    @property
    def table_bytes(self) -> int:
        """Aggregate device memory footprint across all shards."""
        return self.inner.table_bytes

    def expected_fpr(self, load_factor: float) -> float:
        """Aggregate FPR equals the per-shard filter's (paper Eq. 4), because shards are independent same-config cuckoo filters."""
        return self.inner.expected_fpr(load_factor)

    @property
    def batch_align(self) -> int:
        """Dispatch widths must divide across the mesh (DESIGN.md §11)."""
        return self.inner.batch_align

    def init(self) -> SF.ShardedCuckooState:
        """Fresh empty sharded state, placed along the mesh axis."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.device_put(
            self.inner.init(),
            NamedSharding(self.mesh, P(self.inner.axis_name)))

    def resharded(self, num_shards: Optional[int] = None, *,
                  mesh: Any = None,
                  axis_name: Optional[str] = None) -> "ShardedAMQConfig":
        """The same filter over a different device set — exactly.

        Key→partition is fixed (``SF.partition_of`` hashes modulo the
        partition count, never the device count), so only the
        partition→device placement changes: a state restored under the
        resharded config answers every query bit-for-bit identically
        (DESIGN.md §10). Pass ``num_shards`` (a divisor of the partition
        count; a default mesh of that size is derived) and/or an explicit
        new ``mesh``.
        """
        ax = axis_name or self.inner.axis_name
        if mesh is None and num_shards is None:
            mesh, num_shards = _default_mesh(ax, None)
        elif num_shards is None:
            num_shards = mesh.shape[ax]
        # Validate the partition math first: a divisibility error should
        # name partitions, not fail while deriving a default mesh.
        inner = self.inner.resharded(num_shards, axis_name=axis_name)
        if mesh is None:
            mesh, _ = _default_mesh(ax, num_shards)
        return ShardedAMQConfig(inner, mesh)


def _default_mesh(axis_name: str, num_shards: Optional[int]):
    devices = jax.devices()
    n = num_shards or len(devices)
    if n > len(devices):
        raise ValueError(
            f"num_shards={n} exceeds the {len(devices)} available "
            "device(s); pass an explicit mesh= spanning the target devices")
    return jax.sharding.Mesh(np.asarray(devices[:n]), (axis_name,)), n


def _sharded_make_config(capacity, *, num_shards=None, mesh=None,
                         axis_name="data", **kw):
    if mesh is None:
        mesh, num_shards = _default_mesh(axis_name, num_shards)
    elif num_shards is None:
        num_shards = mesh.shape[axis_name]
    kw.setdefault("hash_kind", "fmix32")
    inner = SF.ShardedCuckooConfig.for_capacity(
        capacity, num_shards, axis_name=axis_name, **kw)
    return ShardedAMQConfig(inner, mesh)


@functools.lru_cache(maxsize=128)
def _sharded_fn(config: ShardedAMQConfig, op: str, local_batch: int,
                dedup: bool):
    from jax.sharding import PartitionSpec as P

    ax = config.inner.axis_name
    fn = SF._make_sharded_op(config.inner, op, local_batch,
                             dedup_within_batch=dedup)
    n_in = 5 if op == "apply_ops" else 4
    mapped = _shard_map(fn, mesh=config.mesh,
                        in_specs=(P(ax),) * n_in,
                        out_specs=(P(ax), P(ax), P(ax), P(ax)))
    return jax.jit(mapped)


def _sharded_run(config, state, keys, op, valid, dedup=False, ops=None):
    valid = ensure_valid(keys, valid)
    # shard_map splits the global batch across the mesh axis; bin capacity
    # must be sized from the *per-device* slice, not the global batch.
    num_shards = config.inner.num_shards
    n = keys.shape[0]
    if n % num_shards:
        raise ValueError(
            f"sharded-cuckoo: batch size {n} not divisible by "
            f"num_shards={num_shards}")
    fn = _sharded_fn(config, op, n // num_shards, dedup)
    args = (state.table, state.count, keys, valid)
    if op == "apply_ops":
        args += (jnp.asarray(ops, jnp.int32),)
    table, count, result, routed = fn(*args)
    return SF.ShardedCuckooState(table, count), result, routed


def _sharded_insert(config, state, keys, *, valid=None,
                    dedup_within_batch=False, _op="insert"):
    state, ok, routed = _sharded_run(config, state, keys, _op, valid,
                                     dedup_within_batch)
    n = keys.shape[0]
    return state, InsertReport(ok, *_zero_stats(n), routed)


def _sharded_query(config, state, keys, *, valid=None):
    state, hits, routed = _sharded_run(config, state, keys, "query", valid)
    return state, QueryResult(hits, routed)


def _sharded_delete(config, state, keys, *, valid=None):
    state, ok, routed = _sharded_run(config, state, keys, "delete", valid)
    return state, DeleteReport(ok, routed)


def _sharded_apply_ops(config, state, keys, ops, *, valid=None):
    state, ok, routed = _sharded_run(config, state, keys, "apply_ops",
                                     valid, ops=ops)
    n = keys.shape[0]
    return state, MixedReport(ok, routed, *_zero_stats(n))


def _sharded_restore(config: ShardedAMQConfig, arrays):
    """Sharded restore: validated arrays placed along the config's mesh.

    The partition axis is re-placed under the *target* config's mesh and
    shard count — which may differ from the snapshot's, since the sharded
    fingerprint excludes placement: this is the exact-reshard path.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    state_cls, values = _validated_state_arrays(config, arrays)
    sharding = NamedSharding(config.mesh, P(config.inner.axis_name))
    return state_cls(*(jax.device_put(a, sharding) for a in values))


def _sharded_fingerprint(config: ShardedAMQConfig) -> str:
    """Sharded config identity: per-partition filter + partition count.

    Placement (mesh, shard count, axis name) and routing overprovision are
    deliberately *excluded*: they shape where partitions live, not what
    they contain — which is exactly what licenses snapshot-restore onto a
    new mesh / shard count as the zero-membership-change migration path
    (DESIGN.md §10).
    """
    inner = config.inner
    return f"sharded-cuckoo[P={inner.partitions}]:{inner.shard!r}"


def _sharded_grow_config(prev: ShardedAMQConfig, factor: float,
                         **overlay) -> ShardedAMQConfig:
    """Next cascade level: grow the per-shard filter, keep the *same* mesh.

    Carrying ``prev.mesh`` over (rather than re-deriving a default mesh per
    level) pins the cascade's placement: every level exchanges keys over
    one all-to-all pattern (DESIGN.md §8 "cascade of shards").
    """
    return ShardedAMQConfig(
        prev.inner.grown(factor, fp_bits=overlay.pop("fp_bits", None)),
        prev.mesh)


SHARDED_CUCKOO = AMQAdapter(
    name="sharded-cuckoo",
    capabilities=Capabilities(supports_delete=True, supports_bulk=True,
                              supports_sharding=True, counting=True,
                              supports_expand=True, supports_mixed=True,
                              supports_snapshot=True),
    make_config=_sharded_make_config,
    init=lambda cfg: cfg.init(),
    insert=_sharded_insert,
    insert_bulk=functools.partial(_sharded_insert, _op="insert_bulk"),
    query=_sharded_query,
    delete=_sharded_delete,
    apply_ops=_sharded_apply_ops,
    jit=False,  # ops are shard_map programs jitted per batch shape above
    growth_sizings=_CUCKOO_SIZINGS,  # fp_bits flows to the per-shard config
    grow_config=_sharded_grow_config,
    snapshot=state_snapshot,
    restore=_sharded_restore,
    fingerprint=_sharded_fingerprint,
)


# ---------------------------------------------------------------------------
# Pure-Python oracle (host-side; the conformance reference).
# ---------------------------------------------------------------------------

def _py_mask(keys, valid):
    if valid is None:
        return np.ones((np.asarray(keys).shape[0],), bool)
    return np.asarray(valid, bool)


def _py_insert(config, state, keys, *, valid=None, dedup_within_batch=False):
    raw = keys_to_numpy(keys)
    v = _py_mask(keys, valid)
    ok = np.zeros((raw.shape[0],), bool)
    seen = set()
    for i, k in enumerate(raw.tolist()):
        if not v[i]:
            continue
        if dedup_within_batch and k in seen:
            ok[i] = ok[np.flatnonzero((raw == k) & v)[0]]
            continue
        seen.add(k)
        ok[i] = state.insert(k)
    n = raw.shape[0]
    return state, InsertReport(ok, np.zeros((n,), np.int32),
                               np.zeros((), np.int32), np.ones((n,), bool))


def _py_query(config, state, keys, *, valid=None):
    hits = state.query_batch(keys_to_numpy(keys)) & _py_mask(keys, valid)
    return state, QueryResult(hits, np.ones((hits.shape[0],), bool))


def _py_delete(config, state, keys, *, valid=None):
    raw = keys_to_numpy(keys)
    v = _py_mask(keys, valid)
    ok = np.array([v[i] and state.delete(int(k))
                   for i, k in enumerate(raw)], bool)
    return state, DeleteReport(ok, np.ones((raw.shape[0],), bool))


def _py_apply_ops(config, state, keys, ops, *, valid=None):
    """The mixed-batch *definition*: a literal sequential replay.

    One op at a time, in batch order — this is the oracle the fused paths
    are differentially tested against (tests/test_mixed_ops.py).
    """
    raw = keys_to_numpy(keys)
    ops = np.asarray(ops)
    v = _py_mask(keys, valid)
    n = raw.shape[0]
    ok = np.zeros((n,), bool)
    for i in range(n):
        if not v[i]:
            continue
        k = int(raw[i])
        if ops[i] == OP_QUERY:
            ok[i] = state.query(k)
        elif ops[i] == OP_INSERT:
            ok[i] = state.insert(k)
        elif ops[i] == OP_DELETE:
            ok[i] = state.delete(k)
        else:
            raise ValueError(f"unknown op code {ops[i]} at slot {i}")
    return state, MixedReport(ok, np.ones((n,), bool),
                              np.zeros((n,), np.int32), np.zeros((), np.int32))


def _py_snapshot(config, state) -> Dict[str, Any]:
    """Oracle snapshot: the bucket grid + count as plain arrays.

    The eviction RNG's position is not captured — snapshots preserve
    *membership* exactly; future insert eviction choices may differ from a
    never-snapshotted oracle (irrelevant to correctness, which never
    depends on which victim a cuckoo walk picks).
    """
    del config
    return {"buckets": np.asarray(state.buckets, np.uint32),
            "count": np.asarray(state.count, np.int64)}


def _py_restore(config, arrays):
    from .protocol import SnapshotMismatchError

    filt = config.init()
    want = (config.num_buckets, config.bucket_size)
    buckets = np.asarray(arrays.get("buckets"))
    if "buckets" not in arrays or tuple(buckets.shape) != want:
        raise SnapshotMismatchError(
            f"state array 'buckets': snapshot has "
            f"{None if 'buckets' not in arrays else list(buckets.shape)}, "
            f"config expects {list(want)}")
    if "count" not in arrays:
        raise SnapshotMismatchError(
            "snapshot is missing state array 'count' "
            f"(has {sorted(arrays)})")
    filt.buckets = [[int(t) for t in row] for row in buckets]
    filt.count = int(arrays["count"])
    return filt


CPU_CUCKOO = AMQAdapter(
    name="cpu-cuckoo",
    capabilities=Capabilities(supports_delete=True, counting=True,
                              serial_insert=True, supports_expand=True,
                              supports_mixed=True, supports_snapshot=True),
    make_config=lambda capacity, **kw: PYREF.PyCuckooConfig.for_capacity(
        capacity, **kw),
    init=lambda cfg: cfg.init(),
    insert=_py_insert,
    query=_py_query,
    delete=_py_delete,
    apply_ops=_py_apply_ops,
    jit=False,
    growth_sizings=_CUCKOO_SIZINGS,
    snapshot=_py_snapshot,
    restore=_py_restore,
)


DEFAULT_ADAPTERS: Dict[str, AMQAdapter] = {
    a.name: a for a in
    (CUCKOO, BLOOM, TCF, GQF, BCHT, SHARDED_CUCKOO, CPU_CUCKOO)
}
