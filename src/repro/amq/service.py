"""FilterService: a micro-batching front-end over one AMQ filter.

Serving traffic reaches a filter as many small, interleaved op streams —
one per logical client — while the accelerator wants few, large, fixed-shape
dispatches. The service bridges the two (DESIGN.md §9):

* **Coalescing**: ``query`` / ``insert`` / ``delete`` / ``submit`` calls
  append ops (any count, any client) onto one pending stream in arrival
  order. Nothing is dispatched until a full micro-batch accumulates or a
  result is demanded.
* **Fixed-shape batches**: every dispatch is an :class:`OpBatch` of exactly
  ``batch_size`` slots (short tails are padded with invalid slots), so one
  compiled ``apply_ops`` program serves every traffic pattern — dynamic
  client batch sizes never trigger recompilation.
* **Fused execution**: each micro-batch runs as a single mixed-op pass on
  the wrapped handle — queries, inserts, and deletes of *different* clients
  share one dispatch; in-batch order equals global arrival order, so the
  per-key semantics of DESIGN.md §9 apply across clients.
* **Double buffering**: dispatch is asynchronous — the service keeps each
  batch's :class:`~repro.amq.protocol.MixedReport` as unconcretised device
  arrays and immediately continues packing the next batch while the device
  churns; the handle donates its state buffers to each dispatch, so the
  table is updated in place. Results are only pulled to the host when a
  ticket's :meth:`Ticket.result` is called.
* **Scatter**: every submission returns a :class:`Ticket` that knows which
  slots of which micro-batches carry its ops; ``result()`` gathers exactly
  those slots back into per-client order, however the ops were interleaved.
* **Hot swap** (DESIGN.md §10): :meth:`FilterService.hot_swap` drains the
  pending stream onto the old backend, migrates its state onto a new
  handle via snapshot/restore (including exact resharding onto a new mesh
  or shard count), and resumes — zero-downtime capacity/topology changes;
  no acknowledged operation is lost and issued tickets stay readable.

Example::

    from repro import amq

    svc = amq.FilterService(amq.make("cuckoo", capacity=1 << 20))
    t1 = svc.insert(keys_a)             # client A
    t2 = svc.query(keys_b)              # client B — may share A's batch
    hits = t2.result()                  # flushes pending ops, scatters B's
"""

from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hashing import normalize_keys
from .protocol import (
    OP_DELETE,
    OP_INSERT,
    OP_QUERY,
    MixedReport,
    OpBatch,
    normalize_ops,
)


class _Dispatch:
    """One executed micro-batch: its (lazy) report and concretised cache."""

    __slots__ = ("report", "_ok", "_routed")

    def __init__(self, report: MixedReport):
        self.report = report
        self._ok: Optional[np.ndarray] = None
        self._routed: Optional[np.ndarray] = None

    def ok(self) -> np.ndarray:
        if self._ok is None:  # first touch blocks on the device result
            self._ok = np.asarray(self.report.ok, bool)
        return self._ok

    def routed(self) -> np.ndarray:
        if self._routed is None:
            self._routed = np.asarray(self.report.routed, bool)
        return self._routed


class Ticket:
    """A client's claim on its slice of one or more micro-batches.

    ``result()`` returns ``ok`` per submitted op, in submission order
    (query → hit, insert → landed, delete → removed). ``routed()`` returns
    the matching routed mask (sharded backends). Both force a flush of any
    still-pending part of the submission.
    """

    def __init__(self, service: "FilterService", n: int):
        self._service = service
        self._n = n
        # (dispatch, slots-in-batch, positions-in-submission); appended by
        # the service when a batch carrying part of this submission
        # launches. Tickets are the only owners of _Dispatch objects, so a
        # batch's reports are reclaimed as soon as every ticket that drew
        # from it is garbage — the service itself retains nothing.
        self._parts: List[Tuple[_Dispatch, np.ndarray, np.ndarray]] = []
        self._filled = 0

    def _gather(self, field: str) -> np.ndarray:
        self._service._flush_for(self)
        out = np.zeros((self._n,), bool)
        for dispatch, slots, positions in self._parts:
            out[positions] = getattr(dispatch, field)()[slots]
        return out

    @property
    def dispatched(self) -> bool:
        """True once every op of this submission has left the pending
        stream — ``result()`` will then not force a flush."""
        return self._filled >= self._n

    def result(self) -> np.ndarray:
        """Per-op outcomes, in submission order (bool[n])."""
        return self._gather("ok")

    def routed(self) -> np.ndarray:
        """Per-op routed mask, in submission order (bool[n])."""
        return self._gather("routed")


class FilterService:
    """Coalesce many clients' op streams into fused fixed-size OpBatches.

    ``handle`` is any AMQ handle (static or cascade). ``batch_size`` is the
    micro-batch width — the one compiled shape; keep it large enough to
    amortise dispatch, small enough that padding on a forced flush stays
    cheap (the :attr:`stats_fill` property reports the realised
    utilisation; ``stats`` counts dispatches/ops/padded slots).
    """

    def __init__(self, handle, *, batch_size: int = 1024):
        if batch_size <= 0:
            raise ValueError(f"batch_size must be positive, got {batch_size}")
        self.handle = handle
        self.batch_size = int(batch_size)
        self._keys: List[np.ndarray] = []     # pending key rows [m, 2]
        self._ops: List[np.ndarray] = []      # pending op codes [m]
        # Pending claims as (ticket, start-pos-in-submission, count) ranges
        # — submissions are contiguous in arrival order, so bookkeeping is
        # O(#submissions), never O(#ops).
        self._claims: List[Tuple[Ticket, int, int]] = []
        self._pending = 0
        self.stats = {"dispatches": 0, "ops": 0, "padded": 0}

    # -- introspection -------------------------------------------------------

    @property
    def pending_ops(self) -> int:
        """Ops accepted but not yet dispatched."""
        return self._pending

    @property
    def stats_fill(self) -> float:
        """Realised batch utilisation: live slots / dispatched slots."""
        total = self.stats["ops"] - self._pending + self.stats["padded"]
        live = self.stats["ops"] - self._pending
        return live / total if total else 1.0

    # -- submission ----------------------------------------------------------

    def submit(self, keys, ops) -> Ticket:
        """Append a client's op stream; returns its :class:`Ticket`.

        ``keys``: raw ``uint64[m]`` or packed ``uint32[m, 2]`` pairs (the
        key-format contract — see ``repro.core.hashing.normalize_keys``);
        ``ops``: int32[m] op codes. The ops join the global stream in call
        order — coalescing never reorders. Malformed arguments raise
        ``ValueError`` naming the offending argument at the boundary,
        before anything is enqueued.
        """
        keys = np.asarray(normalize_keys(keys, arg="keys"), np.uint32)
        ops = np.asarray(normalize_ops(ops, keys.shape[0]), np.int32)
        if ((ops == OP_DELETE).any()
                and not self.handle.capabilities.supports_delete):
            raise NotImplementedError(
                f"{self.handle.name}: append-only backend cannot serve "
                "deletes (capabilities.supports_delete is False)")
        ticket = Ticket(self, keys.shape[0])
        if keys.shape[0]:
            self._keys.append(keys)
            self._ops.append(ops)
            self._claims.append((ticket, 0, keys.shape[0]))
            self._pending += keys.shape[0]
            self.stats["ops"] += keys.shape[0]
        while self._pending >= self.batch_size:
            self._dispatch(self.batch_size)
        return ticket

    def query(self, keys) -> Ticket:
        """Enqueue membership queries for ``keys``."""
        return self.submit(keys, np.full((np.asarray(keys).shape[0],),
                                         OP_QUERY, np.int32))

    def insert(self, keys) -> Ticket:
        """Enqueue inserts for ``keys``."""
        return self.submit(keys, np.full((np.asarray(keys).shape[0],),
                                         OP_INSERT, np.int32))

    def delete(self, keys) -> Ticket:
        """Enqueue deletes for ``keys`` (capability-gated at submit)."""
        return self.submit(keys, np.full((np.asarray(keys).shape[0],),
                                         OP_DELETE, np.int32))

    # -- execution -----------------------------------------------------------

    def flush(self) -> None:
        """Dispatch every pending op now (the tail batch is padded)."""
        while self._pending:
            self._dispatch(min(self._pending, self.batch_size))

    def hot_swap(self, new_handle, *, migrate: bool = True) -> dict:
        """Swap the backing filter with zero downtime (DESIGN.md §10).

        Sequence:

        1. **drain** — every accepted-but-pending op is dispatched to the
           *old* handle and the device is synced, so no acknowledged
           operation is lost (tickets already issued keep their claims on
           the old dispatches and stay readable forever);
        2. **migrate** — the old handle's state moves to ``new_handle``
           via the snapshot/restore path (``migrate=True``, the default).
           Fingerprint-compatible targets include a same-config replica,
           a sharded handle on a *different mesh or shard count* (exact
           resharding — capacity/topology changes without dropping a key),
           and a cascade built with the same knobs. Pass ``migrate=False``
           to swap to a pre-populated handle (e.g. rebuilt offline from
           the source of truth).
        3. **resume** — subsequent submissions coalesce onto the new
           handle; nothing about tickets or batching changes.

        Returns swap stats: ``pause_s`` (wall-clock the service could not
        accept dispatches), ``drained_ops``, ``migrated``, and the old/new
        backend names. Mismatched migration targets raise
        :class:`~repro.amq.protocol.SnapshotMismatchError` *before* the
        swap — the service keeps running on the old handle.

        Example::

            >>> svc.hot_swap(old.resharded(num_shards=8))   # grow the mesh
        """
        t0 = time.perf_counter()
        drained = self._pending
        self.flush()
        old = self.handle
        # Sync: the old table(s) are fully materialized before migration
        # (snapshot would block anyway; this also covers migrate=False).
        for lvl in getattr(old, "levels", [old]):
            state = getattr(lvl, "state", None)
            if state is not None and hasattr(state, "_fields"):
                jax.block_until_ready(tuple(state))
        if migrate:
            new_handle.restore(old.snapshot())
        self.handle = new_handle
        return {"pause_s": time.perf_counter() - t0,
                "drained_ops": drained, "migrated": bool(migrate),
                "old_backend": old.name, "new_backend": new_handle.name}

    def _flush_for(self, ticket: Ticket) -> None:
        if ticket._filled < ticket._n:
            self.flush()

    def _take(self, m: int):
        """Pop the first ``m`` pending ops off the stream.

        Returns the packed keys/ops plus the claim ranges they came from,
        splitting the tail range when a submission straddles the batch
        boundary.
        """
        keys_out, ops_out, claims = [], [], []
        need = m
        while need:
            k, o = self._keys[0], self._ops[0]
            ticket, start, cnt = self._claims[0]
            take = min(cnt, need)
            keys_out.append(k[:take])
            ops_out.append(o[:take])
            claims.append((ticket, start, take))
            if take == cnt:
                self._keys.pop(0)
                self._ops.pop(0)
                self._claims.pop(0)
            else:
                self._keys[0] = k[take:]
                self._ops[0] = o[take:]
                self._claims[0] = (ticket, start + take, cnt - take)
            need -= take
        self._pending -= m
        return np.concatenate(keys_out), np.concatenate(ops_out), claims

    def _dispatch(self, m: int) -> None:
        keys, ops, claims = self._take(m)
        batch = OpBatch.make(jnp.asarray(keys), jnp.asarray(ops)).pad_to(
            self.batch_size)
        report = self.handle.apply_ops(batch)   # async: not concretised here
        dispatch = _Dispatch(report)
        self.stats["dispatches"] += 1
        self.stats["padded"] += self.batch_size - m

        # Scatter the contiguous claim ranges back onto tickets (the
        # tickets alone keep the dispatch alive — see Ticket._parts).
        slot = 0
        for ticket, start, cnt in claims:
            ticket._parts.append((dispatch,
                                  np.arange(slot, slot + cnt),
                                  np.arange(start, start + cnt)))
            ticket._filled += cnt
            slot += cnt
