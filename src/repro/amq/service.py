"""FilterService: a deadline-driven, backpressured micro-batching front-end.

Serving traffic reaches a filter as many small, interleaved op streams —
one per logical client — while the accelerator wants few, large, fixed-shape
dispatches. The service bridges the two (DESIGN.md §9, serving engine §11):

* **Coalescing**: ``query`` / ``insert`` / ``delete`` / ``submit`` calls
  append ops (any count, any client) onto one pending stream in arrival
  order. A full micro-batch dispatches immediately; short tails dispatch
  when their **deadline** (``max_delay``) expires, when a result is
  demanded, or on :meth:`flush`.
* **Shape ladder**: a forced (deadline/flush/backpressure) dispatch pads to
  the smallest power-of-two-ish ladder rung that fits instead of the full
  ``batch_size`` (one compiled program per rung — a logarithmic set), so
  deadline-mode padding waste stays bounded by the live op count.
* **Admission control**: ``max_pending`` bounds the pending queue with an
  explicit policy — ``"block"`` (dispatch early to make room — the
  backpressure path), ``"shed"`` (refuse the submission; its ticket
  reports ``shed``), or ``"error"`` (raise
  :class:`~repro.amq.dispatch.QueueFullError`). ``client_share`` caps any
  one client's slice of the queue (fairness).
* **Fused execution**: each micro-batch runs as a single mixed-op pass on
  the wrapped handle — queries, inserts, and deletes of *different* clients
  share one dispatch; in-batch order equals global arrival order, so the
  per-key semantics of DESIGN.md §9 apply across clients.
* **Double buffering**: dispatch is asynchronous — the service keeps each
  batch's :class:`~repro.amq.protocol.MixedReport` as unconcretised device
  arrays and immediately continues packing the next batch while the device
  churns; the handle donates its state buffers to each dispatch, so the
  table is updated in place. ``max_in_flight`` bounds the unconcretised
  window (default 2: classic double buffering); results are pulled to the
  host when a ticket's :meth:`Ticket.result` is called or the window
  slides.
* **Scatter**: every submission returns a :class:`Ticket` that knows which
  slots of which micro-batches carry its ops; ``result()`` gathers exactly
  those slots back into per-client order, however the ops were interleaved.
  Tickets carry enqueue → dispatch → ready timestamps.
* **Observability**: a :class:`~repro.amq.dispatch.ServiceMetrics` ledger
  (histogram-bucketed enqueue→dispatch / enqueue→ready latency, queue
  depth, padding waste, dispatch-size and trigger distributions, per-client
  admission outcomes, swap pauses) — read the legacy counters as
  ``svc.stats["ops"]`` and the full SLO snapshot as ``svc.stats()``.
* **Hot swap** (DESIGN.md §10): :meth:`FilterService.hot_swap` drains the
  pending stream onto the old backend, migrates its state onto a new
  handle via snapshot/restore (including exact resharding onto a new mesh
  or shard count), and resumes — zero-downtime capacity/topology changes;
  no acknowledged operation is lost and issued tickets stay readable.

Example::

    from repro import amq

    svc = amq.FilterService(amq.make("cuckoo", capacity=1 << 20),
                            batch_size=1024, max_delay=0.002,
                            max_pending=8192, admission="shed")
    t1 = svc.insert(keys_a, client="ingest")   # client A
    t2 = svc.query(keys_b, client="serve")     # client B — may share A's batch
    hits = t2.result()                         # flushes pending ops, scatters B's
    svc.stats()["ready"]["p99_s"]              # SLO readout
"""

from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hashing import normalize_keys
from .dispatch import (
    Dispatch,
    PendingStream,
    QueueFullError,
    ServiceMetrics,
    batch_align,
    rung_for,
    shape_ladder,
)
from .protocol import (
    OP_DELETE,
    OP_INSERT,
    OP_QUERY,
    OpBatch,
    normalize_ops,
)

_ADMISSION_POLICIES = ("block", "shed", "error")


class Ticket:
    """A client's claim on its slice of one or more micro-batches.

    ``result()`` returns ``ok`` per submitted op, in submission order
    (query → hit, insert → landed, delete → removed). ``routed()`` returns
    the matching routed mask (sharded backends). Both force a flush of any
    still-pending part of the submission.

    Lifecycle timestamps (service-clock seconds): ``t_enqueue`` when the
    submission was accepted, ``t_dispatch`` when its last op left the
    pending queue, ``t_ready`` when its results were first gathered.
    ``shed`` marks a submission refused by the shed admission policy —
    its ops never ran (``result()`` is all-False and nothing ever flushes
    on its behalf).
    """

    def __init__(self, service: "FilterService", n: int, *, client=None,
                 shed: bool = False):
        self._service = service
        self._n = n
        self.client = client
        self.shed = shed
        self.t_enqueue: float = service._clock()
        self.t_dispatch: Optional[float] = None
        self.t_ready: Optional[float] = None
        # (dispatch, slots-in-batch, positions-in-submission); appended by
        # the service when a batch carrying part of this submission
        # launches. Tickets are the only owners of Dispatch objects, so a
        # batch's reports are reclaimed as soon as every ticket that drew
        # from it is garbage — the service itself only keeps the bounded
        # in-flight window.
        self._parts: List[Tuple[Dispatch, np.ndarray, np.ndarray]] = []
        self._filled = 0
        if n == 0 or shed:
            # Nothing will ever dispatch for this ticket: it is born ready.
            self.t_dispatch = self.t_ready = self.t_enqueue

    def _gather(self, field: str) -> np.ndarray:
        if self.shed:
            return np.zeros((self._n,), bool)
        self._service._flush_for(self)
        out = np.zeros((self._n,), bool)
        for dispatch, slots, positions in self._parts:
            out[positions] = getattr(dispatch, field)()[slots]
        if self.t_ready is None:
            self.t_ready = self._service._clock()
        return out

    @property
    def dispatched(self) -> bool:
        """True once every op of this submission has left the pending
        stream — ``result()`` will then not force a flush."""
        return self.shed or self._filled >= self._n

    def result(self) -> np.ndarray:
        """Per-op outcomes, in submission order (bool[n])."""
        return self._gather("ok")

    def routed(self) -> np.ndarray:
        """Per-op routed mask, in submission order (bool[n])."""
        return self._gather("routed")


class _ServiceStats(dict):
    """Legacy counter dict that is also callable for the full SLO snapshot.

    ``svc.stats["dispatches"]`` keeps working (the pre-§11 counter
    surface); ``svc.stats()`` returns the complete
    :meth:`~repro.amq.dispatch.ServiceMetrics.stats` payload plus these
    counters and the live queue depth.
    """

    def __init__(self, service: "FilterService"):
        super().__init__(dispatches=0, ops=0, padded=0)
        self._service = service

    def __call__(self) -> dict:
        svc = self._service
        out = svc.metrics.stats()
        out.update(self)
        out["pending_ops"] = svc.pending_ops
        out["fill"] = svc.stats_fill
        out["batch_size"] = svc.batch_size
        out["shape_ladder"] = list(svc._ladder)
        out["backend"] = svc.handle.name
        tiers = getattr(svc.handle, "tier_stats", None)
        if callable(tiers):
            # Tiered handles (DESIGN.md §12): budget utilisation and
            # cold-probe traffic belong in the SLO snapshot — cold probes
            # are the service's only off-device work.
            out["tiers"] = tiers()
        return out


def _validate_args(batch_size, max_delay, max_pending, admission,
                   client_share, max_in_flight) -> None:
    """Loud, argument-naming boundary checks (DESIGN.md §10 discipline)."""
    if not isinstance(batch_size, (int, np.integer)) or batch_size <= 0:
        raise ValueError(
            f"batch_size must be a positive int, got {batch_size!r}")
    if max_delay is not None:
        try:
            bad = not (float(max_delay) >= 0.0)
        except (TypeError, ValueError):
            bad = True
        if bad:
            raise ValueError(
                f"max_delay must be None or a non-negative number of "
                f"seconds, got {max_delay!r}")
    if max_pending is not None and (
            not isinstance(max_pending, (int, np.integer))
            or max_pending <= 0):
        raise ValueError(
            f"max_pending must be None or a positive int, got "
            f"{max_pending!r}")
    if admission not in _ADMISSION_POLICIES:
        raise ValueError(
            f"admission must be one of {_ADMISSION_POLICIES}, got "
            f"{admission!r}")
    if not (isinstance(client_share, (int, float, np.floating))
            and 0.0 < float(client_share) <= 1.0):
        raise ValueError(
            f"client_share must be a fraction in (0, 1], got "
            f"{client_share!r}")
    if max_in_flight is not None and (
            not isinstance(max_in_flight, (int, np.integer))
            or max_in_flight <= 0):
        raise ValueError(
            f"max_in_flight must be None or a positive int, got "
            f"{max_in_flight!r}")


class FilterService:
    """Coalesce many clients' op streams into fused, SLO-aware OpBatches.

    ``handle`` is any AMQ handle (static or cascade). ``batch_size`` is the
    micro-batch width — the top of the dispatch shape ladder; keep it large
    enough to amortise dispatch, small enough that a full batch's compute
    fits the latency budget.

    SLO knobs (all validated loudly, DESIGN.md §11):

    * ``max_delay`` — deadline seconds: once the oldest pending op has
      waited this long, the next service interaction (any submit, an
      explicit :meth:`poll`, or a result gather) dispatches the tail at a
      ladder shape instead of letting it wait for a full batch. ``None``
      (default) preserves the pre-§11 dispatch-on-full-only behaviour.
    * ``max_pending`` / ``admission`` / ``client_share`` — admission
      control (see class docstring bullets).
    * ``max_in_flight`` — unconcretised dispatch window (default 2).
    * ``clock`` — injectable monotonic-seconds source (defaults to
      ``time.monotonic``); the traffic harness drives a virtual clock
      through it, which is also how deadline behaviour is unit-tested.
    """

    def __init__(self, handle, *, batch_size: int = 1024,
                 max_delay: Optional[float] = None,
                 max_pending: Optional[int] = None,
                 admission: str = "block",
                 client_share: float = 1.0,
                 max_in_flight: Optional[int] = 2,
                 clock=None):
        _validate_args(batch_size, max_delay, max_pending, admission,
                       client_share, max_in_flight)
        self.handle = handle
        self.batch_size = int(batch_size)
        self.max_delay = None if max_delay is None else float(max_delay)
        self.max_pending = None if max_pending is None else int(max_pending)
        self.admission = admission
        self.client_share = float(client_share)
        self.max_in_flight = (None if max_in_flight is None
                              else int(max_in_flight))
        self._clock = time.monotonic if clock is None else clock
        self._align = batch_align(handle)
        self._ladder = shape_ladder(self.batch_size, self._align)
        self._queue = PendingStream()
        self._in_flight: List[Dispatch] = []
        self.metrics = ServiceMetrics()
        self.stats = _ServiceStats(self)

    # -- introspection -------------------------------------------------------

    @property
    def pending_ops(self) -> int:
        """Ops accepted but not yet dispatched."""
        return self._queue.pending

    @property
    def stats_fill(self) -> float:
        """Realised batch utilisation: live slots / dispatched slots."""
        total = (self.stats["ops"] - self.pending_ops - self.metrics.shed_ops
                 + self.stats["padded"])
        live = self.stats["ops"] - self.pending_ops - self.metrics.shed_ops
        return live / total if total else 1.0

    @property
    def shape_ladder(self) -> Tuple[int, ...]:
        """The dispatch shapes this service pads to (top = batch_size)."""
        return self._ladder

    def _client_limit(self) -> Optional[int]:
        if self.max_pending is None or self.client_share >= 1.0:
            return None
        return max(1, int(self.client_share * self.max_pending))

    # -- submission ----------------------------------------------------------

    def submit(self, keys, ops, *, client=None) -> Ticket:
        """Append a client's op stream; returns its :class:`Ticket`.

        ``keys``: raw ``uint64[m]`` or packed ``uint32[m, 2]`` pairs (the
        key-format contract — see ``repro.core.hashing.normalize_keys``);
        ``ops``: int32[m] op codes; ``client``: optional hashable id for
        fairness accounting and the per-client queue-share bound. The ops
        join the global stream in call order — coalescing never reorders.
        Malformed arguments raise ``ValueError`` naming the offending
        argument at the boundary, before anything is enqueued; a full
        queue follows the admission policy (block / shed / error).

        ``n == 0`` submissions return an immediately-ready empty ticket:
        nothing is enqueued, no padded dispatch is forced, and no deadline
        starts ticking.
        """
        keys = np.asarray(normalize_keys(keys, arg="keys"), np.uint32)
        ops = np.asarray(normalize_ops(ops, keys.shape[0]), np.int32)
        if ((ops == OP_DELETE).any()
                and not self.handle.capabilities.supports_delete):
            raise NotImplementedError(
                f"{self.handle.name}: append-only backend cannot serve "
                "deletes (capabilities.supports_delete is False)")
        n = keys.shape[0]
        if n == 0:
            return Ticket(self, 0, client=client)

        # -- admission control (DESIGN.md §11) -------------------------------
        if self.max_pending is not None:
            if self.admission == "block":
                # Backpressure: make room by dispatching early. Ladder
                # shapes keep the forced padding proportional to the tail.
                while (self._queue.pending
                       and self._queue.pending + n > self.max_pending):
                    self._dispatch(min(self._queue.pending, self.batch_size),
                                   kind="backpressure")
            else:
                share = self._client_limit()
                held = self._queue.client_pending.get(client, 0)
                over_share = share is not None and held + n > share
                over_global = self._queue.pending + n > self.max_pending
                if over_global or over_share:
                    bound = (f"max_pending={self.max_pending}" if over_global
                             else f"client {client!r} share={share} "
                                  f"(client_share={self.client_share})")
                    if self.admission == "error":
                        raise QueueFullError(
                            f"pending queue full: {self._queue.pending} "
                            f"pending + {n} submitted exceeds {bound}")
                    self.metrics.observe_shed(n, client)
                    return Ticket(self, n, client=client, shed=True)

        ticket = Ticket(self, n, client=client)
        self._queue.append(keys, ops, ticket.t_enqueue, ticket, client)
        self.stats["ops"] += n
        self.metrics.observe_enqueue(n, client, self._queue.pending)
        while self._queue.pending >= self.batch_size:
            self._dispatch(self.batch_size, kind="full")
        if (self.max_pending is not None and self.admission == "block"
                and self._queue.pending > self.max_pending):
            # A single over-bound submission: drain its own tail too.
            self._dispatch(self._queue.pending, kind="backpressure")
        self.poll()
        return ticket

    def query(self, keys, *, client=None) -> Ticket:
        """Enqueue membership queries for ``keys``."""
        return self.submit(keys, np.full((np.asarray(keys).shape[0],),
                                         OP_QUERY, np.int32), client=client)

    def insert(self, keys, *, client=None) -> Ticket:
        """Enqueue inserts for ``keys``."""
        return self.submit(keys, np.full((np.asarray(keys).shape[0],),
                                         OP_INSERT, np.int32), client=client)

    def delete(self, keys, *, client=None) -> Ticket:
        """Enqueue deletes for ``keys`` (capability-gated at submit)."""
        return self.submit(keys, np.full((np.asarray(keys).shape[0],),
                                         OP_DELETE, np.int32), client=client)

    # -- execution -----------------------------------------------------------

    def poll(self) -> int:
        """Fire any deadline-due dispatches; returns how many were fired.

        With ``max_delay`` unset this is a no-op. Call it from an event
        loop (or let any submit/result call do it implicitly) — the
        deadline guarantee is: once the oldest pending op has waited
        ``max_delay``, the *next* service interaction dispatches it, so
        enqueue→dispatch latency is bounded by ``max_delay`` plus one
        interaction gap plus one dispatch.
        """
        if self.max_delay is None:
            return 0
        fired = 0
        while self._queue.pending:
            oldest = self._queue.oldest_enqueue()
            if self._clock() - oldest < self.max_delay:
                break
            self._dispatch(min(self._queue.pending, self.batch_size),
                           kind="deadline")
            fired += 1
        return fired

    def flush(self) -> None:
        """Dispatch every pending op now (tails pad to ladder shapes)."""
        while self._queue.pending:
            self._dispatch(min(self._queue.pending, self.batch_size),
                           kind="flush")

    def drain(self) -> None:
        """Flush, then concretise every in-flight dispatch (settles the
        enqueue→ready histogram — the harness calls this before reading
        final metrics)."""
        self.flush()
        for dispatch in self._in_flight:
            dispatch.ok()
        self._in_flight.clear()

    def hot_swap(self, new_handle, *, migrate: bool = True) -> dict:
        """Swap the backing filter with zero downtime (DESIGN.md §10).

        Sequence:

        1. **drain** — every accepted-but-pending op is dispatched to the
           *old* handle and the device is synced, so no acknowledged
           operation is lost (tickets already issued keep their claims on
           the old dispatches and stay readable forever);
        2. **migrate** — the old handle's state moves to ``new_handle``
           via the snapshot/restore path (``migrate=True``, the default).
           Fingerprint-compatible targets include a same-config replica,
           a sharded handle on a *different mesh or shard count* (exact
           resharding — capacity/topology changes without dropping a key),
           and a cascade built with the same knobs. Pass ``migrate=False``
           to swap to a pre-populated handle (e.g. rebuilt offline from
           the source of truth).
        3. **resume** — subsequent submissions coalesce onto the new
           handle; the shape ladder is rebuilt for the new backend's
           ``batch_align`` (a K→K′ reshard changes the legal dispatch
           widths); nothing about tickets or batching changes.

        Returns swap stats: ``pause_s`` (wall-clock the service could not
        accept dispatches), ``drained_ops``, ``migrated``, and the old/new
        backend names; the record is also appended to
        ``metrics.swaps``. Mismatched migration targets raise
        :class:`~repro.amq.protocol.SnapshotMismatchError` *before* the
        swap — the service keeps running on the old handle. An incompatible
        ``batch_align`` (the new mesh cannot split ``batch_size``) raises
        ``ValueError`` before anything drains.

        Example::

            >>> svc.hot_swap(old.resharded(num_shards=8))   # grow the mesh
        """
        align = batch_align(new_handle)
        if self.batch_size % align:
            raise ValueError(
                f"batch_size={self.batch_size} is not a multiple of the "
                f"new handle's batch_align={align}; the swapped-in backend "
                "could never dispatch — refusing before the drain")
        t0 = time.perf_counter()
        drained = self.pending_ops
        self.flush()
        old = self.handle
        # Sync: the old table(s) are fully materialized before migration
        # (snapshot would block anyway; this also covers migrate=False).
        for lvl in getattr(old, "levels", [old]):
            state = getattr(lvl, "state", None)
            if state is not None and hasattr(state, "_fields"):
                jax.block_until_ready(tuple(state))
        if migrate:
            new_handle.restore(old.snapshot())
        self.handle = new_handle
        self._align = align
        self._ladder = shape_ladder(self.batch_size, align)
        record = {"pause_s": time.perf_counter() - t0,
                  "drained_ops": drained, "migrated": bool(migrate),
                  "old_backend": old.name, "new_backend": new_handle.name}
        self.metrics.observe_swap(record)
        return record

    def _flush_for(self, ticket: Ticket) -> None:
        if ticket._filled < ticket._n:
            self.flush()

    def _dispatch(self, m: int, kind: str = "full") -> None:
        now = self._clock()
        keys, ops, enqueued_at, claims = self._queue.take(m)
        shape = rung_for(m, self._ladder)
        # Host-side padding: each channel crosses host->device once, at
        # its final ladder shape (no device concatenates per dispatch).
        batch = OpBatch.make_padded(keys, ops, shape)
        report = self.handle.apply_ops(batch)  # async: not concretised here
        dispatch = Dispatch(report, self.metrics, self._clock, enqueued_at)
        self.stats["dispatches"] += 1
        self.stats["padded"] += shape - m
        self.metrics.observe_dispatch(m, shape, kind, now - enqueued_at)

        # Scatter the contiguous claim ranges back onto tickets (the
        # tickets alone keep a dispatch alive past the in-flight window —
        # see Ticket._parts).
        slot = 0
        for ticket, start, cnt in claims:
            ticket._parts.append((dispatch,
                                  np.arange(slot, slot + cnt),
                                  np.arange(start, start + cnt)))
            ticket._filled += cnt
            if ticket._filled >= ticket._n:
                ticket.t_dispatch = now
            slot += cnt

        # Slide the in-flight window: concretising the oldest batch is the
        # double-buffering backstop (bounded device-result backlog) and
        # what stamps enqueue→ready latencies promptly. With an unbounded
        # window the service tracks nothing (tickets alone own dispatches,
        # the pre-§11 behaviour).
        if self.max_in_flight is not None:
            self._in_flight.append(dispatch)
            while len(self._in_flight) > self.max_in_flight:
                self._in_flight.pop(0).ok()
            self._in_flight = [d for d in self._in_flight if not d.done]
