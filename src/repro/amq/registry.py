"""The AMQ registry: name -> adapter, and the ``make`` front door.

    from repro import amq

    handle = amq.make("cuckoo", capacity=1_000_000)
    report = handle.insert(keys, bulk=True)
    hits = handle.query(keys).hits

Backends registered by default: ``cuckoo``, ``bloom``, ``tcf``, ``gqf``,
``bcht``, ``sharded-cuckoo``, plus the host-side conformance oracle
``cpu-cuckoo``. Register additional backends with :func:`register` — the
conformance suite (tests/test_amq_api.py) and the benchmark consumers pick
them up automatically.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, Optional

from .adapters import DEFAULT_ADAPTERS, AMQAdapter
from .handle import FilterHandle


def _validate(adapter: AMQAdapter) -> None:
    """Capability flags must match the ops actually provided, so consumers
    that branch on a flag get the documented NotImplementedError — never a
    'NoneType is not callable' deep inside a jit cache."""
    caps = adapter.capabilities
    if caps.supports_delete and not callable(adapter.delete):
        raise ValueError(
            f"{adapter.name!r}: supports_delete=True but no delete op")
    if caps.supports_bulk and not callable(adapter.insert_bulk):
        raise ValueError(
            f"{adapter.name!r}: supports_bulk=True but no insert_bulk op")
    if caps.supports_expand and not adapter.growth_sizings:
        raise ValueError(
            f"{adapter.name!r}: supports_expand=True but no growth_sizings "
            "hook (the cascade cannot size levels to their FPR shares)")
    if caps.supports_mixed and not callable(adapter.apply_ops):
        raise ValueError(
            f"{adapter.name!r}: supports_mixed=True but no apply_ops op "
            "(the fused mixed-batch path it advertises)")
    if caps.supports_snapshot and not (callable(adapter.snapshot)
                                       and callable(adapter.restore)):
        raise ValueError(
            f"{adapter.name!r}: supports_snapshot=True but missing "
            "snapshot/restore hooks (the lifecycle surface it advertises)")
    if caps.supports_tiering:
        if not callable(adapter.host_query):
            raise ValueError(
                f"{adapter.name!r}: supports_tiering=True but no host_query "
                "hook (cold levels could never be probed)")
        if not (caps.supports_snapshot and caps.supports_expand):
            raise ValueError(
                f"{adapter.name!r}: supports_tiering=True requires "
                "supports_snapshot and supports_expand (demotion freezes "
                "cascade levels through the snapshot path)")
        if caps.supports_delete and not callable(adapter.host_delete):
            raise ValueError(
                f"{adapter.name!r}: supports_tiering with supports_delete "
                "needs a host_delete hook (cold-tier deletes are host-side "
                "slot clears)")


def register(adapter: AMQAdapter, *, overwrite: bool = False) -> None:
    """Add a backend to the registry (``overwrite=True`` to replace)."""
    _validate(adapter)
    if adapter.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {adapter.name!r} already registered")
    _REGISTRY[adapter.name] = adapter


_REGISTRY: Dict[str, AMQAdapter] = {}
for _adapter in DEFAULT_ADAPTERS.values():
    register(_adapter)


def get(name: str) -> AMQAdapter:
    """Look up a backend adapter by registry name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown AMQ backend {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> Iterable[str]:
    """Registered backend names, in registration order."""
    return tuple(_REGISTRY)


def make(name: str, capacity: Optional[int] = None, *,
         config: Any = None, state: Any = None, snapshot: Any = None,
         auto_expand=False, tiered: bool = False, **kw):
    """Build a ready-to-use filter handle.

    Either pass ``capacity`` (+ backend-specific sizing kwargs, forwarded to
    the adapter's ``make_config``) or a pre-built ``config``. ``state``
    resumes from an existing state pytree; ``snapshot`` restores a
    :class:`~repro.amq.protocol.Snapshot` (taken with ``handle.snapshot()``
    or loaded with :func:`~repro.amq.protocol.load_snapshot`) onto the
    freshly built handle — the snapshot's config fingerprint must match
    the config built here, else
    :class:`~repro.amq.protocol.SnapshotMismatchError` (DESIGN.md §10).

    ``auto_expand=True`` returns a :class:`repro.amq.cascade.CascadeHandle`
    instead of a static :class:`FilterHandle`: ``capacity`` becomes the
    *initial* level size and the filter grows online as a geometric cascade
    (DESIGN.md §8), so streaming workloads need no a-priori sizing. Cascade
    tuning knobs (``growth``, ``watermark``, ``fpr_budget``,
    ``split_ratio``, ``max_levels``) ride along in ``**kw`` next to the
    backend's sizing kwargs. Requires ``capabilities.supports_expand``;
    ``auto_expand="auto"`` expands when the backend supports it and falls
    back to a static handle otherwise (the consumer-friendly default for
    backend-generic callers).

    ``tiered=True`` returns a :class:`repro.amq.tiering.TieredHandle`: an
    auto-expanding cascade whose device footprint is capped at
    ``device_budget_bytes`` (required in ``**kw`` unless a tiered
    ``snapshot`` carries it) — older levels are frozen into host-RAM numpy
    arrays and probed off-device (DESIGN.md §12). Mutually exclusive with
    ``auto_expand`` (a tiered handle *is* an auto-expanding cascade).
    Requires ``capabilities.supports_tiering``.

    Example::

        >>> h = amq.make("cuckoo", capacity=100_000, auto_expand=True)
        >>> h.insert(keys)                # any volume; levels allocate lazily
        >>> len(h.levels)                 # doctest: +SKIP
        4
    """
    adapter = get(name)
    if auto_expand == "auto":
        auto_expand = adapter.capabilities.supports_expand
    if snapshot is not None and state is not None:
        raise TypeError("pass state= or snapshot=, not both")
    if tiered:
        if auto_expand:
            raise TypeError(
                "tiered=True already auto-expands; drop auto_expand=")
        if config is not None or state is not None:
            raise TypeError(
                "tiered=True sizes and allocates levels itself; pass "
                "capacity=..., not config=/state=")
        if capacity is None:
            raise TypeError("make(tiered=True) needs capacity=...")
        if "device_budget_bytes" not in kw and snapshot is not None:
            kw["device_budget_bytes"] = snapshot.meta["device_budget_bytes"]
        if "device_budget_bytes" not in kw:
            raise TypeError("make(tiered=True) needs device_budget_bytes=...")
        from .tiering import TieredHandle

        handle = TieredHandle(adapter, capacity, **kw)
        if snapshot is not None:
            handle.restore(snapshot)
        return handle
    if auto_expand:
        if config is not None or state is not None:
            raise TypeError(
                "auto_expand=True sizes and allocates levels itself; pass "
                "capacity=..., not config=/state=")
        if capacity is None:
            raise TypeError("make(auto_expand=True) needs capacity=...")
        from .cascade import CascadeHandle

        handle = CascadeHandle(adapter, capacity, **kw)
        if snapshot is not None:
            handle.restore(snapshot)
        return handle
    if config is None:
        if capacity is None:
            raise TypeError("make() needs capacity=... or config=...")
        config = adapter.make_config(capacity, **kw)
    elif capacity is not None or kw:
        extra = (["capacity"] if capacity is not None else []) + sorted(kw)
        raise TypeError(f"config= given; conflicting arguments {extra}")
    if snapshot is not None:
        # Build straight from the snapshot: no discarded fresh table.
        return FilterHandle.from_snapshot(adapter, config, snapshot)
    return FilterHandle(adapter, config, state)
