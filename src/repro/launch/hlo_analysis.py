"""Post-SPMD HLO analysis: collective-op inventory + roofline terms.

``cost_analysis()`` gives per-device FLOPs and HBM bytes but not collective
traffic, so we parse ``compiled.as_text()``: every line defining an
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
contributes its result-shape bytes, scaled to *wire bytes per device* with
the standard ring-algorithm factors and the parsed replica-group size.

Hardware constants are TPU v5e-class (DESIGN.md §6).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

# v5e-class constants
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~3 usable links/chip on a torus)
ICI_LINKS = 3
HBM_PER_CHIP = 16 * 2**30

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

# `%x.1 = bf16[8,128]{1,0} all-gather(...)` or tuple results
_DEF_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s+(" + "|".join(_COLL) + r")(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].split(",")
        return max(1, len([x for x in first if x.strip().isdigit()]))
    return default


def collective_stats(hlo_text: str, n_devices: int) -> Dict[str, Dict]:
    """Per-kind counts / result bytes / estimated wire bytes per device."""
    done_seen = set()
    stats: Dict[str, Dict] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0, "wire_bytes": 0})
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in line:  # async pair: count the start only
            continue
        b = _shape_bytes(type_str)
        g = _group_size(line, n_devices)
        frac = (g - 1) / max(g, 1)
        if kind == "all-reduce":
            wire = 2 * b * frac            # ring: reduce-scatter + all-gather
        elif kind == "all-gather":
            wire = b * frac                # result is the gathered buffer
        elif kind == "reduce-scatter":
            wire = b * g * frac            # result is the scattered shard
        elif kind == "all-to-all":
            wire = b * frac
        else:  # collective-permute
            wire = b
        s = stats[kind]
        s["count"] += 1
        s["result_bytes"] += b
        s["wire_bytes"] += int(wire)
    return dict(stats)


def roofline_terms(cost: Dict, colls: Dict[str, Dict],
                   n_devices: int) -> Dict[str, float]:
    """Three roofline terms in seconds (per device, per step)."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    wire = float(sum(s["wire_bytes"] for s in colls.values()))
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": wire / (ICI_LINKS * ICI_BW),
        "hlo_flops": flops,
        "hlo_bytes": bytes_hbm,
        "collective_wire_bytes": wire,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    three = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
    return max(three, key=three.get)


def analytic_model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS: 6·N·D (train) / 2·N·D (inference) **plus** attention
    score/value flops, which dominate parameter flops at 4k+ context for
    the small-d archs. MoE counts active params only. Per the whole job
    (divide by device count for per-device)."""
    n = cfg.param_count(active_only=cfg.moe)
    per_param = {"train": 6, "prefill": 2, "decode": 2}[kind]
    tokens = batch * (seq if kind != "decode" else 1)
    total = float(per_param) * n * tokens

    # attention term
    mult = 3.0 if kind == "train" else 1.0  # bwd ~= 2x fwd
    for k in cfg.layer_kinds():
        mixer = k.split("+")[0]
        if mixer in ("attn", "attn_local", "mla"):
            H = cfg.num_heads
            if mixer == "mla":
                d_qk = cfg.mla_qk_nope_dim + cfg.mla_qk_rope_dim
                d_v = cfg.mla_v_dim
            else:
                d_qk = d_v = cfg.head_dim_()
            if kind == "decode":
                kv = min(cfg.sliding_window, seq) \
                    if mixer == "attn_local" and cfg.sliding_window else seq
                per_tok = 2 * kv * H * (d_qk + d_v)
                total += batch * per_tok
            else:
                w = cfg.sliding_window if mixer == "attn_local" else None
                kv_avg = min(w, seq / 2) if w else (
                    seq if not cfg.causal else seq / 2)
                total += mult * batch * seq * 2 * kv_avg * H * (d_qk + d_v)
        elif mixer == "ssm":
            Hs, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            if kind == "decode":
                total += batch * 4 * Hs * P * N      # recurrent state step
            else:
                L = cfg.ssm_chunk                    # intra-chunk quadratic
                per_tok = 2 * L * Hs * P + 4 * Hs * P * N
                total += mult * batch * seq * per_tok
        elif mixer == "rglru":
            W = cfg.rglru_width or cfg.d_model
            toks = batch if kind == "decode" else batch * seq
            total += (1.0 if kind == "decode" else mult) * toks * 8 * W
    return total
