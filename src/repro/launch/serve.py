"""Serving driver: batched generation with the AMQ-guarded prefix cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_4b --reduced \
        --requests 8 --steps 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import build_model
from ..serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--repeat-fraction", type=float, default=0.5,
                    help="fraction of requests repeating a previous prompt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params, batch=args.batch,
                         max_len=args.prompt_len + args.steps)

    rng = np.random.default_rng(args.seed)
    base_prompts = [rng.integers(0, cfg.vocab_size,
                                 (args.batch, args.prompt_len)).astype(np.int32)
                    for _ in range(max(2, args.requests // 2))]
    total_tokens = 0
    t0 = time.perf_counter()
    for r in range(args.requests):
        if rng.random() < args.repeat_fraction and r > 0:
            prompts = base_prompts[rng.integers(0, len(base_prompts))]
        else:
            prompts = base_prompts[r % len(base_prompts)]
        tokens, stats = engine.generate(prompts, steps=args.steps)
        total_tokens += tokens.size
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.0f} tok/s)")
    print("prefix-cache stats:", stats)


if __name__ == "__main__":
    main()
