"""Training driver: end-to-end runnable on local devices, mesh-ready.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2_130m \
        --reduced --steps 100 --batch 8 --seq 256 [--resume] [--dedup]

On a real cluster the same driver runs under the production mesh
(launch/mesh.py) with the dry-run's shardings; locally it uses whatever
devices exist. XLA latency-hiding scheduler flags for real TPU runs are
recorded here (no-ops on CPU):
    --xla_tpu_enable_latency_hiding_scheduler=true
    --xla_tpu_overlap_compute_collective_tc=true
"""

from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from ..configs import get_config
from ..core import CuckooConfig
from ..data import DataConfig, DedupConfig, dedup_batch, make_batch, make_frames_batch
from ..models import build_model
from ..train import AdamWConfig, TrainingRunner, init_train_state, make_train_step
from ..train import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dedup", action="store_true",
                    help="filter-backed streaming dedup of training data")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps)
    params, opt_state = init_train_state(model, opt_cfg,
                                         jax.random.key(args.seed))
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"devices={jax.device_count()}")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                          seq_len=args.seq, seed=args.seed)

    dedup_state = {"filter": None}
    if args.dedup:
        dcfg = DedupConfig(CuckooConfig.for_capacity(
            max(args.steps * args.batch, 4096), hash_kind="fmix32"))
        dedup_state["filter"] = dcfg.filter.init()
        dedup_fn = jax.jit(lambda s, b: dedup_batch(dcfg, s, b))

    def data_fn(step):
        if cfg.frontend == "frames":
            return make_frames_batch(data_cfg, step, cfg.d_model)
        batch = make_batch(data_cfg, step)
        if dedup_state["filter"] is not None:
            dedup_state["filter"], batch, stats = dedup_fn(
                dedup_state["filter"], batch)
            if step % 20 == 0:
                print(f"  dedup: {int(stats['duplicates'])} duplicate "
                      f"sequences masked at step {step}")
        return batch

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    runner = TrainingRunner(train_step=step_fn, data_fn=data_fn,
                            ckpt_dir=args.ckpt_dir,
                            ckpt_every=args.ckpt_every)
    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        params, opt_state, start = runner.resume(params, opt_state)
        print(f"resumed from step {start}")
    params, opt_state, monitor = runner.run(
        params, opt_state, num_steps=args.steps, start_step=start)
    print("straggler summary:", monitor.summary())


if __name__ == "__main__":
    main()
