import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each live cell this driver builds the production mesh, constructs
ShapeDtypeStruct stand-ins for every input (params and optimizer state via
``jax.eval_shape`` — no allocation anywhere), jits the appropriate step with
explicit in/out shardings, runs ``.lower().compile()``, and records:

  * ``memory_analysis()``   — per-device argument/temp/peak bytes (fits?)
  * ``cost_analysis()``     — per-device HLO FLOPs + HBM bytes
  * collective inventory    — parsed from the post-SPMD HLO text
  * the three roofline terms (launch/hlo_analysis.py)

Results land in ``results/dryrun/<arch>__<shape>__<mesh>.json`` and feed
EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.py.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_2b \
        --shape train_4k [--multi-pod] [--all]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS, get_config  # noqa: E402
from ..models import build_model  # noqa: E402
from ..train import AdamWConfig, adamw_init, make_train_step  # noqa: E402
from . import hlo_analysis as H  # noqa: E402
from . import hlo_cost as HC  # noqa: E402
from .input_specs import SHAPES, SKIPS, input_specs, live_cells  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .shardings import (  # noqa: E402
    make_batch_shardings,
    make_cache_shardings,
    make_opt_shardings,
    make_param_shardings,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _quantize_state(cfg) -> bool:
    # int8 Adam for >=30B-param configs (fits 16 GB/chip budget)
    return cfg.param_count() > 30e9


def spec_kind_is_decode(arch: str, shape_name: str) -> bool:
    return SHAPES[shape_name]["kind"] == "decode"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: int = 1, overrides: dict | None = None,
               no_hints: bool = False, param_mode: str | None = None):
    """Build + lower + compile one cell. Returns (compiled, meta).

    ``overrides`` patches ModelConfig fields; ``no_hints`` disables the
    shard_ctx constraints and ``param_mode`` forces train/serve shardings —
    both used to reproduce §Perf baselines under the final cost model.
    """
    import dataclasses

    from ..models import shard_ctx

    mesh = make_production_mesh(multi_pod=multi_pod)
    if not no_hints:
        shard_ctx.set_dp_axes(("pod", "data") if multi_pod else ("data",))
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    spec = input_specs(cfg, shape_name)
    kind = spec["kind"]

    params_shape = jax.eval_shape(model.init, jax.random.key(0))
    # decode = latency path: TP/EP weights (no per-token FSDP gathers)
    if param_mode is None:
        param_mode = ("serve" if spec_kind_is_decode(arch, shape_name)
                      else "train")
    param_sh = make_param_shardings(mesh, params_shape, mode=param_mode)

    # `with mesh:` provides the context for P-only sharding constraints
    # (shard_ctx hints inside model code)
    with mesh:
        if kind == "train":
            opt_cfg = AdamWConfig(quantize_state=_quantize_state(cfg))
            opt_shape = jax.eval_shape(
                lambda p: adamw_init(opt_cfg, p), params_shape)
            opt_sh = make_opt_shardings(mesh, opt_shape,
                                        quantized=opt_cfg.quantize_state)
            batch_sh = make_batch_shardings(mesh, spec["batch_spec"])
            step = make_train_step(model, opt_cfg, microbatches=microbatches)
            metrics_sh = {"loss": NamedSharding(mesh, P()),
                          "grad_norm": NamedSharding(mesh, P()),
                          "lr": NamedSharding(mesh, P())}
            fn = jax.jit(step,
                         in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, metrics_sh),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_shape, opt_shape, spec["batch_spec"])
        elif kind == "prefill":
            batch_sh = make_batch_shardings(mesh, spec["batch_spec"])
            if cfg.frontend == "frames":
                fn = jax.jit(lambda p, b: model.encode(p, b["frames"]),
                             in_shardings=(param_sh, batch_sh))
            else:
                fn = jax.jit(model.prefill,
                             in_shardings=(param_sh, batch_sh))
            lowered = fn.lower(params_shape, spec["batch_spec"])
        else:  # decode
            cache_shape = spec["cache_spec"]
            cache_sh = make_cache_shardings(mesh, cache_shape, spec["seq"],
                                            spec["batch"])
            tok_sh = make_batch_shardings(mesh, spec["token_spec"])
            pos_sh = NamedSharding(mesh, P())
            fn = jax.jit(model.decode_step,
                         in_shardings=(param_sh, tok_sh, cache_sh, pos_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(params_shape, spec["token_spec"], cache_shape,
                               spec["pos_spec"])

        compiled = lowered.compile()
    shard_ctx.set_dp_axes(None)
    return compiled, {"mesh": dict(zip(mesh.axis_names,
                                       [int(s) for s in mesh.devices.shape])),
                      "n_devices": int(mesh.size), "cfg": cfg, "spec": spec}


def analyse(compiled, meta, *, keep_hlo: bool = False):
    cfg, spec = meta["cfg"], meta["spec"]
    n_dev = meta["n_devices"]
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    # Primary costs come from the text-based model (hlo_cost) because XLA's
    # cost_analysis counts while(scan) bodies once — under-counting a
    # 61-layer scanned stack ~61x. Validated against known matmuls.
    tc = HC.analyse_text(txt, n_dev)
    colls = tc["collectives"]
    terms = {
        "compute_s": tc["flops"] / H.PEAK_FLOPS,
        "memory_s": tc["bytes"] / H.HBM_BW,
        "collective_s": (sum(s["wire_bytes"] for s in colls.values())
                         / (H.ICI_LINKS * H.ICI_BW)),
        "hlo_flops": tc["flops"],
        "hlo_bytes": tc["bytes"],
        "collective_wire_bytes": sum(s["wire_bytes"]
                                     for s in colls.values()),
    }

    # MODEL_FLOPS: 6/2 N D (active params for MoE) + analytic attention/SSM
    # terms (hlo_analysis.analytic_model_flops)
    model_flops = H.analytic_model_flops(cfg, spec["kind"], spec["batch"],
                                         spec["seq"])
    model_flops_per_dev = model_flops / n_dev

    out = {
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "fits_16gb": (mem.argument_size_in_bytes - mem.alias_size_in_bytes
                          + mem.output_size_in_bytes + mem.temp_size_in_bytes)
            < H.HBM_PER_CHIP,
        },
        "cost_xla_unscaled": {k: float(v) for k, v in cost.items()
                              if "flops" in k or k == "bytes accessed"},
        "collectives": colls,
        "roofline": terms,
        "dominant": H.dominant_term(terms),
        "model_flops_per_device": model_flops_per_dev,
        "useful_flop_ratio": (model_flops_per_dev
                              / max(terms["hlo_flops"], 1.0)),
    }
    if keep_hlo:
        out["hlo_len"] = len(txt)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, microbatches: int = 1,
             tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    label = f"{arch}__{shape_name}__{mesh_name}{tag}"
    t0 = time.time()
    try:
        compiled, meta = lower_cell(arch, shape_name, multi_pod,
                                    microbatches=microbatches)
        result = analyse(compiled, meta)
        result.update(status="ok", arch=arch, shape=shape_name,
                      mesh=mesh_name, microbatches=microbatches,
                      compile_s=round(time.time() - t0, 1))
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        result = {"status": "error", "arch": arch, "shape": shape_name,
                  "mesh": mesh_name, "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:],
                  "compile_s": round(time.time() - t0, 1)}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, label + ".json"), "w") as f:
        json.dump(result, f, indent=1, default=str)
    print(f"[{result['status']}] {label} ({result['compile_s']}s) "
          + (result.get("dominant", "") or result.get("error", "")[:120]))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    cells = []
    if args.all:
        cells = list(live_cells())
    elif args.arch and args.shape:
        if (args.arch, args.shape) in SKIPS:
            print(f"SKIP {args.arch} {args.shape}: "
                  f"{SKIPS[(args.arch, args.shape)]}")
            return
        cells = [(args.arch, args.shape)]
    elif args.arch:
        cells = [(args.arch, s) for s in SHAPES
                 if (args.arch, s) not in SKIPS]
    else:
        ap.error("pass --all or --arch [--shape]")

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    ok = err = 0
    for arch, shape in cells:
        for mp in meshes:
            r = run_cell(arch, shape, mp, out_dir=args.out_dir,
                         microbatches=args.microbatches)
            ok += r["status"] == "ok"
            err += r["status"] != "ok"
    print(f"done: {ok} ok, {err} failed")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
