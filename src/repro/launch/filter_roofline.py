"""Roofline plumbing for the filter ops (DESIGN.md §13).

Three pieces connect the analytic bytes model (kernels/roofline.py) to
numbers a benchmark can report honestly:

* :func:`measured_copy_bandwidth` — an empirical STREAM-style ceiling: the
  bytes/s of a device-resident array copy, measured on *this* machine and
  backend. Achieved fractions are quoted against this, never against a
  datasheet — the CPU container and a TPU core get the same treatment.
* :func:`lowered_cost` — lower + compile a jitted filter op and run the
  text-based HLO cost model (launch/hlo_cost.py) over the result: what XLA
  actually materializes, trip-count-scaled.
* :func:`cross_check` — the guard rail: the HLO-parsed bytes of a lowered
  query/insert/mixed program, divided by the model's minimal bytes. The
  ratio must stay ≥ 1 (a *minimal* model can't exceed what the compiled
  program moves) and inside a recorded band (tests/test_roofline_model.py)
  — if the bytes model drifts (a layout change, a probe-count change the
  model missed), the roofline suite's denominators go stale and this ratio
  moves first.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core import cuckoo_filter as CF
from ..kernels import roofline as RM
from . import hlo_cost as HC


def measured_copy_bandwidth(nbytes: int = 1 << 26, iters: int = 5) -> float:
    """Empirical memory-bandwidth ceiling: device copy bytes/s.

    Times ``y = x + 0`` over a ``nbytes`` uint32 array (one read + one
    write per element — 2x ``nbytes`` moved per call) and returns the
    median bytes/s. This is the peak the roofline fractions are quoted
    against; re-measured per process so container/TPU runs self-calibrate.
    """
    n = max(1, nbytes // 4)
    x = jnp.zeros((n,), jnp.uint32)
    copy = jax.jit(lambda a: a + jnp.uint32(0))
    jax.block_until_ready(copy(x))  # compile + warm
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(copy(x))
        times.append(time.perf_counter() - t0)
    return 2.0 * n * 4 / float(np.median(times))


def lowered_cost(fn, *args, n_devices: int = 1) -> Dict:
    """Lower + compile ``fn(*args)`` and run the HLO cost parse over it.

    Returns the :func:`repro.launch.hlo_cost.analyse_text` dict (flops,
    bytes, collectives, n_computations) of the *compiled* program — the
    same machinery the model dry-run uses, pointed at a filter op.
    """
    compiled = jax.jit(fn).lower(*args).compile()
    return HC.analyse_text(compiled.as_text(), n_devices)


def _mixed_ops_array(n: int, op_mix=(0.80, 0.15, 0.05)) -> jnp.ndarray:
    """Deterministic op-code array realizing ``op_mix`` fractions."""
    q, i, d = op_mix
    n_i = int(round(n * i / (q + i + d)))
    n_d = int(round(n * d / (q + i + d)))
    codes = np.zeros((n,), np.int32)
    codes[:n_i] = 1
    codes[n_i:n_i + n_d] = 2
    rng = np.random.default_rng(0)
    rng.shuffle(codes)
    return jnp.asarray(codes)


def cross_check(config, op: str, n: int = 1024, *,
                op_mix=(0.80, 0.15, 0.05)) -> Dict:
    """Model-vs-HLO bytes for one lowered cuckoo program.

    Lowers the *core* jit path (the XLA program every backend dispatches
    outside the Pallas regime), parses its materialized HBM bytes, and
    returns ``{"model_bytes", "hlo_bytes", "ratio", "flops"}`` with
    ``ratio = hlo_bytes / model_bytes``. The model is a lower bound, so a
    correct pairing keeps ``ratio ≥ 1``; the upper edge is pinned by
    tests/test_roofline_model.py per op.
    """
    state = config.init()
    keys = jnp.zeros((n, 2), jnp.uint32)
    if op == "query":
        fn = functools.partial(CF.query, config)
        cost = lowered_cost(fn, state, keys)
    elif op == "insert":
        fn = functools.partial(CF.insert, config)
        cost = lowered_cost(fn, state, keys)
    elif op == "bulk_insert":
        fn = functools.partial(CF.insert_bulk, config)
        cost = lowered_cost(fn, state, keys)
    elif op == "orient_bulk_insert":
        # Lower the graph-orientation bulk engine explicitly (the auto
        # route's bulk path, forced so the check is regime-stable).
        ocfg = dataclasses.replace(config, insert_engine="orientation")
        fn = functools.partial(CF.insert_bulk, ocfg)
        cost = lowered_cost(fn, state, keys)
    elif op == "delete":
        fn = functools.partial(CF.delete, config)
        cost = lowered_cost(fn, state, keys)
    elif op == "apply_ops":
        fn = functools.partial(CF.apply_ops, config)
        cost = lowered_cost(fn, state, keys, _mixed_ops_array(n, op_mix))
    else:
        raise ValueError(f"unknown op {op!r} (want one of {RM.OPS})")
    kw = {"op_mix": op_mix} if op == "apply_ops" else {}
    model = RM.min_batch_bytes(config, op, n, **kw)
    return {
        "model_bytes": float(model),
        "hlo_bytes": float(cost["bytes"]),
        "ratio": float(cost["bytes"]) / float(model),
        "flops": float(cost["flops"]),
    }
