import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Perf probe: per-op breakdown of one dry-run cell (§Perf methodology).

Prints bytes/flops by op kind and the top contributors (shape x while-loop
multiplier), so each hillclimb iteration can name the tensor it is attacking.

    PYTHONPATH=src python -m repro.launch.perf_probe --arch qwen1_5_4b \
        --shape train_4k [--multi-pod] [--microbatches 8]
"""

import argparse  # noqa: E402
from collections import defaultdict  # noqa: E402

from . import hlo_cost as HC  # noqa: E402
from .dryrun import analyse, lower_cell  # noqa: E402


def breakdown(txt: str, n_devices: int, top: int = 20):
    comps, symbols = HC.parse_module(txt)
    mult = HC.computation_multipliers(comps)
    by_op_bytes = defaultdict(float)
    by_op_flops = defaultdict(float)
    items = []
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname.startswith("fused_") or ".fused" in cname
        for ins in instrs:
            op = ins.op
            fl = 0.0
            if op == "dot":
                fl = m * HC._dot_flops(ins, symbols)
            elif op in HC._ELEMENTWISE:
                fl = m * sum(HC._nelems(s) for s in ins.shapes)
            by_op_flops[op] += fl
            if in_fusion or op not in HC._MATERIALIZING:
                continue
            rb = sum(HC._nbytes(s) for s in ins.shapes)
            ob = sum(HC._nbytes(symbols[o][0]) for o in ins.operands
                     if o in symbols and symbols[o])
            b = m * (rb + ob)
            by_op_bytes[op] += b
            items.append((b, fl, op, ins.shapes[:1], int(m), cname[:40]))
    print("\n== bytes by op ==")
    for op, b in sorted(by_op_bytes.items(), key=lambda kv: -kv[1]):
        print(f"  {op:25s} {b / 1e12:10.3f} TB")
    print("== flops by op ==")
    for op, f in sorted(by_op_flops.items(), key=lambda kv: -kv[1])[:8]:
        print(f"  {op:25s} {f / 1e12:10.3f} TFLOP")
    print(f"== top {top} byte contributors ==")
    items.sort(key=lambda t: -t[0])
    for b, fl, op, shapes, m, cname in items[:top]:
        print(f"  {b / 1e12:8.3f} TB x{m:<5d} {op:22s} {shapes} {cname}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--no-hints", action="store_true",
                    help="disable shard_ctx constraints (baseline repro)")
    ap.add_argument("--param-mode", default=None,
                    choices=["train", "serve"])
    ap.add_argument("--override", action="append", default=[],
                    help="ModelConfig field override, e.g. mla_absorb=False")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = {"True": True, "False": False}.get(v, v)

    compiled, meta = lower_cell(args.arch, args.shape, args.multi_pod,
                                microbatches=args.microbatches,
                                overrides=overrides or None,
                                no_hints=args.no_hints,
                                param_mode=args.param_mode)
    result = analyse(compiled, meta)
    r = result["roofline"]
    print(f"terms: compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
          f"collective={r['collective_s']:.3e}s dominant={result['dominant']}")
    print(f"temp={result['memory']['temp_bytes'] / 2**30:.1f}GiB "
          f"useful={result['useful_flop_ratio']:.3f}")
    print("collectives:", {k: f"{v['wire_bytes'] / 1e9:.1f}GB(x{v['count']:.0f})"
                           for k, v in result["collectives"].items()})
    breakdown(compiled.as_text(), meta["n_devices"], args.top)


if __name__ == "__main__":
    main()
