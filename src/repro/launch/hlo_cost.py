"""Text-based HLO cost model with while-loop trip-count accounting.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**, but
our layer stacks are ``lax.scan`` loops — a 61-layer model would be
under-counted ~61x. This module parses ``compiled.as_text()`` into a call
graph, extracts scan trip counts from the loop conditions, and accumulates:

  * FLOPs      — dots (2*M*N*K from operand shapes + contracting dims),
                 elementwise ops, reduces;
  * HBM bytes  — operand + result bytes of *materializing* instructions
                 (fusions, dots, copies, collectives); intra-fusion ops are
                 free (they live in registers/VMEM);
  * collective wire bytes — ring-algorithm factors x replica-group size.

every quantity scaled by the product of enclosing while trip counts. Values
are per-device (the module is the post-SPMD per-device program).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"([a-z]\d+|pred)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*{")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALLED_RE = {
    "body": re.compile(r"body=%?([\w\.\-]+)"),
    "condition": re.compile(r"condition=%?([\w\.\-]+)"),
    "calls": re.compile(r"calls=%?([\w\.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w\.\-]+)"),
}
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "exponential", "tanh", "rsqrt",
    "sqrt", "log", "maximum", "minimum", "power", "logistic", "negate",
    "compare", "select", "and", "or", "xor", "abs", "floor", "cosine",
    "sine", "expm1", "log1p", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic",
}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}
# Ops whose operands/results genuinely transit HBM on TPU. Pure layout /
# elementwise ops (transpose, reshape, broadcast, convert, copy, slice, pad,
# concatenate, iota) fuse into their consumers on TPU and are excluded —
# counting them (as the CPU backend materializes them) inflated the memory
# term ~10x (validated against analytic activation-traffic estimates).
_MATERIALIZING = {"fusion", "dot", "reduce", "dynamic-update-slice",
                  "gather", "scatter", "select-and-scatter", "sort", "rng",
                  "convolution", "custom-call"} | _COLLECTIVES

# opcode = first `word(` token after the type string
_OP_RE = re.compile(r"\s([a-z][\w\-]*)\(")


class Instr:
    __slots__ = ("name", "op", "shapes", "operands", "line")

    def __init__(self, name, op, shapes, operands, line):
        self.name = name
        self.op = op
        self.shapes = shapes        # list of (dtype, [dims])
        self.operands = operands    # operand %names (order preserved)
        self.line = line


def _parse_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nelems(shape: Tuple[str, List[int]]) -> int:
    n = 1
    for d in shape[1]:
        n *= d
    return n


def _nbytes(shape: Tuple[str, List[int]]) -> int:
    return _nelems(shape) * _DTYPE_BYTES.get(shape[0], 4)


def parse_module(txt: str):
    """-> (computations: {name: [Instr]}, symbols: {name: shapes})."""
    comps: Dict[str, List[Instr]] = {}
    symbols: Dict[str, List[Tuple[str, List[int]]]] = {}
    cur: Optional[str] = None
    for line in txt.splitlines():
        h = _HEADER_RE.match(line.strip()) if "{" in line and "=" not in \
            line.split("{")[0].split("(")[0] else None
        if h and ("->" in line):
            cur = h.group(1)
            comps[cur] = []
            continue
        m = _INSTR_RE.match(line)
        if not m or cur is None:
            continue
        name, rhs = m.group(1), m.group(2)
        opm = _OP_RE.search(" " + rhs)
        op = opm.group(1) if opm else "unknown"
        type_str = rhs[:opm.start()] if opm else rhs
        shapes = _parse_shapes(type_str)
        # operand names: %refs before any attr keyword that names computations
        args_part = rhs[opm.end():] if opm else ""
        operands = re.findall(r"%([\w\.\-]+)", args_part.split("),")[0])
        ins = Instr(name, op, shapes, operands, line)
        comps[cur].append(ins)
        symbols[name] = shapes
        # parameters declare shapes too
    return comps, symbols


def _trip_count(cond_comp: List[Instr]) -> int:
    consts = []
    for ins in cond_comp:
        consts += [int(c) for c in _CONST_RE.findall(ins.line)]
    return max(consts) if consts else 1


def computation_multipliers(comps) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name or entry is None:
            pass
    # entry = computation not called by anyone
    called = set()
    for name, instrs in comps.items():
        for ins in instrs:
            for key, rx in _CALLED_RE.items():
                m = rx.search(ins.line)
                if m:
                    called.add(m.group(1))
    roots = [n for n in comps if n not in called]
    for r in roots:
        mult[r] = 1.0
    # propagate in dependency order (HLO call graph is a DAG; iterate)
    for _ in range(len(comps)):
        changed = False
        for name, instrs in comps.items():
            m0 = mult.get(name, 0.0)
            if m0 == 0.0:
                continue
            for ins in instrs:
                if ins.op == "while":
                    b = _CALLED_RE["body"].search(ins.line)
                    c = _CALLED_RE["condition"].search(ins.line)
                    if b and c:
                        trip = _trip_count(comps.get(c.group(1), []))
                        want = m0 * trip
                        if mult[b.group(1)] < want:
                            mult[b.group(1)] = want
                            changed = True
                        if mult[c.group(1)] < want:
                            mult[c.group(1)] = want
                            changed = True
                else:
                    for key in ("calls", "to_apply"):
                        m = _CALLED_RE[key].search(ins.line)
                        if m and mult[m.group(1)] < m0:
                            mult[m.group(1)] = m0
                            changed = True
        if not changed:
            break
    return mult


def _dot_flops(ins: Instr, symbols) -> float:
    out_elems = sum(_nelems(s) for s in ins.shapes)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    k = 1
    if m and ins.operands:
        lhs_shapes = symbols.get(ins.operands[0])
        if lhs_shapes:
            dims = lhs_shapes[0][1]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _inplace_update_comps(comps) -> set:
    """Fusion computations that update a slice of a loop-carried buffer
    (KV-cache writes): a dynamic-update-slice whose dims match the fusion
    root (possibly through a dtype convert). Counted as slice-sized traffic,
    not whole-buffer — which is how TPU executes donated cache updates."""
    out = set()
    for cname, instrs in comps.items():
        root_dims = None
        for ins in instrs:
            if "ROOT" in ins.line and ins.shapes:
                root_dims = ins.shapes[0][1]
        if root_dims is None:
            continue
        for ins in instrs:
            if ins.op == "dynamic-update-slice" and ins.shapes and \
                    ins.shapes[0][1] == root_dims:
                out.add(cname)
                break
    return out


def analyse_text(txt: str, n_devices: int) -> Dict:
    comps, symbols = parse_module(txt)
    mult = computation_multipliers(comps)
    inplace = _inplace_update_comps(comps)

    flops = 0.0
    bytes_hbm = 0.0
    colls: Dict[str, Dict] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        # is this computation a fusion body? (only fusion *instructions*
        # move HBM bytes; ops inside fusion bodies still count flops)
        in_fusion = cname.startswith("fused_") or ".fused" in cname
        for ins in instrs:
            op = ins.op
            if op == "dot":
                flops += m * _dot_flops(ins, symbols)
            elif op in ("reduce", "reduce-window"):
                src = symbols.get(ins.operands[0]) if ins.operands else None
                flops += m * (_nelems(src[0]) if src else
                              sum(_nelems(s) for s in ins.shapes))
            elif op in _ELEMENTWISE:
                flops += m * sum(_nelems(s) for s in ins.shapes)
            elif op == "convolution":
                # rough: out elems x kernel spatial x in-channels x 2
                flops += m * 2 * sum(_nelems(s) for s in ins.shapes)

            if in_fusion:
                continue
            if op in _MATERIALIZING:
                rb = sum(_nbytes(s) for s in ins.shapes)
                ob_list = [_nbytes(symbols[o][0]) for o in ins.operands
                           if o in symbols and symbols[o]]
                called = _CALLED_RE["calls"].search(ins.line)
                if (op == "dynamic-update-slice"
                        or (op == "fusion" and called
                            and called.group(1) in inplace)):
                    # in-place update: count only sub-result-size operands
                    # (the update slice + indices), twice (read + write)
                    small = sum(b for b in ob_list if b < rb)
                    bytes_hbm += m * 2 * small
                elif op == "fusion":
                    # fusions that dynamic-slice/gather from a large buffer
                    # only touch the addressed rows: cap each operand at 8x
                    # the result (keeps reduction fusions honest while not
                    # charging a full stacked-layer cache per slice).
                    ob = sum(min(b, 8 * max(rb, 1)) for b in ob_list)
                    bytes_hbm += m * (rb + ob)
                else:
                    bytes_hbm += m * (rb + sum(ob_list))
            base = op[:-6] if op.endswith("-start") else op
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                b = sum(_nbytes(s) for s in ins.shapes)
                gm = _GROUPS_RE.search(ins.line)
                g = int(gm.group(2)) if gm else n_devices
                frac = (g - 1) / max(g, 1)
                wire = {"all-reduce": 2 * b * frac,
                        "all-gather": b * frac,
                        "reduce-scatter": b * g * frac,
                        "all-to-all": b * frac,
                        "collective-permute": b}[base]
                s = colls[base]
                s["count"] += m
                s["result_bytes"] += m * b
                s["wire_bytes"] += m * wire

    return {"flops": flops, "bytes": bytes_hbm,
            "collectives": {k: dict(v) for k, v in colls.items()},
            "n_computations": len(comps)}
