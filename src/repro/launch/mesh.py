"""Production meshes.

Defined as functions (not module constants) so importing this module never
touches jax device state — required because the dry-run pins the device
count via XLA_FLAGS before any jax initialisation.
"""

from __future__ import annotations

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **compat.auto_axis_types_kw(len(axes)))


def make_test_mesh(devices: int = 8):
    """Small CPU mesh for integration tests (data x model = devices)."""
    model = 2 if devices % 2 == 0 else 1
    return jax.make_mesh((devices // model, model), ("data", "model"),
                         **compat.auto_axis_types_kw(2))


def dp_axes(mesh) -> tuple:
    """Data-parallel axis names for batch sharding."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def fsdp_axis(mesh) -> str:
    """Parameter/optimizer FSDP axis (within-pod)."""
    return "data"


def tp_axis(mesh) -> str:
    return "model"
