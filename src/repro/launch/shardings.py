"""Partition rules: pytree -> PartitionSpec trees for params, optimizer
state, batches and serving caches.

Strategy (DESIGN.md §5): ``model`` = tensor/expert parallel, ``data`` =
FSDP (parameters, grads and optimizer state sharded), ``pod`` = data
parallel replicas. Every rule degrades per-dimension when a dim is not
divisible by the axis size (e.g. hubert's 504-way head stays replicated on
the vocab dim), so one rule set covers all ten architectures.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..train.optimizer import QTensor

# parameter names that are column-parallel (output dim -> model axis)
_COL = {"wq", "wk", "wv", "up", "gate", "wuq", "wuk", "wuv", "wkr", "wdq",
        "wdkv", "in_proj", "x_proj", "gate_proj", "wa", "wx", "head"}
# row-parallel (input dim -> model axis)
_ROW = {"wo", "down", "out_proj"}


def _names(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(f"#{k.idx}")
    return out


def _div(dim: int, mesh, axis) -> bool:
    size = int(np.prod([mesh.shape[a] for a in
                        (axis if isinstance(axis, tuple) else (axis,))]))
    return dim % size == 0


def _guard(spec_dims, shape, mesh) -> P:
    """Replace any axis assignment whose dim is not divisible with None."""
    out = []
    for dim, ax in zip(shape, spec_dims):
        out.append(ax if ax is not None and _div(dim, mesh, ax) else None)
    return P(*out)


def param_spec(mesh, path, leaf, mode: str = "train") -> P:
    """Partition rule for one parameter leaf (handles scan-stacked dims).

    mode="train": FSDP over 'data' + TP over 'model' (ZeRO-3 style).
    mode="serve": TP/EP only — decode must not all-gather weights every
    token (§Perf deepseek decode iteration 3); weights replicate over
    'data' and shard over 'model'.
    """
    names = _names(path)
    shape = leaf.shape
    fsdp = "data" if mode == "train" else None
    tp = "model"
    # scan-stacked params have 1 leading rep dim beyond the logical rank
    logical = shape
    lead = 0
    # embed / router / experts / norms identified by name
    base = names[-1] if names else ""
    parents = set(names)

    if base == "table":  # embedding [V, d]
        return _guard((tp, fsdp), shape, mesh)
    if base == "router":
        lead = len(shape) - 2
        return _guard((None,) * lead + (fsdp, None), shape, mesh)
    if base in ("wgate", "wup", "wdown"):  # experts [.., E, d, ff]/[.., E, ff, d]
        lead = len(shape) - 3
        if mode == "serve":
            # full EP: experts over model x data (1 expert/device at 256/256)
            # — weights stay resident, tokens move (all-to-all), no per-step
            # weight gathers. Few-expert configs (mixtral E=8) shard the FFN
            # dim over model x data instead (else 141B replicates).
            if _div(shape[lead], mesh, (tp, "data")):
                spec = (None,) * lead + ((tp, "data"), None, None)
            elif _div(shape[lead], mesh, tp):
                spec = (None,) * lead + (tp, None, None)
            else:
                ff_dim = 1 if base == "wdown" else 2
                inner = [None, None, None]
                inner[0] = None
                inner[ff_dim] = (tp, "data")
                spec = (None,) * lead + tuple(inner)
        elif base == "wdown":
            spec = (None,) * lead + ((tp, None, fsdp)
                                     if _div(shape[lead], mesh, tp)
                                     else (None, tp, fsdp))
        else:
            spec = (None,) * lead + ((tp, fsdp, None)
                                     if _div(shape[lead], mesh, tp)
                                     else (None, fsdp, tp))
        return _guard(spec, shape, mesh)
    if base == "w" and len(names) >= 2:
        owner = names[-2]
        lead = len(shape) - 2
        if owner in _COL:
            return _guard((None,) * lead + (fsdp, tp), shape, mesh)
        if owner in _ROW:
            return _guard((None,) * lead + (tp, fsdp), shape, mesh)
        return _guard((None,) * lead + (fsdp, None), shape, mesh)
    if base == "b" and len(names) >= 2:
        owner = names[-2]
        lead = len(shape) - 1
        if owner in _COL:
            return _guard((None,) * lead + (tp,), shape, mesh)
        return P(*(None,) * len(shape))
    if base in ("conv_w",):
        lead = len(shape) - 2
        return _guard((None,) * lead + (None, fsdp), shape, mesh)
    if base in ("lambda", "conv_b"):
        lead = len(shape) - 1
        return _guard((None,) * lead + (fsdp,), shape, mesh)
    # norms, scalars, small vectors: replicated
    return P(*(None,) * len(shape))


def make_param_shardings(mesh, params_shape, mode: str = "train"):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(mesh, path, leaf, mode)),
        params_shape)


def make_opt_shardings(mesh, opt_shape, quantized: bool = False):
    """AdamWState shardings: step replicated; m/v follow params (fp32) or
    use the blocked QTensor layout (int8 q [nblocks, 256] + fp32 scale
    [nblocks], nblocks always divisible by 512)."""
    import jax.numpy as jnp

    fsdp = ("pod", "data") if "pod" in mesh.axis_names else "data"

    def rule(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if quantized:
            # in quantized mode every non-scalar m/v leaf is a QTensor part
            if leaf.dtype == jnp.int8:
                return NamedSharding(mesh, P(fsdp, None))
            if leaf.ndim == 1:
                return NamedSharding(mesh, P(fsdp))
        return NamedSharding(mesh, param_spec(mesh, path, leaf))

    return jax.tree_util.tree_map_with_path(
        rule, opt_shape,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def batch_spec(mesh) -> P:
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    return P(dp)


def make_batch_shardings(mesh, batch_shape):
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"

    def rule(leaf):
        if leaf.shape and _div(leaf.shape[0], mesh, dp):
            return NamedSharding(mesh, P(dp, *(None,) * (leaf.ndim - 1)))
        return NamedSharding(mesh, P(*(None,) * leaf.ndim))

    return jax.tree_util.tree_map(rule, batch_shape)


def cache_spec(mesh, leaf, seq_len: int, batch: int) -> P:
    """Serving-cache rule: shard the long sequence dim of KV/latent caches
    over 'model' (and over everything for batch-1 long-context); shard batch
    over dp when divisible."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    shape = leaf.shape
    batch_ok = _div(shape[0], mesh, dp) if shape else False
    b_ax = dp if batch_ok else None
    # caches carry one leading stacked-layer dim handled upstream; here the
    # first dim is batch.
    if len(shape) >= 2 and shape[1] >= seq_len // 2 and seq_len > 1:
        seq_ax = ("data", "model") if not batch_ok and \
            _div(shape[1], mesh, ("data", "model")) else "model"
        if not _div(shape[1], mesh, seq_ax):
            seq_ax = None
        return P(b_ax, seq_ax, *(None,) * (len(shape) - 2))
    # states / conv windows: shard the widest trailing dim over model.
    # (Replicating small SWA ring caches instead was tried and REFUTED —
    # §Perf recurrentgemma iter 2: resharding the attention output costs
    # more than the 16 MB per-step window gather.)
    if len(shape) >= 2 and _div(shape[-1], mesh, "model"):
        return P(b_ax, *(None,) * (len(shape) - 2), "model")
    return P(b_ax, *(None,) * (len(shape) - 1))


def make_cache_shardings(mesh, caches_shape, seq_len: int, batch: int):
    def rule(leaf):
        shape = leaf.shape
        # strip the scan-stacked leading dim (reps)
        inner = jax.ShapeDtypeStruct(shape[1:], leaf.dtype)
        spec = cache_spec(mesh, inner, seq_len, batch)
        return NamedSharding(mesh, P(None, *spec))

    return jax.tree_util.tree_map(rule, caches_shape)
