"""Input ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

Shapes are the assigned public-literature set:
    train_4k      seq 4,096   global_batch 256   (training step)
    prefill_32k   seq 32,768  global_batch 32    (inference prefill)
    decode_32k    seq 32,768  global_batch 128   (one decode step, full cache)
    long_500k     seq 524,288 global_batch 1     (long-context decode)

SKIPS (DESIGN.md §4): encoder-only hubert has no decode; pure full-attention
archs skip long_500k (unbounded full KV at 500k).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import build_model

SHAPES: Dict[str, Dict] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

SKIPS: Dict[Tuple[str, str], str] = {
    ("hubert_xlarge", "decode_32k"): "encoder-only: no autoregressive step",
    ("hubert_xlarge", "long_500k"): "encoder-only: no autoregressive step",
    ("deepseek_v3_671b", "long_500k"):
        "pure full-attention decode at 500k (unbounded KV)",
    ("qwen1_5_4b", "long_500k"):
        "pure full-attention decode at 500k (unbounded KV)",
    ("chameleon_34b", "long_500k"):
        "pure full-attention decode at 500k (unbounded KV)",
}


def live_cells():
    from ..configs.base import ARCHS

    for arch in ARCHS:
        for shape in SHAPES:
            if (arch, shape) not in SKIPS:
                yield arch, shape


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins + step kind for one cell."""
    s = SHAPES[shape_name]
    kind, seq, batch = s["kind"], s["seq"], s["batch"]
    model = build_model(cfg)
    out: Dict[str, Any] = {"kind": kind, "seq": seq, "batch": batch}

    if kind == "train":
        if cfg.frontend == "frames":
            out["batch_spec"] = {
                "frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            }
        else:
            out["batch_spec"] = {
                "tokens": jax.ShapeDtypeStruct((batch, seq + 1), jnp.int32)}
    elif kind == "prefill":
        if cfg.frontend == "frames":
            out["batch_spec"] = {
                "frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.bfloat16)}
        else:
            out["batch_spec"] = {
                "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    else:  # decode
        out["token_spec"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
        out["pos_spec"] = jax.ShapeDtypeStruct((), jnp.int32)
        out["cache_spec"] = jax.eval_shape(
            lambda: model.init_caches(batch, seq))
    return out
