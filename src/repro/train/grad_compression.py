"""Gradient compression for cross-pod reduction (DESIGN.md §5).

At 2+ pods the gradient all-reduce crosses the (slow) DCI once per step. The
standard mitigation is compressing the cross-pod leg: blockwise int8 with
**error feedback** (the quantization residual is carried into the next step,
keeping the accumulated update unbiased — Seide et al. / 1-bit SGD lineage).

Usage (train loop):
    residual = zero_residual(grads)
    q, residual = compress(grads, residual)     # int8 payload (+ scales)
    q = jax.lax.pmean(q, "pod")                 # or psum on the wire
    grads = decompress(q)

The compressed payload is 4x smaller than fp32 (2x vs bf16); tests assert
the error-feedback property (mean update error -> 0 over steps).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

_BLOCK = 256


class CompressedGrads(NamedTuple):
    q: Any        # pytree of int8 [nblocks, _BLOCK]
    scale: Any    # pytree of f32 [nblocks]


def _quant_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[:, None], 1e-12)).astype(jnp.int8)
    return q, scale


def _dequant_leaf(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    n = 1
    for d in shape:
        n *= d
    return (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n] \
        .reshape(shape)


def zero_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress(grads, residual) -> Tuple[CompressedGrads, Any]:
    """-> (compressed, new_residual). Error feedback: residual carries the
    quantization error into the next step."""
    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(residual)
    qs, scales, res = [], [], []
    for g, r in zip(g_leaves, r_leaves):
        x = g.astype(jnp.float32) + r
        q, scale = _quant_leaf(x)
        qs.append(q)
        scales.append(scale)
        res.append(x - _dequant_leaf(q, scale, g.shape))
    return (CompressedGrads(treedef.unflatten(qs), treedef.unflatten(scales)),
            treedef.unflatten(res))


def decompress(c: CompressedGrads, grads_template) -> Any:
    """Dequantize to f32 (optimizer input precision) — casting back down to
    bf16 would break the error-feedback telescoping exactness."""
    return jax.tree.map(
        lambda q, s, g: _dequant_leaf(q, s, g.shape),
        c.q, c.scale, grads_template)
