"""Training substrate: optimizer, step factory, checkpointing, resilience."""

from . import checkpoint  # noqa: F401
from .fault_tolerance import StragglerMonitor, TrainingRunner, remesh  # noqa: F401
from .grad_compression import compress, decompress, zero_residual  # noqa: F401
from .optimizer import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    schedule,
)
from .train_loop import init_train_state, make_train_step  # noqa: F401
