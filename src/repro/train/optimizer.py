"""AdamW with optional int8-quantized moments + LR schedules.

The int8 path (blockwise-scaled, à la 8-bit Adam) is what lets the 671B
config fit 256 x 16 GB chips: m and v cost 1 byte/param instead of 4
(EXPERIMENTS.md §Dry-run memory table). Quantization is blockwise symmetric
(m) / blockwise max (v, non-negative) over flattened 256-element blocks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_state: bool = False   # int8 m/v (671B config)
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


# --- blockwise int8 quantization -------------------------------------------

class QTensor(NamedTuple):
    q: jnp.ndarray        # int8[nblocks, _BLOCK]  (nblocks % 512 == 0)
    scale: jnp.ndarray    # fp32[nblocks]
    shape: Tuple[int, ...]  # static, carried on the type


def _quantize(x: jnp.ndarray) -> QTensor:
    shape = x.shape
    flat = x.reshape(-1)
    # pad so nblocks is a multiple of 512 — shardable over any production
    # mesh axis combination (pod x data x model divides 512).
    pad = (-flat.shape[0]) % (_BLOCK * 512)
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[:, None], 1e-12)
                  ).astype(jnp.int8)
    return QTensor(q, scale, shape)


def _dequantize(t: QTensor) -> jnp.ndarray:
    blocks = t.q.astype(jnp.float32) * t.scale[:, None]
    n = int(np.prod(t.shape)) if t.shape else 1
    return blocks.reshape(-1)[:n].reshape(t.shape)


jax.tree_util.register_pytree_node(
    QTensor,
    lambda t: ((t.q, t.scale), t.shape),
    lambda shape, xs: QTensor(xs[0], xs[1], shape))


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any   # pytree of fp32 arrays or QTensors
    v: Any


def adamw_init(cfg: AdamWConfig, params) -> AdamWState:
    """v is stored in sqrt-domain when quantized: v = q^2. Squaring halves
    the dynamic range the int8 grid must span — linear-domain int8 flushes
    small v to 0 and the eps-divided update explodes (8-bit Adam lesson,
    validated by test_quantized_optimizer_tracks_fp32)."""

    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize(z) if cfg.quantize_state else z

    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zero_like, params),
                      jax.tree.map(zero_like, params))


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        tree, jnp.zeros(()))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """-> (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = _dequantize(m) if isinstance(m, QTensor) else m
        vf = jnp.square(_dequantize(v)) if isinstance(v, QTensor) else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        delta = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        if p.ndim > 1:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if isinstance(m, QTensor):
            return new_p, _quantize(mf), _quantize(jnp.sqrt(vf))
        return new_p, mf, vf

    is_q = lambda x: isinstance(x, QTensor)
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = jax.tree.flatten(state.m, is_leaf=is_q)[0]
    v_leaves = jax.tree.flatten(state.v, is_leaf=is_q)[0]
    results = [upd(p, g, m, v) for p, g, m, v
               in zip(p_leaves, g_leaves, m_leaves, v_leaves)]
    new_params = treedef.unflatten([r[0] for r in results])
    new_m = treedef.unflatten([r[1] for r in results])
    new_v = treedef.unflatten([r[2] for r in results])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
