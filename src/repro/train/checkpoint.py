"""Checkpointing: atomic, resumable, keep-last-k.

Format: one directory per step, ``arrays.npz`` (flattened pytree leaves keyed
by path) + ``meta.json`` (step, leaf treedef paths, aux metadata such as the
data-pipeline cursor and per-host step timings for straggler forensics).
Writes go to a temp dir + atomic rename, so a crash mid-write never corrupts
the latest checkpoint — the restart path (train.py --resume) always finds a
complete one.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # npz cannot serialize ml_dtypes (bf16 etc.) — widen to fp32;
            # restore() casts back to the template dtype (lossless for bf16).
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save(ckpt_dir: str, step: int, tree: Any,
         aux: Optional[Dict[str, Any]] = None, keep: int = 3) -> str:
    """Atomically write checkpoint for ``step``; prune to ``keep`` latest."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "aux": aux or {},
                   "n_arrays": len(arrays)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any,
            step: Optional[int] = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``template`` (shapes must match)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        got = arrays[key]
        assert got.shape == leaf.shape, (key, got.shape, leaf.shape)
        leaves.append(got.astype(leaf.dtype))
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            meta["step"], meta["aux"])
