"""Fault tolerance + elasticity utilities (DESIGN.md §5).

At 1000+ nodes the failure model is: a host dies mid-step, the job restarts
on (possibly fewer) hosts, and training must resume bit-identically from the
last complete checkpoint. Everything here is built around that:

* ``TrainingRunner`` — checkpointed step loop with resume, per-step wall-time
  tracking (straggler forensics persisted into checkpoint aux), and a failure
  injection hook for tests.
* ``remesh`` — re-places a train state onto a new (smaller/larger) mesh; with
  microbatch accumulation the global batch is preserved under a shrunken
  ``data`` axis (elastic scaling).
* ``StragglerMonitor`` — flags steps slower than k·median; on a real cluster
  this feeds host-replacement, here it records the evidence.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from . import checkpoint as ckpt


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.flagged: List[int] = []

    def record(self, step: int, dt: float):
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) >= 10:
            med = float(np.median(hist))
            if dt > self.threshold * med:
                self.flagged.append(step)

    def summary(self) -> Dict[str, Any]:
        if not self.times:
            return {}
        return {
            "median_s": float(np.median(self.times)),
            "p99_s": float(np.percentile(self.times, 99)),
            "straggler_steps": self.flagged[-20:],
        }


def remesh(state, old_mesh: Optional[Mesh], new_mesh: Mesh, spec_fn):
    """Re-place a pytree onto a new mesh (elastic shrink/grow).

    ``spec_fn(path, leaf) -> PartitionSpec`` decides placement per leaf. On a
    real cluster this is a device_put across the new topology; semantics are
    identical here.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    out = []
    for path, leaf in flat:
        spec = spec_fn(path, leaf)
        out.append(jax.device_put(leaf, NamedSharding(new_mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class TrainingRunner:
    """Checkpointed training loop with resume + failure injection."""

    train_step: Callable  # (params, opt_state, batch) -> (p, o, metrics)
    data_fn: Callable     # (step) -> batch   (stateless-resumable pipeline)
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    fail_at_step: Optional[int] = None  # test hook: raise mid-run

    def run(self, params, opt_state, num_steps: int,
            start_step: int = 0, log_every: int = 10,
            log_fn: Callable[[str], None] = print):
        monitor = StragglerMonitor()
        step = start_step
        while step < num_steps:
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = self.data_fn(step)
            params, opt_state, metrics = self.train_step(
                params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            monitor.record(step, dt)
            step += 1
            if step % log_every == 0:
                log_fn(f"step {step}: loss={float(metrics['loss']):.4f} "
                       f"gnorm={float(metrics['grad_norm']):.3f} "
                       f"lr={float(metrics['lr']):.2e} ({dt * 1e3:.0f} ms)")
            if step % self.ckpt_every == 0 or step == num_steps:
                ckpt.save(self.ckpt_dir, step,
                          {"params": params, "opt": opt_state},
                          aux={"straggler": monitor.summary(),
                               "data_cursor": step})
        return params, opt_state, monitor

    def resume(self, params_template, opt_template):
        """Restore the latest checkpoint into matching templates."""
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return params_template, opt_template, 0
        state, step, aux = ckpt.restore(
            self.ckpt_dir, {"params": params_template, "opt": opt_template})
        return state["params"], state["opt"], step
