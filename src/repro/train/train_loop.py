"""Training step factory: loss -> grads -> AdamW, with microbatch
accumulation and pjit shardings supplied by the launcher.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import Model
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, remat: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With microbatches > 1 the global batch is split along axis 0 and
    gradients are accumulated with a ``lax.scan`` (memory/throughput knob for
    the biggest configs).
    """

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zero), micro)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        params, opt_state, metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def init_train_state(model: Model, opt_cfg: AdamWConfig, key):
    params = model.init(key)
    return params, adamw_init(opt_cfg, params)
