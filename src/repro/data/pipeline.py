"""Synthetic token pipeline — deterministic, cursor-resumable.

Production discipline: the pipeline is a pure function of (seed, step), so a
restart at step k regenerates exactly the batches k, k+1, ... — the
checkpoint only needs to store the cursor (fault-tolerance requirement, no
data-state files). Token statistics are Zipf-ish with injected duplicate
sequences to exercise the dedup filter.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    duplicate_fraction: float = 0.2   # fraction of sequences that are repeats
    zipf_a: float = 1.2


def make_batch(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    """Batch for ``step``: tokens int32[batch, seq_len + 1]."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    z = rng.zipf(cfg.zipf_a, size=(cfg.batch, cfg.seq_len + 1))
    tokens = (z - 1) % cfg.vocab_size
    # inject duplicates: some rows repeat a small pool of canned sequences
    n_dup = int(cfg.batch * cfg.duplicate_fraction)
    if n_dup:
        pool_rng = np.random.default_rng(cfg.seed + 7)
        pool = (pool_rng.zipf(cfg.zipf_a, size=(8, cfg.seq_len + 1)) - 1) \
            % cfg.vocab_size
        rows = rng.choice(cfg.batch, size=n_dup, replace=False)
        tokens[rows] = pool[rng.integers(0, len(pool), n_dup)]
    return {"tokens": jnp.asarray(tokens, jnp.int32)}


def make_frames_batch(cfg: DataConfig, step: int, d_model: int):
    """Audio-stub batch: frame embeddings + codebook labels (hubert)."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 999_983 + step))
    frames = rng.normal(size=(cfg.batch, cfg.seq_len, d_model)) * 0.02
    labels = rng.integers(0, cfg.vocab_size, (cfg.batch, cfg.seq_len))
    return {"frames": jnp.asarray(frames, jnp.float32),
            "labels": jnp.asarray(labels, jnp.int32)}


def data_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield make_batch(cfg, step)
        step += 1
