"""Data substrate: synthetic pipelines, filter-backed dedup, k-mer tooling."""

from .dedup import (  # noqa: F401
    DedupConfig,
    StreamingDeduper,
    dedup_batch,
    forget_keys,
    make_dedup,
    make_deduper,
    sequence_keys,
)
from .pipeline import DataConfig, data_iterator, make_batch, make_frames_batch  # noqa: F401
