"""Data substrate: synthetic pipelines, filter-backed dedup, k-mer tooling."""

from .dedup import DedupConfig, dedup_batch, forget_keys, sequence_keys  # noqa: F401
from .pipeline import DataConfig, data_iterator, make_batch, make_frames_batch  # noqa: F401
