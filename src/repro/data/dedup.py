"""Streaming training-data dedup backed by any registered AMQ backend.

The paper's AMQ as a first-class framework feature: every incoming sequence
is hashed to a 64-bit key; a query+insert against the filter decides whether
the sequence was seen before. Duplicate sequences get their loss mask zeroed
(shape-static — no dynamic batch filtering, per the straggler discipline).

The filter is addressed through the unified AMQ protocol (``repro.amq``), so
dedup runs unchanged on every backend — the default Cuckoo filter, the
mesh-sharded variant, or any baseline. Deletion support still matters:
time-windowed dedup (``forget``) removes expired epochs' keys, which an
append-only Bloom filter cannot do (``forget_keys`` is capability-gated) —
the paper's core argument for dynamic AMQs.

Two surfaces:

* :func:`dedup_batch` — functional, jit-fusable, static filter config (the
  in-pipeline fast path).
* :class:`StreamingDeduper` (via :func:`make_deduper`) — handle-based and
  auto-expanding by default (DESIGN.md §8), for streams whose total volume
  is unknown a priori.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .. import amq
from ..core import CuckooConfig
from ..core.hashing import fmix32


@dataclasses.dataclass(frozen=True)
class DedupConfig:
    """Static dedup config: an AMQ backend name + that backend's config.

    ``filter`` remains the first field so existing
    ``DedupConfig(CuckooConfig...)`` call sites keep working; ``backend``
    selects the adapter from the AMQ registry.
    """

    filter: Any                   # the backend's static config
    ngram: Optional[int] = None   # None = whole-sequence keys
    backend: str = "cuckoo"

    @property
    def adapter(self):
        return amq.get(self.backend)


def sequence_keys(tokens: jnp.ndarray) -> jnp.ndarray:
    """Hash int32[B, S] sequences to uint32[B, 2] keys (order-sensitive)."""
    t = tokens.astype(jnp.uint32)
    pos = jnp.arange(t.shape[-1], dtype=jnp.uint32)
    mixed = fmix32(t + pos * np.uint32(0x9E3779B9))
    lo = fmix32(jnp.sum(mixed, axis=-1, dtype=jnp.uint32))
    hi = fmix32(jnp.sum(mixed * (pos + np.uint32(1)), axis=-1,
                        dtype=jnp.uint32) ^ lo)
    return jnp.stack([lo, hi], axis=-1)


def intra_batch_duplicates(keys: jnp.ndarray) -> jnp.ndarray:
    """Mask non-first occurrences of each 64-bit key within a batch.

    First-occurrence detection runs on the full 64-bit key values
    (backend-independent, so set semantics hold even for counting filters;
    no 32-bit mixing — a mix collision would silently drop a live
    sequence).
    """
    lo, hi = keys[:, 0], keys[:, 1]
    order = jnp.lexsort((lo, hi))
    lo_s, hi_s = lo[order], hi[order]
    dup_sorted = jnp.concatenate([
        jnp.zeros((1,), bool),
        (lo_s[1:] == lo_s[:-1]) & (hi_s[1:] == hi_s[:-1]),
    ])
    return jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)


def dedup_batch(cfg: DedupConfig, state: Any,
                batch: Dict[str, jnp.ndarray]
                ) -> Tuple[Any, Dict[str, jnp.ndarray], Dict]:
    """Mask duplicate sequences; insert fresh ones into the filter.

    Returns (filter_state', batch + {"mask"}, stats). jit-compatible with
    cfg static (the adapter's functional ops trace like any other op).
    """
    ad = cfg.adapter
    tokens = batch["tokens"]
    keys = sequence_keys(tokens)
    _, qres = ad.query(cfg.filter, state, keys)
    seen = qres.hits
    intra_dup = intra_batch_duplicates(keys)

    fresh = ~seen & ~intra_dup
    state, report = ad.insert(cfg.filter, state, keys, valid=fresh)
    mask = fresh  # duplicates (cross- or intra-batch) contribute no loss
    out = dict(batch)
    out["mask"] = mask
    stats = {"duplicates": jnp.sum(~mask),
             "insert_failures": jnp.sum(fresh & ~report.ok & report.routed),
             "unrouted": jnp.sum(fresh & ~report.routed)}
    return state, out, stats


def make_dedup(capacity: int, backend: str = "cuckoo",
               **kw) -> Tuple[DedupConfig, Any]:
    """Convenience: size a dedup filter on any backend via the registry.

    Returns (cfg, fresh_state) ready for :func:`dedup_batch`.
    """
    ad = amq.get(backend)
    fcfg = ad.make_config(capacity, **kw)
    return DedupConfig(fcfg, backend=backend), ad.init(fcfg)


def forget_keys(cfg: DedupConfig, state: Any,
                keys: jnp.ndarray) -> Any:
    """Expire keys from the dedup window (needs deletion support — the
    capability Bloom filters lack, paper §1)."""
    ad = cfg.adapter
    if not ad.capabilities.supports_delete:
        raise NotImplementedError(
            f"{cfg.backend}: append-only backend cannot forget keys "
            "(capabilities.supports_delete is False)")
    state, _ = ad.delete(cfg.filter, state, keys)
    return state


class StreamingDeduper:
    """Service-based dedup for unbounded streams (no a-priori sizing).

    Wraps any ``amq`` handle — by default an auto-expanding cascade
    (DESIGN.md §8) — behind a :class:`repro.amq.FilterService` micro-batch
    (DESIGN.md §9): the membership probe and the fresh-key admission are
    *enqueued* op streams, so only the fresh slice of each batch is ever
    inserted (variable-size at the host level, absorbed by the service's
    fixed-shape padding — no recompilation per duplicate count), and
    several dedupers can coalesce into one shared service. Host-driven
    (the cascade allocates levels between batches), unlike
    :func:`dedup_batch` which stays fully jit-fusable over a static
    filter.
    """

    def __init__(self, handle, *, service_batch: int = 512,
                 service: Optional["amq.FilterService"] = None,
                 service_kw: Optional[dict] = None):
        if service is not None and service_kw:
            raise TypeError("service_kw only applies when the deduper builds "
                            "its own service")
        self.service = (amq.FilterService(handle, batch_size=service_batch,
                                          **(service_kw or {}))
                        if service is None else service)
        self.stats = {"duplicates": 0, "insert_failures": 0}
        self._admissions: list = []   # tickets whose failures aren't counted

    @property
    def handle(self):
        """The live filter handle — tracks ``FilterService.hot_swap``."""
        return self.service.handle

    def _drain_admissions(self) -> int:
        """Fold finished admission tickets into ``insert_failures``.

        Only tickets already dispatched are resolved — draining never
        forces a flush, so admissions stay lazy. Returns the failures
        counted by this drain.
        """
        drained = 0
        live = []
        for t in self._admissions:
            if not t.dispatched:
                live.append(t)
                continue
            drained += int((~t.result()).sum())
        self._admissions = live
        self.stats["insert_failures"] += drained
        return drained

    def dedup(self, batch: Dict[str, jnp.ndarray]
              ) -> Tuple[Dict[str, jnp.ndarray], Dict]:
        """Mask duplicates in ``batch`` and insert fresh sequence keys.

        Returns ``(batch + {"mask"}, per_batch_stats)`` and accumulates
        totals in ``self.stats``. Admissions are *enqueued*: this batch's
        fresh keys ride the service's micro-batches and are only forced
        onto the device by the next membership probe (or :meth:`flush`),
        so ``insert_failures`` — both per batch and in ``self.stats`` —
        trails the admissions by one flush. ``duplicates`` is always
        exact for the current batch.
        """
        keys = np.asarray(sequence_keys(batch["tokens"]))
        seen = self.service.query(keys).result()
        failures = self._drain_admissions()   # prior admissions just flushed
        fresh = ~seen & ~np.asarray(intra_batch_duplicates(jnp.asarray(keys)))
        self._admissions.append(self.service.insert(keys[fresh]))
        out = dict(batch)
        out["mask"] = jnp.asarray(fresh)
        stats = {"duplicates": int((~fresh).sum()),
                 "insert_failures": failures}
        self.stats["duplicates"] += stats["duplicates"]
        return out, stats

    def flush(self) -> None:
        """Force pending admissions onto the filter and settle stats."""
        self.service.flush()
        self._drain_admissions()

    def forget(self, keys: jnp.ndarray) -> None:
        """Expire keys from the window (capability-gated, like forget_keys)."""
        if not self.handle.capabilities.supports_delete:
            raise NotImplementedError(
                f"{self.handle.name}: append-only backend cannot forget keys "
                "(capabilities.supports_delete is False)")
        self.service.delete(np.asarray(keys)).result()
        self._drain_admissions()


def make_deduper(capacity: int, backend: str = "cuckoo", *,
                 auto_expand: bool = True, service_batch: int = 512,
                 service_kw: Optional[dict] = None,
                 device_budget_bytes: Optional[int] = None,
                 **kw) -> StreamingDeduper:
    """Build a :class:`StreamingDeduper` on any registry backend.

    ``capacity`` is the initial window size; with ``auto_expand`` (the
    default, where the backend supports it) the filter grows online, so
    streaming jobs no longer need to guess their dedup volume up front.
    ``device_budget_bytes`` upgrades the handle to a GPU-hot / host-cold
    :class:`~repro.amq.tiering.TieredHandle` (DESIGN.md §12): the dedup
    keyset may grow far past device memory, with old levels frozen into
    host RAM and probed off the padded hot path. ``service_kw`` flows to
    the underlying :class:`repro.amq.FilterService` (deadline, admission
    policy, queue bound — DESIGN.md §11).
    """
    if device_budget_bytes is not None:
        handle = amq.make(backend, capacity=capacity, tiered=True,
                          device_budget_bytes=device_budget_bytes, **kw)
    else:
        handle = amq.make(backend, capacity=capacity,
                          auto_expand="auto" if auto_expand else False, **kw)
    return StreamingDeduper(
        handle, service_batch=service_batch, service_kw=service_kw)


# Backwards-compat convenience mirroring the original module surface.
def default_config(capacity: int, **kw) -> DedupConfig:
    return DedupConfig(CuckooConfig.for_capacity(capacity, **kw))
