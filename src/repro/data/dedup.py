"""Streaming training-data dedup backed by any registered AMQ backend.

The paper's AMQ as a first-class framework feature: every incoming sequence
is hashed to a 64-bit key; a query+insert against the filter decides whether
the sequence was seen before. Duplicate sequences get their loss mask zeroed
(shape-static — no dynamic batch filtering, per the straggler discipline).

The filter is addressed through the unified AMQ protocol (``repro.amq``), so
dedup runs unchanged on every backend — the default Cuckoo filter, the
mesh-sharded variant, or any baseline. Deletion support still matters:
time-windowed dedup (``forget``) removes expired epochs' keys, which an
append-only Bloom filter cannot do (``forget_keys`` is capability-gated) —
the paper's core argument for dynamic AMQs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .. import amq
from ..core import CuckooConfig
from ..core.hashing import fmix32


@dataclasses.dataclass(frozen=True)
class DedupConfig:
    """Static dedup config: an AMQ backend name + that backend's config.

    ``filter`` remains the first field so existing
    ``DedupConfig(CuckooConfig...)`` call sites keep working; ``backend``
    selects the adapter from the AMQ registry.
    """

    filter: Any                   # the backend's static config
    ngram: Optional[int] = None   # None = whole-sequence keys
    backend: str = "cuckoo"

    @property
    def adapter(self):
        return amq.get(self.backend)


def sequence_keys(tokens: jnp.ndarray) -> jnp.ndarray:
    """Hash int32[B, S] sequences to uint32[B, 2] keys (order-sensitive)."""
    t = tokens.astype(jnp.uint32)
    pos = jnp.arange(t.shape[-1], dtype=jnp.uint32)
    mixed = fmix32(t + pos * np.uint32(0x9E3779B9))
    lo = fmix32(jnp.sum(mixed, axis=-1, dtype=jnp.uint32))
    hi = fmix32(jnp.sum(mixed * (pos + np.uint32(1)), axis=-1,
                        dtype=jnp.uint32) ^ lo)
    return jnp.stack([lo, hi], axis=-1)


def dedup_batch(cfg: DedupConfig, state: Any,
                batch: Dict[str, jnp.ndarray]
                ) -> Tuple[Any, Dict[str, jnp.ndarray], Dict]:
    """Mask duplicate sequences; insert fresh ones into the filter.

    Returns (filter_state', batch + {"mask"}, stats). jit-compatible with
    cfg static (the adapter's functional ops trace like any other op).
    """
    ad = cfg.adapter
    tokens = batch["tokens"]
    keys = sequence_keys(tokens)
    _, qres = ad.query(cfg.filter, state, keys)
    seen = qres.hits
    # Intra-batch duplicates: first-occurrence detection on the full 64-bit
    # key values (backend-independent, so set semantics hold even for
    # counting filters; no 32-bit mixing — a mix collision would silently
    # drop a live sequence).
    lo, hi = keys[:, 0], keys[:, 1]
    order = jnp.lexsort((lo, hi))
    lo_s, hi_s = lo[order], hi[order]
    dup_sorted = jnp.concatenate([
        jnp.zeros((1,), bool),
        (lo_s[1:] == lo_s[:-1]) & (hi_s[1:] == hi_s[:-1]),
    ])
    intra_dup = jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)

    fresh = ~seen & ~intra_dup
    state, report = ad.insert(cfg.filter, state, keys, valid=fresh)
    mask = fresh  # duplicates (cross- or intra-batch) contribute no loss
    out = dict(batch)
    out["mask"] = mask
    stats = {"duplicates": jnp.sum(~mask),
             "insert_failures": jnp.sum(fresh & ~report.ok & report.routed),
             "unrouted": jnp.sum(fresh & ~report.routed)}
    return state, out, stats


def make_dedup(capacity: int, backend: str = "cuckoo",
               **kw) -> Tuple[DedupConfig, Any]:
    """Convenience: size a dedup filter on any backend via the registry.

    Returns (cfg, fresh_state) ready for :func:`dedup_batch`.
    """
    ad = amq.get(backend)
    fcfg = ad.make_config(capacity, **kw)
    return DedupConfig(fcfg, backend=backend), ad.init(fcfg)


def forget_keys(cfg: DedupConfig, state: Any,
                keys: jnp.ndarray) -> Any:
    """Expire keys from the dedup window (needs deletion support — the
    capability Bloom filters lack, paper §1)."""
    ad = cfg.adapter
    if not ad.capabilities.supports_delete:
        raise NotImplementedError(
            f"{cfg.backend}: append-only backend cannot forget keys "
            "(capabilities.supports_delete is False)")
    state, _ = ad.delete(cfg.filter, state, keys)
    return state


# Backwards-compat convenience mirroring the original module surface.
def default_config(capacity: int, **kw) -> DedupConfig:
    return DedupConfig(CuckooConfig.for_capacity(capacity, **kw))
