"""Streaming training-data dedup backed by the Cuckoo filter.

The paper's AMQ as a first-class framework feature: every incoming sequence
is hashed to a 64-bit key; a query+insert against the (optionally
mesh-sharded) filter decides whether the sequence was seen before. Duplicate
sequences get their loss mask zeroed (shape-static — no dynamic batch
filtering, per the straggler discipline). Deletion support matters here:
time-windowed dedup (``forget``) removes expired epochs' keys, which a Bloom
filter cannot do — the paper's core argument for dynamic AMQs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CuckooConfig, CuckooState
from ..core import insert as cuckoo_insert
from ..core import query as cuckoo_query
from ..core.hashing import fmix32


@dataclasses.dataclass(frozen=True)
class DedupConfig:
    filter: CuckooConfig
    ngram: Optional[int] = None   # None = whole-sequence keys


def sequence_keys(tokens: jnp.ndarray) -> jnp.ndarray:
    """Hash int32[B, S] sequences to uint32[B, 2] keys (order-sensitive)."""
    t = tokens.astype(jnp.uint32)
    pos = jnp.arange(t.shape[-1], dtype=jnp.uint32)
    mixed = fmix32(t + pos * np.uint32(0x9E3779B9))
    lo = fmix32(jnp.sum(mixed, axis=-1, dtype=jnp.uint32))
    hi = fmix32(jnp.sum(mixed * (pos + np.uint32(1)), axis=-1,
                        dtype=jnp.uint32) ^ lo)
    return jnp.stack([lo, hi], axis=-1)


def dedup_batch(cfg: DedupConfig, state: CuckooState,
                batch: Dict[str, jnp.ndarray]
                ) -> Tuple[CuckooState, Dict[str, jnp.ndarray], Dict]:
    """Mask duplicate sequences; insert fresh ones into the filter.

    Returns (filter_state', batch + {"mask"}, stats). jit-compatible with
    cfg static.
    """
    tokens = batch["tokens"]
    keys = sequence_keys(tokens)
    seen = cuckoo_query(cfg.filter, state, keys)
    # Intra-batch duplicates: the insert pass is sequential per conflict
    # round, but two identical keys in one batch both "succeed" — detect
    # intra-batch dupes by first-occurrence on sorted keys.
    flat = keys[:, 0].astype(jnp.uint64) | (keys[:, 1].astype(jnp.uint64) << 32) \
        if False else keys[:, 0] ^ (keys[:, 1] * np.uint32(0x85EBCA6B))
    order = jnp.argsort(flat, stable=True)
    sf = flat[order]
    dup_sorted = jnp.concatenate([jnp.zeros((1,), bool), sf[1:] == sf[:-1]])
    intra_dup = jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)

    fresh = ~seen & ~intra_dup
    state, ok, _ = cuckoo_insert(cfg.filter, state, keys, valid=fresh)
    mask = fresh  # duplicates (cross- or intra-batch) contribute no loss
    out = dict(batch)
    out["mask"] = mask
    stats = {"duplicates": jnp.sum(~mask), "insert_failures": jnp.sum(fresh & ~ok)}
    return state, out, stats


def forget_keys(cfg: DedupConfig, state: CuckooState,
                keys: jnp.ndarray) -> CuckooState:
    """Expire keys from the dedup window (needs deletion support — the
    capability Bloom filters lack, paper §1)."""
    from ..core import delete as cuckoo_delete

    state, _ = cuckoo_delete(cfg.filter, state, keys)
    return state
