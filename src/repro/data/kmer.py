"""Genomic k-mer tooling (paper §5.5 case study).

Pipeline: FASTA-like base string -> 2-bit codes -> rolling 31-mers (Pallas
kernel) -> optional canonicalization (min of k-mer and reverse complement,
the KMC3 convention) -> filter keys.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ..core import bits64 as b64
from ..kernels.ops import kmer_pack

_CODE = np.full(256, 255, np.uint8)
for i, c in enumerate("ACGT"):
    _CODE[ord(c)] = i
    _CODE[ord(c.lower())] = i


def synthetic_genome(n_bases: int, seed: int = 0) -> np.ndarray:
    """Random ACGT codes with mild repeat structure (uint8[n])."""
    rng = np.random.default_rng(seed)
    bases = rng.integers(0, 4, size=n_bases).astype(np.uint8)
    # paste in repeated segments so the k-mer multiset is realistically skewed
    seg = rng.integers(0, 4, size=512).astype(np.uint8)
    for _ in range(max(1, n_bases // 8192)):
        at = int(rng.integers(0, max(1, n_bases - 512)))
        bases[at:at + 512] = seg[: max(0, min(512, n_bases - at))]
    return bases


def encode_bases(seq: str) -> np.ndarray:
    """ACGT string -> 2-bit codes; raises on non-ACGT (caller splits on N)."""
    codes = _CODE[np.frombuffer(seq.encode(), np.uint8)]
    if (codes == 255).any():
        raise ValueError("non-ACGT base; split reads on N first")
    return codes


def kmer_keys(bases: np.ndarray, k: int = 31, canonical: bool = True
              ) -> jnp.ndarray:
    """uint8/uint32 base codes -> uint32[n-k+1, 2] filter keys."""
    keys = kmer_pack(jnp.asarray(bases, jnp.uint32), k=k)
    if canonical:
        keys = canonicalize(keys, k)
    return keys


def canonicalize(keys: jnp.ndarray, k: int) -> jnp.ndarray:
    """min(kmer, revcomp(kmer)) per key — strand-independent identity."""
    hi, lo = keys[:, 1], keys[:, 0]
    rh, rl = _revcomp((hi, lo), k)
    less = (rh < hi) | ((rh == hi) & (rl < lo))
    return jnp.stack([jnp.where(less, rl, lo), jnp.where(less, rh, hi)],
                     axis=-1)


def _revcomp(x: b64.U64, k: int) -> b64.U64:
    """Reverse complement of a 2-bit-packed k-mer in a u64 pair."""
    hi, lo = x
    # complement: A<->T (00<->11), C<->G (01<->10) == bitwise NOT per 2 bits
    hi, lo = ~hi, ~lo
    # reverse 2-bit groups within each word, then swap/realign words
    def rev2(v):
        v = ((v & jnp.uint32(0x33333333)) << 2) | ((v >> 2) & jnp.uint32(0x33333333))
        v = ((v & jnp.uint32(0x0F0F0F0F)) << 4) | ((v >> 4) & jnp.uint32(0x0F0F0F0F))
        v = ((v & jnp.uint32(0x00FF00FF)) << 8) | ((v >> 8) & jnp.uint32(0x00FF00FF))
        return (v << 16) | (v >> 16)

    rhi, rlo = rev2(lo), rev2(hi)   # word swap completes the 64-bit reverse
    # the k-mer occupies the low 2k bits; shift the reversed value down
    return b64.shr((rhi, rlo), 64 - 2 * k)
