"""Batched serving engine: prefill + decode with prefix-cache reuse.

Serving path used by examples/serve_with_prefix_filter.py and the decode
shape cells of the dry-run. Static shapes throughout: the engine pads the
request batch, allocates max_len caches up front, and steps decode under
jit; the PrefixCache (cuckoo-filter-guarded) short-circuits prefill for
previously-seen prompts.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import Model
from .prefix_cache import PrefixCache


class ServeEngine:
    def __init__(self, model: Model, params, *, batch: int, max_len: int,
                 prefix_cache_entries: int = 64,
                 prefix_cache_backend: str = "cuckoo",
                 prefix_cache_auto_expand: bool = True,
                 prefix_cache_kw: Optional[Dict[str, Any]] = None,
                 prefix_cache_service_kw: Optional[Dict[str, Any]] = None):
        """``prefix_cache_backend`` / ``prefix_cache_auto_expand`` /
        ``prefix_cache_kw`` flow to :class:`PrefixCache`, so the engine's
        guard filter uses the full AMQ registry surface (any backend,
        auto-expanding by default) instead of the legacy fixed-capacity
        construction. ``prefix_cache_service_kw`` configures the guard
        filter's micro-batching service (deadline, admission policy —
        DESIGN.md §11); its SLO snapshot rides the stats returned by
        :meth:`generate` under ``"filter_service"``."""
        if model.cfg.frontend == "frames":
            raise ValueError("encoder-only arch has no autoregressive serve")
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.prefix_cache = PrefixCache(
            prefix_cache_entries,
            backend=prefix_cache_backend,
            auto_expand=prefix_cache_auto_expand,
            service_kw=prefix_cache_service_kw,
            **(prefix_cache_kw or {}))
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def _grow_caches(self, caches, prompt_len: int):
        big = self.model.init_caches(self.batch, self.max_len)

        def fill(dst, src):
            return jax.lax.dynamic_update_slice(
                dst.astype(src.dtype), src, (0,) * src.ndim)

        return jax.tree.map(fill, big, caches)

    def generate(self, prompts: np.ndarray, steps: int, *,
                 greedy: bool = True, reuse_prefix: bool = True
                 ) -> Tuple[np.ndarray, Dict]:
        """prompts: int32[batch, prompt_len]. Returns (tokens, stats)."""
        assert prompts.shape[0] == self.batch
        prompt_len = prompts.shape[1]
        assert prompt_len + steps <= self.max_len

        cached = self.prefix_cache.lookup(prompts.reshape(-1)) \
            if reuse_prefix else None
        if cached is not None:
            logits, caches = cached
        else:
            logits, caches = self._prefill(
                self.params, {"tokens": jnp.asarray(prompts, jnp.int32)})
            caches = self._grow_caches(caches, prompt_len)
            if reuse_prefix:
                self.prefix_cache.insert(prompts.reshape(-1),
                                         (logits, caches))

        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for t in range(steps):
            out.append(np.asarray(tok))
            pos = jnp.asarray(prompt_len + t, jnp.int32)
            logits, caches = self._decode(self.params, tok, caches, pos)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
        tokens = np.stack(out, axis=1)
        stats = dict(self.prefix_cache.stats)
        stats["filter_service"] = self.prefix_cache.slo_stats()
        return tokens, stats
