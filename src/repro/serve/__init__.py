"""Serving layer: batched prefill/decode engine + AMQ-guarded prefix cache."""

from .engine import ServeEngine  # noqa: F401
from .prefix_cache import PrefixCache, prefix_key  # noqa: F401
