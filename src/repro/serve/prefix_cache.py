"""Prefix-cache admission guarded by an AMQ filter (any registry backend).

Serving-side integration of the paper's technique: the KV prefix cache is
expensive to probe (sharded, host-sized), so a per-host filter sits in front
of it as an AMQ: a negative lookup ("this prefix hash was never cached")
skips the probe entirely. Crucially, cache *eviction* must remove the key
from the filter too — deletion support, the paper's headline capability vs
Bloom filters, is what keeps the filter in sync with an LRU cache instead of
rotting toward 100% false positives.

The filter is any ``repro.amq`` handle — by default an auto-expanding
cascade (DESIGN.md §8), so serving fleets no longer size the guard filter
for peak traffic up front: the filter starts small and grows with the
working set. On backends without deletion (``supports_delete`` False,
e.g. ``bloom``) the cache still works but evicted keys go stale in the
filter — tracked in ``stats["stale"]`` so operators can see the rot the
paper warns about; with auto-expansion those stale keys also keep
*occupying* the cascade, which is exactly why the delete-capable default
backend matters.

All filter traffic flows through a :class:`repro.amq.FilterService`
micro-batch (DESIGN.md §9): eviction deletes and admission inserts are
*enqueued* (coalesced across calls — and across caches, when several share
one service) and only forced when a lookup needs an answer, so a burst of
cache churn costs one fused mixed-op dispatch instead of a filter
round-trip per entry.

The guard filter is also *swappable under live traffic*
(:meth:`PrefixCache.hot_swap_filter`, DESIGN.md §10): the service drains
queued admissions/evictions onto the old backend, migrates its state via
snapshot/exact-reshard, and resumes — capacity or mesh changes for the
serving fleet without a cache rebuild or a stale-filter window.
"""

from __future__ import annotations

import collections
from typing import Any, Optional

import numpy as np

from .. import amq
from ..core.hashing import fmix32_py


def prefix_key(tokens) -> int:
    """Order-sensitive 64-bit hash of a token prefix (host-side)."""
    h1, h2 = 0x9E3779B9, 0x85EBCA6B
    for i, t in enumerate(np.asarray(tokens).tolist()):
        h1 = fmix32_py(h1 ^ (t + i))
        h2 = fmix32_py(h2 + (t ^ (i * 0x27D4EB2F)))
    return (h2 << 32) | h1


class PrefixCache:
    """LRU prefix->cache-entry store with filter-guarded lookups.

    ``backend`` picks any AMQ registry backend for the guard filter;
    alternatively pass a ready-made ``filter_handle`` (sized by the caller)
    or a shared ``service`` (several caches coalescing into one filter's
    micro-batches). ``auto_expand`` (default True, where the backend
    supports it) makes the guard an auto-expanding cascade, so
    ``filter_capacity`` is only an initial size, not a ceiling.
    ``service_kw`` flows to the :class:`repro.amq.FilterService` the cache
    builds (``max_delay``, ``max_pending``, ``admission``, ... — DESIGN.md
    §11), so serving deployments set deadline/backpressure policy at the
    cache constructor.
    """

    def __init__(self, capacity_entries: int, filter_capacity: int = 0,
                 backend: str = "cuckoo",
                 filter_handle: Optional["amq.FilterHandle"] = None,
                 auto_expand: bool = True,
                 service: Optional["amq.FilterService"] = None,
                 service_batch: int = 64,
                 service_kw: Optional[dict] = None,
                 **filter_kw):
        self.capacity = capacity_entries
        self.entries: "collections.OrderedDict[int, Any]" = \
            collections.OrderedDict()
        if service is None:
            if filter_handle is None:
                fcap = filter_capacity or capacity_entries * 4
                filter_handle = amq.make(
                    backend, capacity=fcap,
                    auto_expand="auto" if auto_expand else False, **filter_kw)
            service = amq.FilterService(filter_handle,
                                        batch_size=service_batch,
                                        **(service_kw or {}))
        elif filter_handle is not None:
            raise TypeError("pass filter_handle= or service=, not both")
        elif service_kw:
            raise TypeError("service_kw only applies when the cache builds "
                            "its own service; configure the shared service "
                            "directly instead")
        self.service = service
        self.stats = {"hits": 0, "misses": 0, "filtered": 0,
                      "evictions": 0, "stale": 0}

    @property
    def filter(self):
        """The live guard-filter handle — always the service's current one.

        A property (not a captured reference) so a
        :meth:`~repro.amq.FilterService.hot_swap` on the shared service is
        immediately observed: capability gates (eviction deletes) and stats
        consult the post-swap backend.
        """
        return self.service.handle

    def hot_swap_filter(self, new_handle, **kw) -> dict:
        """Swap the guard filter under live traffic (zero downtime).

        Delegates to :meth:`repro.amq.FilterService.hot_swap`: queued
        admissions/evictions drain to the old filter, its state migrates
        onto ``new_handle`` (snapshot / exact reshard), and subsequent
        lookups are guarded by the new backend. Returns the swap stats.
        """
        return self.service.hot_swap(new_handle, **kw)

    def slo_stats(self) -> dict:
        """Serving-SLO snapshot of the guard-filter service.

        The full :meth:`repro.amq.FilterService.stats` payload — queue-wait
        and enqueue-to-ready latency percentiles, dispatch-size histogram,
        padding waste, admission counters — for the service this cache
        rides (shared or private).
        """
        return self.service.stats()

    def _fkey(self, key: int):
        return np.asarray(
            [[key & 0xFFFFFFFF, (key >> 32) & 0xFFFFFFFF]], np.uint32)

    def lookup(self, tokens) -> Optional[Any]:
        key = prefix_key(tokens)
        # AMQ front door: definite-negative skips the (expensive) probe.
        # The ticket flushes any admissions/evictions queued ahead of it.
        if not bool(self.service.query(self._fkey(key)).result()[0]):
            self.stats["filtered"] += 1
            return None
        entry = self.entries.get(key)
        if entry is None:
            self.stats["misses"] += 1  # filter false positive (or stale key)
            return None
        self.entries.move_to_end(key)
        self.stats["hits"] += 1
        return entry

    def insert(self, tokens, entry: Any):
        key = prefix_key(tokens)
        if key in self.entries:
            self.entries.move_to_end(key)
            self.entries[key] = entry
            return
        while len(self.entries) >= self.capacity:
            old_key, _ = self.entries.popitem(last=False)   # LRU eviction
            if self.filter.capabilities.supports_delete:
                # Enqueued, not dispatched: the micro-batch keeps the AMQ
                # in sync at the next flush, before any lookup reads it.
                self.service.delete(self._fkey(old_key))
            else:
                self.stats["stale"] += 1  # append-only backend: key rots
            self.stats["evictions"] += 1
        self.entries[key] = entry
        self.service.insert(self._fkey(key))
