"""Pallas TPU kernel: fused flash attention (forward).

§Perf follow-up for the memory-bound prefill/train cells: the XLA-level
online-softmax attention (models/attention.flash_attention) materializes
each [Cq, Ck] score chunk in HBM per scan step — the dominant memory-term
contributor for every long-sequence cell. This kernel keeps the score block,
running max/denominator and output accumulator in VMEM scratch across the
KV-block grid steps; HBM traffic collapses to the q/k/v reads + out write.

Grid: (B * KVH, g, nq, nk) — nk innermost, so scratch accumulators persist
across a q-row's KV sweep (TPU grid steps run sequentially on a core).
GQA is handled by indexing k/v blocks with the leading B*KVH coordinate
while q/out carry the per-kv-group head dim g.

Validated in interpret mode against models.attention.flash_attention and
kernels/ref.py; on this CPU container the interpret lowering necessarily
re-materializes blocks (no VMEM), so the §Perf effect is reported as a
projection (EXPERIMENTS.md §Perf B4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(causal: bool, window, scale: float, blk_q: int, blk_k: int,
                  seq_k: int,
                  q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)

    # skip fully-masked blocks (causal upper triangle / outside the window)
    relevant = True
    if causal:
        relevant = (ik * blk_k) <= (iq * blk_q + blk_q - 1)
    if window is not None:
        relevant = relevant & ((iq * blk_q) - (ik * blk_k + blk_k - 1)
                               < window)

    @pl.when(relevant)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # [blk_q, D]
        k = k_ref[0].astype(jnp.float32)             # [blk_k, D]
        v = v_ref[0].astype(jnp.float32)             # [blk_k, Dv]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, NEG_INF * 1e-10)
        p = jnp.exp(s - m_safe[:, None])
        corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           blk_q: int = 512, blk_k: int = 512,
                           interpret: bool = True):
    """Fused attention forward.

    q: [BK, g, Sq, D]; k: [BK, Sk, D]; v: [BK, Sk, Dv] where BK = B * KVH
    and g = query heads per KV head. Returns [BK, g, Sq, Dv].
    """
    BK, g, Sq, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    pq = (-Sq) % blk_q
    pk = (-Sk) % blk_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // blk_q
    nk = (Sk + pk) // blk_k

    kernel = functools.partial(_flash_kernel, causal, window, scale,
                               blk_q, blk_k, Sk)
    out = pl.pallas_call(
        kernel,
        grid=(BK, g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, h, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, Dv), lambda b, h, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BK, g, Sq + pq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, Dv), jnp.float32),   # acc
            pltpu.VMEM((blk_q,), jnp.float32),      # running max
            pltpu.VMEM((blk_q,), jnp.float32),      # running denom
        ],
        interpret=interpret,
        name="flash_attention_fwd",
    )(q, k, v)
    return out[:, :, :Sq]
