"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each ``*_ref`` function computes exactly what the corresponding kernel in
this package must produce; kernel tests sweep shapes/dtypes and
``assert_allclose`` (exact equality for these integer ops) against them.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import layout as L
from ..core import bits64 as b64
from ..core.cuckoo_filter import CuckooConfig, CuckooState
from ..core.cuckoo_filter import query as cuckoo_query_core
from ..core.hashing import xxhash64_u64
from ..filters.blocked_bloom import BloomConfig, BloomState
from ..filters.blocked_bloom import query as bloom_query_core

_U32 = np.uint32


def _pack_keys(keys_lo: jnp.ndarray, keys_hi: jnp.ndarray) -> jnp.ndarray:
    return jnp.stack([keys_lo, keys_hi], axis=-1)


def cuckoo_query_ref(config: CuckooConfig, table: jnp.ndarray,
                     keys_lo: jnp.ndarray, keys_hi: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.cuckoo_query — reuses the core query (Alg. 2)."""
    state = CuckooState(table, jnp.zeros((), jnp.int32))
    hit = cuckoo_query_core(config, state, _pack_keys(keys_lo, keys_hi))
    return hit.astype(jnp.uint32)


def cuckoo_insert_ref(config: CuckooConfig, table: jnp.ndarray,
                      keys_lo: jnp.ndarray, keys_hi: jnp.ndarray):
    """Oracle for kernels.cuckoo_insert (direct-insert fast path only).

    Sequential semantics: keys are applied one at a time in batch order; each
    key scans bucket i1 then i2 from its fingerprint-derived start and takes
    the first empty slot (no eviction — kernel reports failure instead).
    Returns (table', ok uint32[n]).
    """
    import jax

    lay = config.layout
    pol = config.placement
    from ..core.cuckoo_filter import prepare_keys

    keys = _pack_keys(keys_lo, keys_hi)
    base_tag, i1, i2 = prepare_keys(config, keys)
    tag1 = pol.place_tag(base_tag, jnp.zeros(base_tag.shape, bool))
    tag2 = pol.place_tag(base_tag, jnp.ones(base_tag.shape, bool))

    def body(i, carry):
        table, ok = carry
        words1 = L.gather_bucket_words(table, i1[i], lay)
        words2 = L.gather_bucket_words(table, i2[i], lay)
        start = L.scan_start(base_tag[i], lay)
        f1, s1 = L.first_true_circular(
            L.unpack_words(words1, lay.fp_bits) == 0, start)
        f2, s2 = L.first_true_circular(
            L.unpack_words(words2, lay.fp_bits) == 0, start)
        bucket = jnp.where(f1, i1[i], i2[i])
        slot = jnp.where(f1, s1, s2)
        tag = jnp.where(f1, tag1[i], tag2[i])
        widx, sw = L.slot_to_word(slot, lay)
        word = jnp.where(f1, words1[widx], words2[widx])
        desired = L.replace_tag(word, sw, tag, lay.fp_bits)
        addr = L.word_addr(bucket, widx, lay)
        found = f1 | f2
        table = jnp.where(found, table.at[addr].set(desired), table)
        ok = ok.at[i].set(found.astype(jnp.uint32))
        return table, ok

    n = keys_lo.shape[0]
    return jax.lax.fori_loop(0, n, body,
                             (table, jnp.zeros((n,), jnp.uint32)))


def cuckoo_mixed_ref(config: CuckooConfig, table: jnp.ndarray,
                     keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                     ops: jnp.ndarray, valid: jnp.ndarray = None):
    """Oracle for kernels.cuckoo_mixed — exact sequential op-stream semantics.

    One key at a time in batch order: QUERY is a match scan over both
    buckets, INSERT a first-empty-slot claim (i1 preferred, no eviction),
    DELETE a first-match clear; operation ``i`` observes every mutation of
    operations ``j < i``. Returns (table', ok uint32[n]).
    """
    import jax

    lay = config.layout
    pol = config.placement
    from ..core.cuckoo_filter import prepare_keys

    keys = _pack_keys(keys_lo, keys_hi)
    base_tag, i1, i2 = prepare_keys(config, keys)
    tag1 = pol.place_tag(base_tag, jnp.zeros(base_tag.shape, bool))
    tag2 = pol.place_tag(base_tag, jnp.ones(base_tag.shape, bool))
    t1, t2 = pol.query_match_tags(base_tag)
    start = L.scan_start(base_tag, lay)
    n = keys_lo.shape[0]
    if valid is None:
        valid = jnp.ones((n,), jnp.uint32)
    ops = ops.astype(jnp.int32)

    def body(i, carry):
        table, ok = carry
        opc = ops[i]
        live = valid[i] != 0
        is_i = opc == 1
        is_d = opc == 2
        words1 = L.gather_bucket_words(table, i1[i], lay)
        words2 = L.gather_bucket_words(table, i2[i], lay)
        lanes1 = L.unpack_words(words1, lay.fp_bits)
        lanes2 = L.unpack_words(words2, lay.fp_bits)
        flags1 = jnp.where(is_i, lanes1 == 0, lanes1 == t1[i])
        flags2 = jnp.where(is_i, lanes2 == 0, lanes2 == t2[i])
        f1, s1 = L.first_true_circular(flags1, start[i])
        f2, s2 = L.first_true_circular(flags2, start[i])
        hit = f1 | f2
        bucket = jnp.where(f1, i1[i], i2[i])
        slot = jnp.where(f1, s1, s2)
        store_tag = jnp.where(is_i, jnp.where(f1, tag1[i], tag2[i]), _U32(0))
        widx, sw = L.slot_to_word(slot, lay)
        word = jnp.where(f1, words1, words2)[widx]
        desired = L.replace_tag(word, sw, store_tag, lay.fp_bits)
        addr = L.word_addr(bucket, widx, lay)
        ok_i = live & hit
        do_write = ok_i & (is_i | is_d)
        table = jnp.where(do_write, table.at[addr].set(desired), table)
        ok = ok.at[i].set(ok_i.astype(jnp.uint32))
        return table, ok

    return jax.lax.fori_loop(0, n, body,
                             (table, jnp.zeros((n,), jnp.uint32)))


def bloom_query_ref(config: BloomConfig, table: jnp.ndarray,
                    keys_lo: jnp.ndarray, keys_hi: jnp.ndarray) -> jnp.ndarray:
    state = BloomState(table, jnp.zeros((), jnp.int32))
    hit = bloom_query_core(config, state, _pack_keys(keys_lo, keys_hi))
    return hit.astype(jnp.uint32)


def bloom_insert_ref(config: BloomConfig, table: jnp.ndarray,
                     keys_lo: jnp.ndarray, keys_hi: jnp.ndarray) -> jnp.ndarray:
    from ..filters.blocked_bloom import insert as bloom_insert_core

    state = BloomState(table, jnp.zeros((), jnp.int32))
    state, _ = bloom_insert_core(config, state, _pack_keys(keys_lo, keys_hi))
    return state.table


def hash64_ref(keys_lo: jnp.ndarray, keys_hi: jnp.ndarray, seed: int = 0):
    """Oracle for kernels.hash64 — xxHash64 on (hi, lo) uint32 pairs."""
    hi, lo = xxhash64_u64((keys_hi, keys_lo), seed=seed)
    return hi, lo


def kmer_pack_ref(bases: jnp.ndarray, k: int = 31):
    """Oracle for kernels.kmer_pack.

    bases: uint32[n] 2-bit base codes (0..3), padded with >= k-1 trailing
    entries. Output: (hi, lo) uint32[n] where position i holds the 2k-bit
    packed k-mer starting at i (positions beyond n-k+1 are don't-care but
    computed identically from the padding).
    """
    n = bases.shape[0]
    acc = (jnp.zeros((n,), jnp.uint32), jnp.zeros((n,), jnp.uint32))
    padded = jnp.concatenate([bases, jnp.zeros((k,), jnp.uint32)])
    for j in range(k):
        nxt = padded[j:j + n]
        acc = b64.shl(acc, 2)
        acc = (acc[0], acc[1] | (nxt & _U32(3)))
    return acc
