"""Pallas TPU kernels: Blocked Bloom filter query + insert (GBBF baseline).

The blocked Bloom layout is the friendliest possible for TPU: one key maps
to exactly one contiguous block (cache line on GPU, vector row here), so both
operations are a single gather/RMW per key with no conflict structure beyond
word-level merging. Query is fully vectorized; insert uses the same
sequential-grid RMW trick as cuckoo_insert (core-exclusive VMEM ownership
replaces ``atomicOr``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..filters.blocked_bloom import BloomConfig, _bit_positions

_U32 = np.uint32


def _query_kernel(config: BloomConfig, table_ref, keys_lo_ref, keys_hi_ref,
                  out_ref):
    keys = jnp.stack([keys_lo_ref[...], keys_hi_ref[...]], axis=-1)
    block, word, mask = _bit_positions(config, keys)
    table = table_ref[...]
    addr = block[:, None] * config.words_per_block + word     # [K, k]
    words = table[addr]
    out_ref[...] = jnp.all((words & mask) == mask, axis=-1).astype(jnp.uint32)


def bloom_query_pallas(config: BloomConfig, table: jnp.ndarray,
                       keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                       *, block_keys: int = 1024,
                       interpret: bool = True) -> jnp.ndarray:
    n = keys_lo.shape[0]
    assert n % block_keys == 0
    kernel = functools.partial(_query_kernel, config)
    return pl.pallas_call(
        kernel,
        grid=(n // block_keys,),
        in_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_keys,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
        name="bloom_query",
    )(table, keys_lo, keys_hi)


def _insert_kernel(config: BloomConfig, block_keys: int,
                   table_in_ref, keys_lo_ref, keys_hi_ref, valid_ref,
                   table_out_ref):
    keys = jnp.stack([keys_lo_ref[...], keys_hi_ref[...]], axis=-1)
    block, word, mask = _bit_positions(config, keys)
    addr = block[:, None] * config.words_per_block + word     # [K, k]
    live_mask = jnp.where((valid_ref[...] != 0)[:, None], mask,
                          jnp.zeros_like(mask))

    @pl.when(pl.program_id(0) == 0)
    def _():
        table_out_ref[...] = table_in_ref[...]

    def body(i, _):
        def set_bit(j, __):
            a = addr[i, j]
            w = table_out_ref[pl.ds(a, 1)]
            table_out_ref[pl.ds(a, 1)] = w | live_mask[i, j][None]
            return 0
        return jax.lax.fori_loop(0, config.k, set_bit, 0)

    jax.lax.fori_loop(0, block_keys, body, 0)


def bloom_insert_pallas(config: BloomConfig, table: jnp.ndarray,
                        keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                        valid: jnp.ndarray | None = None,
                        *, block_keys: int = 256,
                        interpret: bool = True) -> jnp.ndarray:
    n = keys_lo.shape[0]
    assert n % block_keys == 0
    if valid is None:
        valid = jnp.ones((n,), jnp.uint32)
    kernel = functools.partial(_insert_kernel, config, block_keys)
    return pl.pallas_call(
        kernel,
        grid=(n // block_keys,),
        in_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec(table.shape, lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct(table.shape, jnp.uint32),
        input_output_aliases={0: 0},
        interpret=interpret,
        name="bloom_insert",
    )(table, keys_lo, keys_hi, valid)
