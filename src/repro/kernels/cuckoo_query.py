"""Pallas TPU kernel: batched Cuckoo-filter query (paper Alg. 2).

TPU mapping of the paper's query design (DESIGN.md §2):

* the filter table lives **entirely in VMEM** for the duration of the kernel
  — the TPU analogue of the paper's L2-resident regime (§5.2). One BlockSpec
  pins the full packed table; the key stream is tiled over the grid.
* per grid step, a tile of keys is hashed on the VPU (emulated-u64 xxHash64
  or the fmix32 fast path — both pure 32-bit lane arithmetic), both candidate
  buckets are gathered from the VMEM table, and matching uses the same
  equality-on-unpacked-lanes algebra as the SWAR masks (exact per lane).
* bucket-major layout means each bucket's ``words_per_bucket`` uint32 words
  are contiguous — a single vector row per bucket, the analogue of the
  paper's 256-bit ``ld.global.nc.v4.u64`` vectorized loads.

VMEM budget: table_bytes + 2 tiles of keys + gathered buckets. With the
paper's 16×16-bit buckets, a 2^18-bucket filter is 16 MiB — the VMEM-resident
ceiling on v5e (recorded in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core import layout as L
from ..core.cuckoo_filter import CuckooConfig
from ..core.hashing import hash_key

_U32 = np.uint32


def _query_kernel(config: CuckooConfig, table_ref, keys_lo_ref, keys_hi_ref,
                  out_ref):
    lay = config.layout
    pol = config.placement

    table = table_ref[...]
    keys = jnp.stack([keys_lo_ref[...], keys_hi_ref[...]], axis=-1)
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    tag = pol.make_tag(hi)
    i1, i2 = pol.initial_buckets(lo, tag)
    t1, t2 = pol.query_match_tags(tag)

    wpb = lay.words_per_bucket
    offs = jnp.arange(wpb, dtype=jnp.int32)

    def bucket_hit(bucket, match_tag):
        idx = bucket.astype(jnp.int32)[:, None] * wpb + offs  # [K, wpb]
        words = table[idx]                                    # VMEM gather
        lanes = L.unpack_words(words, lay.fp_bits)            # [K, b]
        return jnp.any(lanes == match_tag[:, None], axis=-1)

    hit = bucket_hit(i1, t1) | bucket_hit(i2, t2)
    out_ref[...] = hit.astype(jnp.uint32)


def _query_fused_kernel(config: CuckooConfig, table_ref, keys_lo_ref,
                        keys_hi_ref, out_ref):
    """Fused hash + gather + SWAR match (no per-lane unpack).

    Versus ``_query_kernel``: both candidate buckets are fetched with a
    *single* gather (one index vector of ``2 * words_per_bucket`` columns),
    and matching runs the paper's §4.3 SWAR algebra directly on the packed
    words — ``broadcast_tag`` + carry-free zero-mask — instead of widening
    every word to ``tags_per_word`` uint32 lanes first. At fp_bits=8 that
    is a 4x cut in comparison-operand width on the VPU.
    """
    lay = config.layout
    pol = config.placement

    table = table_ref[...]
    keys = jnp.stack([keys_lo_ref[...], keys_hi_ref[...]], axis=-1)
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    tag = pol.make_tag(hi)
    i1, i2 = pol.initial_buckets(lo, tag)
    t1, t2 = pol.query_match_tags(tag)

    wpb = lay.words_per_bucket
    offs = jnp.arange(wpb, dtype=jnp.int32)
    idx = jnp.concatenate(
        [i1.astype(jnp.int32)[:, None] * wpb + offs,
         i2.astype(jnp.int32)[:, None] * wpb + offs], axis=-1)  # [K, 2*wpb]
    words = table[idx]                                          # one gather

    m1 = L.swar_match_mask(words[:, :wpb], t1[:, None], lay.fp_bits)
    m2 = L.swar_match_mask(words[:, wpb:], t2[:, None], lay.fp_bits)
    hit = jnp.any((m1 | m2) != _U32(0), axis=-1)
    out_ref[...] = hit.astype(jnp.uint32)


def _query_call(kernel_body, config: CuckooConfig, table: jnp.ndarray,
                keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                block_keys: int, interpret: bool, name: str) -> jnp.ndarray:
    n = keys_lo.shape[0]
    assert n % block_keys == 0, (n, block_keys)
    grid = (n // block_keys,)
    kernel = functools.partial(kernel_body, config)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),          # whole table
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_keys,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
        name=name,
    )(table, keys_lo, keys_hi)


def cuckoo_query_pallas(config: CuckooConfig, table: jnp.ndarray,
                        keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                        *, block_keys: int = 1024,
                        interpret: bool = True) -> jnp.ndarray:
    """Query ``n`` keys against a VMEM-resident filter table.

    n must be a multiple of ``block_keys`` (callers pad; see ops.py).
    Returns uint32[n] (1 = maybe-present, 0 = definitely absent).
    """
    return _query_call(_query_kernel, config, table, keys_lo, keys_hi,
                       block_keys, interpret, "cuckoo_query")


def cuckoo_query_fused_pallas(config: CuckooConfig, table: jnp.ndarray,
                              keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                              *, block_keys: int = 1024,
                              interpret: bool = True) -> jnp.ndarray:
    """Fused-SWAR variant of :func:`cuckoo_query_pallas` — same contract.

    Kept alongside the unpack-based kernel so the roofline suite can
    measure both (the ``query_kernel_prepr`` baseline row).
    """
    return _query_call(_query_fused_kernel, config, table, keys_lo, keys_hi,
                       block_keys, interpret, "cuckoo_query_fused")
