"""Pallas TPU kernel: Cuckoo-filter direct insertion (paper Alg. 1 phase 1).

TPU adaptation of the lock-free CAS insert (DESIGN.md §2): a TPU core's grid
steps execute **sequentially**, so read-modify-write on a VMEM-resident table
is race-free *by construction* — the atomicity the GPU buys with CAS, the TPU
gets from exclusive core ownership. Parallel scale-out happens above this
kernel (one filter shard per core via shard_map; see core/sharded_filter.py).

The kernel implements the *direct-insert fast path*: hash a tile of keys on
the VPU (vectorized), then apply them with an in-kernel sequential loop —
scan bucket i1 then i2 from the fingerprint-derived start, take the first
empty slot, store the updated word back to VMEM. Keys whose buckets are both
full are reported in the failure mask; the (rare at <95% load) eviction path
is handled by the general batch machinery in core/cuckoo_filter.py. This
hybrid mirrors the paper's own structure, where phase 2 is the slow path.

The table is input/output-aliased so the update is in-place in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core import layout as L
from ..core.cuckoo_filter import CuckooConfig
from ..core.hashing import hash_key

_U32 = np.uint32


def _insert_kernel(config: CuckooConfig, block_keys: int,
                   table_in_ref, keys_lo_ref, keys_hi_ref, valid_ref,
                   table_out_ref, ok_ref):
    lay = config.layout
    pol = config.placement
    wpb = lay.words_per_bucket

    # Phase A (vectorized over the tile): hashing + candidate derivation.
    keys = jnp.stack([keys_lo_ref[...], keys_hi_ref[...]], axis=-1)
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    base_tag = pol.make_tag(hi)
    i1, i2 = pol.initial_buckets(lo, base_tag)
    tag1 = pol.place_tag(base_tag, jnp.zeros((block_keys,), bool))
    tag2 = pol.place_tag(base_tag, jnp.ones((block_keys,), bool))
    start = L.scan_start(base_tag, lay)

    # Phase B (sequential RMW): grid steps and this loop both execute in
    # order on the core, so each iteration sees all prior writes.
    def body(i, _):
        def try_bucket(bucket, tag):
            base = bucket.astype(jnp.int32) * wpb
            words = table_out_ref[pl.ds(base, wpb)]
            lanes = L.unpack_words(words, lay.fp_bits)
            found, slot = L.first_true_circular(lanes == 0, start[i])
            widx, sw = L.slot_to_word(slot, lay)
            desired = L.replace_tag(words[widx], sw, tag, lay.fp_bits)
            return found, base + widx, desired

        f1, addr1_, des1 = try_bucket(i1[i], tag1[i])
        f2, addr2_, des2 = try_bucket(i2[i], tag2[i])
        found = (f1 | f2) & (valid_ref[i] != 0)
        addr = jnp.where(f1, addr1_, addr2_)
        desired = jnp.where(f1, des1, des2)
        # Masked store: failed keys write back the original word.
        current = table_out_ref[pl.ds(addr, 1)]
        table_out_ref[pl.ds(addr, 1)] = jnp.where(found, desired[None],
                                                  current)
        ok_ref[pl.ds(i, 1)] = found.astype(jnp.uint32)[None]
        return 0

    # First grid step: copy the table into the aliased output buffer (no-op
    # under aliasing, but keeps interpret mode and real lowering identical).
    @pl.when(pl.program_id(0) == 0)
    def _():
        table_out_ref[...] = table_in_ref[...]

    jax.lax.fori_loop(0, block_keys, body, 0)


def cuckoo_insert_pallas(config: CuckooConfig, table: jnp.ndarray,
                         keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                         valid: jnp.ndarray | None = None,
                         *, block_keys: int = 256,
                         interpret: bool = True):
    """Direct-insert a key stream; returns (table', ok uint32[n]).

    ok==0 keys need the eviction path (core.cuckoo_filter.insert).
    ``valid`` (uint32[n], nonzero = live) masks padding keys.
    """
    n = keys_lo.shape[0]
    assert n % block_keys == 0, (n, block_keys)
    if valid is None:
        valid = jnp.ones((n,), jnp.uint32)
    grid = (n // block_keys,)
    kernel = functools.partial(_insert_kernel, config, block_keys)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(table.shape, jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
        name="cuckoo_insert_direct",
    )(table, keys_lo, keys_hi, valid)


# ---------------------------------------------------------------------------
# Fused-SWAR variant (joins the fused kernel family, DESIGN.md §13/§14).
# ---------------------------------------------------------------------------

def _insert_fused_kernel(config: CuckooConfig, block_keys: int,
                         table_in_ref, keys_lo_ref, keys_hi_ref, valid_ref,
                         table_out_ref, ok_ref):
    """Fused hash + double-bucket load + SWAR free-slot scan.

    Versus ``_insert_kernel``: both candidate buckets are read as one
    ``2 * words_per_bucket`` packed row and the free-lane search runs the
    §4.3 SWAR zero-mask directly on the packed words — no per-bucket
    unpack-to-lanes pass — then a single circular-preference scan (bucket
    i1's slots from the fingerprint-derived start, then i2's) picks the
    slot, exactly the order the unfused kernel and the core scan use.
    """
    lay = config.layout
    pol = config.placement
    wpb = lay.words_per_bucket
    b = config.bucket_size

    keys = jnp.stack([keys_lo_ref[...], keys_hi_ref[...]], axis=-1)
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    base_tag = pol.make_tag(hi)
    i1, i2 = pol.initial_buckets(lo, base_tag)
    tag1 = pol.place_tag(base_tag, jnp.zeros((block_keys,), bool))
    tag2 = pol.place_tag(base_tag, jnp.ones((block_keys,), bool))
    start = L.scan_start(base_tag, lay)
    slots = jnp.arange(b, dtype=jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _():
        table_out_ref[...] = table_in_ref[...]

    def body(i, _):
        base1 = i1[i].astype(jnp.int32) * wpb
        base2 = i2[i].astype(jnp.int32) * wpb
        words = jnp.concatenate([table_out_ref[pl.ds(base1, wpb)],
                                 table_out_ref[pl.ds(base2, wpb)]])
        free = L.swar_mask_to_bools(
            L.swar_zero_mask(words, lay.fp_bits), lay.fp_bits).reshape(2 * b)
        # Circular preference order: i1's slots from start[i], then i2's.
        rot = (start[i] + slots) % b
        positions = jnp.concatenate([rot, b + rot])
        cand = free[positions]
        found = jnp.any(cand) & (valid_ref[i] != 0)
        abs_slot = positions[jnp.argmax(cand)]
        in_b2 = abs_slot >= b
        slot = abs_slot - jnp.where(in_b2, b, 0)
        widx, sw = L.slot_to_word(slot, lay)
        word = words[jnp.where(in_b2, wpb, 0) + widx]
        desired = L.replace_tag(
            word, sw, jnp.where(in_b2, tag2[i], tag1[i]), lay.fp_bits)
        addr = jnp.where(in_b2, base2, base1) + widx
        current = table_out_ref[pl.ds(addr, 1)]
        table_out_ref[pl.ds(addr, 1)] = jnp.where(found, desired[None],
                                                  current)
        ok_ref[pl.ds(i, 1)] = found.astype(jnp.uint32)[None]
        return 0

    jax.lax.fori_loop(0, block_keys, body, 0)


def cuckoo_insert_fused_pallas(config: CuckooConfig, table: jnp.ndarray,
                               keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                               valid: jnp.ndarray | None = None,
                               *, block_keys: int = 256,
                               interpret: bool = True):
    """Fused-SWAR variant of :func:`cuckoo_insert_pallas` — same contract,
    bit-identical results (the roofline suite measures both)."""
    n = keys_lo.shape[0]
    assert n % block_keys == 0, (n, block_keys)
    if valid is None:
        valid = jnp.ones((n,), jnp.uint32)
    grid = (n // block_keys,)
    kernel = functools.partial(_insert_fused_kernel, config, block_keys)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(table.shape, jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
        name="cuckoo_insert_fused",
    )(table, keys_lo, keys_hi, valid)


# ---------------------------------------------------------------------------
# Bucket-major tile variant (bulk-build fast path, DESIGN.md §6).
# ---------------------------------------------------------------------------

def _bulk_insert_kernel(config: CuckooConfig, block_keys: int,
                        table_in_ref, keys_lo_ref, keys_hi_ref, valid_ref,
                        table_out_ref, ok_ref):
    """Direct insert for a tile of keys **pre-sorted by primary bucket**.

    Bucket-major order lets the kernel keep the current primary bucket's
    packed words in registers across the run of keys that target it: the
    bucket is loaded once per segment and flushed once when the segment
    ends, instead of a VMEM read-modify-write per key. Same sequential
    semantics as ``_insert_kernel`` (and ``ref.cuckoo_insert_ref`` on the
    sorted stream) — only the memory traffic pattern changes.
    """
    lay = config.layout
    pol = config.placement
    wpb = lay.words_per_bucket
    warange = jnp.arange(wpb, dtype=jnp.int32)

    keys = jnp.stack([keys_lo_ref[...], keys_hi_ref[...]], axis=-1)
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    base_tag = pol.make_tag(hi)
    i1, i2 = pol.initial_buckets(lo, base_tag)
    tag1 = pol.place_tag(base_tag, jnp.zeros((block_keys,), bool))
    tag2 = pol.place_tag(base_tag, jnp.ones((block_keys,), bool))
    start = L.scan_start(base_tag, lay)

    @pl.when(pl.program_id(0) == 0)
    def _():
        table_out_ref[...] = table_in_ref[...]

    # Prime the cache with the first key's primary bucket.
    b0 = i1[0].astype(jnp.int32)
    words0 = table_out_ref[pl.ds(b0 * wpb, wpb)]

    def body(i, carry):
        cur_bucket, cur_words = carry
        live = valid_ref[i] != 0
        b1 = i1[i].astype(jnp.int32)
        seg_end = b1 != cur_bucket

        # Segment boundary: flush the cached bucket, then load the new one.
        @pl.when(seg_end)
        def _():
            table_out_ref[pl.ds(cur_bucket * wpb, wpb)] = cur_words

        fresh = table_out_ref[pl.ds(b1 * wpb, wpb)]
        wordsA = jnp.where(seg_end, fresh, cur_words)

        lanesA = L.unpack_words(wordsA, lay.fp_bits)
        foundA, slotA = L.first_true_circular(lanesA == 0, start[i])
        widxA, swA = L.slot_to_word(slotA, lay)
        desiredA = L.replace_tag(wordsA[widxA], swA, tag1[i], lay.fp_bits)
        okA = foundA & live
        wordsA = jnp.where((warange == widxA) & okA, desiredA, wordsA)

        # Secondary bucket: straight to VMEM, except when it aliases the
        # cached primary bucket (possible under XOR when H(fp)&mask == 0).
        b2 = i2[i].astype(jnp.int32)
        sameB = b2 == b1
        wordsB = jnp.where(sameB, wordsA,
                           table_out_ref[pl.ds(b2 * wpb, wpb)])
        lanesB = L.unpack_words(wordsB, lay.fp_bits)
        foundB, slotB = L.first_true_circular(lanesB == 0, start[i])
        widxB, swB = L.slot_to_word(slotB, lay)
        desiredB = L.replace_tag(wordsB[widxB], swB, tag2[i], lay.fp_bits)
        okB = foundB & live & ~okA

        cur_words = jnp.where((warange == widxB) & okB & sameB,
                              desiredB, wordsA)
        addrB = b2 * wpb + widxB
        currentB = table_out_ref[pl.ds(addrB, 1)]
        table_out_ref[pl.ds(addrB, 1)] = jnp.where(okB & ~sameB,
                                                   desiredB[None], currentB)

        ok_ref[pl.ds(i, 1)] = (okA | okB).astype(jnp.uint32)[None]
        return b1, cur_words

    final_bucket, final_words = jax.lax.fori_loop(
        0, block_keys, body, (b0, words0))
    table_out_ref[pl.ds(final_bucket * wpb, wpb)] = final_words


def cuckoo_insert_bulk_pallas(config: CuckooConfig, table: jnp.ndarray,
                              keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                              valid: jnp.ndarray | None = None,
                              *, block_keys: int = 256,
                              interpret: bool = True):
    """Bucket-major direct insert; callers must pass keys sorted by primary
    bucket (``prepare_keys``'s ``i1``). Returns (table', ok uint32[n])."""
    n = keys_lo.shape[0]
    assert n % block_keys == 0, (n, block_keys)
    if valid is None:
        valid = jnp.ones((n,), jnp.uint32)
    grid = (n // block_keys,)
    kernel = functools.partial(_bulk_insert_kernel, config, block_keys)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(table.shape, jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
        name="cuckoo_insert_bulk",
    )(table, keys_lo, keys_hi, valid)
