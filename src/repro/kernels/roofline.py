"""Minimal-bytes-per-op roofline model for the filter kernels (DESIGN.md §13).

The paper's central claim is cast in bytes: a Cuckoo filter embraces random
access and still saturates global memory bandwidth (PAPER.md §1), and
"High-Performance Filters for GPUs" makes bytes-per-op the standard scale
for comparing dynamic AMQs. This module computes, purely from a backend's
static config (layout widths, bucket geometry, probe counts), the *minimal*
bytes each operation must move — the denominator of every achieved-bandwidth
number the roofline suite reports (benchmarks/roofline_filters.py) and the
quantity the HLO cross-check pins (launch/filter_roofline.py,
tests/test_roofline_model.py).

Two residency regimes are modelled explicitly (the paper's §5.2 L2-resident
vs DRAM-resident split, mapped to our substrate):

* ``table_resident=False`` (default): the table lives in main memory and
  every per-key bucket probe is charged at word granularity — the paper's
  own accounting, and the right model for the XLA core paths (and any
  table too large to pin).
* ``table_resident=True``: the table is pinned in fast memory for the
  kernel's duration (the Pallas VMEM regime) — main-memory traffic is the
  key/result streams plus ONE table load (and one store for mutating ops);
  the per-key random access happens against the pinned copy and is *free*
  at the HBM tier.

All figures are lower bounds by construction: sort/permutation traffic of
the bulk path, eviction-chain re-reads past the first probe, and padding
are deliberately excluded — achieved/minimal is then a fraction ≤ 1 of the
bandwidth ceiling with equality only for a perfect kernel.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# Bytes of one packed key on the stream (uint32[2] — the 64-bit key pair).
KEY_BYTES = 8
# Bytes of one per-op result lane (uint32 ok/hit in the kernel paths; the
# core paths return bool[n] but XLA materializes predicates word-wide too).
RESULT_BYTES = 4

# Op names accepted by the per-backend models. ``orient_bulk_insert`` is
# cuckoo-only (the graph-orientation bulk engine, DESIGN.md §14); the other
# backends reject it like any unknown op.
OPS = ("query", "insert", "bulk_insert", "orient_bulk_insert", "delete",
       "apply_ops")


@dataclasses.dataclass(frozen=True)
class OpTraffic:
    """Per-key minimal traffic, split by direction and residency tier.

    ``stream_read``/``stream_write`` cross main memory in every regime
    (keys in, results out). ``table_read``/``table_write`` are the per-key
    probe bytes against the table — main-memory traffic when the table is
    memory-resident, fast-tier traffic when it is pinned.
    """

    stream_read: float
    stream_write: float
    table_read: float
    table_write: float

    @property
    def per_key(self) -> float:
        """Total bytes per key with a memory-resident table."""
        return (self.stream_read + self.stream_write
                + self.table_read + self.table_write)

    def batch_bytes(self, n: int, table_bytes: int = 0,
                    table_resident: bool = False) -> float:
        """Minimal bytes for an ``n``-key batch.

        ``table_resident=True`` charges the table once (one load, plus one
        store when the op writes) instead of per-key probe traffic.
        """
        stream = n * (self.stream_read + self.stream_write)
        if table_resident:
            spill = table_bytes * (2 if self.table_write else 1)
            return stream + spill
        return stream + n * (self.table_read + self.table_write)


def _mix(q: float, i: float, d: float):
    total = q + i + d
    if total <= 0:
        raise ValueError("op mix must have a positive total")
    return q / total, i / total, d / total


# ---------------------------------------------------------------------------
# Cuckoo (core contribution): packed fingerprints, two candidate buckets.
# ---------------------------------------------------------------------------

def cuckoo_op_traffic(config, op: str, *,
                      op_mix: Optional[tuple] = None,
                      batch: Optional[int] = None) -> OpTraffic:
    """Minimal per-key traffic for one cuckoo op, from the packed layout.

    * ``query``: read both candidate buckets (``2 * words_per_bucket``
      uint32 words — the §4.2 vectorized bucket loads), no table write.
    * ``insert`` / ``delete``: same two bucket reads plus exactly one
      word read-modify-write (the claimed/cleared slot's word).
    * ``bulk_insert``: the bucket-major stream amortizes the *primary*
      bucket load/flush over the expected run of keys per bucket
      (``batch / num_buckets`` when ``batch`` is given) — the whole point
      of sorting first (DESIGN.md §6). Sort traffic itself is excluded
      (lower bound).
    * ``apply_ops``: op-mix-weighted blend, ``op_mix=(query, insert,
      delete)`` fractions (default the uniform read-heavy 80/15/5).
    """
    lay = config.layout
    bucket_bytes = lay.words_per_bucket * 4

    if op == "query":
        return OpTraffic(KEY_BYTES, RESULT_BYTES, 2 * bucket_bytes, 0.0)
    if op == "insert":
        return OpTraffic(KEY_BYTES, RESULT_BYTES, 2 * bucket_bytes, 4.0)
    if op == "delete":
        return OpTraffic(KEY_BYTES, RESULT_BYTES, 2 * bucket_bytes, 4.0)
    if op == "bulk_insert":
        seg = max(1.0, (batch or 1) / config.num_buckets)
        # Primary bucket: one load + one flush per segment; secondary
        # bucket: per-key load, one word write for spilled keys (charged
        # fully — a lower bound need not model the spill rate).
        table_read = bucket_bytes / seg + bucket_bytes
        table_write = bucket_bytes / seg + 4.0
        return OpTraffic(KEY_BYTES, RESULT_BYTES, table_read, table_write)
    if op == "orient_bulk_insert":
        # Graph-orientation bulk build (DESIGN.md §14): the batch is edges
        # of the bucket graph; orientation sweeps touch O(batch) per-edge
        # state, and the commit streams the *whole* table exactly once —
        # one load + one store amortized over the batch. Per-sweep edge
        # traffic and the residue pass are excluded (lower bound).
        n = max(1, batch or 1)
        whole_table = float(config.table_bytes) / n
        return OpTraffic(KEY_BYTES, RESULT_BYTES, whole_table, whole_table)
    if op == "apply_ops":
        q, i, d = _mix(*(op_mix or (0.80, 0.15, 0.05)))
        return OpTraffic(KEY_BYTES, RESULT_BYTES, 2 * bucket_bytes,
                         4.0 * (i + d))
    raise ValueError(f"unknown cuckoo op {op!r} (want one of {OPS})")


# ---------------------------------------------------------------------------
# Blocked Bloom: one cache-line-style block per key.
# ---------------------------------------------------------------------------

def bloom_op_traffic(config, op: str, *,
                     op_mix: Optional[tuple] = None,
                     batch: Optional[int] = None) -> OpTraffic:
    """Minimal per-key traffic for the blocked-Bloom baseline.

    Every probe touches exactly one block (``words_per_block`` uint32
    words — the GPU-cache-line layout that makes Blocked Bloom the
    bandwidth yardstick); inserts additionally write the ≤ k distinct
    words carrying the set bits. Deletes are unsupported (append-only).
    """
    del batch
    block_bytes = config.words_per_block * 4
    write_words = min(config.k, config.words_per_block)

    if op == "query":
        return OpTraffic(KEY_BYTES, RESULT_BYTES, block_bytes, 0.0)
    if op in ("insert", "bulk_insert"):
        return OpTraffic(KEY_BYTES, RESULT_BYTES, block_bytes,
                         4.0 * write_words)
    if op == "apply_ops":
        q, i, d = _mix(*(op_mix or (0.80, 0.20, 0.0)))
        if d:
            raise ValueError("bloom: append-only — delete fraction must be 0")
        return OpTraffic(KEY_BYTES, RESULT_BYTES, block_bytes,
                         4.0 * write_words * i)
    raise ValueError(f"unknown bloom op {op!r}")


# ---------------------------------------------------------------------------
# BCHT (exact membership): full 64-bit keys + occupancy lanes per slot.
# ---------------------------------------------------------------------------

_BCHT_SLOT_BYTES = 9  # 8B key + 1B used lane (matches BCHTConfig.table_bytes)


def bcht_op_traffic(config, op: str, *,
                    op_mix: Optional[tuple] = None,
                    batch: Optional[int] = None) -> OpTraffic:
    """Minimal per-key traffic for the bucketed cuckoo hash table.

    Exactness costs bandwidth: a probe compares full 64-bit keys across
    both candidate buckets (``2 * bucket_size`` slots at 9 B/slot), and a
    mutation rewrites one whole slot — the bytes-per-op gap to the packed
    fingerprint filter is the point of measuring both.
    """
    del batch
    bucket_bytes = config.bucket_size * _BCHT_SLOT_BYTES

    if op == "query":
        return OpTraffic(KEY_BYTES, RESULT_BYTES, 2 * bucket_bytes, 0.0)
    if op in ("insert", "bulk_insert", "delete"):
        return OpTraffic(KEY_BYTES, RESULT_BYTES, 2 * bucket_bytes,
                         float(_BCHT_SLOT_BYTES))
    if op == "apply_ops":
        q, i, d = _mix(*(op_mix or (0.80, 0.15, 0.05)))
        return OpTraffic(KEY_BYTES, RESULT_BYTES, 2 * bucket_bytes,
                         _BCHT_SLOT_BYTES * (i + d))
    raise ValueError(f"unknown bcht op {op!r}")


# ---------------------------------------------------------------------------
# Dispatch by config type (duck-typed on the distinguishing fields).
# ---------------------------------------------------------------------------

def op_traffic(config, op: str, **kw) -> OpTraffic:
    """Route a backend config to its bytes model by its layout fields."""
    if hasattr(config, "words_per_block"):           # BloomConfig
        return bloom_op_traffic(config, op, **kw)
    if hasattr(config, "fp_bits") and hasattr(config, "layout"):
        return cuckoo_op_traffic(config, op, **kw)   # CuckooConfig
    if hasattr(config, "bucket_size"):               # BCHTConfig
        return bcht_op_traffic(config, op, **kw)
    inner = getattr(config, "inner", None)           # ShardedAMQConfig
    if inner is not None:
        shard = getattr(inner, "shard", None)
        if shard is not None:
            return op_traffic(shard, op, **kw)
    raise TypeError(
        f"no bytes model for config type {type(config).__name__}")


def min_batch_bytes(config, op: str, n: int, *,
                    table_resident: bool = False, **kw) -> float:
    """Minimal bytes an ``n``-key batch of ``op`` must move (see module
    docstring for the residency regimes)."""
    t = op_traffic(config, op, batch=n, **kw)
    return t.batch_bytes(n, table_bytes=int(config.table_bytes),
                         table_resident=table_resident)
