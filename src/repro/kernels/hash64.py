"""Pallas TPU kernel: batched xxHash64 (paper §4.3 step 1).

Pure VPU arithmetic — the emulated-u64 xxHash64 runs entirely in 32-bit
lanes (16-bit-limb multiplies). Exists both as a building block and as the
cleanest micro-benchmark of the hashing cost the paper folds into every op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.hashing import xxhash64_u64


def _hash_kernel(seed: int, keys_lo_ref, keys_hi_ref, out_hi_ref, out_lo_ref):
    hi, lo = xxhash64_u64((keys_hi_ref[...], keys_lo_ref[...]), seed=seed)
    out_hi_ref[...] = hi
    out_lo_ref[...] = lo


def hash64_pallas(keys_lo: jnp.ndarray, keys_hi: jnp.ndarray, *,
                  seed: int = 0, block_keys: int = 2048,
                  interpret: bool = True):
    """xxHash64 of n packed keys -> (hi, lo) uint32[n]."""
    n = keys_lo.shape[0]
    assert n % block_keys == 0
    kernel = functools.partial(_hash_kernel, seed)
    return pl.pallas_call(
        kernel,
        grid=(n // block_keys,),
        in_specs=[
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        interpret=interpret,
        name="xxhash64",
    )(keys_lo, keys_hi)
