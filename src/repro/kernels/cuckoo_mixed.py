"""Pallas TPU kernel: fused mixed-operation Cuckoo-filter pass (DESIGN.md §9).

One kernel executes an interleaved QUERY/INSERT/DELETE stream against a
VMEM-resident table. Like the insert kernels, grid steps (and the in-kernel
key loop) run **sequentially** on a TPU core, so read-modify-write needs no
CAS — and, unlike the batch-synchronous XLA path in
``core.cuckoo_filter.apply_ops``, the kernel's per-key loop realises the
*exact* sequential semantics of the op stream, including cross-key
fingerprint aliasing: operation ``i`` observes every table mutation of
operations ``j < i``, full stop.

Structure per key (bucket-major, one vector row per bucket):

* Phase A (vectorized over the tile): hash every key on the VPU, derive
  tags, both candidate buckets, and the per-bucket match tags.
* Phase B (sequential): dispatch on the op code —

  - QUERY: SWAR match-mask over both buckets' packed words
    (``layout.swar_match_mask``), any lane set → hit; no write.
  - INSERT: first-empty-slot scan (``layout.swar_zero_mask``) from the
    fingerprint-derived circular start, bucket i1 then i2; write the
    claimed word back. Both full → ``ok=0`` (the direct-insert contract:
    the eviction path stays in ``core.cuckoo_filter``).
  - DELETE: first-match scan, i1 then i2; zero the matched lane.

Each key commits at most one word write, applied as a masked store (failed
or read-only ops write the current word back), so the loop body is a single
homogeneous RMW regardless of op mix — no divergent branches, exactly the
property that makes the mixed stream fuse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core import layout as L
from ..core.cuckoo_filter import CuckooConfig
from ..core.hashing import hash_key

_U32 = np.uint32

# Op codes (mirrors repro.amq.protocol; plain ints so the kernel module
# stays importable without the amq package).
_OP_QUERY, _OP_INSERT, _OP_DELETE = 0, 1, 2


def _mixed_kernel(config: CuckooConfig, block_keys: int,
                  table_in_ref, keys_lo_ref, keys_hi_ref, ops_ref, valid_ref,
                  table_out_ref, ok_ref):
    lay = config.layout
    pol = config.placement
    wpb = lay.words_per_bucket
    warange = jnp.arange(wpb, dtype=jnp.int32)

    # Phase A: vectorized hashing + candidate derivation for the whole tile.
    keys = jnp.stack([keys_lo_ref[...], keys_hi_ref[...]], axis=-1)
    hi, lo = hash_key(keys, config.hash_kind, config.seed)
    base_tag = pol.make_tag(hi)
    i1, i2 = pol.initial_buckets(lo, base_tag)
    tag1 = pol.place_tag(base_tag, jnp.zeros((block_keys,), bool))
    tag2 = pol.place_tag(base_tag, jnp.ones((block_keys,), bool))
    t1, t2 = pol.query_match_tags(base_tag)
    start = L.scan_start(base_tag, lay)

    @pl.when(pl.program_id(0) == 0)
    def _():
        table_out_ref[...] = table_in_ref[...]

    def body(i, _):
        opc = ops_ref[i]
        live = valid_ref[i] != 0
        is_q = opc == _OP_QUERY
        is_i = opc == _OP_INSERT
        is_d = opc == _OP_DELETE

        b1 = i1[i].astype(jnp.int32)
        b2 = i2[i].astype(jnp.int32)
        words1 = table_out_ref[pl.ds(b1 * wpb, wpb)]
        words2 = table_out_ref[pl.ds(b2 * wpb, wpb)]

        # SWAR masks per bucket: match lanes (query/delete) and zero lanes
        # (insert) — the §4.3 algebra, carry-free exact per lane.
        match1 = L.swar_mask_to_bools(
            L.swar_match_mask(words1, t1[i], lay.fp_bits),
            lay.fp_bits).reshape(-1)
        match2 = L.swar_mask_to_bools(
            L.swar_match_mask(words2, t2[i], lay.fp_bits),
            lay.fp_bits).reshape(-1)
        free1 = L.swar_mask_to_bools(
            L.swar_zero_mask(words1, lay.fp_bits), lay.fp_bits).reshape(-1)
        free2 = L.swar_mask_to_bools(
            L.swar_zero_mask(words2, lay.fp_bits), lay.fp_bits).reshape(-1)

        # Per-op slot election, bucket i1 preferred (paper Alg. 1-3 order).
        flags1 = jnp.where(is_i, free1, match1)
        flags2 = jnp.where(is_i, free2, match2)
        f1, s1 = L.first_true_circular(flags1, start[i])
        f2, s2 = L.first_true_circular(flags2, start[i])
        hit = f1 | f2

        use1 = f1
        bucket = jnp.where(use1, b1, b2)
        slot = jnp.where(use1, s1, s2)
        store_tag = jnp.where(
            is_i, jnp.where(use1, tag1[i], tag2[i]), _U32(0))  # delete zeros
        widx, sw = L.slot_to_word(slot, lay)
        word = jnp.where(use1, words1, words2)[widx]
        desired = L.replace_tag(word, sw, store_tag, lay.fp_bits)
        addr = bucket * wpb + widx

        del is_q  # query ok is just "any match found" — same election path
        ok = live & hit
        do_write = ok & (is_i | is_d)

        current = table_out_ref[pl.ds(addr, 1)]
        table_out_ref[pl.ds(addr, 1)] = jnp.where(do_write, desired[None],
                                                  current)
        ok_ref[pl.ds(i, 1)] = ok.astype(jnp.uint32)[None]
        return 0

    jax.lax.fori_loop(0, block_keys, body, 0)


def cuckoo_mixed_pallas(config: CuckooConfig, table: jnp.ndarray,
                        keys_lo: jnp.ndarray, keys_hi: jnp.ndarray,
                        ops: jnp.ndarray,
                        valid: jnp.ndarray | None = None,
                        *, block_keys: int = 256,
                        interpret: bool = True):
    """Fused mixed-op pass; returns (table', ok uint32[n]).

    ``ops`` is int32[n] op codes (0 query / 1 insert / 2 delete); ``ok``
    is the per-op outcome (hit / landed / removed). Failed inserts
    (``ok==0`` on an insert slot) need the eviction-capable
    ``core.cuckoo_filter`` path. ``valid`` masks padding keys.
    """
    n = keys_lo.shape[0]
    assert n % block_keys == 0, (n, block_keys)
    if valid is None:
        valid = jnp.ones((n,), jnp.uint32)
    grid = (n // block_keys,)
    kernel = functools.partial(_mixed_kernel, config, block_keys)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec(table.shape, lambda i: (0,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(table.shape, jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
        name="cuckoo_mixed",
    )(table, keys_lo, keys_hi, ops, valid)
