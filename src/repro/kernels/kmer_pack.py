"""Pallas TPU kernel: rolling k-mer packing (paper §5.5 case study).

Packs 2-bit base codes into 2k-bit k-mer values (k <= 31 fits the 62-bit
budget of a u64 pair): output position i holds bases[i : i+k] packed
big-endian-by-base. The genomic pipeline (data/kmer.py) feeds these straight
into the filter as keys, reproducing the paper's KMC3 -> uint64 path.

Tiling: each grid step computes one tile of positions and needs a (k-1)-base
halo; the input stays in ANY/HBM memory and the kernel pl.load's its
(block + halo) window — the standard overlapping-window pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core import bits64 as b64

_U32 = np.uint32


def _kmer_kernel(k: int, block: int, bases_ref, out_hi_ref, out_lo_ref):
    i = pl.program_id(0)
    window = bases_ref[pl.ds(i * block, block + k)]   # tile + halo
    acc = (jnp.zeros((block,), jnp.uint32), jnp.zeros((block,), jnp.uint32))
    for j in range(k):  # statically unrolled rolling pack
        nxt = jax.lax.dynamic_slice(window, (j,), (block,))
        acc = b64.shl(acc, 2)
        acc = (acc[0], acc[1] | (nxt & _U32(3)))
    out_hi_ref[...] = acc[0]
    out_lo_ref[...] = acc[1]


def kmer_pack_pallas(bases: jnp.ndarray, k: int = 31, *,
                     block: int = 1024, interpret: bool = True):
    """bases: uint32[n] 2-bit codes, n a multiple of ``block``.

    Returns (hi, lo) uint32[n]; positions > n-k are computed from zero
    padding and should be sliced off by the caller.
    """
    n = bases.shape[0]
    assert n % block == 0, (n, block)
    padded = jnp.concatenate([bases, jnp.zeros((k,), jnp.uint32)])
    kernel = functools.partial(_kmer_kernel, k, block)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        interpret=interpret,
        name="kmer_pack",
    )(padded)
