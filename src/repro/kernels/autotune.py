"""Cached block-size autotuning for the Pallas filter kernels.

The kernels tile the key stream over a 1-D grid of ``block_keys``-sized
blocks while the table stays pinned; the right tile is a trade between
grid-step overhead (small blocks) and VMEM pressure next to the resident
table (large blocks), and it shifts with backend, op, and table geometry.

Two-level protocol so hot paths never pay for tuning:

* :func:`resolve_block_keys` — O(1) lookup: the tuned value if a sweep has
  recorded one for this (op, backend, geometry) cell, else the static
  per-op default. This is what ops.py calls when ``block_keys=None``.
* :func:`autotune` — the small timed sweep (a few candidates × a few
  iterations on synthetic keys) that populates the cache. Benchmarks run
  it once per configuration; tests and services just inherit the result.

The cache is in-process by default; set ``REPRO_AUTOTUNE_CACHE=<path>`` to
persist sweeps as JSON across runs (the roofline suite points this at its
results directory so repeated invocations skip re-tuning).
"""

from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Per-op fallback when no sweep has run (the pre-autotune hardwired values).
DEFAULT_BLOCK_KEYS: Dict[str, int] = {
    "query": 1024,
    "insert": 256,
    "bulk_insert": 256,
    "apply_ops": 256,
}

# Candidate tiles: powers of two around the defaults. Kept short — the
# sweep is meant to be cheap enough to run inside a benchmark warmup.
CANDIDATES: Tuple[int, ...] = (256, 512, 1024, 2048)

_cache: Dict[str, int] = {}
_loaded_from: Optional[str] = None


def cache_key(config, op: str) -> str:
    """Stable cell id: op × backend × the geometry that moves the optimum."""
    lay = config.layout
    return (f"{op}|{jax.default_backend()}|fp{lay.fp_bits}"
            f"|b{lay.bucket_size}|nb{lay.num_buckets}")


def _cache_path() -> Optional[pathlib.Path]:
    p = os.environ.get("REPRO_AUTOTUNE_CACHE")
    return pathlib.Path(p) if p else None


def _load_persistent() -> None:
    global _loaded_from
    path = _cache_path()
    if path is None or _loaded_from == str(path):
        return
    _loaded_from = str(path)
    if path.exists():
        try:
            stored = json.loads(path.read_text())
        except (OSError, ValueError):
            return
        for k, v in stored.items():
            _cache.setdefault(k, int(v))


def _store_persistent() -> None:
    path = _cache_path()
    if path is None:
        return
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(_cache, indent=2, sort_keys=True))


def resolve_block_keys(config, op: str) -> int:
    """Tuned tile for this cell if known, else the per-op default. O(1)."""
    _load_persistent()
    got = _cache.get(cache_key(config, op))
    if got is not None:
        return got
    return DEFAULT_BLOCK_KEYS[op]


def record(config, op: str, block_keys: int) -> None:
    """Pin a tile for a cell without sweeping (tests / explicit overrides)."""
    _cache[cache_key(config, op)] = int(block_keys)
    _store_persistent()


def clear() -> None:
    """Drop the in-process cache (tests)."""
    global _loaded_from
    _cache.clear()
    _loaded_from = None


def _median_time(fn, iters: int) -> float:
    jax.block_until_ready(fn())          # compile + warm
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def autotune(config, op: str, *, n: int = 4096,
             candidates: Sequence[int] = CANDIDATES,
             iters: int = 3) -> int:
    """Timed sweep over ``candidates`` for one (op, config) cell.

    Builds a synthetic half-loaded filter and times the public ops.py
    wrapper at each tile; the winner is recorded in the cache (and the
    ``REPRO_AUTOTUNE_CACHE`` file when set) and returned. Re-running is a
    cache hit — pass ``force`` by calling :func:`clear` first.
    """
    _load_persistent()
    key = cache_key(config, op)
    if key in _cache:
        return _cache[key]

    from . import ops  # local import: ops.py imports us for resolve()
    from ..core.cuckoo_filter import CuckooState

    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 2**32, size=(n, 2), dtype=np.uint32))
    opcodes = jnp.asarray(rng.integers(0, 3, size=(n,), dtype=np.int32))
    state0 = config.init()
    if op in ("query", "insert", "bulk_insert", "apply_ops"):
        # Sweep against a half-loaded table: query needs matches to occur,
        # and the mutating kernels' free-slot scan lengths (so the tile
        # optimum) depend on occupancy — an empty-table sweep would tune
        # for a regime the serving paths never run in.
        fill = jnp.asarray(
            rng.integers(0, 2**32, size=(n // 2, 2), dtype=np.uint32))
        state0, _ = ops.cuckoo_insert_bulk(
            config, state0, fill,
            block_keys=DEFAULT_BLOCK_KEYS["bulk_insert"])
    table0 = jnp.array(state0.table)     # donation-proof master copy
    count0 = jnp.array(state0.count)

    def run(bk: int):
        # Fresh state per call: the mutating wrappers donate their input.
        st = CuckooState(jnp.array(table0), jnp.array(count0))
        if op == "query":
            return ops.cuckoo_query(config, st, keys, block_keys=bk)
        if op == "insert":
            return ops.cuckoo_insert_direct(config, st, keys, block_keys=bk)
        if op == "bulk_insert":
            return ops.cuckoo_insert_bulk(config, st, keys, block_keys=bk)
        if op == "apply_ops":
            return ops.cuckoo_apply_ops(config, st, keys, opcodes,
                                        block_keys=bk)
        raise ValueError(f"unknown op {op!r}")

    best_bk, best_t = None, None
    for bk in candidates:
        if n % bk:
            continue                     # keep grids exact, skip odd tiles
        t = _median_time(lambda: run(bk), iters)
        if best_t is None or t < best_t:
            best_bk, best_t = bk, t
    if best_bk is None:                  # no candidate divided n
        best_bk = DEFAULT_BLOCK_KEYS[op]
    _cache[key] = int(best_bk)
    _store_persistent()
    return int(best_bk)
