"""Public jit'd wrappers around the Pallas kernels.

Handles padding to block multiples, key packing conventions, and backend
selection: kernels run compiled on TPU and in interpret mode elsewhere
(CPU validation per DESIGN.md; the kernel body is identical).

``block_keys`` defaults to ``None`` on the cuckoo wrappers, meaning "ask
:mod:`.autotune`": the tuned tile for this (op, backend, geometry) cell if
a sweep recorded one, else the static per-op default. Resolution happens
*outside* the jit boundary so a later sweep takes effect on the next call
instead of being baked into a cached trace.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.cuckoo_filter import CuckooConfig, CuckooState, prepare_keys
from ..filters.blocked_bloom import BloomConfig, BloomState
from . import autotune
from .bloom import bloom_insert_pallas, bloom_query_pallas
from .cuckoo_insert import (
    cuckoo_insert_bulk_pallas,
    cuckoo_insert_fused_pallas,
    cuckoo_insert_pallas,
)
from .cuckoo_mixed import cuckoo_mixed_pallas
from .cuckoo_query import cuckoo_query_fused_pallas, cuckoo_query_pallas
from .hash64 import hash64_pallas
from .kmer_pack import kmer_pack_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, multiple: int, fill=0):
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pad = jnp.full((rem,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad]), n


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def _cuckoo_query_jit(config: CuckooConfig, state: CuckooState,
                      keys: jnp.ndarray, block_keys: int,
                      fused: bool) -> jnp.ndarray:
    keys, n = _pad_to(keys, block_keys)
    kern = cuckoo_query_fused_pallas if fused else cuckoo_query_pallas
    out = kern(config, state.table, keys[:, 0], keys[:, 1],
               block_keys=block_keys, interpret=not _on_tpu())
    return out[:n].astype(bool)


def cuckoo_query(config: CuckooConfig, state: CuckooState,
                 keys: jnp.ndarray, block_keys: int = None,
                 fused: bool = True) -> jnp.ndarray:
    """Kernel-backed batch query. keys: uint32[n, 2] -> bool[n].

    ``fused=True`` (default) runs the single-gather SWAR kernel;
    ``fused=False`` keeps the unpack-based variant measurable (the
    roofline suite's pre-fusion comparison row).
    """
    if block_keys is None:
        block_keys = autotune.resolve_block_keys(config, "query")
    return _cuckoo_query_jit(config, state, keys, block_keys, fused)


@functools.partial(jax.jit, static_argnums=(0, 3, 4), donate_argnums=(1,))
def _cuckoo_insert_direct_jit(config: CuckooConfig, state: CuckooState,
                              keys: jnp.ndarray, block_keys: int,
                              fused: bool):
    n0 = keys.shape[0]
    keys, n = _pad_to(keys, block_keys, fill=0)
    valid = (jnp.arange(keys.shape[0]) < n0).astype(jnp.uint32)
    kern = cuckoo_insert_fused_pallas if fused else cuckoo_insert_pallas
    table, ok = kern(config, state.table,
                     keys[:, 0], keys[:, 1], valid,
                     block_keys=block_keys,
                     interpret=not _on_tpu())
    count = state.count + jnp.sum(ok[:n], dtype=jnp.int32)
    return CuckooState(table, count), ok[:n].astype(bool)


def cuckoo_insert_direct(config: CuckooConfig, state: CuckooState,
                         keys: jnp.ndarray, block_keys: int = None,
                         fused: bool = True):
    """Kernel-backed direct insert (no eviction). -> (state', ok bool[n]).

    ``fused=True`` (default) runs the single-row SWAR free-slot kernel;
    ``fused=False`` keeps the unpack-based variant measurable (the
    roofline suite's pre-fusion comparison row). Both are bit-identical.
    Failed keys (ok==False) should be retried through the eviction-capable
    core.cuckoo_filter.insert.
    """
    if block_keys is None:
        block_keys = autotune.resolve_block_keys(config, "insert")
    return _cuckoo_insert_direct_jit(config, state, keys, block_keys, fused)


@functools.partial(jax.jit, static_argnums=(0, 3), donate_argnums=(1,))
def _cuckoo_insert_bulk_jit(config: CuckooConfig, state: CuckooState,
                            keys: jnp.ndarray, block_keys: int):
    n0 = keys.shape[0]
    _, i1, _ = prepare_keys(config, keys)
    order = jnp.argsort(i1.astype(jnp.int32), stable=True)
    keys_sorted, _ = _pad_to(keys[order], block_keys, fill=0)
    valid = (jnp.arange(keys_sorted.shape[0]) < n0).astype(jnp.uint32)
    table, ok_s = cuckoo_insert_bulk_pallas(
        config, state.table, keys_sorted[:, 0], keys_sorted[:, 1], valid,
        block_keys=block_keys, interpret=not _on_tpu())
    ok = jnp.zeros((n0,), jnp.uint32).at[order].set(ok_s[:n0])
    count = state.count + jnp.sum(ok, dtype=jnp.int32)
    return CuckooState(table, count), ok.astype(bool)


def cuckoo_insert_bulk(config: CuckooConfig, state: CuckooState,
                       keys: jnp.ndarray, block_keys: int = None):
    """Kernel-backed bucket-major direct insert. -> (state', ok bool[n]).

    Sorts the batch by primary bucket once (the bulk-build order, DESIGN.md
    §6) so the kernel streams whole bucket segments; ``ok`` comes back in
    the original batch order. Failed keys need the eviction-capable
    core.cuckoo_filter path.
    """
    if block_keys is None:
        block_keys = autotune.resolve_block_keys(config, "bulk_insert")
    return _cuckoo_insert_bulk_jit(config, state, keys, block_keys)


@functools.partial(jax.jit, static_argnums=(0, 4), donate_argnums=(1,))
def _cuckoo_apply_ops_jit(config: CuckooConfig, state: CuckooState,
                          keys: jnp.ndarray, ops: jnp.ndarray,
                          block_keys: int):
    n0 = keys.shape[0]
    keys, n = _pad_to(keys, block_keys, fill=0)
    ops_p, _ = _pad_to(ops.astype(jnp.int32), block_keys, fill=0)
    valid = (jnp.arange(keys.shape[0]) < n0).astype(jnp.uint32)
    table, ok = cuckoo_mixed_pallas(config, state.table,
                                    keys[:, 0], keys[:, 1], ops_p, valid,
                                    block_keys=block_keys,
                                    interpret=not _on_tpu())
    ok = ok[:n0].astype(bool)
    delta = (jnp.sum(ok & (ops == 1), dtype=jnp.int32)
             - jnp.sum(ok & (ops == 2), dtype=jnp.int32))
    return CuckooState(table, state.count + delta), ok


def cuckoo_apply_ops(config: CuckooConfig, state: CuckooState,
                     keys: jnp.ndarray, ops: jnp.ndarray,
                     block_keys: int = None):
    """Kernel-backed fused mixed-op pass. -> (state', ok bool[n]).

    ``ops``: int32[n] op codes (0 query / 1 insert / 2 delete). The kernel
    realises exact sequential in-batch semantics (DESIGN.md §9); inserts
    are direct-only — failed insert slots (ok==False) should be retried
    through the eviction-capable ``core.cuckoo_filter`` path.
    """
    if block_keys is None:
        block_keys = autotune.resolve_block_keys(config, "apply_ops")
    return _cuckoo_apply_ops_jit(config, state, keys, ops, block_keys)


@functools.partial(jax.jit, static_argnums=(0, 3))
def bloom_query(config: BloomConfig, state: BloomState,
                keys: jnp.ndarray, block_keys: int = 1024) -> jnp.ndarray:
    keys, n = _pad_to(keys, block_keys)
    out = bloom_query_pallas(config, state.table, keys[:, 0], keys[:, 1],
                             block_keys=block_keys,
                             interpret=not _on_tpu())
    return out[:n].astype(bool)


@functools.partial(jax.jit, static_argnums=(0, 3), donate_argnums=(1,))
def bloom_insert(config: BloomConfig, state: BloomState,
                 keys: jnp.ndarray, block_keys: int = 256):
    n0 = keys.shape[0]
    keys, n = _pad_to(keys, block_keys)
    valid = (jnp.arange(keys.shape[0]) < n0).astype(jnp.uint32)
    table = bloom_insert_pallas(config, state.table, keys[:, 0], keys[:, 1],
                                valid, block_keys=block_keys,
                                interpret=not _on_tpu())
    return BloomState(table, state.count + n), jnp.ones((n,), bool)


@functools.partial(jax.jit, static_argnums=(1, 2))
def hash64(keys: jnp.ndarray, seed: int = 0, block_keys: int = 2048):
    """xxHash64 of uint32[n, 2] keys -> (hi, lo) uint32[n]."""
    keys, n = _pad_to(keys, block_keys)
    hi, lo = hash64_pallas(keys[:, 0], keys[:, 1], seed=seed,
                           block_keys=block_keys, interpret=not _on_tpu())
    return hi[:n], lo[:n]


@functools.partial(jax.jit, static_argnums=(1, 2))
def kmer_pack(bases: jnp.ndarray, k: int = 31, block: int = 1024):
    """2-bit base codes uint32[n] -> packed k-mer keys uint32[n-k+1, 2]."""
    bases, n = _pad_to(bases.astype(jnp.uint32), block)
    hi, lo = kmer_pack_pallas(bases, k=k, block=block,
                              interpret=not _on_tpu())
    m = n - k + 1
    return jnp.stack([lo[:m], hi[:m]], axis=-1)
