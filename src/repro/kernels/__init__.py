"""Pallas TPU kernels for the filter hot paths.

Layout per kernel: ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec
tiling, ``ops.py`` the jit'd public wrappers, ``ref.py`` the pure-jnp
oracles. All kernels validate in interpret mode on CPU (this container) and
target TPU VMEM-resident tables (the paper's L2-resident regime analogue).
"""

from . import ops, ref  # noqa: F401
from .flash_attention import flash_attention_pallas  # noqa: F401
from .ops import (  # noqa: F401
    bloom_insert,
    bloom_query,
    cuckoo_insert_bulk,
    cuckoo_insert_direct,
    cuckoo_query,
    hash64,
    kmer_pack,
)
